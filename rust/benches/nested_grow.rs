//! Bench E2/E3/E4 (§5.2, Figs 1a/1b): nested MatchGrow across the
//! five-level hierarchy for the Table 1 request sizes — communication,
//! add+update, and null-match timing distributions per level.

use fluxion::experiments::{nested, ExpConfig};

fn main() {
    let cfg = ExpConfig {
        iters: 50,
        ..ExpConfig::default()
    };
    let tests = nested::default_tests();
    let r = nested::run(&cfg, &tests);
    for t in &tests {
        println!("{}", r.figure1_table(t));
    }
    println!("\nE4 (§5.2.3) — null-match time by level (T2)");
    for level in 0..=4usize {
        if let Some(s) = r.match_summary(level, "T2") {
            println!(
                "  L{level}: mean {:.6}s median {:.6}s (graph shrinks with depth)",
                s.mean, s.median
            );
        }
    }
    println!("\nraw series:\n{}", r.recorder.table());
}
