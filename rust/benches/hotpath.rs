//! §Perf harness: micro-benchmarks of the L3 hot paths that make up a
//! MatchGrow — match, JGF encode/decode, JSON dump/parse, AddSubgraph +
//! UpdateMetadata, a full typed-RPC round trip, the `batch/` family
//! (apply_batch queues vs one-call-at-a-time; those rows record **per-op**
//! seconds — each sample is one whole batch divided by its queue length, so
//! `batch/match_T1x32@L0` compares directly against `match/T1@L0`), the
//! `par/` family (the same probe-heavy batch through `SchedService` worker
//! pools of 1/2/4 vs. the sequential baseline, per-op seconds), and the
//! `cached-probe/` pair (epoch-keyed probe cache hit vs. cold). Used by
//! the performance pass (EXPERIMENTS.md §Perf, PERF.md) to measure
//! before/after each optimization. The `shard/` family measures the
//! intra-match sharded traversal (one T7 match split across top-level node
//! subtrees, PERF.md PR 5), `cached-probe/precheck_T1@L0` the
//! count-only MatchAllocate pre-check served from the probe cache, and the
//! `rcu/` family (PR 9) the read path under writer churn — instance
//! read-lock probes vs. pinned RCU-snapshot probes while a writer cycles
//! allocate/free as fast as it can.
//!
//! Flags (after `cargo bench --bench hotpath --`):
//!   --json       write `BENCH_hotpath.json` at the repo root (the perf
//!                trajectory file successive PRs diff; scripts/verify.sh
//!                gates `batch/*` medians against the committed copy)
//!   --smoke      1 warmup / 5 iters per case (CI smoke via scripts/verify.sh)
//!   --threads N  top of the `shard/*` ladder (default 4): rows are
//!                s2, s4, ... up to N (powers of two plus N itself), and
//!                the shard service's pool is sized to N

use fluxion::jobspec::table1_jobspec;
use fluxion::resource::builder::{table2_graph, UidGen};
use fluxion::resource::graph::JobId;
use fluxion::resource::jgf::Jgf;
use fluxion::rpc::transport::Conn;
use fluxion::sched::{MatchScratch, PruneConfig, SchedInstance, SchedOp, SchedReply, SchedService};
use fluxion::util::bench::{run_simple, run_timed, BenchReport};
use fluxion::util::json::Json;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json = args.iter().any(|a| a == "--json");
    // `--threads N`: top of the shard ladder + shard pool size (default 4,
    // the acceptance runner's core floor)
    let threads = args
        .iter()
        .position(|a| a == "--threads")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 2)
        .unwrap_or(4);
    let (warm, iters) = if smoke { (1, 5) } else { (5, 200) };
    let (gwarm, giters) = if smoke { (1, 5) } else { (3, 100) };
    let mut report = BenchReport::new();

    let mut uids = UidGen::new();
    let mut inst = SchedInstance::new(table2_graph(0, &mut uids), PruneConfig::default());
    let t1 = table1_jobspec("T1");
    let t7 = table1_jobspec("T7");

    // 1. match: T1 (64 nodes) and T7 (1 node) on the 8961-unit L0 graph
    let s = run_simple(warm, iters, || inst.match_only(&t1).unwrap().selection.len());
    report.row("match/T1@L0", &s);
    let s = run_simple(warm, iters, || inst.match_only(&t7).unwrap().selection.len());
    report.row("match/T7@L0", &s);

    // null match on a fully-allocated graph
    let mut full = SchedInstance::new(table2_graph(1, &mut UidGen::new()), PruneConfig::default());
    let all = full
        .match_allocate(&fluxion::jobspec::JobSpec::nodes_sockets_cores(8, 2, 16))
        .unwrap();
    let _ = all;
    let s = run_simple(warm, iters, || full.match_only(&t7).is_err());
    report.row("match/null@L1", &s);

    // 1b. ablation: the ALL:core pruning filter on vs off (DESIGN.md calls
    // this design choice out; the paper's §5.2.3 match behavior depends on
    // it). "off" = no tracked types: full traversal on null matches.
    // (measured on the fully-allocated 128-node L0 graph, where the
    // difference is visible: pruning stops at node vertices, no-pruning
    // walks all 4481)
    let mut unpruned = SchedInstance::new(
        table2_graph(0, &mut UidGen::new()),
        fluxion::sched::PruneConfig { tracked: vec![] },
    );
    let mut pruned =
        SchedInstance::new(table2_graph(0, &mut UidGen::new()), PruneConfig::default());
    // allocate every socket+core (nodes stay traversable scope), then ask
    // for one core: pruning rejects each node at its aggregate; without
    // the filter the matcher inspects every core vertex
    let sockets = fluxion::jobspec::JobSpec::nodes_sockets_cores(0, 256, 16);
    unpruned.match_allocate(&sockets).unwrap();
    pruned.match_allocate(&sockets).unwrap();
    let one_core = fluxion::jobspec::JobSpec::new(vec![
        fluxion::jobspec::ResourceReq::new("core", 1),
    ]);
    let s = run_simple(warm, iters, || unpruned.match_only(&one_core).is_err());
    report.row("ablate/null_no_pruning@L0", &s);
    let s = run_simple(warm, iters, || pruned.match_only(&one_core).is_err());
    report.row("ablate/null_with_pruning@L0", &s);

    // 2. JGF encode of a T1-sized grant selection
    let sel = inst.match_only(&t1).unwrap().selection;
    let s = run_simple(warm, iters, || {
        Jgf::from_selection_closed(&inst.graph, &sel).nodes.len()
    });
    report.row("jgf/encode_T1", &s);

    // 3. JSON dump + parse of the T1 grant document
    let jgf = Jgf::from_selection_closed(&inst.graph, &sel);
    let s = run_simple(warm, iters, || jgf.dump().len());
    report.row("json/dump_T1", &s);
    let text = jgf.dump();
    println!("  (T1 JGF wire size: {} bytes)", text.len());
    let s = run_simple(warm, iters, || Json::parse(&text).unwrap());
    report.row("json/parse_T1", &s);
    let s = run_simple(warm, iters, || Jgf::parse(&text).unwrap().nodes.len());
    report.row("jgf/parse_T1", &s);

    // 4. AddSubgraph + UpdateMetadata of the T1 grant into a fresh child
    let s = run_timed(
        gwarm,
        giters,
        || {
            SchedInstance::new(
                fluxion::resource::builder::ClusterSpec::new("cluster", 2, 2, 16)
                    .with_node_base(200)
                    .build(&mut UidGen::starting_at(1 << 40)),
                PruneConfig::default(),
            )
        },
        |mut child| {
            child.accept_grant(&jgf, None).unwrap();
            child.graph.size()
        },
    );
    report.row("grow/add_update_T1", &s);

    // 5. typed-protocol costs, split by layer:
    //    (a) the reply codec itself — encode a `grown` reply carrying the
    //        T1 grant to wire text, and decode it back to the typed enum
    //        (this is what the TCP internode hop pays per message; the
    //        in-proc transport skips it)
    let grown = SchedReply::Grown {
        subgraph: jgf.clone(),
        levels: Vec::new(),
    };
    let s = run_simple(warm, iters, || grown.to_json().dump().len());
    report.row("rpc/reply_encode_T1", &s);
    let grown_text = grown.to_json().dump();
    let s = run_simple(warm, iters, || {
        SchedReply::from_json(&Json::parse(&grown_text).unwrap()).unwrap()
    });
    report.row("rpc/reply_decode_T1", &s);

    //    (b) the in-proc round trip: the InProc transport moves the typed
    //        structs over a channel WITHOUT serializing, so this row is
    //        dispatch + payload clone + channel hop. Renamed from PR 1's
    //        rpc/inproc_T1_grant (whose payload was a raw Json document)
    //        to keep the cross-PR trajectory diff honest.
    let server = fluxion::rpc::transport::InProcServer::spawn(
        fluxion::rpc::transport::handler(move |req: fluxion::rpc::Request| {
            fluxion::rpc::Response::ok(req.id, grown.clone())
        }),
    );
    let mut conn = server.connect();
    let req = fluxion::rpc::Request::new(1, SchedOp::FreeJob { job: JobId(1) });
    let s = run_simple(warm, iters, || conn.call(&req).unwrap());
    report.row("rpc/inproc_T1_grant_typed", &s);
    server.shutdown();

    // 6. batched submission (ROADMAP "batched match"): a queue through one
    //    warm scratch with spec-level dedup, vs. the sequential rows above.
    //    Rows are PER-OP seconds (sample / queue length).
    let mut binst =
        SchedInstance::new(table2_graph(0, &mut UidGen::new()), PruneConfig::default());
    let t1_probe_x32: Vec<SchedOp> = (0..32)
        .map(|_| SchedOp::Probe { spec: t1.clone() })
        .collect();
    let s = run_simple(warm, iters, || {
        let replies = binst.apply_batch(&t1_probe_x32);
        assert!(replies.iter().all(|r| !r.is_error()));
        replies.len()
    });
    let per_op: Vec<f64> = s.iter().map(|x| x / 32.0).collect();
    report.row("batch/match_T1x32@L0", &per_op);

    // dedup ablation: alternating specs defeat the compile amortization,
    // isolating how much of the batch win is dedup vs. warm-scratch reuse
    let mixed_x32: Vec<SchedOp> = (0..32)
        .map(|i| SchedOp::Probe {
            spec: if i % 2 == 0 { t1.clone() } else { t7.clone() },
        })
        .collect();
    let s = run_simple(warm, iters, || {
        let replies = binst.apply_batch(&mixed_x32);
        assert!(replies.iter().all(|r| !r.is_error()));
        replies.len()
    });
    let per_op: Vec<f64> = s.iter().map(|x| x / 32.0).collect();
    report.row("batch/match_mixed32@L0", &per_op);

    // mutating batch: 16 MatchAllocates then 16 FreeJobs on a fresh
    // instance per repetition (setup excluded from timing)
    let mut alloc_free: Vec<SchedOp> = (0..16)
        .map(|_| SchedOp::MatchAllocate { spec: t7.clone() })
        .collect();
    alloc_free.extend((0..16u64).map(|i| SchedOp::FreeJob { job: JobId(i) }));
    let s = run_timed(
        gwarm,
        giters,
        || {
            SchedInstance::new(
                table2_graph(0, &mut UidGen::starting_at(1 << 41)),
                PruneConfig::default(),
            )
        },
        |mut inst| {
            let replies = inst.apply_batch(&alloc_free);
            assert!(replies.iter().all(|r| !r.is_error()));
            replies.len()
        },
    );
    let per_op: Vec<f64> = s.iter().map(|x| x / 32.0).collect();
    report.row("batch/alloc_free_T7x16@L0", &per_op);

    // 7. concurrent serving (`sched::service`): a probe-heavy batch fanned
    //    across the worker pool vs. the sequential batch above, and the
    //    epoch-keyed probe cache. `par/*` rows are PER-OP seconds over 32
    //    DISTINCT heavy probe specs (33..=64 nodes on the L0 graph) —
    //    distinct so neither the batch's spec dedup nor the result cache
    //    shortcuts the traversals; `clear_cache` inside the timed body
    //    (O(32) map clear, noise-level) keeps iterations cold.
    let par_ops: Vec<SchedOp> = (0..32u64)
        .map(|i| SchedOp::Probe {
            spec: fluxion::jobspec::JobSpec::nodes_sockets_cores(33 + i, 2, 16),
        })
        .collect();
    let mut seq_inst =
        SchedInstance::new(table2_graph(0, &mut UidGen::new()), PruneConfig::default());
    let s = run_simple(warm, iters, || {
        let replies = seq_inst.apply_batch(&par_ops);
        assert!(replies.iter().all(|r| !r.is_error()));
        replies.len()
    });
    let per_op: Vec<f64> = s.iter().map(|x| x / 32.0).collect();
    report.row("par/probe_mix32@L0/seq", &per_op);
    for workers in [1usize, 2, 4] {
        let svc = SchedService::with_workers(
            SchedInstance::new(table2_graph(0, &mut UidGen::new()), PruneConfig::default()),
            workers,
        );
        let s = run_simple(warm, iters, || {
            svc.clear_cache();
            let replies = svc.apply_batch(&par_ops);
            assert!(replies.iter().all(|r| !r.is_error()));
            replies.len()
        });
        let per_op: Vec<f64> = s.iter().map(|x| x / 32.0).collect();
        report.row(&format!("par/probe_mix32@L0/w{workers}"), &per_op);
    }

    // cached-probe: one T1 probe through the service — cold (cache cleared
    // every call: clear + full traversal + insert) vs. hit (answered from
    // the epoch-keyed cache without re-traversal). The acceptance bar is
    // hit ≥10x cheaper than cold.
    let svc = SchedService::with_workers(
        SchedInstance::new(table2_graph(0, &mut UidGen::new()), PruneConfig::default()),
        2,
    );
    let s = run_simple(warm, iters, || {
        svc.clear_cache();
        assert!(!svc.probe(&t1).is_error());
    });
    report.row("cached-probe/cold_T1@L0", &s);
    svc.probe(&t1); // warm the entry
    let s = run_simple(warm, iters, || assert!(!svc.probe(&t1).is_error()));
    report.row("cached-probe/hit_T1@L0", &s);

    // 8. intra-match sharded traversal (`shard/` family, PERF.md PR 5):
    //    ONE T7 match whose candidate scan splits across top-level node
    //    subtrees. Measured where sharding has headroom — a fragmented,
    //    pruning-weak graph: every node except the last has one core
    //    allocated, and with no tracked types the per-node aggregate
    //    reject is unavailable, so the sequential scan walks ~35 vertices
    //    into all 128 node subtrees before succeeding at node127 (~4.5k
    //    visits). That is exactly the paper's wide-graph regime (§5.2.3):
    //    when pruning CAN reject at the node vertex, the scan is already
    //    O(high-level resources) and sharding it buys nothing — which is
    //    why the K=1 bail exists. `seq` probes through the sequential
    //    service path on the same graph + cache-clear discipline, so the
    //    sN:seq ratio isolates split/merge overhead vs. scan-width win.
    let mut frag = SchedInstance::new(
        table2_graph(0, &mut UidGen::new()),
        fluxion::sched::PruneConfig { tracked: vec![] },
    );
    let frag_victims: Vec<_> = (0..127)
        .map(|i| {
            frag.graph
                .lookup_path(&format!("/cluster0/node{i}/socket0/core0"))
                .expect("L0 core path")
        })
        .collect();
    let frag_prune = frag.prune.clone();
    frag.allocs
        .allocate(&mut frag.graph, &frag_prune, frag_victims)
        .expect("fragment L0");
    let shard_svc = SchedService::with_workers(frag, threads);
    let s = run_simple(warm, iters, || {
        shard_svc.clear_cache();
        assert!(!shard_svc.probe(&t7).is_error());
    });
    report.row("shard/match_T7@L0/seq", &s);
    let mut ladder: Vec<usize> = Vec::new();
    let mut k = 2usize;
    while k <= threads {
        ladder.push(k);
        k *= 2;
    }
    if ladder.last() != Some(&threads) {
        ladder.push(threads);
    }
    for &k in &ladder {
        let s = run_simple(warm, iters, || {
            shard_svc.clear_cache();
            assert!(!shard_svc.probe_sharded(&t7, k).is_error());
        });
        report.row(&format!("shard/match_T7@L0/s{k}"), &s);
    }

    // 9. count-only pre-check admission (`cached-probe/precheck_T1@L0`):
    //    MatchAllocate of a spec the probe cache knows is infeasible at
    //    the current epoch — rejected without the write lock or a
    //    traversal. Setup saturates L0 so T1 (64 nodes) is infeasible and
    //    the negative probe answer is warm; the rejection never mutates,
    //    so the entry stays valid across iterations. Compare against
    //    cached-probe/hit_T1@L0 (same cache, probe-op path).
    let pre_svc = SchedService::with_workers(
        SchedInstance::new(table2_graph(0, &mut UidGen::new()), PruneConfig::default()),
        2,
    );
    let everything = fluxion::jobspec::JobSpec::nodes_sockets_cores(128, 2, 16);
    let SchedReply::Allocated { .. } = pre_svc.apply(&SchedOp::MatchAllocate { spec: everything })
    else {
        panic!("saturating L0 failed");
    };
    assert!(pre_svc.probe(&t1).is_error()); // warm the negative entry
    let pre_op = SchedOp::MatchAllocate { spec: t1.clone() };
    let s = run_simple(warm, iters, || {
        let r = pre_svc.apply(&pre_op);
        assert!(r.is_error());
    });
    report.row("cached-probe/precheck_T1@L0", &s);

    // 10. sharded write commits (`wrshard/` family, PR 8): `threads`
    //     writer threads each cycling a 1-node MatchAllocate + FreeJob
    //     through ONE service on the 128-node L0 graph. `serial` holds the
    //     instance write lock across each whole op (match included); `sK`
    //     prepares the match under the READ lock and commits through K
    //     subtree shards (OCC), so concurrent writers queue only on the
    //     short validate+commit section. Rows are PER-OP seconds summed
    //     across all writers; the sN:serial ratio is the write-path
    //     scaling headroom (see PERF.md).
    let wr_cycles = if smoke { 4 } else { 16 };
    let wr_ops = threads * wr_cycles * 2;
    let mut wr_modes: Vec<(String, usize)> = vec![("serial".into(), 0)];
    for &k in &ladder {
        wr_modes.push((format!("s{k}"), k));
    }
    for (label, k) in &wr_modes {
        let svc = SchedService::with_workers(
            SchedInstance::new(table2_graph(0, &mut UidGen::new()), PruneConfig::default()),
            threads,
        );
        if *k > 1 {
            svc.set_write_shards(*k);
        }
        let t7 = t7.clone();
        let s = run_simple(gwarm, giters, || {
            let mut handles = Vec::new();
            for _ in 0..threads {
                let svc = svc.clone();
                let spec = t7.clone();
                handles.push(std::thread::spawn(move || {
                    for _ in 0..wr_cycles {
                        let reply = svc.apply(&SchedOp::MatchAllocate { spec: spec.clone() });
                        let SchedReply::Allocated { job, .. } = reply else {
                            panic!("wrshard allocation failed: {reply:?}");
                        };
                        assert!(!svc.apply(&SchedOp::FreeJob { job }).is_error());
                    }
                }));
            }
            for h in handles {
                h.join().expect("wrshard writer panicked");
            }
            wr_ops
        });
        let per_op: Vec<f64> = s.iter().map(|x| x / wr_ops as f64).collect();
        report.row(&format!("wrshard/alloc_free_T7x{threads}w@L0/{label}"), &per_op);
        let snap = svc.telemetry_snapshot();
        println!(
            "  (wrshard {label}: {} shard commits, {} conflicts, {} spine contentions)",
            snap.shard_commits, snap.shard_conflicts, snap.spine_contentions
        );
    }

    // 11. lock-free read path under writer churn (`rcu/` family, PR 9):
    //     one probe thread measured while a background writer cycles a
    //     1-node MatchAllocate + FreeJob as fast as it can (each commit
    //     publishes a fresh snapshot version). `rwlock` takes the instance
    //     read lock per probe — the pre-PR 9 read path, which queues
    //     behind every in-flight write — while `rcu` pins the latest
    //     published snapshot and never touches the lock. Both rows run
    //     the raw traversal (no probe cache; the cache would hide the
    //     lock cost being measured), so the rwlock:rcu ratio is purely
    //     lock acquisition + writer queueing vs. an Arc pin.
    let churn_svc = SchedService::with_workers(
        SchedInstance::new(table2_graph(0, &mut UidGen::new()), PruneConfig::default()),
        2,
    );
    let stop = Arc::new(AtomicBool::new(false));
    let writer = {
        let svc = churn_svc.clone();
        let stop = Arc::clone(&stop);
        let spec = t7.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let reply = svc.apply(&SchedOp::MatchAllocate { spec: spec.clone() });
                if let SchedReply::Allocated { job, .. } = reply {
                    assert!(!svc.apply(&SchedOp::FreeJob { job }).is_error());
                }
            }
        })
    };
    let mut scratch = MatchScratch::new();
    let s = run_simple(warm, iters, || {
        let inst = churn_svc.read();
        assert!(!inst.probe_with(&t1, &mut scratch).is_error());
    });
    report.row("rcu/probe_under_churn@L0/rwlock", &s);
    let s = run_simple(warm, iters, || {
        let snap = churn_svc.pin_snapshot();
        assert!(!snap.probe_with(&t1, &mut scratch).is_error());
    });
    report.row("rcu/probe_under_churn@L0/rcu", &s);
    stop.store(true, Ordering::Relaxed);
    writer.join().expect("churn writer panicked");
    let ss = churn_svc.snapshot_stats();
    println!(
        "  (rcu churn: {} pins, {} publishes, {} retired, {} live)",
        ss.pins, ss.publishes, ss.retired, ss.live
    );

    if json {
        let path = "BENCH_hotpath.json";
        report.write_json(path).expect("write bench report");
        println!("wrote {path} ({} benchmarks)", report.len());
    }
}
