//! §Serving harness: open-loop latency-percentile soak against the live
//! serving stack (`BENCH_serving.json`).
//!
//! Replays deterministic seeded op traces (`workload::optrace`) through
//! `serving::run_scenario` and reports client-side arrival-to-completion
//! latency percentiles per scenario and per op kind. Row families:
//!
//!   serve/{probe_heavy,balanced,churn}@L0/r{rate}
//!       the three standing mixes against a 128-node `SchedService`,
//!       across an offered-rate ladder (open loop: when the target
//!       saturates, queueing delay lands in the percentiles — the
//!       coordinated-omission-safe convention)
//!   serve/churn-wrshard@L0/r{rate}
//!       the churn mix with 4-way sharded OCC write commits armed
//!       (PR 8), paired against serve/churn@L0 at the same rate
//!   serve/churn-rcu@L0/r{rate}
//!       the probe-heavy mix with background churn-writer threads
//!       hammering allocate/free off-schedule (PR 9), paired against
//!       serve/churn-wrshard@L0 at the same rate — the read tail under
//!       continuous snapshot publication, plus the pin/publish/retire
//!       lifecycle totals
//!   serve/depth@L{0..3}
//!       one balanced mix across the Table 2 graph-size sweep
//!   serve/retry_storm@L4
//!       pure-allocate pressure against a single-node instance with
//!       immediate re-issues (3 per failure) — the saturation storm
//!   serve/hier3, serve/hier3_chaos
//!       a 3-level hierarchy (8-node root) replayed single-threaded,
//!       without and with seeded link-fault injection, so the same seed
//!       reports percentiles clean vs. faulty in one run
//!   serve/kill-restart@L2
//!       the serve/hier3 topology with write-ahead journaling armed and
//!       the leaf level killed + rebuilt from its journal every 32 ops
//!       (PR 10) — recovery (replay, grant-ledger reconcile, breaker
//!       reset) runs on the replay clock, so the pair against serve/hier3
//!       prices crash consistency at depth
//!
//! Every scenario also prints issued/error/retry/breaker-trip totals, and
//! per-kind `name/kind` rows ride along in the JSON.
//!
//! Flags (after `cargo bench --bench serving --`):
//!   --json       write `BENCH_serving.json` at the repo root (the serving
//!                latency trajectory file; non-gating — see PERF.md)
//!   --smoke      short traces (~0.25 s per scenario; CI smoke via
//!                `scripts/verify.sh --serving-smoke`)
//!   --rate R     replace the service rate ladder with the single rate R
//!   --clients N  client threads per service scenario (default 4)
//!   --ops N      hard cap on ops per scenario (default 400000)

use std::time::Duration;

use fluxion::fault::FaultRates;
use fluxion::hier::{ChaosConfig, LevelSpec, LinkKind};
use fluxion::serving::{run_scenario, Scenario};
use fluxion::util::bench::BenchReport;
use fluxion::workload::optrace::{OpMix, OpTraceSpec};

fn flag_val<T: std::str::FromStr>(args: &[String], flag: &str) -> Option<T> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse::<T>().ok())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json = args.iter().any(|a| a == "--json");
    let clients: usize = flag_val(&args, "--clients").unwrap_or(4);
    let ops_cap: usize = flag_val(&args, "--ops").unwrap_or(400_000);
    let rate_override: Option<f64> = flag_val(&args, "--rate");

    // open-loop sizing: each (mix, rate) run lasts ~target_s, so the op
    // count scales with the offered rate instead of stretching wall-clock
    let target_s = if smoke { 0.25 } else { 4.0 };
    let rates: Vec<f64> = match rate_override {
        Some(r) => vec![r],
        None if smoke => vec![20_000.0],
        None => vec![2_000.0, 20_000.0, 100_000.0],
    };
    let seed = 0x5E21CE;
    let mut report = BenchReport::new();
    let mut results = Vec::new();

    // 1. mix × rate ladder on the 128-node L0 service
    let mixes = [
        ("probe_heavy", OpMix::probe_heavy()),
        ("balanced", OpMix::balanced()),
        ("churn", OpMix::churn()),
    ];
    for (mix_name, mix) in &mixes {
        for &rate in &rates {
            let ops = ((rate * target_s) as usize).clamp(1_000, ops_cap);
            let trace = OpTraceSpec {
                ops,
                seed,
                rate_ops_per_sec: rate,
                mix: *mix,
                tenants: 8,
                nodes: (1, 4),
            };
            let name = format!("serve/{mix_name}@L0/r{rate:.0}");
            let r = run_scenario(&Scenario::service(&name, trace, clients, 0, clients));
            r.report_rows(&mut report);
            print_totals(&r);
            results.push(r);
        }
    }

    // 1b. multi-writer churn with sharded write commits (PR 8): the same
    //     churn mix, but the service prepares matches under the read lock
    //     and commits through 4 subtree shards (OCC). Pairs against
    //     serve/churn@L0 at the same rate — the delta is what the short
    //     commit section buys the tail when every client thread mutates.
    {
        let wr_rate = rate_override.unwrap_or(20_000.0);
        let ops = ((wr_rate * target_s) as usize).clamp(1_000, ops_cap);
        let trace = OpTraceSpec {
            ops,
            seed,
            rate_ops_per_sec: wr_rate,
            mix: OpMix::churn(),
            tenants: 8,
            nodes: (1, 4),
        };
        let name = format!("serve/churn-wrshard@L0/r{wr_rate:.0}");
        let sc = Scenario::service(&name, trace, clients, 0, clients).with_write_shards(4);
        let r = run_scenario(&sc);
        r.report_rows(&mut report);
        print_totals(&r);
        let snap = &r.services[0];
        println!(
            "  (wrshard: {} shard commits, {} conflicts, {} spine contentions)",
            snap.shard_commits, snap.shard_conflicts, snap.spine_contentions
        );
        results.push(r);
    }

    // 1c. lock-free reads under multi-writer churn (PR 9): a probe-heavy
    //     trace measured while 2 background churn writers cycle
    //     allocate/free off-schedule — every commit publishes a fresh RCU
    //     snapshot version, and the measured probes pin versions instead
    //     of queueing on the instance lock. Pairs against
    //     serve/churn-wrshard@L0 at the same rate: that row's tail is the
    //     write path under contention, this one's is the read path under
    //     the same kind of write pressure.
    {
        let rcu_rate = rate_override.unwrap_or(20_000.0);
        let ops = ((rcu_rate * target_s) as usize).clamp(1_000, ops_cap);
        let trace = OpTraceSpec {
            ops,
            seed,
            rate_ops_per_sec: rcu_rate,
            mix: OpMix::probe_heavy(),
            tenants: 8,
            nodes: (1, 4),
        };
        let name = format!("serve/churn-rcu@L0/r{rcu_rate:.0}");
        let sc = Scenario::service(&name, trace, clients, 0, clients).with_churn_writers(2);
        let r = run_scenario(&sc);
        r.report_rows(&mut report);
        print_totals(&r);
        let snap = &r.services[0];
        println!(
            "  (rcu: {} snapshot pins, {} publishes, {} retired)",
            snap.snapshot_pins, snap.snapshot_publishes, snap.snapshots_retired
        );
        results.push(r);
    }

    // 2. hierarchy-depth sweep: the same balanced mix against each Table 2
    //    graph size (per-op cost grows with graph size; the percentiles
    //    show how far each level can be pushed at a fixed offered rate)
    let depth_rate = if smoke { 10_000.0 } else { 20_000.0 };
    for level in 0..=3usize {
        let ops = ((depth_rate * target_s) as usize).clamp(1_000, ops_cap);
        let trace = OpTraceSpec {
            ops,
            seed,
            rate_ops_per_sec: depth_rate,
            mix: OpMix::balanced(),
            tenants: 8,
            nodes: (1, 2),
        };
        let name = format!("serve/depth@L{level}");
        let r = run_scenario(&Scenario::service(&name, trace, clients, level, clients));
        r.report_rows(&mut report);
        print_totals(&r);
        results.push(r);
    }

    // 3. allocate-retry storm against a saturated single-node instance:
    //    every op asks for 2 nodes of a 1-node graph and re-issues 3 times
    let storm_ops = if smoke { 2_000 } else { 50_000 };
    let storm = Scenario::service(
        "serve/retry_storm@L4",
        OpTraceSpec {
            ops: storm_ops,
            seed,
            rate_ops_per_sec: if smoke { 10_000.0 } else { 20_000.0 },
            mix: OpMix::allocate_only(),
            tenants: 8,
            nodes: (2, 4),
        },
        clients,
        4,
        clients,
    )
    .with_retries(3);
    let r = run_scenario(&storm);
    r.report_rows(&mut report);
    print_totals(&r);
    results.push(r);

    // 4. 3-level hierarchy (Table 2: 8-node root, 4-node L1, 2-node L2),
    //    clean and under seeded link chaos — same trace seed, so the pair
    //    isolates what fault injection does to the tail
    let hier_levels = || {
        vec![
            LevelSpec {
                boot_nodes: 4,
                link: LinkKind::InProc,
            },
            LevelSpec {
                boot_nodes: 2,
                link: LinkKind::InProc,
            },
        ]
    };
    let hier_trace = OpTraceSpec {
        ops: if smoke { 40 } else { 300 },
        seed,
        rate_ops_per_sec: if smoke { 150.0 } else { 100.0 },
        mix: OpMix::balanced(),
        tenants: 4,
        nodes: (1, 2),
    };
    let r = run_scenario(&Scenario::hierarchy(
        "serve/hier3",
        hier_trace.clone(),
        1,
        hier_levels(),
        None,
    ));
    r.report_rows(&mut report);
    print_totals(&r);
    results.push(r);

    let chaos = ChaosConfig::client_only(
        seed ^ 0xC4A05,
        FaultRates {
            drop: 0.02,
            delay: 0.05,
            delay_for: Duration::from_micros(200),
            ..FaultRates::none()
        },
    );
    let r = run_scenario(&Scenario::hierarchy(
        "serve/hier3_chaos",
        hier_trace,
        1,
        hier_levels(),
        Some(chaos),
    ));
    r.report_rows(&mut report);
    print_totals(&r);
    results.push(r);

    // 5. the same 3-level topology with crash/recovery cycles: journaling
    //    armed on every level and the leaf killed + rebuilt from its
    //    journal every 32 ops (PR 10). Each cycle replays the committed
    //    prefix, reconciles grant ledgers with the parent, and resets the
    //    link breaker — all on the replay clock, so recovery cost lands in
    //    the surrounding ops' percentiles. Pairs against serve/hier3.
    let kill_trace = OpTraceSpec {
        ops: if smoke { 48 } else { 300 },
        seed,
        rate_ops_per_sec: if smoke { 150.0 } else { 100.0 },
        mix: OpMix::balanced(),
        tenants: 4,
        nodes: (1, 2),
    };
    let r = run_scenario(
        &Scenario::hierarchy("serve/kill-restart@L2", kill_trace, 1, hier_levels(), None)
            .with_kill_restart(2, 32),
    );
    r.report_rows(&mut report);
    print_totals(&r);
    let leaf = r.services.last().expect("leaf snapshot");
    println!(
        "  (recovery: {} journal appends, {} replayed, {} reconciles, {} orphans released)",
        leaf.journal_appends, leaf.journal_replays, leaf.reconciles, leaf.orphans_released
    );
    results.push(r);

    let total_ops: usize = results.iter().map(|r| r.planned).sum();
    println!(
        "\n{} scenarios, {} ops total, {} report rows",
        results.len(),
        total_ops,
        report.len()
    );

    if json {
        let path = "BENCH_serving.json";
        report.write_json(path).expect("write serving report");
        println!("wrote {path} ({} rows)", report.len());
    }
}

fn print_totals(r: &fluxion::serving::ScenarioResult) {
    println!(
        "  ({}: issued={} errors={} retries={} breaker_trips={} offered={:.0}/s attained={:.0}/s)",
        r.name,
        r.planned,
        r.errors(),
        r.retries(),
        r.breaker_trips(),
        r.offered_ops_per_sec,
        r.attained_ops_per_sec
    );
}
