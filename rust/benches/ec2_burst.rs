//! Bench E5/E6 (§5.3, Fig 2): EC2 instance creation times by type, Fleet
//! dynamic binding, and the static-configuration blowup comparison at the
//! paper's full 300×77×128 scale.

use fluxion::experiments::{ec2, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    // Fig 2: 8 types × {1,2,4,8} × 20 reps = 640 requests
    let r = ec2::run_creation(&cfg, 20);
    println!("{}", r.figure2_table());
    println!("total requests: {}", r.requests_run);

    // Fleet + static comparison at paper scale
    let f = ec2::run_fleet(&cfg, 10, 10, 300, 77, 128);
    println!("{}", f.table());

    // ablation: dynamic graph cost scales with use, not catalog size
    for nodes in [10usize, 100, 1000] {
        println!(
            "dynamic add of {nodes} cloud nodes: {:.6}s",
            ec2::dynamic_equivalent_cost(nodes)
        );
    }
}
