//! Bench E1 (§5.1): single-level MatchAllocate vs MatchGrow.
//! Regenerates the paper's prose numbers: MA match 0.002871s, MG match
//! 0.002883s, MG add/update 0.005592s, comparable max RSS.

use fluxion::experiments::{single_level, ExpConfig};

fn main() {
    let cfg = ExpConfig {
        iters: 100, // the paper's repetition count
        ..ExpConfig::default()
    };
    let r = single_level::run(&cfg);
    println!("{}", r.table());
    println!("{}", r.recorder.table());
}
