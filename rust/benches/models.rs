//! Bench E8/E9/E10 (§6): fit the component models from a fresh nested run
//! (Table 4, Figs 3/4), apply Eq. 6 to the held-out GPU+memory request
//! (Table 5), and validate the §6.3 bound. Uses the XLA linreg artifact
//! when built (three-layer stack on the paper's own analysis).

use fluxion::experiments::{models, nested, ExpConfig};
use fluxion::perfmodel::FitBackend;

fn main() {
    let cfg = ExpConfig {
        iters: 50,
        ..ExpConfig::default()
    };
    let tests = nested::default_tests();
    let data = nested::run(&cfg, &tests);
    let backend = FitBackend::best();
    println!("fit backend: {}\n", backend.name());
    let model = models::fit_models(&data, &backend);
    println!("E8 (Table 4, raw samples)\n{}", model.table4());
    let robust = models::fit_models_median(&data, &backend);
    println!(
        "E8 (Table 4, per-size medians — robust to shared-machine noise)\n{}",
        robust.table4()
    );
    println!("{}", models::figure34_table(&data, &model));
    println!("{}", models::apply_model(&cfg, &model).table());
    let (obs, bound, factor) = models::validate_bound(&data, "T7");
    println!("E10 — observed total match {obs:.6}s <= bound {bound:.6}s (factor {factor:.3})");
    println!("{}", models::bound_ablation());
}
