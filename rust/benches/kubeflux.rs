//! Bench E7 (§5.4): KubeFlux ReplicaSet scheduling — MA for the first pod,
//! MG for the scale-up to 100 pods on the 4343-vertex OpenShift graph.

use fluxion::experiments::{kubeflux, ExpConfig};

fn main() {
    let cfg = ExpConfig {
        iters: 10,
        ..ExpConfig::default()
    };
    let r = kubeflux::run(&cfg, 100);
    println!("{}", r.table());
    println!("{}", r.recorder.table());
}
