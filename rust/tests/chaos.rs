//! Chaos soak: a 3-level hierarchy driven through a mixed op stream under
//! deterministic, seeded fault injection (dropped/delayed/truncated/
//! corrupted frames on every parent link, API failures / capacity refusals /
//! spot reclaims on the external provider), with the allocation-table
//! oracle (`Hierarchy::check_all`) verified after EVERY op — including every
//! quarantine and every recovery.
//!
//! Reproducibility contract: the whole schedule derives from one master
//! seed. Re-run a failure with the same seed via
//! `CHAOS_SEED=0x5EED cargo test --test chaos` (decimal or 0x-hex).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use fluxion::external::ec2::{Ec2Provider, Ec2SimConfig};
use fluxion::external::provider::{ExternalGrant, ExternalProvider, ProviderError};
use fluxion::fault::{
    Backoff, CommitFaultPlan, FaultInjector, FaultRates, FaultyProvider, FrameFault,
    ProviderFault, RetryPolicy,
};
use fluxion::hier::{ChaosConfig, Hierarchy, LevelSpec, LinkKind, LinkPolicy};
use fluxion::jobspec::JobSpec;
use fluxion::resource::builder::{ClusterSpec, UidGen};
use fluxion::rpc::proto::code;
use fluxion::sched::{PruneConfig, SchedInstance, SchedOp, SchedReply, SchedService};
use fluxion::util::rng::Rng;

/// Master seed for the soak. Override with `CHAOS_SEED=<int>` (decimal or
/// `0x`-prefixed hex) to reproduce or explore a different schedule.
fn chaos_seed() -> u64 {
    match std::env::var("CHAOS_SEED") {
        Ok(s) => {
            let s = s.trim().to_string();
            let parsed = match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
                Some(hex) => u64::from_str_radix(hex, 16),
                None => s.parse::<u64>(),
            };
            parsed.unwrap_or_else(|_| panic!("CHAOS_SEED must be an integer, got {s:?}"))
        }
        Err(_) => 0x5EED,
    }
}

/// Every error code a faulted hierarchy is allowed to surface. Anything
/// outside this set (or a panic, or a poisoned lock) fails the soak.
const KNOWN_CODES: &[&str] = &[
    code::NO_MATCH,
    code::GROW_FAILED,
    code::SHRINK_FAILED,
    code::MATCH_GROW_FAILED,
    code::PROVIDER_UNSATISFIABLE,
    code::PROVIDER_API,
    code::TRANSPORT,
    code::TIMEOUT,
    code::DISCONNECTED,
    code::LEVEL_UNAVAILABLE,
    code::PANIC,
    code::BAD_REPLY,
    code::CRASHED,
];

fn assert_known_code(err: &str, what: &str) {
    assert!(
        KNOWN_CODES.iter().any(|c| err.starts_with(c)),
        "{what} surfaced an unstructured error: {err}"
    );
}

/// An [`ExternalProvider`] the test keeps a handle to after the hierarchy
/// boxes it: both sides share the same provider through the mutex, so tests
/// can assert on `live_instances` while the hierarchy owns the box.
struct SharedProvider(Arc<Mutex<FaultyProvider<Ec2Provider>>>);

impl ExternalProvider for SharedProvider {
    fn name(&self) -> &str {
        "shared-faulty-ec2"
    }

    fn request(&mut self, spec: &JobSpec) -> Result<ExternalGrant, ProviderError> {
        self.0.lock().unwrap().request(spec)
    }

    fn release(&mut self, instance_ids: &[String]) -> Result<(), ProviderError> {
        self.0.lock().unwrap().release(instance_ids)
    }
}

/// A 2-deep burst hierarchy whose only free capacity is the cloud: the root
/// grants its single node to the leaf at boot, so every grow escalates to
/// the provider. Returns the hierarchy, the provider fault injector (for
/// scripting), and the shared provider handle (for orphan assertions).
fn burst_hierarchy(
    seed: u64,
) -> (
    Hierarchy,
    FaultInjector,
    Arc<Mutex<FaultyProvider<Ec2Provider>>>,
) {
    let root = ClusterSpec::new("cluster", 1, 2, 16).build(&mut UidGen::new());
    let inj = FaultInjector::new(seed, FaultRates::none());
    let provider = FaultyProvider::new(
        Ec2Provider::new(Ec2SimConfig {
            time_scale: 1e-4,
            ..Ec2SimConfig::default()
        }),
        inj.clone(),
    );
    let shared = Arc::new(Mutex::new(provider));
    let levels = vec![LevelSpec {
        boot_nodes: 1,
        link: LinkKind::InProc,
    }];
    let h = Hierarchy::build_with_external(
        root,
        &levels,
        Some(Box::new(SharedProvider(shared.clone()))),
    )
    .expect("burst hierarchy");
    (h, inj, shared)
}

/// Satellite: `ProviderError::Unsatisfiable` vs `Api` keep their structured
/// codes across a hierarchy level — the leaf can tell "the cloud said no"
/// from "the cloud broke" from a plain local miss, through the RPC hop.
#[test]
fn provider_errors_propagate_through_hierarchy_with_codes() {
    let (h, inj, shared) = burst_hierarchy(0xC0DE);

    inj.push_provider_fault(ProviderFault::Unsatisfiable);
    let e = h
        .grow_from_leaf(&JobSpec::nodes_sockets_cores(1, 2, 16))
        .expect_err("scripted unsatisfiable");
    assert!(
        e.starts_with(code::PROVIDER_UNSATISFIABLE),
        "want provider_unsatisfiable, got: {e}"
    );

    inj.push_provider_fault(ProviderFault::Api);
    let e = h
        .grow_from_leaf(&JobSpec::nodes_sockets_cores(1, 2, 16))
        .expect_err("scripted api failure");
    assert!(e.starts_with(code::PROVIDER_API), "want provider_api, got: {e}");

    // neither failure left provider-side state behind
    assert!(shared.lock().unwrap().inner().live_instances().is_empty());
    h.check_all().expect("consistent after provider failures");

    // unscripted, the same request bursts fine
    let report = h
        .grow_from_leaf(&JobSpec::nodes_sockets_cores(1, 2, 16))
        .expect("clean burst");
    assert!(report.subgraph_size > 0);
    assert!(!shared.lock().unwrap().inner().live_instances().is_empty());
    h.check_all().expect("consistent after burst");
    h.shutdown();
}

/// Satellite: a spot reclaim mid-grant surfaces as `provider_api` at the
/// leaf and leaves zero orphaned `instance_ids` — the instances were
/// created, reclaimed, and released before the error surfaced; and a later
/// `reset` returns every *successful* grant too.
#[test]
fn spot_reclaim_leaves_no_orphaned_instances() {
    let (h, inj, shared) = burst_hierarchy(0x5407);

    inj.push_provider_fault(ProviderFault::Reclaim);
    let e = h
        .grow_from_leaf(&JobSpec::nodes_sockets_cores(1, 2, 16))
        .expect_err("scripted spot reclaim");
    assert!(e.starts_with(code::PROVIDER_API), "want provider_api, got: {e}");
    assert!(e.contains("reclaimed"), "reclaim context preserved: {e}");
    assert_eq!(
        shared.lock().unwrap().inner().live_instances().len(),
        0,
        "orphaned instances"
    );
    assert!(inj.stats().provider_reclaims >= 1);
    h.check_all().expect("consistent after reclaim");

    // a clean burst creates real instances; reset must release them all
    h.grow_from_leaf(&JobSpec::nodes_sockets_cores(1, 2, 16))
        .expect("clean burst");
    assert!(!shared.lock().unwrap().inner().live_instances().is_empty());
    h.reset();
    assert!(
        shared.lock().unwrap().inner().live_instances().is_empty(),
        "reset must release cloud grants back to the provider"
    );
    h.check_all().expect("consistent after reset");
    h.shutdown();
}

/// The tentpole soak: a 3-level hierarchy under seeded client-side frame
/// faults on both links and provider faults at the top, driven through a
/// mixed grow/probe/shrink/reset stream with link maintenance between ops.
/// After every single op the allocation oracle must hold on every level;
/// at the end the links must recover to `closed` and a clean grow must
/// succeed — zero poisoned locks, zero hung calls.
#[test]
fn chaos_soak_three_levels_oracle_verified() {
    let seed = chaos_seed();
    let frame_rates = FaultRates {
        drop: 0.12,
        delay: 0.10,
        delay_for: Duration::from_millis(1),
        truncate: 0.06,
        corrupt: 0.06,
        ..FaultRates::none()
    };
    let policy = LinkPolicy {
        deadline: Some(Duration::from_secs(2)),
        retry: RetryPolicy {
            max_attempts: 3,
            backoff: Backoff {
                base: Duration::from_millis(1),
                factor: 2.0,
                max: Duration::from_millis(8),
                jitter: 0.2,
            },
            retry_mutating: false,
            seed: seed ^ 0xB0FF,
        },
        breaker_threshold: 3,
        breaker_cooldown: Duration::from_millis(20),
        chaos: Some(ChaosConfig::client_only(seed, frame_rates)),
    };

    // provider faults ride a separate injector stream so frame draws never
    // perturb the provider schedule
    let provider_inj = FaultInjector::new(
        seed ^ 0xEC2FA017,
        FaultRates {
            provider_api: 0.25,
            provider_unsat: 0.15,
            provider_reclaim: 0.10,
            ..FaultRates::none()
        },
    );
    let provider = FaultyProvider::new(
        Ec2Provider::new(Ec2SimConfig {
            time_scale: 1e-4,
            ..Ec2SimConfig::default()
        }),
        provider_inj.clone(),
    );

    // root: 3 nodes; L1 boots 2, L2 boots 1 -> one free node at L0, so the
    // stream alternates between on-prem grants and cloud bursts as grows
    // and shrinks cycle capacity
    let root = ClusterSpec::new("cluster", 3, 2, 16).build(&mut UidGen::new());
    let levels = vec![
        LevelSpec {
            boot_nodes: 2,
            link: LinkKind::InProc,
        },
        LevelSpec {
            boot_nodes: 1,
            link: LinkKind::InProc,
        },
    ];
    let h = Hierarchy::build_with_policy(root, &levels, Some(Box::new(provider)), policy)
        .expect("soak hierarchy");
    assert_eq!(h.depth(), 3);
    // PR 8: route every level's write commits through the sharded OCC
    // path, so the whole soak — faulted frames, quarantines, resets —
    // exercises shard-bucketed marks and spine merges under the same
    // after-every-op oracle
    h.set_write_shards_all(4);
    // PR 10: every level write-ahead journals, so seeded kill/restart
    // cycles ride the same stream — recovery + reconciliation must hold
    // up under concurrent frame and provider faults
    h.enable_journals(16);

    let mut rng = Rng::new(seed ^ 0x50AC);
    let mut live_roots: Vec<String> = Vec::new();
    let mut grows_ok = 0u32;
    let mut grow_errs = 0u32;
    let mut shrinks_ok = 0u32;
    let mut kills = 0u32;
    let small = JobSpec::nodes_sockets_cores(1, 2, 16);
    let big = JobSpec::nodes_sockets_cores(2, 2, 16);
    let probe = JobSpec::nodes_sockets_cores(1, 1, 8);

    for i in 0..160 {
        match rng.below(100) {
            0..=44 => match h.grow_from_leaf(&small) {
                Ok(report) => {
                    grows_ok += 1;
                    live_roots.extend(report.roots);
                }
                Err(e) => {
                    grow_errs += 1;
                    assert_known_code(&e, &format!("grow[{i}]"));
                }
            },
            45..=54 => match h.grow_from_leaf(&big) {
                Ok(report) => {
                    grows_ok += 1;
                    live_roots.extend(report.roots);
                }
                Err(e) => {
                    grow_errs += 1;
                    assert_known_code(&e, &format!("big grow[{i}]"));
                }
            },
            55..=74 => match h.probe_up(&probe) {
                Ok((_, _)) => {}
                Err(e) => assert_eq!(
                    e.code,
                    code::LEVEL_UNAVAILABLE,
                    "probe_up may only fail on quarantine: {e}"
                ),
            },
            75..=89 => {
                if let Some(path) = live_roots.pop() {
                    match h.shrink_from_leaf(&path) {
                        Ok(_) => shrinks_ok += 1,
                        // a failed shrink may have partially ascended;
                        // the path is spent either way (per-level graphs
                        // stay individually consistent — verified below)
                        Err(e) => assert_known_code(&e, &format!("shrink[{i}]")),
                    }
                }
            }
            90..=94 => {
                // seeded level kill: discard the level's live state,
                // rebuild from its journal, reconcile with its neighbors.
                // Under active frame faults the reconcile half of the
                // restart may fail (and ledgers stay diverged until a
                // later handshake) — the per-level oracle must hold
                // regardless, and the sweep below must converge at the end.
                let level = 1 + rng.below(2) as usize;
                let report = h.kill_and_restart_level(level).unwrap_or_else(|e| {
                    panic!("kill/restart L{level} at op {i} (seed {seed:#x}): {e}")
                });
                kills += 1;
                for e in &report.reconcile_errors {
                    assert_known_code(e, &format!("restart reconcile[{i}]"));
                }
            }
            _ => {
                h.reset();
                live_roots.clear();
            }
        }
        // the oracle holds after every op, faulted or not
        h.check_all()
            .unwrap_or_else(|e| panic!("oracle violated after op {i} (seed {seed:#x}): {e}"));
        // one maintenance tick: half-open links get their trial probe
        h.maintain();
    }

    assert!(grows_ok > 0, "soak never completed a grow (seed {seed:#x})");
    let frame_stats = [1, 2].map(|l| h.client_injector(l).expect("chaos link").stats());
    let injected: u64 = frame_stats
        .iter()
        .map(|s| s.dropped + s.delayed + s.truncated + s.corrupted)
        .sum();
    assert!(
        injected > 0,
        "soak injected no frame faults (seed {seed:#x}) — chaos not wired"
    );
    eprintln!(
        "soak seed {seed:#x}: {grows_ok} grows ok, {grow_errs} grow errors, \
         {shrinks_ok} shrinks ok, {kills} kills, {injected} frame faults, \
         provider stats {:?}",
        provider_inj.stats()
    );

    // Recovery: force clean frames (scripts win over rates), tick
    // maintenance through the cooldown until every link closes again.
    for level in 1..=2 {
        let inj = h.client_injector(level).expect("chaos link");
        for _ in 0..64 {
            inj.push_frame_fault(FrameFault::Deliver);
        }
    }
    for _ in 0..16 {
        provider_inj.push_provider_fault(ProviderFault::Deliver);
    }
    let mut states = h.maintain();
    for _ in 0..200 {
        if states.iter().all(|(_, s)| *s == "closed") {
            break;
        }
        std::thread::sleep(Duration::from_millis(25));
        states = h.maintain();
    }
    assert!(
        states.iter().all(|(_, s)| *s == "closed"),
        "links failed to recover after the soak: {states:?} (seed {seed:#x})"
    );

    // PR 10: with the links clean, explicit handshakes re-converge
    // whatever the faulted restarts left diverged — the cross-level
    // ledger invariant must hold at quiescence
    for level in 1..=2 {
        let inj = h.client_injector(level).expect("chaos link");
        for _ in 0..64 {
            inj.push_frame_fault(FrameFault::Deliver);
        }
    }
    for _ in 0..8 {
        if h.check_ledgers().is_ok() {
            break;
        }
        for level in 1..h.depth() {
            let _ = h.reconcile_level(level);
        }
        // a handshake that tripped a breaker needs its cooldown to elapse
        std::thread::sleep(Duration::from_millis(25));
    }
    h.check_ledgers()
        .unwrap_or_else(|e| panic!("ledgers failed to converge (seed {seed:#x}): {e}"));
    if kills > 0 {
        let reconciles: u64 = (1..h.depth())
            .map(|l| h.telemetry_snapshot_at(l).reconciles)
            .sum();
        assert!(
            reconciles > 0,
            "kill/restart cycles ran but no reconcile was counted (seed {seed:#x})"
        );
    }

    // and the recovered hierarchy still works end to end
    h.reset();
    let report = h.grow_from_leaf(&small).expect("clean grow after recovery");
    assert!(report.subgraph_size > 0);
    let (_, reply) = h.probe_up(&probe).expect("probe after recovery");
    drop(reply);
    h.check_all().expect("consistent after recovery");
    h.shutdown();
}

/// PR 8 targeted injection: a scripted panic fired MID-COMMIT — after some
/// shard buckets of a multi-subtree allocation have already written, as
/// bucket 2 of 0..=3 starts — must roll back that single commit without
/// poisoning sibling shards or the service. The pre-existing job survives,
/// the six torn marks are restored, the exhausted fault plan lets the
/// identical allocation succeed on retry, and the full oracle (graph
/// invariants, table, shard partition, aggregates) holds at every step.
#[test]
fn commit_fault_mid_shard_rolls_back_without_poisoning_siblings() {
    let svc = SchedService::with_workers(
        SchedInstance::new(
            ClusterSpec::new("c", 8, 2, 4).build(&mut UidGen::new()),
            PruneConfig::default(),
        ),
        2,
    );
    svc.set_write_shards(4); // 8 root children -> 2 nodes per shard

    // a pre-existing job on node0 (shard 0) — the sibling that must survive
    let one_node = JobSpec::nodes_sockets_cores(1, 2, 4);
    let SchedReply::Allocated { job: survivor, .. } = svc.apply(&SchedOp::MatchAllocate {
        spec: one_node.clone(),
    }) else {
        panic!("seed allocation failed");
    };

    // script: the next sharded commit panics when bucket 2 starts writing —
    // buckets 0 and 1 of the victim allocation are already marked by then
    svc.write()
        .set_commit_faults(Some(CommitFaultPlan::script(&[Some(2)])));
    let six_nodes = JobSpec::nodes_sockets_cores(6, 2, 4);
    let reply = svc.apply(&SchedOp::MatchAllocate {
        spec: six_nodes.clone(),
    });
    assert_eq!(
        reply.as_error().expect("injected fault must surface").code,
        code::PANIC,
        "got {reply:?}"
    );
    assert_eq!(svc.telemetry_snapshot().rollbacks, 1);

    // single-commit rollback: the six torn marks are gone (7 nodes free
    // again) but the sibling's node is NOT freed (8 remain infeasible)
    let seven = JobSpec::nodes_sockets_cores(7, 2, 4);
    assert!(
        matches!(svc.probe(&seven), SchedReply::Probed { .. }),
        "rollback did not restore the torn shard marks"
    );
    let eight = JobSpec::nodes_sockets_cores(8, 2, 4);
    assert_eq!(
        svc.probe(&eight).as_error().expect("survivor lost").code,
        code::NO_MATCH,
        "rollback clobbered the sibling shard's pre-existing allocation"
    );
    svc.read().check().expect("oracle after contained fault");

    // the plan is spent: the identical allocation now commits cleanly
    let SchedReply::Allocated { job: retried, .. } =
        svc.apply(&SchedOp::MatchAllocate { spec: six_nodes })
    else {
        panic!("retry after contained fault failed");
    };
    for job in [survivor, retried] {
        let freed = svc.apply(&SchedOp::FreeJob { job });
        assert!(matches!(freed, SchedReply::Freed { .. }), "{freed:?}");
    }
    assert!(
        matches!(svc.probe(&eight), SchedReply::Probed { .. }),
        "capacity lost after rollback + retry + free"
    );
    svc.read().check().expect("oracle at quiescence");
}
