//! Concurrency coverage for the `SchedService` serving layer (loom-free:
//! plain `std::thread` stress plus deterministic epoch/cache checks).
//!
//! The contract under test:
//! 1. N threads probing while one thread allocates/frees — every probe
//!    result must be consistent with SOME epoch of the graph (i.e. it is
//!    one of the answers a quiescent graph in one of its visited states
//!    would give; the probe cache must never serve an answer from a
//!    different epoch's state).
//! 2. `apply_batch`'s read/write partitioning preserves the sequential
//!    reply order index-for-index.
//! 3. Error-path invalidation: a mutating op that FAILS after touching the
//!    graph (failed grow) must still advance the epoch, so no stale probe
//!    entry survives it.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use fluxion::jobspec::JobSpec;
use fluxion::resource::builder::{table2_graph, UidGen};
use fluxion::resource::graph::JobId;
use fluxion::resource::jgf::Jgf;
use fluxion::rpc::proto::code;
use fluxion::sched::{PruneConfig, SchedInstance, SchedOp, SchedReply, SchedService};

fn service(level: usize, workers: usize) -> SchedService {
    SchedService::with_workers(
        SchedInstance::new(table2_graph(level, &mut UidGen::new()), PruneConfig::default()),
        workers,
    )
}

/// N probers race one writer that flips the graph between two known
/// states: both nodes free and both nodes allocated. Every probe answer
/// must match one of those two states exactly — anything else means a
/// probe observed a torn graph or the cache served a stale epoch.
#[test]
fn probes_race_writer_and_stay_epoch_consistent() {
    let svc = service(3, 4); // L3: 2 nodes
    let one_node = JobSpec::nodes_sockets_cores(1, 2, 16);
    let both_nodes = JobSpec::nodes_sockets_cores(2, 2, 16);

    // the two legitimate answers for `one_node`, captured quiescently:
    // free graph -> Probed{..}; fully-allocated graph -> no_match error
    let free_answer = svc.probe(&one_node);
    assert!(matches!(free_answer, SchedReply::Probed { .. }));
    let job = match svc.apply(&SchedOp::MatchAllocate {
        spec: both_nodes.clone(),
    }) {
        SchedReply::Allocated { job, .. } => job,
        other => panic!("setup allocation failed: {other:?}"),
    };
    let full_answer = svc.probe(&one_node);
    assert_eq!(full_answer.as_error().unwrap().code, code::NO_MATCH);
    svc.apply(&SchedOp::FreeJob { job });

    let stop = Arc::new(AtomicBool::new(false));
    let mut probers = Vec::new();
    for _ in 0..4 {
        let svc = svc.clone();
        let spec = one_node.clone();
        let free_answer = free_answer.clone();
        let full_answer = full_answer.clone();
        let stop = stop.clone();
        probers.push(std::thread::spawn(move || {
            let mut seen: HashSet<&'static str> = HashSet::new();
            // probe-then-check-stop: even a prober scheduled only after
            // the writer finished still validates one answer, so the
            // `distinct >= 1` assertion below cannot fail spuriously
            loop {
                let r = svc.probe(&spec);
                if r == free_answer {
                    seen.insert("free");
                } else if r == full_answer {
                    seen.insert("full");
                } else {
                    panic!("probe answer consistent with NO epoch: {r:?}");
                }
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            seen.len()
        }));
    }
    // writer: allocate both nodes, free them, repeat
    for _ in 0..200 {
        let reply = svc.apply(&SchedOp::MatchAllocate {
            spec: both_nodes.clone(),
        });
        let SchedReply::Allocated { job, .. } = reply else {
            panic!("writer allocation failed: {reply:?}");
        };
        let freed = svc.apply(&SchedOp::FreeJob { job });
        assert!(matches!(freed, SchedReply::Freed { .. }), "{freed:?}");
    }
    stop.store(true, Ordering::Relaxed);
    for p in probers {
        let distinct = p.join().expect("prober panicked");
        assert!(distinct >= 1, "prober observed no valid state");
    }
    // quiescent again (writer ended freed): the truth must be `free`
    assert_eq!(svc.probe(&one_node), free_answer);
    svc.read().check().unwrap();
    let stats = svc.cache_stats();
    assert!(stats.hits + stats.misses > 0, "cache was never consulted");
}

/// Read/write partitioning answers a mixed batch with exactly the replies
/// sequential application produces, index-for-index.
#[test]
fn partitioned_batch_preserves_sequential_reply_order() {
    let svc = service(1, 4);
    let mut twin =
        SchedInstance::new(table2_graph(1, &mut UidGen::new()), PruneConfig::default());
    let t7 = JobSpec::nodes_sockets_cores(1, 2, 16);
    let mut ops: Vec<SchedOp> = Vec::new();
    // read run (distinct specs -> true fan-out), write run, read run, ...
    for nodes in 1..=5u64 {
        ops.push(SchedOp::Probe {
            spec: JobSpec::nodes_sockets_cores(nodes, 2, 16),
        });
    }
    ops.push(SchedOp::MatchAllocate { spec: t7.clone() });
    ops.push(SchedOp::MatchAllocate { spec: t7.clone() });
    ops.push(SchedOp::Probe { spec: t7.clone() });
    ops.push(SchedOp::FreeJob { job: JobId(0) });
    ops.push(SchedOp::Probe { spec: t7.clone() });
    ops.push(SchedOp::FreeJob { job: JobId(99) }); // fails in place
    ops.push(SchedOp::Probe { spec: t7 });

    let par = svc.apply_batch(&ops);
    let seq = twin.apply_batch(&ops);
    assert_eq!(par.len(), seq.len());
    for (i, (p, s)) in par.iter().zip(&seq).enumerate() {
        match (p, s) {
            (
                SchedReply::Allocated {
                    job: j1,
                    subgraph: g1,
                    ..
                },
                SchedReply::Allocated {
                    job: j2,
                    subgraph: g2,
                    ..
                },
            ) => {
                assert_eq!(j1, j2, "op {i}");
                assert_eq!(g1, g2, "op {i}");
            }
            _ => assert_eq!(p, s, "op {i}"),
        }
    }
    svc.read().check().unwrap();
    twin.check().unwrap();
}

/// Regression (error-path invalidation): `AcceptGrant` that splices the
/// subgraph and THEN fails (unknown job) has mutated the graph — the epoch
/// must advance so the pre-grow probe entry cannot be served. Before the
/// epoch model, a result cache keyed on anything weaker (e.g. "last op
/// succeeded") would keep answering from the pre-grow graph.
#[test]
fn failed_grow_invalidates_stale_probe_entries() {
    let svc = service(4, 2); // 1 node
    let two_nodes = JobSpec::nodes_sockets_cores(2, 2, 16);
    // cache a negative answer: only one node exists
    let before = svc.probe(&two_nodes);
    assert_eq!(before.as_error().unwrap().code, code::NO_MATCH);
    // repeat is served consistently (same epoch)
    assert_eq!(svc.probe(&two_nodes), before);
    let epoch_before = svc.epoch();

    // mint a grant of node0+node1 from a 2-node donor; node0 is the
    // identity, node1 splices in — then charging JobId(999) fails
    let mut donor =
        SchedInstance::new(table2_graph(3, &mut UidGen::new()), PruneConfig::default());
    let grant = donor
        .match_only(&two_nodes)
        .map(|m| Jgf::from_selection(&donor.graph, &m.selection))
        .unwrap();
    let reply = svc.apply(&SchedOp::AcceptGrant {
        subgraph: grant,
        job: Some(JobId(999)),
    });
    assert_eq!(reply.as_error().unwrap().code, code::GROW_FAILED);

    // the failed op mutated the graph, so the epoch moved...
    assert!(svc.epoch() > epoch_before, "failed grow must bump the epoch");
    // ...and the same probe now sees the spliced (free) node1: feasible.
    // A stale cache hit would have repeated `before`.
    let after = svc.probe(&two_nodes);
    assert!(
        matches!(after, SchedReply::Probed { .. }),
        "stale probe entry served after failed grow: {after:?}"
    );
    svc.read().check().unwrap();
}

/// Mutating ops that fail WITHOUT touching the graph may keep the epoch —
/// and then the cached entries they did not invalidate are still accurate.
#[test]
fn clean_failures_keep_accurate_cache_entries() {
    let svc = service(4, 2);
    let spec = JobSpec::nodes_sockets_cores(1, 2, 16);
    let first = svc.probe(&spec);
    assert!(matches!(first, SchedReply::Probed { .. }));
    // freeing an unknown job fails before any graph write
    let r = svc.apply(&SchedOp::FreeJob { job: JobId(42) });
    assert_eq!(r.as_error().unwrap().code, code::SHRINK_FAILED);
    // the entry (if retained) answers identically; either way the reply
    // must equal the quiescent truth
    assert_eq!(svc.probe(&spec), first);
    svc.read().check().unwrap();
}

/// Sharded matches racing concurrent sequential probes and a writer that
/// flips the graph between two known states. Every answer — from either
/// probe path, cached or computed — must be consistent with one of those
/// states. Replies are classified on (feasibility, vertex count) because
/// that is the sharded path's contract: selection/count bit-identical,
/// `visited` an upper bound (and the shared cache may legitimately hand a
/// sharded-computed reply to a sequential prober, or vice versa).
#[test]
fn sharded_matches_race_concurrent_probes() {
    let svc = service(1, 4); // L1: 8 nodes
    let one_node = JobSpec::nodes_sockets_cores(1, 2, 16);
    let all_nodes = JobSpec::nodes_sockets_cores(8, 2, 16);

    // quiescent truths for `one_node`: feasible with 35 vertices, or NO_MATCH
    let classify = |r: &SchedReply| -> &'static str {
        match r {
            SchedReply::Probed { vertices: 35, .. } => "free",
            SchedReply::Probed { vertices, .. } => panic!("impossible vertex count {vertices}"),
            other => {
                assert_eq!(
                    other.as_error().expect("probe error").code,
                    code::NO_MATCH,
                    "unexpected reply {other:?}"
                );
                "full"
            }
        }
    };
    assert_eq!(classify(&svc.probe(&one_node)), "free");
    assert_eq!(classify(&svc.probe_sharded(&one_node, 4)), "free");

    let stop = Arc::new(AtomicBool::new(false));
    let mut threads = Vec::new();
    for sharded in [true, true, false, false] {
        let svc = svc.clone();
        let spec = one_node.clone();
        let stop = stop.clone();
        threads.push(std::thread::spawn(move || loop {
            let r = if sharded {
                svc.probe_sharded(&spec, 4)
            } else {
                svc.probe(&spec)
            };
            // classification panics inside the thread on any answer that
            // matches neither quiescent state
            match r {
                SchedReply::Probed { vertices, .. } => assert_eq!(vertices, 35),
                other => assert_eq!(other.as_error().expect("probe error").code, code::NO_MATCH),
            }
            if stop.load(Ordering::Relaxed) {
                break;
            }
        }));
    }
    for _ in 0..100 {
        let SchedReply::Allocated { job, .. } = svc.apply(&SchedOp::MatchAllocate {
            spec: all_nodes.clone(),
        }) else {
            panic!("writer allocation failed");
        };
        let freed = svc.apply(&SchedOp::FreeJob { job });
        assert!(matches!(freed, SchedReply::Freed { .. }), "{freed:?}");
    }
    stop.store(true, Ordering::Relaxed);
    for t in threads {
        t.join().expect("prober panicked");
    }
    // quiescent again (writer ended freed): both paths agree on the truth
    svc.clear_cache();
    assert_eq!(classify(&svc.probe_sharded(&one_node, 4)), "free");
    assert_eq!(classify(&svc.probe(&one_node)), "free");
    svc.read().check().unwrap();
}

/// Telemetry counters under probe-vs-writer contention: every recorded
/// total must equal the number of ops actually issued — lock-free Relaxed
/// counters may not lose or double-count an op no matter the interleaving.
#[test]
fn telemetry_counters_stay_exact_under_contention() {
    let svc = service(3, 4); // L3: 2 nodes
    let one_node = JobSpec::nodes_sockets_cores(1, 2, 16);
    let both_nodes = JobSpec::nodes_sockets_cores(2, 2, 16);
    const PROBERS: u64 = 4;
    const PROBES_EACH: u64 = 500;
    const WRITE_CYCLES: u64 = 100;

    let mut threads = Vec::new();
    for _ in 0..PROBERS {
        let svc = svc.clone();
        let spec = one_node.clone();
        threads.push(std::thread::spawn(move || {
            for _ in 0..PROBES_EACH {
                // feasible or NO_MATCH depending on the writer's phase —
                // either way it must be recorded exactly once
                let _ = svc.probe(&spec);
            }
        }));
    }
    // sole mutator: allocations cannot fail, so error totals stay exact too
    for _ in 0..WRITE_CYCLES {
        let SchedReply::Allocated { job, .. } = svc.apply(&SchedOp::MatchAllocate {
            spec: both_nodes.clone(),
        }) else {
            panic!("writer allocation failed");
        };
        let freed = svc.apply(&SchedOp::FreeJob { job });
        assert!(matches!(freed, SchedReply::Freed { .. }), "{freed:?}");
    }
    for t in threads {
        t.join().expect("prober panicked");
    }

    let snap = svc.telemetry_snapshot();
    assert_eq!(snap.kind("probe").unwrap().ops, PROBERS * PROBES_EACH);
    assert_eq!(snap.kind("match_allocate").unwrap().ops, WRITE_CYCLES);
    assert_eq!(snap.kind("match_allocate").unwrap().errors, 0);
    assert_eq!(snap.kind("free_job").unwrap().ops, WRITE_CYCLES);
    assert_eq!(snap.kind("free_job").unwrap().errors, 0);
    assert_eq!(
        snap.ops_total(),
        PROBERS * PROBES_EACH + 2 * WRITE_CYCLES,
        "telemetry lost or double-counted ops under contention"
    );
    // histogram mass equals the op count: no sample was dropped either
    assert_eq!(
        snap.kind("probe").unwrap().hist.count,
        PROBERS * PROBES_EACH
    );
    // cache counters come stamped from the authoritative probe cache
    let stats = svc.cache_stats();
    assert_eq!(snap.cache_hits, stats.hits);
    assert_eq!(snap.cache_misses, stats.misses);
    svc.read().check().unwrap();
}

/// PR 8: the exactness contract extended to the sharded WRITE path.
/// N writer threads cycle 1-node allocate/free through the OCC commit
/// protocol while probe readers race them. Every op must be recorded
/// exactly once; every successful match-family commit must be accounted
/// as either an OCC shard commit or a conflict-downgraded serial commit
/// (`shard_commits + shard_conflicts` — nothing vanishes, nothing
/// double-counts); and the final state must show no lost update and no
/// torn aggregate.
#[test]
fn multi_writer_sharded_commits_stay_exact_under_contention() {
    let svc = service(1, 4); // L1: 8 nodes
    svc.set_write_shards(4);
    let one_node = JobSpec::nodes_sockets_cores(1, 2, 16);
    const WRITERS: u64 = 4;
    const CYCLES: u64 = 150;
    const PROBERS: u64 = 2;
    const PROBES_EACH: u64 = 300;

    let mut threads = Vec::new();
    for _ in 0..PROBERS {
        let svc = svc.clone();
        let spec = one_node.clone();
        threads.push(std::thread::spawn(move || {
            for _ in 0..PROBES_EACH {
                // 8 nodes, 4 writers holding at most 1 each: every
                // consistent epoch has >= 4 free nodes, so a 1-node probe
                // is feasible in ALL of them — NO_MATCH means a torn read
                let r = svc.probe(&spec);
                assert!(
                    matches!(r, SchedReply::Probed { .. }),
                    "probe observed an impossible state: {r:?}"
                );
            }
        }));
    }
    for _ in 0..WRITERS {
        let svc = svc.clone();
        let spec = one_node.clone();
        threads.push(std::thread::spawn(move || {
            for _ in 0..CYCLES {
                let reply = svc.apply(&SchedOp::MatchAllocate { spec: spec.clone() });
                let SchedReply::Allocated { job, .. } = reply else {
                    panic!("allocation must not fail (>= 4 nodes free): {reply:?}");
                };
                let freed = svc.apply(&SchedOp::FreeJob { job });
                assert!(matches!(freed, SchedReply::Freed { .. }), "{freed:?}");
            }
        }));
    }
    for t in threads {
        t.join().expect("thread panicked");
    }

    // one quiescent cycle: with no rival writer the epoch cannot move
    // between prepare and commit, so this commit provably takes the OCC
    // fast path — shard_commits is nonzero deterministically
    let reply = svc.apply(&SchedOp::MatchAllocate {
        spec: one_node.clone(),
    });
    let SchedReply::Allocated { job, .. } = reply else {
        panic!("quiescent allocation failed: {reply:?}");
    };
    let freed = svc.apply(&SchedOp::FreeJob { job });
    assert!(matches!(freed, SchedReply::Freed { .. }), "{freed:?}");

    let snap = svc.telemetry_snapshot();
    assert_eq!(snap.kind("probe").unwrap().ops, PROBERS * PROBES_EACH);
    let allocs = WRITERS * CYCLES + 1;
    assert_eq!(snap.kind("match_allocate").unwrap().ops, allocs);
    assert_eq!(snap.kind("match_allocate").unwrap().errors, 0);
    assert_eq!(snap.kind("free_job").unwrap().ops, allocs);
    assert_eq!(snap.kind("free_job").unwrap().errors, 0);
    assert_eq!(
        snap.shard_commits + snap.shard_conflicts,
        allocs,
        "a successful match commit was lost or double-counted \
         (commits {} conflicts {} contentions {})",
        snap.shard_commits,
        snap.shard_conflicts,
        snap.spine_contentions
    );
    assert!(
        snap.shard_commits >= 1,
        "the quiescent commit must take the OCC path"
    );
    // no lost update: every job was freed, so the whole level is free again
    let all_nodes = JobSpec::nodes_sockets_cores(8, 2, 16);
    let r = svc.probe(&all_nodes);
    assert!(
        matches!(r, SchedReply::Probed { .. }),
        "lost update: freed capacity missing at quiescence: {r:?}"
    );
    // no torn aggregate / shard map: full oracle over graph + table +
    // shard partition + recomputed pruning aggregates
    svc.read().check().unwrap();
}

/// Many threads hammering the single-probe cached path on a static graph:
/// all answers identical, and after the first traversal the cache absorbs
/// (nearly) everything.
#[test]
fn concurrent_identical_probes_share_one_answer() {
    let svc = service(0, 4);
    let spec = JobSpec::nodes_sockets_cores(64, 2, 16);
    let expected = svc.probe(&spec);
    assert!(matches!(expected, SchedReply::Probed { .. }));
    let mut threads = Vec::new();
    for _ in 0..8 {
        let svc = svc.clone();
        let spec = spec.clone();
        let expected = expected.clone();
        threads.push(std::thread::spawn(move || {
            for _ in 0..100 {
                assert_eq!(svc.probe(&spec), expected);
            }
        }));
    }
    for t in threads {
        t.join().expect("prober panicked");
    }
    let stats = svc.cache_stats();
    assert!(stats.hits >= 800, "cache barely used: {stats:?}");
}
