//! Concurrent-oracle equivalence layer for the subtree-sharded write
//! commits (PR 8).
//!
//! The commit protocol's contract: with a fixed op stream, an instance
//! committing through the sharded path (per-subtree allocation maps +
//! per-shard spine-delta buffers merged at the root) ends **bit-identical**
//! to serial application — same replies, same allocation table, same
//! pruning aggregates, same epoch after every op. These tests prove it
//! with seeded randomized streams (allocate / free / grow / shrink over
//! disjoint and overlapping subtrees) replayed at shard widths
//! K ∈ {1, 2, 4, 8} against the K = 1 serial run, with the instance's
//! full oracle (`check`: graph invariants, table consistency, shard-map
//! partition, aggregate recomputation) and a brute-force feasibility
//! oracle consulted after every commit.

use std::collections::HashSet;

use fluxion::jobspec::{JobSpec, ResourceReq};
use fluxion::resource::builder::{ClusterSpec, UidGen};
use fluxion::resource::graph::{JobId, ResourceGraph, VertexId};
use fluxion::sched::{PruneConfig, SchedInstance, SchedOp, SchedReply, SchedService};
use fluxion::util::rng::Rng;

const NODES: usize = 6;
const SOCKETS: usize = 2;
const CORES: usize = 4;

fn instance(write_shards: usize) -> SchedInstance {
    let mut inst = SchedInstance::new(
        ClusterSpec::new("c", NODES, SOCKETS, CORES).build(&mut UidGen::new()),
        PruneConfig::default(),
    );
    if write_shards > 1 {
        inst.set_write_shards(write_shards);
    }
    inst
}

/// Random chain spec. Half the draws fit inside one node subtree
/// (disjoint-subtree commits); the rest span several subtrees, so their
/// mark/bubble traffic overlaps shard boundaries and the spine.
fn rand_spec(rng: &mut Rng) -> JobSpec {
    let n = 1 + rng.below(NODES as u64 / 2);
    JobSpec::nodes_sockets_cores(n, 1 + rng.below(SOCKETS as u64), 1 + rng.below(CORES as u64))
}

/// Build one deterministic op stream by replaying the draws against a
/// scratch serial instance (job-targeting ops need concrete ids). The
/// returned `Vec<SchedOp>` is what every shard width replays verbatim.
fn build_stream(seed: u64, len: usize) -> Vec<SchedOp> {
    let mut inst = instance(1);
    let mut rng = Rng::new(seed);
    let mut live: Vec<JobId> = Vec::new();
    let mut ops = Vec::with_capacity(len);
    for _ in 0..len {
        let op = match rng.below(10) {
            0..=3 => SchedOp::MatchAllocate {
                spec: rand_spec(&mut rng),
            },
            4..=5 if !live.is_empty() => SchedOp::MatchGrowLocal {
                job: live[rng.below(live.len() as u64) as usize],
                spec: rand_spec(&mut rng),
            },
            6..=7 if !live.is_empty() => SchedOp::FreeJob {
                job: live.swap_remove(rng.below(live.len() as u64) as usize),
            },
            8 => SchedOp::ShrinkSubtree {
                path: format!("/c0/node{}", rng.below(NODES as u64)),
            },
            _ => SchedOp::MatchAllocate {
                spec: rand_spec(&mut rng),
            },
        };
        if let SchedReply::Allocated { job, .. } = inst.apply(&op) {
            if matches!(op, SchedOp::MatchAllocate { .. }) {
                live.push(job);
            }
        }
        ops.push(op);
    }
    ops
}

/// Replies must agree structurally: allocation payloads exactly (job id +
/// granted subgraph), errors by code (messages may embed path-dependent
/// diagnostics), everything else bit-for-bit. Timing floats are excluded
/// by construction (the Allocated arm compares only job + subgraph).
fn assert_reply_equal(a: &SchedReply, b: &SchedReply, ctx: &str) {
    match (a, b) {
        (
            SchedReply::Allocated {
                job: j1,
                subgraph: g1,
                ..
            },
            SchedReply::Allocated {
                job: j2,
                subgraph: g2,
                ..
            },
        ) => {
            assert_eq!(j1, j2, "{ctx}: job id");
            assert_eq!(g1, g2, "{ctx}: granted subgraph");
        }
        _ => match (a.as_error(), b.as_error()) {
            (Some(e1), Some(e2)) => assert_eq!(e1.code, e2.code, "{ctx}: error code"),
            _ => assert_eq!(a, b, "{ctx}: reply"),
        },
    }
}

/// Full-state equality: epoch, live vertex set, per-vertex allocation
/// info, and the running half of the allocation table (vertex lists in
/// commit order — the sharded path preserves selection order).
fn assert_state_equal(a: &SchedInstance, b: &SchedInstance, ctx: &str) {
    assert_eq!(a.graph.epoch(), b.graph.epoch(), "{ctx}: epoch");
    let live_a: Vec<VertexId> = a.graph.iter_live().collect();
    let live_b: Vec<VertexId> = b.graph.iter_live().collect();
    assert_eq!(live_a, live_b, "{ctx}: live vertex set");
    for &v in &live_a {
        assert_eq!(
            a.graph.vertex(v).alloc,
            b.graph.vertex(v).alloc,
            "{ctx}: alloc info at {v:?}"
        );
    }
    let running = |inst: &SchedInstance| -> Vec<(u64, Vec<u32>)> {
        let mut js: Vec<(u64, Vec<u32>)> = inst
            .allocs
            .running_jobs()
            .map(|al| (al.job.0, al.vertices.iter().map(|v| v.0).collect()))
            .collect();
        js.sort();
        js
    };
    assert_eq!(running(a), running(b), "{ctx}: running allocation table");
}

// ---- brute-force feasibility oracle (chain specs; see matcher_oracle.rs) --

fn oracle_candidates(g: &ResourceGraph, scope: VertexId, tname: &str, out: &mut Vec<VertexId>) {
    for &c in g.children_of(scope) {
        if g.type_name(c) == tname {
            out.push(c);
        } else {
            oracle_candidates(g, c, tname, out);
        }
    }
}

fn oracle_sat_req(
    g: &ResourceGraph,
    taken: &mut HashSet<VertexId>,
    trail: &mut Vec<VertexId>,
    scope: VertexId,
    req: &ResourceReq,
) -> bool {
    assert!(req.with.len() <= 1, "oracle handles chain specs only");
    let mut cands = Vec::new();
    oracle_candidates(g, scope, &req.rtype, &mut cands);
    oracle_choose(g, taken, trail, &cands, 0, req.count, req)
}

fn oracle_choose(
    g: &ResourceGraph,
    taken: &mut HashSet<VertexId>,
    trail: &mut Vec<VertexId>,
    cands: &[VertexId],
    i: usize,
    remaining: u64,
    req: &ResourceReq,
) -> bool {
    if remaining == 0 {
        return true;
    }
    if i >= cands.len() {
        return false;
    }
    let c = cands[i];
    let free = !g.vertex(c).alloc.is_allocated() && !taken.contains(&c);
    if !req.exclusive || free {
        let mark = trail.len();
        if req.exclusive {
            taken.insert(c);
            trail.push(c);
        }
        let mut ok = true;
        for sub in &req.with {
            if !oracle_sat_req(g, taken, trail, c, sub) {
                ok = false;
                break;
            }
        }
        if ok && oracle_choose(g, taken, trail, cands, i + 1, remaining - 1, req) {
            return true;
        }
        for v in trail.drain(mark..) {
            taken.remove(&v);
        }
    }
    oracle_choose(g, taken, trail, cands, i + 1, remaining, req)
}

fn oracle_feasible(g: &ResourceGraph, spec: &JobSpec) -> bool {
    let Some(root) = g.root() else { return false };
    let mut taken = HashSet::new();
    let mut trail = Vec::new();
    spec.resources
        .iter()
        .all(|req| oracle_sat_req(g, &mut taken, &mut trail, root, req))
}

// ---- the equivalence layer -------------------------------------------------

/// Tentpole oracle: seeded randomized streams at K ∈ {1, 2, 4, 8} end
/// bit-identical to the serial run — replies, epochs after every op,
/// allocation table, aggregates — with `check()` (graph invariants, table
/// consistency, shard-map partition, aggregate recomputation) and the
/// brute-force feasibility oracle consulted after every commit.
#[test]
fn sharded_streams_equal_serial_for_k_ladder() {
    let probe = JobSpec::nodes_sockets_cores(1, SOCKETS as u64, CORES as u64);
    for seed in [1u64, 0xBEEF, 0x5EED77] {
        let ops = build_stream(seed, 80);
        let mut serial = instance(1);
        let mut serial_replies = Vec::with_capacity(ops.len());
        let mut serial_epochs = Vec::with_capacity(ops.len());
        for op in &ops {
            serial_replies.push(serial.apply(op));
            serial_epochs.push(serial.graph.epoch());
        }
        serial.check().unwrap();
        for k in [1usize, 2, 4, 8] {
            let mut inst = instance(k);
            for (i, op) in ops.iter().enumerate() {
                let ctx = format!("seed {seed:#x} K {k} op {i} ({op:?})");
                let r = inst.apply(op);
                assert_reply_equal(&r, &serial_replies[i], &ctx);
                assert_eq!(inst.graph.epoch(), serial_epochs[i], "{ctx}: epoch");
                inst.check().unwrap_or_else(|e| panic!("{ctx}: {e}"));
                assert_eq!(
                    inst.match_only(&probe).is_ok(),
                    oracle_feasible(&inst.graph, &probe),
                    "{ctx}: matcher vs brute-force oracle"
                );
            }
            assert_state_equal(&serial, &inst, &format!("seed {seed:#x} K {k} final"));
        }
    }
}

/// The same ladder through `SchedService::apply` with the OCC two-phase
/// path armed: prepare-under-read-lock + commit-under-write-lock must
/// stay bit-identical to the serial instance on a single-threaded stream,
/// and every successful match-family op must be counted as a sharded
/// commit with zero conflicts.
#[test]
fn service_occ_ladder_matches_serial_instance() {
    let ops = build_stream(0xD00D, 60);
    let mut serial = instance(1);
    let serial_replies: Vec<SchedReply> = ops.iter().map(|op| serial.apply(op)).collect();
    let committed = serial_replies
        .iter()
        .filter(|r| matches!(r, SchedReply::Allocated { .. }))
        .count() as u64;
    assert!(committed > 0, "stream must exercise successful commits");
    for k in [1usize, 2, 4, 8] {
        let svc = SchedService::with_workers(instance(1), 4);
        if k > 1 {
            svc.set_write_shards(k);
        }
        for (i, op) in ops.iter().enumerate() {
            let ctx = format!("K {k} op {i} ({op:?})");
            let r = svc.apply(op);
            assert_reply_equal(&r, &serial_replies[i], &ctx);
        }
        {
            let guard = svc.read();
            guard.check().unwrap();
            assert_state_equal(&serial, &guard, &format!("K {k} final"));
        }
        let snap = svc.telemetry_snapshot();
        if k > 1 {
            assert_eq!(snap.shard_commits, committed, "K {k}: commit count");
            assert_eq!(snap.shard_conflicts, 0, "K {k}: nothing races one thread");
            assert_eq!(snap.spine_contentions, 0, "K {k}");
        } else {
            assert_eq!(snap.shard_commits, 0, "serial path takes no shard commits");
        }
    }
}

/// Toggling sharding mid-stream (on a live, partially-allocated instance)
/// re-indexes existing allocations and stays equivalent to serial from
/// that point on.
#[test]
fn toggling_shards_on_live_instance_stays_equivalent() {
    let ops = build_stream(0xCAFE, 60);
    let mut serial = instance(1);
    let mut inst = instance(1);
    for (i, op) in ops.iter().enumerate() {
        // off → 4 shards at op 15, re-plan to 2 at op 30, off again at 45
        match i {
            15 => inst.set_write_shards(4),
            30 => inst.set_write_shards(2),
            45 => inst.set_write_shards(0),
            _ => {}
        }
        let ctx = format!("op {i} ({op:?})");
        assert_reply_equal(&inst.apply(op), &serial.apply(op), &ctx);
        assert_eq!(inst.graph.epoch(), serial.graph.epoch(), "{ctx}: epoch");
        inst.check().unwrap_or_else(|e| panic!("{ctx}: {e}"));
    }
    assert_state_equal(&serial, &inst, "final");
}
