//! Integration coverage for the serving harness + telemetry stack:
//! deterministic replay (the BENCH_serving reproducibility contract),
//! histogram bucket round-trips at the public API boundary, chaos-wired
//! hierarchy runs, and the report-row schema `BENCH_serving.json` is
//! built from.

use std::time::Duration;

use fluxion::fault::FaultRates;
use fluxion::hier::{ChaosConfig, LevelSpec, LinkKind};
use fluxion::serving::{run_scenario, Scenario};
use fluxion::telemetry::{bucket_bounds, bucket_index, LatencyHistogram, BUCKETS};
use fluxion::util::bench::BenchReport;
use fluxion::util::json::Json;
use fluxion::workload::optrace::{
    count_by_kind, generate_ops, OpMix, OpTraceSpec, OP_KIND_NAMES,
};

fn quick_trace(ops: usize, mix: OpMix) -> OpTraceSpec {
    OpTraceSpec {
        ops,
        seed: 0xD15EA5E,
        rate_ops_per_sec: 150_000.0,
        mix,
        tenants: 4,
        nodes: (1, 2),
    }
}

/// Same seed ⇒ the identical planned op stream, op for op — the property
/// every other determinism claim rests on.
#[test]
fn same_seed_replays_identical_op_stream() {
    let spec = quick_trace(5_000, OpMix::balanced());
    let a = generate_ops(&spec);
    let b = generate_ops(&spec);
    assert_eq!(a, b);
    assert_eq!(count_by_kind(&a), count_by_kind(&b));
    // and the stream is non-trivial: several kinds present
    let active = count_by_kind(&a).iter().filter(|&&c| c > 0).count();
    assert!(active >= 4, "balanced mix should hit >=4 kinds");
}

/// Re-running a multi-client scenario reproduces the issued-per-kind
/// counters exactly (latencies and — across interleavings — success/error
/// splits may differ; issued counts must not).
#[test]
fn seeded_rerun_reproduces_issued_counters() {
    let mk = || {
        Scenario::service(
            "serve/it/rerun@L1",
            quick_trace(600, OpMix::churn()),
            4,
            1,
            4,
        )
    };
    let a = run_scenario(&mk());
    let b = run_scenario(&mk());
    assert_eq!(a.issued_by_kind, b.issued_by_kind);
    assert_eq!(a.planned, b.planned);
    for name in OP_KIND_NAMES.iter() {
        assert_eq!(
            a.harness.kind(name).unwrap().ops,
            b.harness.kind(name).unwrap().ops,
            "kind {name} issued-count drifted across reruns"
        );
    }
    // every planned op was recorded exactly once on both runs
    assert_eq!(a.harness.ops_total(), 600);
    assert_eq!(b.harness.ops_total(), 600);
}

/// PR 8: the rerun contract holds with the OCC sharded write path armed —
/// a multi-writer churn scenario with `write_shards = 4` reproduces its
/// issued-per-kind counters exactly across seeded reruns, commits through
/// the shard layer on both runs, and never leaves a torn commit behind
/// (`shard_commits + shard_conflicts` covers every successful match, so a
/// lost or doubled commit would break the ops-total identity below).
#[test]
fn write_sharded_churn_rerun_reproduces_issued_counters() {
    let mk = || {
        Scenario::service(
            "serve/it/wrshard-rerun@L1",
            quick_trace(600, OpMix::churn()),
            4,
            1,
            4,
        )
        .with_write_shards(4)
    };
    let a = run_scenario(&mk());
    let b = run_scenario(&mk());
    assert_eq!(a.issued_by_kind, b.issued_by_kind);
    assert_eq!(a.planned, b.planned);
    for name in OP_KIND_NAMES.iter() {
        assert_eq!(
            a.harness.kind(name).unwrap().ops,
            b.harness.kind(name).unwrap().ops,
            "kind {name} issued-count drifted across write-sharded reruns"
        );
    }
    assert_eq!(a.harness.ops_total(), 600);
    assert_eq!(b.harness.ops_total(), 600);
    // both runs actually exercised the sharded commit path
    for (run, r) in [("a", &a), ("b", &b)] {
        let snap = &r.services[0];
        assert!(
            snap.shard_commits > 0,
            "run {run}: churn mix never commits through the shard layer"
        );
    }
}

/// Bucket round-trip at the public boundary: for a spread of latencies,
/// recording a duration and reading the histogram back keeps the value
/// inside its reported bucket bounds (≤6.25% relative error by design).
#[test]
fn histogram_buckets_round_trip_recorded_latencies() {
    let h = LatencyHistogram::new();
    let values_ns: Vec<u64> = (0..60)
        .map(|i| 3u64.saturating_pow(i).min(u64::MAX / 2))
        .chain([0, 1, 15, 16, 31, 32, 1_000, 1_000_000, 123_456_789])
        .collect();
    for &v in &values_ns {
        h.record(Duration::from_nanos(v));
        let idx = bucket_index(v);
        assert!(idx < BUCKETS);
        let (lo, hi) = bucket_bounds(idx);
        assert!(
            (lo..=hi).contains(&v),
            "{v} escaped its bucket [{lo}, {hi}]"
        );
    }
    let snap = h.snapshot();
    assert_eq!(snap.count, values_ns.len() as u64);
    assert_eq!(snap.max_ns, *values_ns.iter().max().unwrap());
    assert_eq!(snap.min_ns, *values_ns.iter().min().unwrap());
    // quantiles are clamped into the observed range and ordered
    let p50 = snap.quantile_ns(0.50);
    let p99 = snap.quantile_ns(0.99);
    assert!(snap.min_ns <= p50 && p50 <= p99 && p99 <= snap.max_ns);
}

/// A chaos-wired hierarchy scenario completes, records every planned op,
/// and surfaces per-level service telemetry (the clean/faulty pairing the
/// bench reports relies on this path).
#[test]
fn hierarchy_chaos_scenario_records_every_op() {
    let trace = OpTraceSpec {
        ops: 48,
        rate_ops_per_sec: 2_000.0,
        ..quick_trace(48, OpMix::balanced())
    };
    let chaos = ChaosConfig::client_only(
        0xC4A05,
        FaultRates {
            drop: 0.05,
            delay: 0.05,
            delay_for: Duration::from_micros(100),
            ..FaultRates::none()
        },
    );
    let sc = Scenario::hierarchy(
        "serve/it/hier_chaos",
        trace,
        2, // 4-node root
        vec![
            LevelSpec {
                boot_nodes: 2,
                link: LinkKind::InProc,
            },
            LevelSpec {
                boot_nodes: 1,
                link: LinkKind::InProc,
            },
        ],
        Some(chaos),
    );
    let r = run_scenario(&sc);
    assert_eq!(r.harness.ops_total(), 48, "an op went unrecorded");
    assert_eq!(r.services.len(), 3, "one telemetry snapshot per level");
    assert!(r.errors() <= 48);
    let issued: u64 = r.issued_by_kind.iter().sum();
    assert_eq!(issued, 48);
    // wall-clock and throughput are sane (finite, positive)
    assert!(r.wall_s > 0.0 && r.wall_s.is_finite());
    assert!(r.attained_ops_per_sec > 0.0);
}

/// The report rows a scenario emits carry the `BENCH_serving.json` schema:
/// base Summary fields plus `p50_s`/`p95_s`/`p99_s`/`ops_per_sec`/`errors`
/// extras, valid JSON end to end.
#[test]
fn report_rows_match_bench_serving_schema() {
    let sc = Scenario::service(
        "serve/it/schema@L2",
        quick_trace(400, OpMix::probe_heavy()),
        2,
        2,
        2,
    );
    let r = run_scenario(&sc);
    let mut report = BenchReport::new();
    r.report_rows(&mut report);
    let doc = Json::parse(&report.to_json().dump()).expect("report JSON parses");
    let rows = doc.get("benchmarks").and_then(|b| b.as_arr()).unwrap();
    let head = rows
        .iter()
        .find(|row| row.get("name").and_then(|n| n.as_str()) == Some("serve/it/schema@L2"))
        .expect("headline row present");
    for key in ["n", "mean_s", "median_s", "p50_s", "p95_s", "p99_s", "ops_per_sec", "errors"] {
        assert!(head.get(key).is_some(), "row missing {key}");
    }
    let p50 = head.get("p50_s").and_then(|v| v.as_f64()).unwrap();
    let p99 = head.get("p99_s").and_then(|v| v.as_f64()).unwrap();
    assert!(p50.is_finite() && p99.is_finite() && p50 <= p99 && p50 > 0.0);
    // per-kind rows ride along under name/kind
    assert!(
        rows.iter().any(|row| {
            row.get("name").and_then(|n| n.as_str()) == Some("serve/it/schema@L2/probe")
        }),
        "probe kind row missing"
    );
}
