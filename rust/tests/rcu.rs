//! RCU snapshot contract for the lock-free read path (PR 9).
//!
//! Three pillars, matching the design's acceptance criteria:
//! 1. **No read ever takes the instance lock**: every probe flavor
//!    (single, sharded, batched read phase through the pool) completes
//!    while a writer deliberately stalls holding the write lock.
//! 2. **Pinned versions are immutable**: a reader pinned at version E
//!    keeps getting bit-identical match results while K writer threads
//!    commit — the pinned `Arc<GraphSnapshot>` is the consistency unit.
//! 3. **No snapshot leaks**: retirement is `Arc` reclamation, and the
//!    lifecycle counters prove it — with no pins outstanding exactly one
//!    version (the head) is live, no matter how much churn preceded.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use fluxion::jobspec::JobSpec;
use fluxion::resource::builder::{table2_graph, UidGen};
use fluxion::sched::{
    MatchScratch, PruneConfig, SchedInstance, SchedOp, SchedReply, SchedService,
};

fn service(level: usize, workers: usize) -> SchedService {
    SchedService::with_workers(
        SchedInstance::new(table2_graph(level, &mut UidGen::new()), PruneConfig::default()),
        workers,
    )
}

/// The acceptance stress: a writer takes the write lock and STALLS on it.
/// Every read-path flavor must still complete promptly — pre-PR 9, each of
/// these queued behind the stalled guard (readers block while a writer
/// holds, or even waits for, an `RwLock`). The deadline turns "probe
/// acquired the instance lock" into a deterministic failure instead of a
/// hang.
#[test]
fn probes_complete_while_a_writer_stalls_on_the_write_lock() {
    let svc = service(1, 4); // L1: 8 nodes
    let spec = JobSpec::nodes_sockets_cores(1, 2, 16);
    let expected = svc.probe(&spec);
    assert!(matches!(expected, SchedReply::Probed { .. }));

    // park a writer inside the guard; `held` fires only once the write
    // lock is genuinely held
    let (held_tx, held_rx) = channel();
    let (release_tx, release_rx) = channel::<()>();
    let stalled = {
        let svc = svc.clone();
        std::thread::spawn(move || {
            let guard = svc.write();
            held_tx.send(()).expect("main thread alive");
            release_rx.recv().expect("released");
            drop(guard);
        })
    };
    held_rx.recv().expect("writer reached the guard");

    // all three read flavors on a helper thread, against a cleared cache
    // (real traversals, not cache hits), with a hard deadline
    let (done_tx, done_rx) = channel();
    let prober = {
        let svc = svc.clone();
        let spec = spec.clone();
        let expected = expected.clone();
        std::thread::spawn(move || {
            svc.clear_cache();
            assert_eq!(svc.probe(&spec), expected);
            svc.clear_cache();
            // sharded contract: feasibility + vertex count identical,
            // `visited` an upper bound
            match (svc.probe_sharded(&spec, 4), &expected) {
                (
                    SchedReply::Probed { vertices: a, .. },
                    SchedReply::Probed { vertices: b, .. },
                ) => assert_eq!(a, *b),
                (other, _) => panic!("sharded probe failed under stall: {other:?}"),
            }
            svc.clear_cache();
            let ops: Vec<SchedOp> = (1..=4u64)
                .map(|n| SchedOp::Probe {
                    spec: JobSpec::nodes_sockets_cores(n, 2, 16),
                })
                .collect();
            let replies = svc.apply_batch(&ops);
            assert!(
                replies.iter().all(|r| matches!(r, SchedReply::Probed { .. })),
                "batched read phase failed under stall: {replies:?}"
            );
            done_tx.send(()).expect("main thread alive");
        })
    };
    done_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("read path blocked behind a stalled writer — probes must never take the instance lock");
    prober.join().expect("prober panicked");
    release_tx.send(()).expect("stalled writer alive");
    stalled.join().expect("stalled writer panicked");
    svc.read().check().unwrap();
}

/// Property: a reader pinned at version E observes bit-identical match
/// results for the pin's whole lifetime, no matter how many writers
/// commit (and publish) behind it. The pinned snapshot IS version E —
/// there is no window where a reader sees a mix of epochs.
#[test]
fn pinned_reader_sees_bit_identical_results_while_writers_commit() {
    const WRITERS: usize = 3;
    const CYCLES: usize = 60;
    let svc = service(1, 4); // L1: 8 nodes
    let specs: Vec<JobSpec> = (1..=4u64)
        .map(|n| JobSpec::nodes_sockets_cores(n, 2, 16))
        .collect();

    let snap = svc.pin_snapshot();
    let pinned_version = snap.version;
    let baseline: Vec<SchedReply> = {
        let mut scratch = MatchScratch::new();
        specs.iter().map(|s| snap.probe_with(s, &mut scratch)).collect()
    };

    let stop = Arc::new(AtomicBool::new(false));
    let reader = {
        let snap = Arc::clone(&snap);
        let specs = specs.clone();
        let baseline = baseline.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut scratch = MatchScratch::new();
            let mut rounds = 0usize;
            // probe-then-check-stop: at least one full round always runs
            loop {
                for (spec, expect) in specs.iter().zip(&baseline) {
                    let r = snap.probe_with(spec, &mut scratch);
                    assert_eq!(
                        &r, expect,
                        "pinned version {pinned_version} drifted mid-pin"
                    );
                }
                rounds += 1;
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            rounds
        })
    };

    let mut writers = Vec::new();
    for _ in 0..WRITERS {
        let svc = svc.clone();
        let spec = specs[0].clone();
        writers.push(std::thread::spawn(move || {
            for _ in 0..CYCLES {
                let reply = svc.apply(&SchedOp::MatchAllocate { spec: spec.clone() });
                let SchedReply::Allocated { job, .. } = reply else {
                    panic!("writer allocation failed (>= 5 nodes always free): {reply:?}");
                };
                let freed = svc.apply(&SchedOp::FreeJob { job });
                assert!(matches!(freed, SchedReply::Freed { .. }), "{freed:?}");
            }
        }));
    }
    for w in writers {
        w.join().expect("writer panicked");
    }
    stop.store(true, Ordering::Relaxed);
    let rounds = reader.join().expect("pinned reader panicked");
    assert!(rounds >= 1);

    // the writers really did publish past the pin...
    assert_eq!(snap.version, pinned_version);
    assert!(
        svc.epoch() > pinned_version,
        "writers committed, the head must have moved past the pin"
    );
    let stats = svc.snapshot_stats();
    assert!(
        stats.publishes >= (WRITERS * CYCLES * 2) as u64,
        "every alloc and free publishes: {stats:?}"
    );
    // ...and with our pin still held, exactly two versions are live: the
    // pinned one and the head
    assert_eq!(stats.live, 2, "{stats:?}");
    drop(snap);
    assert_eq!(svc.snapshot_stats().live, 1);
    svc.read().check().unwrap();
}

/// No-leak invariant: versions retire the moment their last pin drops.
/// After arbitrary churn with no reader pinned, exactly one version (the
/// head) is live and `publishes == retired`; a held pin keeps exactly one
/// superseded version alive, releasing it reclaims immediately.
#[test]
fn snapshot_versions_retire_exactly_when_unpinned() {
    let svc = service(3, 2); // L3: 2 nodes
    let spec = JobSpec::nodes_sockets_cores(1, 2, 16);
    for _ in 0..100 {
        let SchedReply::Allocated { job, .. } =
            svc.apply(&SchedOp::MatchAllocate { spec: spec.clone() })
        else {
            panic!("allocation failed on a free graph");
        };
        let freed = svc.apply(&SchedOp::FreeJob { job });
        assert!(matches!(freed, SchedReply::Freed { .. }), "{freed:?}");
    }
    let s = svc.snapshot_stats();
    assert!(s.publishes >= 200, "each alloc and free publishes: {s:?}");
    assert_eq!(s.retired, s.publishes, "a superseded version leaked: {s:?}");
    assert_eq!(s.live, 1, "only the head may remain live: {s:?}");

    // a pin holds its version across supersession — and only that version
    let pin = svc.pin_snapshot();
    let SchedReply::Allocated { job, .. } =
        svc.apply(&SchedOp::MatchAllocate { spec: spec.clone() })
    else {
        panic!("allocation failed on a free graph");
    };
    assert_eq!(svc.snapshot_stats().live, 2, "pinned old version + head");
    let freed = svc.apply(&SchedOp::FreeJob { job });
    assert!(matches!(freed, SchedReply::Freed { .. }), "{freed:?}");
    // the alloc-era head was unpinned, so it retired on the free's publish
    assert_eq!(svc.snapshot_stats().live, 2, "pinned old version + new head");
    drop(pin);
    let s = svc.snapshot_stats();
    assert_eq!(s.live, 1, "unpinning must reclaim immediately: {s:?}");
    assert_eq!(s.retired, s.publishes);
    svc.read().check().unwrap();
}
