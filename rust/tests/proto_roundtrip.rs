//! Property tests for the typed scheduler protocol: every [`SchedOp`] /
//! [`SchedReply`] must survive `decode(encode(x)) == x` through the full
//! wire text (dump + reparse), the request/response envelope must reject
//! ambiguity, and a frame stream cut mid-batch must yield exactly the
//! complete prefix then a clean error — never garbage, never a panic.
//!
//! Driven by the in-repo shrink-lite property harness (`util/prop.rs`);
//! deterministic per-variant coverage lives in `rpc::proto`'s unit tests,
//! these push randomized structures (nested specs, escape-heavy paths,
//! real JGF selections) through the same codec.

use fluxion::hier::report::LevelTiming;
use fluxion::jobspec::{JobSpec, ResourceReq};
use fluxion::resource::builder::{ClusterSpec, UidGen};
use fluxion::resource::graph::JobId;
use fluxion::resource::jgf::Jgf;
use fluxion::rpc::proto::{RpcError, SchedOp, SchedReply};
use fluxion::rpc::{encode_frame, read_frame, Request, Response};
use fluxion::util::json::Json;
use fluxion::util::prop::{check, ensure};
use fluxion::util::rng::Rng;

// ---------------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------------

fn gen_req(rng: &mut Rng, depth: usize) -> ResourceReq {
    const TYPES: [&str; 6] = ["node", "socket", "core", "gpu", "memory", "rack"];
    let mut r = ResourceReq::new(
        TYPES[rng.below(TYPES.len() as u64) as usize],
        rng.range(1, 4),
    );
    if rng.below(4) == 0 {
        r = r.shared();
    }
    if rng.below(3) == 0 {
        r = r.with_attr("zone", "us-east-1a");
    }
    if rng.below(4) == 0 {
        r = r.with_attr("instance_type", "t2.micro");
    }
    if depth > 0 && rng.below(2) == 0 {
        let kids = rng.range(1, 2);
        for _ in 0..kids {
            r = r.with_child(gen_req(rng, depth - 1));
        }
    }
    r
}

fn gen_spec(rng: &mut Rng, size: usize) -> JobSpec {
    let depth = (size / 8).min(3);
    let n = rng.range(1, 2) as usize;
    let mut spec = JobSpec::new((0..n).map(|_| gen_req(rng, depth)).collect());
    if rng.below(3) == 0 {
        spec = spec.with_attr("user", "alice");
    }
    spec
}

/// Paths deliberately include JSON-hostile characters to stress escaping.
fn gen_path(rng: &mut Rng) -> String {
    match rng.below(4) {
        0 => format!("/cluster0/node{}", rng.below(128)),
        1 => format!("/c0/node{}/socket{}", rng.below(8), rng.below(2)),
        2 => format!("/burst/\"quoted\"/n{}", rng.below(9)),
        _ => format!("/weird/\\back\nslash\t{}", rng.below(9)),
    }
}

/// A real JGF document: an upward-closed prefix of a small cluster's DFS
/// order (what `Jgf::from_selection` is fed in production).
fn gen_jgf(rng: &mut Rng, size: usize) -> Jgf {
    let nodes = 1 + (size / 10).min(2);
    let g = ClusterSpec::new("c", nodes, 2, 2).build(&mut UidGen::new());
    let all = g.dfs(g.root().unwrap());
    let take = 1 + rng.below(all.len() as u64) as usize;
    Jgf::from_selection(&g, &all[..take])
}

fn gen_f64(rng: &mut Rng) -> f64 {
    match rng.below(3) {
        0 => 0.0,
        1 => rng.below(1000) as f64, // integer-valued (itoa fast path)
        _ => rng.f64() * 1e-3,       // realistic op timings
    }
}

fn gen_op(rng: &mut Rng, size: usize) -> SchedOp {
    match rng.below(9) {
        0 => SchedOp::MatchAllocate {
            spec: gen_spec(rng, size),
        },
        1 => SchedOp::MatchGrowLocal {
            job: JobId(rng.below(1 << 20)),
            spec: gen_spec(rng, size),
        },
        2 => SchedOp::Probe {
            spec: gen_spec(rng, size),
        },
        3 => SchedOp::AcceptGrant {
            subgraph: gen_jgf(rng, size),
            job: if rng.below(2) == 0 {
                Some(JobId(rng.below(100)))
            } else {
                None
            },
        },
        4 => SchedOp::FreeJob {
            job: JobId(rng.below(1 << 20)),
        },
        5 => SchedOp::ShrinkSubtree {
            path: gen_path(rng),
        },
        6 => SchedOp::RemoveSubgraph {
            path: gen_path(rng),
        },
        7 => SchedOp::MatchGrow {
            spec: gen_spec(rng, size),
        },
        _ => SchedOp::ShrinkReturn {
            path: gen_path(rng),
        },
    }
}

fn gen_levels(rng: &mut Rng) -> Vec<LevelTiming> {
    (0..rng.below(4))
        .map(|i| LevelTiming {
            level: i as usize,
            match_s: gen_f64(rng),
            match_ok: rng.below(2) == 0,
            comms_s: gen_f64(rng),
            add_upd_s: gen_f64(rng),
            visited: rng.below(10_000) as usize,
        })
        .collect()
}

fn gen_reply(rng: &mut Rng, size: usize) -> SchedReply {
    const CODES: [&str; 4] = ["no_match", "grow_failed", "provider_api", "shrink_failed"];
    match rng.below(7) {
        0 => SchedReply::Allocated {
            job: JobId(rng.below(1 << 20)),
            subgraph: gen_jgf(rng, size),
            match_s: gen_f64(rng),
            add_upd_s: gen_f64(rng),
            visited: rng.below(10_000) as usize,
        },
        1 => SchedReply::Probed {
            visited: rng.below(10_000) as usize,
            vertices: rng.below(10_000) as usize,
        },
        2 => SchedReply::Accepted {
            added: rng.below(1000) as usize,
            preexisting: rng.below(10) as usize,
            add_upd_s: gen_f64(rng),
        },
        3 => SchedReply::Freed {
            vertices: rng.below(1000) as usize,
        },
        4 => SchedReply::Removed {
            vertices: rng.below(1000) as usize,
        },
        5 => SchedReply::Grown {
            subgraph: gen_jgf(rng, size),
            levels: gen_levels(rng),
        },
        _ => SchedReply::Error(RpcError::new(
            CODES[rng.below(CODES.len() as u64) as usize],
            format!("failed at {}: \"why\"\n", gen_path(rng)),
        )),
    }
}

// ---------------------------------------------------------------------------
// Properties
// ---------------------------------------------------------------------------

#[test]
fn prop_sched_op_roundtrips_through_wire_text() {
    check(0xC0DE, 200, 40, gen_op, |op| {
        let text = op.to_json().dump();
        let doc = Json::parse(&text).map_err(|e| e.to_string())?;
        let back = SchedOp::from_json(&doc).map_err(|e| e.to_string())?;
        ensure(&back == op, "op changed across encode/decode")
    });
}

#[test]
fn prop_sched_reply_roundtrips_through_wire_text() {
    check(0xFEED, 200, 40, gen_reply, |reply| {
        let text = reply.to_json().dump();
        let doc = Json::parse(&text).map_err(|e| e.to_string())?;
        let back = SchedReply::from_json(&doc).map_err(|e| e.to_string())?;
        ensure(&back == reply, "reply changed across encode/decode")
    });
}

#[test]
fn prop_request_response_envelopes_roundtrip_framed() {
    check(
        0xABCD,
        150,
        40,
        |rng: &mut Rng, size: usize| {
            let req = Request::new(rng.below(1 << 30), gen_op(rng, size));
            let resp = Response {
                id: rng.below(1 << 30),
                reply: gen_reply(rng, size),
            };
            (req, resp)
        },
        |(req, resp)| {
            let mut cur = std::io::Cursor::new(encode_frame(&req.to_json()));
            let doc = read_frame(&mut cur).map_err(|e| e.to_string())?;
            let back = Request::from_json(&doc).map_err(|e| e.to_string())?;
            ensure(&back == req, "request changed across the frame")?;

            let mut cur = std::io::Cursor::new(encode_frame(&resp.to_json()));
            let doc = read_frame(&mut cur).map_err(|e| e.to_string())?;
            let back = Response::from_json(&doc).map_err(|e| e.to_string())?;
            ensure(&back == resp, "response changed across the frame")
        },
    );
}

/// Truncating a stream of frames mid-batch yields exactly the frames that
/// fit before the cut, then a clean I/O error — the reader never yields a
/// partial document and never panics.
#[test]
fn prop_frame_stream_truncation_mid_batch() {
    check(
        0xBA7C4,
        150,
        30,
        |rng: &mut Rng, size: usize| {
            let k = rng.range(1, 5) as usize;
            let ops: Vec<SchedOp> = (0..k).map(|_| gen_op(rng, size)).collect();
            let frames: Vec<Vec<u8>> =
                ops.iter().map(|op| encode_frame(&op.to_json())).collect();
            let total: usize = frames.iter().map(Vec::len).sum();
            let cut = rng.below(total as u64 + 1) as usize;
            (ops, frames, cut)
        },
        |(ops, frames, cut)| {
            let mut stream: Vec<u8> = Vec::new();
            for f in frames {
                stream.extend_from_slice(f);
            }
            stream.truncate(*cut);

            // how many whole frames survive the cut
            let mut whole = 0usize;
            let mut consumed = 0usize;
            for f in frames {
                if consumed + f.len() <= *cut {
                    whole += 1;
                    consumed += f.len();
                } else {
                    break;
                }
            }

            let mut cur = std::io::Cursor::new(stream);
            for op in ops.iter().take(whole) {
                let doc = read_frame(&mut cur)
                    .map_err(|e| format!("complete frame failed to read: {e}"))?;
                let back = SchedOp::from_json(&doc).map_err(|e| e.to_string())?;
                ensure(&back == op, "op changed across the framed stream")?;
            }
            // anything after the last whole frame must error (partial frame)
            // or cleanly EOF (cut exactly on a boundary)
            match read_frame(&mut cur) {
                Err(_) => Ok(()),
                Ok(doc) => Err(format!("decoded a frame past the cut: {doc}")),
            }
        },
    );
}

/// The envelope rejects ambiguous and legacy error shapes regardless of
/// what valid reply document is spliced in.
#[test]
fn prop_ambiguous_response_rejected() {
    check(0xD0C5, 100, 30, gen_reply, |reply| {
        let ok = Response {
            id: 1,
            reply: reply.clone(),
        };
        let mut doc = ok.to_json();
        if doc.get("error").is_some() {
            // error reply: splice in a result too
            doc.set("result", Json::obj().with("reply", Json::from("freed")));
        } else {
            // ok reply: splice in an error too
            doc.set(
                "error",
                RpcError::new("no_match", "also failed?").to_json(),
            );
        }
        ensure(
            Response::from_json(&doc).is_err(),
            "ambiguous response was accepted",
        )
    });
}
