//! Crash-consistency suite (PR 10): write-ahead journal replay, torn-tail
//! truncation, scripted level crashes at every injection point, and the
//! parent-child grant reconciliation that re-converges the hierarchy after
//! each kill/restart cycle.
//!
//! Invariants proven here, after EVERY cycle:
//!   - the per-level allocation oracle (`Hierarchy::check_all`);
//!   - the cross-level ledger invariant (`Hierarchy::check_ledgers`):
//!     every parent grant has exactly one live child claim and vice versa;
//!   - committed-prefix replay is bit-identical
//!     (`fluxion::sched::states_bit_identical`).
//!
//! Reproducibility contract mirrors the chaos soak: the seeded streams
//! derive from one master seed, overridable with
//! `RECOVERY_SEED=0x2EC0 cargo test --test recovery` (decimal or 0x-hex).

use std::sync::{Arc, Mutex};

use fluxion::external::ec2::{Ec2Provider, Ec2SimConfig};
use fluxion::external::provider::{ExternalGrant, ExternalProvider, ProviderError};
use fluxion::fault::{
    CrashPlan, CrashPoint, FaultInjector, FaultRates, FaultyProvider, ProviderFault,
};
use fluxion::hier::{Hierarchy, LevelSpec, LinkKind};
use fluxion::jobspec::JobSpec;
use fluxion::resource::builder::{ClusterSpec, UidGen};
use fluxion::resource::graph::JobId;
use fluxion::rpc::proto::code;
use fluxion::sched::{
    recover, states_bit_identical, PruneConfig, SchedInstance, SchedOp, SchedReply,
    SchedService,
};
use fluxion::util::rng::Rng;

/// Master seed. Override with `RECOVERY_SEED=<int>` (decimal or
/// `0x`-prefixed hex) to reproduce or explore a different schedule.
fn recovery_seed() -> u64 {
    match std::env::var("RECOVERY_SEED") {
        Ok(s) => {
            let s = s.trim().to_string();
            let parsed = match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
                Some(hex) => u64::from_str_radix(hex, 16),
                None => s.parse::<u64>(),
            };
            parsed.unwrap_or_else(|_| panic!("RECOVERY_SEED must be an integer, got {s:?}"))
        }
        Err(_) => 0x2EC0,
    }
}

/// A journaled single service driven through a seeded alloc/free/probe
/// stream. Returns the service and the jobs still live at the end.
fn journaled_service(seed: u64, ops: usize) -> (SchedService, Vec<JobId>) {
    let svc = SchedService::new(SchedInstance::new(
        ClusterSpec::new("c", 4, 2, 8).build(&mut UidGen::new()),
        PruneConfig::default(),
    ));
    svc.enable_journal(3 + seed % 5);
    let mut rng = Rng::new(seed);
    let mut live: Vec<JobId> = Vec::new();
    let shapes = [(1u64, 1u64, 2u64), (1, 2, 8), (2, 2, 8), (1, 1, 8)];
    for _ in 0..ops {
        match rng.below(10) {
            0..=5 => {
                let (n, s, c) = shapes[rng.below(shapes.len() as u64) as usize];
                let reply = svc.apply(&SchedOp::MatchAllocate {
                    spec: JobSpec::nodes_sockets_cores(n, s, c),
                });
                if let SchedReply::Allocated { job, .. } = reply {
                    live.push(job);
                }
            }
            6..=7 => {
                if !live.is_empty() {
                    let job = live.swap_remove(rng.below(live.len() as u64) as usize);
                    let reply = svc.apply(&SchedOp::FreeJob { job });
                    assert!(matches!(reply, SchedReply::Freed { .. }), "{reply:?}");
                }
            }
            _ => {
                // read-only: probes never touch the journal
                let _ = svc.probe(&JobSpec::nodes_sockets_cores(1, 1, 1));
            }
        }
    }
    (svc, live)
}

/// Tentpole: replaying the committed journal prefix of a seeded mixed op
/// stream reproduces the live graph epoch, alloc table, and aggregates
/// bit-identically — the PR 8 equivalence contract, now across a crash.
#[test]
fn seeded_op_stream_replays_bit_identically() {
    let seed = recovery_seed();
    let (svc, live) = journaled_service(seed, 90);
    let rec = svc.recover_from_journal().expect("journal enabled");
    assert_eq!(rec.torn, 0, "clean journal has no torn tail (seed {seed:#x})");
    assert_eq!(rec.uncommitted, 0, "every accepted op committed (seed {seed:#x})");
    assert_eq!(
        rec.epoch_mismatches, 0,
        "replay diverged from recorded epochs (seed {seed:#x})"
    );
    states_bit_identical(&rec.inst, &svc.read())
        .unwrap_or_else(|e| panic!("replay not bit-identical (seed {seed:#x}): {e}"));
    rec.inst.check().expect("recovered oracle");
    assert!(
        svc.telemetry_snapshot().journal_appends > 0,
        "journaled stream recorded no appends"
    );
    drop(live);
}

/// Satellite: a torn tail — the last frame truncated mid-write or
/// corrupted — is discarded from the first bad frame on, and the journal
/// still replays the committed prefix cleanly at every truncation depth.
#[test]
fn torn_tail_is_discarded_and_prefix_replays() {
    let seed = recovery_seed() ^ 0x7EA4;
    let (svc, _live) = journaled_service(seed, 60);
    let (base, frames) = svc.journal_export().expect("journal enabled");
    let prune = PruneConfig::default();
    let full = recover(&base, &frames, prune.clone());
    states_bit_identical(&full.inst, &svc.read()).expect("full replay bit-identical");

    // frame-boundary truncation: suffix frames simply absent (the classic
    // torn write that lost whole appends). Not corruption — torn stays 0,
    // but an op whose commit frame fell off is dropped as uncommitted.
    for k in 1..=frames.len().min(4) {
        let cut = &frames[..frames.len() - k];
        let rec = recover(&base, cut, prune.clone());
        assert_eq!(rec.torn, 0, "truncation at depth {k} is not corruption");
        rec.inst.check().unwrap_or_else(|e| {
            panic!("oracle violated after truncating {k} frames (seed {seed:#x}): {e}")
        });
        assert!(
            rec.inst.graph.epoch() <= svc.read().graph.epoch(),
            "a replayed prefix can never be ahead of the live timeline"
        );
    }

    // mid-frame corruption: flip bytes inside the last frame — the
    // checksum rejects it and recovery discards the suffix from there.
    let mut torn = frames.clone();
    let last = torn.last_mut().expect("stream journaled frames");
    let cutoff = last.len() / 2;
    last.truncate(cutoff);
    let rec = recover(&base, &torn, prune.clone());
    assert_eq!(rec.torn, 1, "half-written final frame must be detected");
    rec.inst
        .check()
        .expect("oracle after discarding the torn suffix");
}

/// A 3-level chain with spare capacity at the root: L1 boots 2 nodes, the
/// leaf boots 1 of those, one node stays free at L0 — so a leaf grow
/// escalates to the top and the grant descends through every link.
fn chain3() -> Hierarchy {
    let root = ClusterSpec::new("cluster", 3, 2, 16).build(&mut UidGen::new());
    let levels = vec![
        LevelSpec {
            boot_nodes: 2,
            link: LinkKind::InProc,
        },
        LevelSpec {
            boot_nodes: 1,
            link: LinkKind::InProc,
        },
    ];
    Hierarchy::build(root, &levels).expect("chain hierarchy")
}

/// Crash point 1 (pre-journal): the leaf dies after the grant reply
/// arrives but before splicing it — the parent holds an orphaned grant the
/// child never committed. The restart reconcile must release it upstream
/// and restore the ledger invariant AND the capacity.
#[test]
fn orphaned_grant_is_released_after_child_restart() {
    let h = chain3();
    h.enable_journals(4);
    h.check_ledgers().expect("balanced at boot");
    let spec = JobSpec::nodes_sockets_cores(1, 2, 16);
    let leaf = h.depth() - 1;

    h.set_crash_plan(leaf, CrashPlan::once(CrashPoint::PreJournal));
    let err = h.grow_from_leaf(&spec).expect_err("scripted crash");
    assert!(err.starts_with(code::CRASHED), "want crashed, got: {err}");
    h.check_ledgers()
        .expect_err("orphaned grant must show as ledger divergence");
    h.check_all().expect("per-level oracle still holds");

    let report = h.kill_and_restart_level(leaf).expect("restart");
    assert!(
        report.matched_live,
        "the crash predates any leaf mutation: {report:?}"
    );
    assert!(report.reconcile_errors.is_empty(), "{:?}", report.reconcile_errors);
    h.check_ledgers().expect("reconcile released the orphan");
    h.check_all().expect("oracle after restart");
    assert!(
        h.telemetry_snapshot_at(leaf - 1).orphans_released >= 1,
        "the leaf's parent must count the released orphan"
    );
    // the released capacity is reusable: the same grow now lands
    let report = h.grow_from_leaf(&spec).expect("grow after recovery");
    assert!(report.subgraph_size > 0);
    h.check_ledgers().expect("balanced after re-grow");
    h.shutdown();
}

/// Crash point 2 (post-journal / pre-commit durability): a mid-level
/// grants downward but dies before its ledger write lands — after its
/// restart the child holds a ghost subtree the parent has no record of.
/// The handshake cancels the ghost below and releases the now-unclaimed
/// upstream grant as an orphan above.
#[test]
fn ghost_subtree_is_cancelled_after_parent_restart() {
    let h = chain3();
    h.enable_journals(4);
    let spec = JobSpec::nodes_sockets_cores(1, 2, 16);

    h.set_crash_plan(1, CrashPlan::once(CrashPoint::PostJournal));
    // the grow SUCCEEDS at the leaf — the crash hits L1's durability only
    let report = h.grow_from_leaf(&spec).expect("grant descends");
    assert!(report.subgraph_size > 0);
    h.check_ledgers()
        .expect_err("undurable grant must show as ledger divergence");

    let restart = h.kill_and_restart_level(1).expect("restart");
    assert!(
        !restart.matched_live,
        "the journal is legitimately behind the pre-kill live state: {restart:?}"
    );
    assert!(restart.reconcile_errors.is_empty(), "{:?}", restart.reconcile_errors);
    h.check_ledgers()
        .expect("ghost cancelled below, orphan released above");
    h.check_all().expect("oracle after restart");
    assert!(
        h.telemetry_snapshot_at(0).orphans_released >= 1,
        "L0 must release the grant L1 lost"
    );
    // full capacity is back: the same grow lands again end to end
    let report = h.grow_from_leaf(&spec).expect("grow after recovery");
    assert!(report.subgraph_size > 0);
    h.check_ledgers().expect("balanced after re-grow");
    h.shutdown();
}

/// Crash point 3 (mid-reconcile): the child crashes after receiving the
/// `Reconciled` reply but before cancelling its ghosts. The handshake is
/// idempotent — a retried reconcile re-reports the same ghosts and
/// converges.
#[test]
fn mid_reconcile_crash_retries_idempotently() {
    let h = chain3();
    h.enable_journals(4);
    let spec = JobSpec::nodes_sockets_cores(1, 2, 16);
    let leaf = h.depth() - 1;

    // ghost setup as above: L1 grants without durability, then restarts
    h.set_crash_plan(1, CrashPlan::once(CrashPoint::PostJournal));
    h.grow_from_leaf(&spec).expect("grant descends");
    // ...but the leaf's half of the restart handshake dies mid-reconcile
    h.set_crash_plan(leaf, CrashPlan::once(CrashPoint::MidReconcile));
    let restart = h.kill_and_restart_level(1).expect("restart");
    assert!(
        restart
            .reconcile_errors
            .iter()
            .any(|e| e.starts_with(code::CRASHED)),
        "the scripted mid-reconcile crash must surface: {restart:?}"
    );
    h.check_ledgers()
        .expect_err("ghost not yet cancelled: divergence persists");
    h.check_all().expect("oracle between handshake attempts");

    // retry (crash plan exhausted): same claims, same ghosts, converges
    let (_, ghosts) = h.reconcile_level(leaf).expect("retried reconcile");
    assert!(!ghosts.is_empty(), "retry must re-report the ghost");
    h.check_ledgers().expect("converged after retry");
    h.check_all().expect("oracle after convergence");
    h.shutdown();
}

/// An [`ExternalProvider`] the test keeps a handle to after the hierarchy
/// boxes it (same pattern as the chaos soak).
struct SharedProvider(Arc<Mutex<FaultyProvider<Ec2Provider>>>);

impl ExternalProvider for SharedProvider {
    fn name(&self) -> &str {
        "shared-faulty-ec2"
    }

    fn request(&mut self, spec: &JobSpec) -> Result<ExternalGrant, ProviderError> {
        self.0.lock().unwrap().request(spec)
    }

    fn release(&mut self, instance_ids: &[String]) -> Result<(), ProviderError> {
        self.0.lock().unwrap().release(instance_ids)
    }
}

/// Satellite 2: a spot reclaim racing a level crash. `FaultyProvider`'s
/// release-before-error contract means the failed burst leaves no
/// provider-side state, so the subsequent kill/restart reconciles to a
/// clean ledger with zero orphaned instances; and a SUCCESSFUL burst's
/// cloud bookkeeping survives the owner's restart via the ledger note.
#[test]
fn spot_reclaim_racing_level_crash_leaves_no_orphans() {
    let root = ClusterSpec::new("cluster", 1, 2, 16).build(&mut UidGen::new());
    let inj = FaultInjector::new(recovery_seed() ^ 0x5407, FaultRates::none());
    let provider = FaultyProvider::new(
        Ec2Provider::new(Ec2SimConfig {
            time_scale: 1e-4,
            ..Ec2SimConfig::default()
        }),
        inj.clone(),
    );
    let shared = Arc::new(Mutex::new(provider));
    let levels = vec![LevelSpec {
        boot_nodes: 1,
        link: LinkKind::InProc,
    }];
    let h = Hierarchy::build_with_external(
        root,
        &levels,
        Some(Box::new(SharedProvider(shared.clone()))),
    )
    .expect("burst hierarchy");
    h.enable_journals(4);
    let spec = JobSpec::nodes_sockets_cores(1, 2, 16);

    // the reclaim fires mid-grant; the provider released its instances
    // before surfacing the error, so the crash window holds no state
    inj.push_provider_fault(ProviderFault::Reclaim);
    let e = h.grow_from_leaf(&spec).expect_err("scripted reclaim");
    assert!(e.starts_with(code::PROVIDER_API), "want provider_api, got: {e}");
    assert!(shared.lock().unwrap().inner().live_instances().is_empty());
    for level in [1, 0] {
        let r = h.kill_and_restart_level(level).expect("restart");
        assert!(r.reconcile_errors.is_empty(), "{:?}", r.reconcile_errors);
    }
    h.check_ledgers().expect("no orphaned grants from the failed burst");
    h.check_all().expect("oracle after failed burst + restarts");

    // a clean burst, then the OWNER of the cloud grant restarts: its
    // cloud_grants bookkeeping must come back from the journal ledger
    // note, so the later shrink still releases the real instances
    let report = h.grow_from_leaf(&spec).expect("clean burst");
    assert!(!shared.lock().unwrap().inner().live_instances().is_empty());
    h.check_ledgers().expect("balanced after burst");
    let r = h.kill_and_restart_level(0).expect("owner restart");
    assert!(r.matched_live, "burst state was journaled: {r:?}");
    h.check_ledgers().expect("balanced after owner restart");
    h.shrink_from_leaf(&report.roots[0]).expect("shrink burst");
    assert!(
        shared.lock().unwrap().inner().live_instances().is_empty(),
        "restart lost the cloud grant bookkeeping: instances orphaned"
    );
    h.check_all().expect("oracle at quiescence");
    h.shutdown();
}

/// The kill/restart soak: a seeded mixed op stream where random levels are
/// killed and restarted mid-stream. After EVERY op the per-level oracle
/// holds; after every kill/restart cycle the cross-level ledger invariant
/// holds too, and the reconcile/replay counters advance.
#[test]
fn seeded_kill_restart_soak_converges_every_cycle() {
    let seed = recovery_seed() ^ 0x50AC;
    let h = chain3();
    h.enable_journals(8);
    h.set_write_shards_all(4);
    let mut rng = Rng::new(seed);
    let spec = JobSpec::nodes_sockets_cores(1, 2, 16);
    let probe = JobSpec::nodes_sockets_cores(1, 1, 8);
    let mut live_roots: Vec<String> = Vec::new();
    let mut grows_ok = 0u32;
    let mut kills = 0u32;

    for i in 0..80 {
        match rng.below(100) {
            0..=39 => {
                if let Ok(report) = h.grow_from_leaf(&spec) {
                    grows_ok += 1;
                    live_roots.extend(report.roots);
                }
            }
            40..=59 => {
                if let Some(path) = live_roots.pop() {
                    let _ = h.shrink_from_leaf(&path);
                }
            }
            60..=74 => {
                let _ = h.probe_up(&probe);
            }
            75..=89 => {
                let level = 1 + rng.below((h.depth() - 1) as u64) as usize;
                let report = h
                    .kill_and_restart_level(level)
                    .unwrap_or_else(|e| panic!("restart L{level} at op {i} (seed {seed:#x}): {e}"));
                assert!(
                    report.matched_live,
                    "clean kill must replay bit-identically at op {i} (seed {seed:#x}): {report:?}"
                );
                assert!(
                    report.reconcile_errors.is_empty(),
                    "op {i} (seed {seed:#x}): {:?}",
                    report.reconcile_errors
                );
                h.check_ledgers().unwrap_or_else(|e| {
                    panic!("ledger invariant after kill L{level} at op {i} (seed {seed:#x}): {e}")
                });
                kills += 1;
            }
            _ => {
                h.reset();
                live_roots.clear();
            }
        }
        h.check_all()
            .unwrap_or_else(|e| panic!("oracle violated at op {i} (seed {seed:#x}): {e}"));
    }

    assert!(grows_ok > 0, "soak never grew (seed {seed:#x})");
    assert!(kills > 0, "soak never killed a level (seed {seed:#x})");
    let reconciles: u64 = (1..h.depth())
        .map(|l| h.telemetry_snapshot_at(l).reconciles)
        .sum();
    assert!(
        reconciles >= kills as u64,
        "every restart reconciles at least once: {reconciles} < {kills} (seed {seed:#x})"
    );
    eprintln!(
        "recovery soak seed {seed:#x}: {grows_ok} grows, {kills} kill/restart cycles, \
         {reconciles} reconciles"
    );
    h.check_ledgers().expect("balanced at quiescence");
    h.shutdown();
}
