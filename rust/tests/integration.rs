//! Cross-module integration tests: full hierarchy + external provider
//! (Algorithm 1's top-level escalation), KubeFlux over grown graphs, the
//! XLA runtime on the EC2 decision path, and property tests over the
//! graph-editing invariants.

use fluxion::external::ec2::{Ec2Provider, Ec2SimConfig};
use fluxion::external::provider::ExternalProvider;
use fluxion::hier::{Hierarchy, LevelSpec, LinkKind};
use fluxion::jobspec::{table1_jobspec, JobSpec, ResourceReq};
use fluxion::resource::builder::{table2_graph, ClusterSpec, UidGen};
use fluxion::resource::jgf::Jgf;
use fluxion::rpc::transport::Latency;
use fluxion::sched::{PruneConfig, SchedInstance};
use fluxion::util::prop::{check, ensure};
use fluxion::util::rng::Rng;

fn small_levels(n: usize) -> Vec<LevelSpec> {
    (0..n)
        .map(|i| LevelSpec {
            boot_nodes: 1,
            link: if i == 0 {
                LinkKind::Tcp(Latency::of(100, 5.0))
            } else {
                LinkKind::InProc
            },
        })
        .collect()
}

/// Algorithm 1 lines 23–27: the top level consults the ExternalAPI when it
/// cannot match, and the cloud subgraph descends the hierarchy like any
/// parent grant.
#[test]
fn hierarchy_bursts_to_external_provider_when_exhausted() {
    // a tiny root: 2 nodes; the level below boots with 1; growing by 4
    // nodes must burst
    let root = ClusterSpec::new("cluster", 2, 2, 16).build(&mut UidGen::new());
    let provider = Ec2Provider::new(Ec2SimConfig {
        time_scale: 1e-4,
        ..Ec2SimConfig::default()
    });
    let h = Hierarchy::build_with_external(root, &small_levels(2), Some(Box::new(provider)))
        .expect("hierarchy");
    // local capacity: 1 free node at L0 -> a 4-node grow needs the cloud
    let spec = JobSpec::new(vec![ResourceReq::new("node", 4)
        .with_child(ResourceReq::new("core", 8))]);
    let report = h.grow_from_leaf(&spec).expect("burst grow");
    assert!(report.subgraph_size > 0);
    // top level reports a comms phase (the provider call) and a miss
    let l0 = report.timing_for(0).expect("L0 entry");
    assert!(!l0.match_ok, "L0 must have missed locally");
    assert!(l0.comms_s > 0.0, "provider call time recorded");
    h.check_all().expect("consistent after burst");
    h.shutdown();
}

#[test]
fn grown_cloud_resources_are_schedulable_at_leaf() {
    let root = ClusterSpec::new("cluster", 2, 2, 16).build(&mut UidGen::new());
    let provider = Ec2Provider::new(Ec2SimConfig {
        time_scale: 1e-4,
        ..Ec2SimConfig::default()
    });
    let h = Hierarchy::build_with_external(root, &small_levels(1), Some(Box::new(provider)))
        .expect("hierarchy");
    let spec = JobSpec::new(vec![ResourceReq::new("node", 2)
        .with_child(ResourceReq::new("core", 4))]);
    let before = h.graph_size(1);
    let report = h.grow_from_leaf(&spec).expect("grow via cloud");
    assert_eq!(h.graph_size(1), before + report.subgraph_size);
    h.shutdown();
}

/// A five-level hierarchy across a real TCP link carrying JGF grants: the
/// wire format and the graph edits agree end to end.
#[test]
fn five_level_tcp_hierarchy_t_series() {
    let root = table2_graph(0, &mut UidGen::new());
    let levels = vec![
        LevelSpec {
            boot_nodes: 8,
            link: LinkKind::Tcp(Latency::of(200, 10.0)),
        },
        LevelSpec {
            boot_nodes: 4,
            link: LinkKind::InProc,
        },
        LevelSpec {
            boot_nodes: 2,
            link: LinkKind::InProc,
        },
        LevelSpec {
            boot_nodes: 1,
            link: LinkKind::InProc,
        },
    ];
    let h = Hierarchy::build(root, &levels).expect("hierarchy");
    for test in ["T8", "T7", "T6"] {
        let report = h.grow_from_leaf(&table1_jobspec(test)).expect(test);
        assert_eq!(report.levels.len(), 5, "{test}");
        h.reset();
    }
    h.check_all().expect("consistent");
    h.shutdown();
}

/// Property: JGF round-trips over the wire form for arbitrary cluster
/// shapes and arbitrary matched selections.
#[test]
fn prop_jgf_roundtrip_arbitrary_clusters() {
    check(
        0xA11CE,
        40,
        8,
        |rng: &mut Rng, size: usize| {
            let nodes = 1 + rng.below(size as u64 + 1) as usize;
            let sockets = 1 + rng.below(3) as usize;
            let cores = 1 + rng.below(8) as usize;
            (nodes, sockets, cores)
        },
        |&(nodes, sockets, cores)| {
            let g = ClusterSpec::new("c", nodes, sockets, cores).build(&mut UidGen::new());
            let jgf = Jgf::from_graph(&g);
            let round = Jgf::parse(&jgf.dump()).map_err(|e| e.to_string())?;
            ensure(round == jgf, "JGF wire roundtrip")?;
            let rebuilt = round.build_graph(true).map_err(|e| e.to_string())?;
            ensure(
                rebuilt.num_vertices() == g.num_vertices()
                    && rebuilt.num_edges() == g.num_edges(),
                "rebuild preserves size",
            )
        },
    );
}

/// Property: allocate→grow→free conserves capacity for arbitrary request
/// sequences (no over-allocation, full restoration).
#[test]
fn prop_allocation_conservation() {
    check(
        0xBEEF,
        30,
        6,
        |rng: &mut Rng, size: usize| {
            let reqs: Vec<(u64, u64)> = (0..1 + rng.below(size as u64 + 1))
                .map(|_| (1 + rng.below(3), 1 + rng.below(8)))
                .collect();
            reqs
        },
        |reqs| {
            let mut inst = SchedInstance::new(
                ClusterSpec::new("c", 8, 2, 8).build(&mut UidGen::new()),
                PruneConfig::default(),
            );
            let free0 = {
                let root = inst.graph.root().unwrap();
                inst.prune
                    .free_at(&inst.graph, root, &fluxion::resource::ResourceType::Core)
            };
            let mut jobs = Vec::new();
            for &(nodes, cores) in reqs {
                let spec = JobSpec::new(vec![ResourceReq::new("node", nodes)
                    .with_child(ResourceReq::new("core", cores))]);
                if let Ok(out) = inst.match_allocate(&spec) {
                    jobs.push(out.job);
                }
            }
            inst.check().map_err(|e| e.to_string())?;
            for job in jobs {
                inst.free_job(job).map_err(|e| e.to_string())?;
            }
            let free1 = {
                let root = inst.graph.root().unwrap();
                inst.prune
                    .free_at(&inst.graph, root, &fluxion::resource::ResourceType::Core)
            };
            ensure(free0 == free1, "capacity restored after free")?;
            inst.check().map_err(|e| e.to_string())
        },
    );
}

/// Property: add_subgraph ∘ remove_subgraph is the identity on graph size
/// and aggregates, for arbitrary grant shapes.
#[test]
fn prop_grow_shrink_identity() {
    check(
        0xD1CE,
        30,
        6,
        |rng: &mut Rng, size: usize| {
            (
                1 + rng.below(size as u64 + 1), // granted nodes
                1 + rng.below(2),               // sockets
                1 + rng.below(8),               // cores
            )
        },
        |&(nodes, sockets, cores)| {
            let mut uids = UidGen::new();
            let donor = ClusterSpec::new("c", nodes as usize + 2, sockets as usize, cores as usize)
                .build(&mut uids);
            let mut inst = SchedInstance::new(
                ClusterSpec::new("c", 2, sockets as usize, cores as usize)
                    .with_node_base(100)
                    .build(&mut uids),
                PruneConfig::default(),
            );
            let mut donor_inst = SchedInstance::new(donor, PruneConfig::default());
            let m = donor_inst
                .match_only(&JobSpec::nodes_sockets_cores(nodes, sockets, cores))
                .map_err(|e| e.to_string())?;
            let jgf = Jgf::from_selection_closed(&donor_inst.graph, &m.selection);

            let size0 = inst.graph.size();
            let (report, _) = inst.accept_grant(&jgf, None).map_err(|e| e.to_string())?;
            ensure(!report.added.is_empty(), "something added")?;
            inst.check().map_err(|e| e.to_string())?;
            // remove every added attach root bottom-up
            let roots: Vec<String> = report
                .added
                .iter()
                .filter(|&&v| {
                    inst.graph
                        .parent_of(v)
                        .map(|p| !report.added.contains(&p))
                        .unwrap_or(true)
                })
                .map(|&v| inst.graph.vertex(v).path.clone())
                .collect();
            for r in roots {
                inst.remove_subgraph(&r).map_err(|e| e.to_string())?;
            }
            ensure(inst.graph.size() == size0, "size restored")?;
            inst.check().map_err(|e| e.to_string())
        },
    );
}

/// The XLA selector drives a real provider decision identically to the
/// native selector (skipped without artifacts).
#[test]
fn xla_selector_in_provider_pipeline() {
    if !fluxion::runtime::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let run = |use_xla: bool| -> Vec<String> {
        let mut p = Ec2Provider::new(Ec2SimConfig {
            time_scale: 1e-5,
            ..Ec2SimConfig::default()
        });
        if use_xla {
            p = p.with_selector(Box::new(
                fluxion::runtime::scorer::XlaSelector::load().expect("artifact"),
            ));
        }
        let spec = JobSpec::new(vec![ResourceReq::new("node", 3)
            .with_child(ResourceReq::new("core", 4))
            .with_child(ResourceReq::new("memory", 8))]);
        p.request(&spec).expect("feasible");
        p.live_instances().iter().map(|i| i.itype.name.to_string()).collect()
    };
    assert_eq!(run(true), run(false), "XLA and native selector must agree");
}

/// Failure injection: a provider that errors must not corrupt the
/// hierarchy.
#[test]
fn failing_provider_leaves_hierarchy_consistent() {
    struct Broken;
    impl ExternalProvider for Broken {
        fn name(&self) -> &str {
            "broken"
        }
        fn request(
            &mut self,
            _: &JobSpec,
        ) -> Result<fluxion::external::ExternalGrant, fluxion::external::ProviderError> {
            Err(fluxion::external::ProviderError::Api("cloud is down".into()))
        }
        fn release(
            &mut self,
            _: &[String],
        ) -> Result<(), fluxion::external::ProviderError> {
            Ok(())
        }
    }
    let root = ClusterSpec::new("cluster", 1, 2, 16).build(&mut UidGen::new());
    let h = Hierarchy::build_with_external(root, &small_levels(1), Some(Box::new(Broken)))
        .expect("hierarchy");
    let spec = JobSpec::new(vec![ResourceReq::new("node", 5)
        .with_child(ResourceReq::new("core", 8))]);
    let err = h.grow_from_leaf(&spec).unwrap_err();
    assert!(err.contains("cloud is down"), "{err}");
    h.check_all().expect("no corruption after provider failure");
    // and the hierarchy still serves satisfiable requests... none exist
    // locally (1 node, fully allocated), so a second failure is also clean
    assert!(h.grow_from_leaf(&spec).is_err());
    h.shutdown();
}

/// §3 subtractive transformation: a grow followed by a shrink restores
/// every level's graph, ascending bottom-up through real RPC.
#[test]
fn hierarchical_shrink_restores_all_levels() {
    let root = table2_graph(0, &mut UidGen::new());
    let levels = vec![
        LevelSpec {
            boot_nodes: 2,
            link: LinkKind::Tcp(Latency::of(100, 5.0)),
        },
        LevelSpec {
            boot_nodes: 1,
            link: LinkKind::InProc,
        },
    ];
    let h = Hierarchy::build(root, &levels).expect("hierarchy");
    let sizes: Vec<usize> = (0..h.depth()).map(|l| h.graph_size(l)).collect();

    let report = h.grow_from_leaf(&table1_jobspec("T7")).expect("grow");
    // the grant landed at every level below the owner
    assert_eq!(h.graph_size(2), sizes[2] + report.subgraph_size);
    assert_eq!(report.roots.len(), 1, "T7 grants one node subtree");

    let removed = h
        .shrink_from_leaf(&report.roots[0])
        .expect("hierarchical shrink");
    assert_eq!(removed, 35, "T7 grant = 35 vertices at the leaf");
    // levels that dynamically added the grant returned to their pre-grow
    // sizes; the owner (L0) keeps its physical inventory
    for (l, &before) in sizes.iter().enumerate() {
        assert_eq!(h.graph_size(l), before, "level {l}");
    }
    h.check_all().expect("consistent after shrink");
    // and the freed capacity at L0 is matchable again: grow the same
    // request a second time
    h.grow_from_leaf(&table1_jobspec("T7")).expect("regrow");
    h.check_all().expect("consistent after regrow");
    h.shutdown();
}

/// §3 per-user external specialization: a nested level with its own
/// provider bursts independently; the top level never sees the resources,
/// and shrinking releases the instances at that level.
#[test]
fn per_user_specialization_is_independent_of_top_level() {
    let root = ClusterSpec::new("cluster", 2, 2, 16).build(&mut UidGen::new());
    let h = Hierarchy::build(root, &small_levels(2)).expect("hierarchy");
    // the *leaf* gets its own provider (e.g. its own AWS account)
    h.set_external(
        2,
        Box::new(Ec2Provider::new(Ec2SimConfig {
            time_scale: 1e-4,
            ..Ec2SimConfig::default()
        })),
    );
    let l0_before = h.graph_size(0);
    let l1_before = h.graph_size(1);
    let leaf_before = h.graph_size(2);

    // leaf is fully allocated; this grow bursts through the leaf's own
    // provider WITHOUT consulting the parent
    let spec = JobSpec::new(vec![ResourceReq::new("node", 2)
        .with_child(ResourceReq::new("core", 4))]);
    let report = h.grow_from_leaf(&spec).expect("specialized burst");
    assert_eq!(report.levels.len(), 1, "no ancestor participated");
    assert_eq!(h.graph_size(0), l0_before, "G_0 untouched (E_i = G_i \\ G_0)");
    assert_eq!(h.graph_size(1), l1_before);
    assert!(h.graph_size(2) > leaf_before);
    h.check_all().expect("consistent");
    h.shutdown();
}
