//! Matcher correctness against independent oracles, plus aggregate
//! consistency under mixed dynamic sequences — the safety net under the
//! zero-allocation hot-path refactor (interned types, slot-indexed
//! aggregates, reusable match scratch).

use std::collections::HashSet;

use fluxion::jobspec::{JobSpec, ResourceReq};
use fluxion::resource::builder::{ClusterSpec, UidGen};
use fluxion::resource::graph::{ResourceGraph, VertexId};
use fluxion::resource::jgf::Jgf;
use fluxion::resource::ResourceType;
use fluxion::sched::{match_resources, match_resources_sharded, PruneConfig, SchedInstance};
use fluxion::util::rng::Rng;

// ---- brute-force oracle ---------------------------------------------------
//
// An independent exhaustive search: no pruning aggregates, no interned
// types, full backtracking over every candidate combination. Restricted to
// chain-shaped requests (each level has at most one nested request), where
// candidate subtrees are disjoint and the search below is complete.

/// All candidate vertices of `tname` reachable from `scope` by descending
/// through other-typed vertices (the matcher's candidate semantics).
fn oracle_candidates(g: &ResourceGraph, scope: VertexId, tname: &str, out: &mut Vec<VertexId>) {
    for &c in g.children_of(scope) {
        if g.type_name(c) == tname {
            out.push(c);
        } else {
            oracle_candidates(g, c, tname, out);
        }
    }
}

fn oracle_sat_req(
    g: &ResourceGraph,
    taken: &mut HashSet<VertexId>,
    trail: &mut Vec<VertexId>,
    scope: VertexId,
    req: &ResourceReq,
) -> bool {
    assert!(req.with.len() <= 1, "oracle handles chain specs only");
    let mut cands = Vec::new();
    oracle_candidates(g, scope, &req.rtype, &mut cands);
    oracle_choose(g, taken, trail, &cands, 0, req.count, req)
}

/// Pick `remaining` satisfiable candidates out of `cands[i..]`, trying both
/// taking and skipping each (complete search over subsets).
fn oracle_choose(
    g: &ResourceGraph,
    taken: &mut HashSet<VertexId>,
    trail: &mut Vec<VertexId>,
    cands: &[VertexId],
    i: usize,
    remaining: u64,
    req: &ResourceReq,
) -> bool {
    if remaining == 0 {
        return true;
    }
    if i >= cands.len() {
        return false;
    }
    let c = cands[i];
    let free = !g.vertex(c).alloc.is_allocated() && !taken.contains(&c);
    if !req.exclusive || free {
        let mark = trail.len();
        if req.exclusive {
            taken.insert(c);
            trail.push(c);
        }
        let mut ok = true;
        for sub in &req.with {
            if !oracle_sat_req(g, taken, trail, c, sub) {
                ok = false;
                break;
            }
        }
        if ok && oracle_choose(g, taken, trail, cands, i + 1, remaining - 1, req) {
            return true;
        }
        for v in trail.drain(mark..) {
            taken.remove(&v);
        }
    }
    oracle_choose(g, taken, trail, cands, i + 1, remaining, req)
}

fn oracle_feasible(g: &ResourceGraph, spec: &JobSpec) -> bool {
    let Some(root) = g.root() else { return false };
    let mut taken = HashSet::new();
    let mut trail = Vec::new();
    spec.resources
        .iter()
        .all(|req| oracle_sat_req(g, &mut taken, &mut trail, root, req))
}

/// Sanity-check a successful selection: free vertices, per-type counts
/// matching the spec's totals.
fn assert_selection_valid(g: &ResourceGraph, spec: &JobSpec, selection: &[VertexId]) {
    let mut seen = HashSet::new();
    for &v in selection {
        assert!(!g.vertex(v).alloc.is_allocated(), "selected allocated vertex");
        assert!(seen.insert(v), "vertex selected twice");
    }
    for tname in ["node", "socket", "core"] {
        let want = spec.total_of(tname);
        let got = selection
            .iter()
            .filter(|&&v| g.type_name(v) == tname)
            .count() as u64;
        assert_eq!(got, want, "selection {tname} count");
    }
}

/// Matcher (pruned and unpruned) agrees with the exhaustive oracle on small
/// random graphs with random pre-allocations.
#[test]
fn matcher_agrees_with_bruteforce_oracle() {
    let mut rng = Rng::new(0x04AC1E ^ 0xF00D);
    for round in 0..60 {
        let nodes = 1 + rng.below(3) as usize;
        let sockets = 1 + rng.below(2) as usize;
        let cores = 1 + rng.below(4) as usize;
        let mut g = ClusterSpec::new("c", nodes, sockets, cores).build(&mut UidGen::new());
        let cfg = PruneConfig::default();
        fluxion::sched::pruning::init_aggregates(&mut g, &cfg);

        // randomly pre-allocate some cores (each its own job)
        let mut table = fluxion::sched::AllocTable::new();
        let all_cores: Vec<VertexId> = g
            .iter_live()
            .filter(|&v| g.type_name(v) == "core")
            .collect();
        let k = rng.below(all_cores.len() as u64 + 1) as usize;
        let picks = rng.sample_indices(all_cores.len(), k);
        let victims: Vec<VertexId> = picks.iter().map(|&i| all_cores[i]).collect();
        if !victims.is_empty() {
            table.allocate(&mut g, &cfg, victims).unwrap();
        }

        // random chain spec: nodes{sockets{cores}} with 0 meaning "start
        // lower in the chain" (T8-style socket-rooted requests)
        let spec = JobSpec::nodes_sockets_cores(
            rng.below(nodes as u64 + 2),
            1 + rng.below(sockets as u64 + 1),
            1 + rng.below(cores as u64 + 1),
        );

        let want = oracle_feasible(&g, &spec);
        let pruned = match_resources(&g, &cfg, &spec);
        let unpruned = match_resources(&g, &PruneConfig { tracked: vec![] }, &spec);
        assert_eq!(
            pruned.is_ok(),
            want,
            "round {round}: pruned matcher disagrees with oracle \
             ({nodes}x{sockets}x{cores}, spec {})",
            spec.dump()
        );
        assert_eq!(
            unpruned.is_ok(),
            want,
            "round {round}: unpruned matcher disagrees with oracle"
        );
        if let (Ok(a), Ok(b)) = (&pruned, &unpruned) {
            assert_eq!(a.selection, b.selection, "pruning changed the selection");
            assert_selection_valid(&g, &spec, &a.selection);
        }
        fluxion::sched::pruning::check_aggregates(&g, &cfg).unwrap();
    }
}

// ---- sharded-vs-sequential selection equality -------------------------------

/// The sharded scan's selection is bit-identical to the sequential scan's
/// on random graphs with random pre-allocations, for shard widths below,
/// at, and above the root's child count (K > children exercises range
/// clamping; allocation-saturated subtrees exercise empty shards that
/// contribute zero candidates). K = 1 is the explicit sequential bail.
#[test]
fn sharded_selection_equals_sequential_on_random_graphs() {
    let mut rng = Rng::new(0x5AAD ^ 0xF00D);
    for round in 0..60 {
        let nodes = 1 + rng.below(6) as usize;
        let sockets = 1 + rng.below(3) as usize;
        let cores = 1 + rng.below(4) as usize;
        let mut g = ClusterSpec::new("c", nodes, sockets, cores).build(&mut UidGen::new());
        let cfg = PruneConfig::default();
        fluxion::sched::pruning::init_aggregates(&mut g, &cfg);

        // random pre-allocations, node-heavy so whole subtrees go empty
        let mut table = fluxion::sched::AllocTable::new();
        let all_cores: Vec<VertexId> = g
            .iter_live()
            .filter(|&v| g.type_name(v) == "core")
            .collect();
        let k = rng.below(all_cores.len() as u64 + 1) as usize;
        let picks = rng.sample_indices(all_cores.len(), k);
        let victims: Vec<VertexId> = picks.iter().map(|&i| all_cores[i]).collect();
        if !victims.is_empty() {
            table.allocate(&mut g, &cfg, victims).unwrap();
        }

        let spec = JobSpec::nodes_sockets_cores(
            rng.below(nodes as u64 + 2),
            1 + rng.below(sockets as u64 + 1),
            1 + rng.below(cores as u64 + 1),
        );
        let seq = match_resources(&g, &cfg, &spec);
        for shards in [1usize, 2, 4, 7] {
            let sharded = match_resources_sharded(&g, &cfg, &spec, shards);
            match (&seq, &sharded) {
                (Ok(a), Ok(b)) => assert_eq!(
                    a.selection,
                    b.selection,
                    "round {round} K {shards} ({nodes}x{sockets}x{cores}, spec {})",
                    spec.dump()
                ),
                (Err(_), Err(_)) => {}
                _ => panic!(
                    "round {round} K {shards}: feasibility diverged for {}",
                    spec.dump()
                ),
            }
        }
        fluxion::sched::pruning::check_aggregates(&g, &cfg).unwrap();
    }
}

/// Targeted empty-shard coverage: with whole node subtrees saturated, the
/// shards covering them contribute zero candidates and the merge must pull
/// everything from the shard holding the free tail — still bit-identical,
/// including when K exceeds the root's child count.
#[test]
fn sharded_selection_survives_empty_and_clamped_shards() {
    let mut g = ClusterSpec::new("c", 3, 2, 4).build(&mut UidGen::new());
    let cfg = PruneConfig::default();
    fluxion::sched::pruning::init_aggregates(&mut g, &cfg);
    let mut table = fluxion::sched::AllocTable::new();
    // saturate node0 and node1 entirely: their shards are empty of candidates
    for n in 0..2 {
        let sub = g.dfs(g.lookup_path(&format!("/c0/node{n}")).unwrap());
        table.allocate(&mut g, &cfg, sub).unwrap();
    }
    for spec in [
        JobSpec::nodes_sockets_cores(1, 2, 4),
        JobSpec::nodes_sockets_cores(0, 2, 4), // socket-rooted (T8 shape)
        JobSpec::nodes_sockets_cores(2, 1, 1), // needs 2 nodes: infeasible
    ] {
        let seq = match_resources(&g, &cfg, &spec);
        for shards in [2usize, 3, 7, 64] {
            let sharded = match_resources_sharded(&g, &cfg, &spec, shards);
            match (&seq, &sharded) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.selection, b.selection, "K {shards} spec {}", spec.dump())
                }
                (Err(_), Err(_)) => {}
                _ => panic!("K {shards}: feasibility diverged for {}", spec.dump()),
            }
        }
    }
}

/// Non-exclusive (shared-scope) and multi-top-level-request specs through
/// the sharded path: the merged selection of request r seeds request r+1's
/// shard scans, and shared candidates contribute scope, not selection.
#[test]
fn sharded_selection_handles_shared_and_multi_request_specs() {
    let mut g = ClusterSpec::new("c", 4, 2, 4).build(&mut UidGen::new());
    let cfg = PruneConfig::default();
    fluxion::sched::pruning::init_aggregates(&mut g, &cfg);
    let shared_spec = JobSpec::new(vec![ResourceReq::new("node", 2)
        .shared()
        .with_child(ResourceReq::new("socket", 1).with_child(ResourceReq::new("core", 2)))]);
    let multi_spec = JobSpec::new(vec![
        ResourceReq::new("node", 1)
            .with_child(ResourceReq::new("socket", 2).with_child(ResourceReq::new("core", 4))),
        ResourceReq::new("node", 2)
            .with_child(ResourceReq::new("socket", 1).with_child(ResourceReq::new("core", 1))),
    ]);
    for spec in [shared_spec, multi_spec] {
        // (no assert_selection_valid here: its per-type totals assume
        // exclusive requests, and the first spec's nodes are scope-only)
        let seq = match_resources(&g, &cfg, &spec).unwrap();
        for shards in [2usize, 3, 4, 9] {
            let sharded = match_resources_sharded(&g, &cfg, &spec, shards).unwrap();
            assert_eq!(
                seq.selection,
                sharded.selection,
                "K {shards} spec {}",
                spec.dump()
            );
        }
    }
}

// ---- mixed dynamic sequences ----------------------------------------------

/// A donor instance that mints chain-shaped grants for the subject.
fn mint_grant(donor: &mut SchedInstance, nodes: u64) -> Option<Jgf> {
    let spec = JobSpec::nodes_sockets_cores(nodes, 2, 4);
    let m = donor.match_only(&spec).ok()?;
    let jgf = Jgf::from_selection_closed(&donor.graph, &m.selection);
    // mark them used donor-side so successive grants are disjoint
    let prune = donor.prune.clone();
    donor
        .allocs
        .allocate(&mut donor.graph, &prune, m.selection)
        .unwrap();
    Some(jgf)
}

/// Aggregates and invariants stay exact under random interleavings of
/// allocate / grow(accept_grant) / shrink(release_subtree) / free /
/// re-match, with the instance's reusable scratch live the whole time.
#[test]
fn aggregates_consistent_under_mixed_sequences() {
    for seed in [1u64, 7, 42, 1234] {
        let mut rng = Rng::new(seed);
        let mut uids = UidGen::new();
        // donor owns nodes 100.. of the same namespace; subject owns 0..2
        let mut donor = SchedInstance::new(
            ClusterSpec::new("c", 8, 2, 4).with_node_base(100).build(&mut uids),
            PruneConfig::default(),
        );
        let mut inst = SchedInstance::new(
            ClusterSpec::new("c", 2, 2, 4).build(&mut uids),
            PruneConfig::default(),
        );
        let mut jobs: Vec<fluxion::resource::graph::JobId> = Vec::new();
        let mut grant_roots: Vec<String> = Vec::new();

        for _ in 0..40 {
            match rng.below(5) {
                // allocate a small job
                0 => {
                    let spec = JobSpec::nodes_sockets_cores(
                        rng.below(2),
                        1 + rng.below(2),
                        1 + rng.below(4),
                    );
                    if let Ok(out) = inst.match_allocate(&spec) {
                        jobs.push(out.job);
                    }
                }
                // grow: splice a donor grant, sometimes into a running job
                1 => {
                    if let Some(jgf) = mint_grant(&mut donor, 1 + rng.below(2)) {
                        let job = if !jobs.is_empty() && rng.bool_with(0.5) {
                            Some(jobs[rng.below(jobs.len() as u64) as usize])
                        } else {
                            None
                        };
                        let (report, _) = inst.accept_grant(&jgf, job).unwrap();
                        // record attach roots for later shrinks
                        let added: HashSet<VertexId> =
                            report.added.iter().copied().collect();
                        for &v in &report.added {
                            let is_root = inst
                                .graph
                                .parent_of(v)
                                .map(|p| !added.contains(&p))
                                .unwrap_or(true);
                            if is_root {
                                grant_roots.push(inst.graph.vertex(v).path.clone());
                            }
                        }
                    }
                }
                // shrink: release + detach one granted subtree
                2 => {
                    if !grant_roots.is_empty() {
                        let i = rng.below(grant_roots.len() as u64) as usize;
                        let path = grant_roots.swap_remove(i);
                        if inst.graph.lookup_path(&path).is_some() {
                            inst.release_subtree(&path).unwrap();
                        }
                    }
                }
                // free a running job (vertices may be partially shrunk away)
                3 => {
                    if !jobs.is_empty() {
                        let i = rng.below(jobs.len() as u64) as usize;
                        let job = jobs.swap_remove(i);
                        inst.free_job(job).unwrap();
                    }
                }
                // re-match probe through the reused scratch
                _ => {
                    let _ = inst.match_only(&JobSpec::nodes_sockets_cores(1, 2, 4));
                }
            }
            inst.check().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            inst.graph
                .check_invariants()
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        }

        // drain: free everything, shrink remaining grants, verify the
        // subject ends consistent and fully free
        for job in jobs.drain(..) {
            let _ = inst.free_job(job);
        }
        for path in grant_roots.drain(..) {
            if inst.graph.lookup_path(&path).is_some() {
                inst.release_subtree(&path).unwrap();
            }
        }
        inst.check().unwrap();
        let root = inst.graph.root().unwrap();
        let free = inst.prune.free_at(&inst.graph, root, &ResourceType::Core);
        let live_cores = inst
            .graph
            .iter_live()
            .filter(|&v| inst.graph.type_name(v) == "core")
            .count() as i64;
        assert_eq!(free, live_cores, "seed {seed}: every remaining core free");
    }
}

/// The end-to-end zero-allocation criterion from the issue: 100 matches
/// against one instance leave the scratch footprint exactly as warmed.
#[test]
fn scratch_footprint_stable_over_100_matches() {
    let mut inst = SchedInstance::new(
        ClusterSpec::new("c", 16, 2, 16).build(&mut UidGen::new()),
        PruneConfig::default(),
    );
    let specs = [
        JobSpec::nodes_sockets_cores(4, 2, 16),
        JobSpec::nodes_sockets_cores(1, 1, 4),
        JobSpec::nodes_sockets_cores(0, 1, 16),
    ];
    // warm with the largest request shape
    for spec in &specs {
        inst.match_only(spec).unwrap();
    }
    let warm = inst.scratch_footprint();
    for i in 0..100 {
        inst.match_only(&specs[i % specs.len()]).unwrap();
    }
    assert_eq!(inst.scratch_footprint(), warm);
}
