//! Fully hierarchical scheduling: the paper's central runtime.
//!
//! A hierarchy is a chain of scheduler instances (`L0` at the top), each
//! holding a resource graph that is a subgraph of its parent's
//! (`G_c ⊆ G_p`, §3). Children boot by issuing a `MatchAllocate` to their
//! parent and instantiating their graph from the returned JGF — "each
//! instance initializes its resource graph with only those resources within
//! its purview".
//!
//! [`Hierarchy::grow_from_leaf`] implements Algorithm 1's bottom-up /
//! top-down `MatchGrow`: the leaf tries a local match; on failure the
//! request ascends parent links (RPC) until a level matches (or the
//! top-level consults its [`ExternalProvider`]); the granted subgraph then
//! descends, each level splicing it via `AddSubgraph` + `UpdateMetadata`
//! and handing the new vertices to the child's allocation.
//!
//! Transports model the paper's testbed: L1↔L0 crosses nodes (TCP with
//! injected IPoIB-like latency); deeper pairs share a node (in-proc).
//!
//! §Concurrency: each level's instance lives inside a [`SchedService`] —
//! the read/write-partitioned concurrent server. The RPC handler routes
//! **read-only** ops ([`SchedOp::is_read_only`], i.e. `probe`) straight to
//! the service's cached concurrent read path *without taking the node
//! mutex*, so capacity queries are served in parallel with (and never
//! blocked behind) a slow hierarchical `MatchGrow` holding the node lock.
//! Mutating ops keep the per-node mutex: they interact with the node's
//! grant/burst bookkeeping (`added_roots`, `cloud_grants`), which must
//! stay consistent with the instance.
//!
//! §Fault tolerance: every parent link is built with a [`LinkPolicy`] —
//! per-call deadline, bounded retry ([`crate::fault::RetryConn`], read-only
//! ops only), and a quarantine [`CircuitBreaker`]: a link that repeatedly
//! times out or disconnects is refused outright with a structured
//! [`code::LEVEL_UNAVAILABLE`] error (no hanging on a link known bad) until
//! a cooldown elapses and a half-open trial probe ([`Hierarchy::maintain`])
//! restores it. [`Hierarchy::probe_up`] routes feasibility probes around
//! quarantined levels. Mutating handlers run under `catch_unwind` so a
//! panicking op answers with a typed [`code::PANIC`] error instead of
//! poisoning the node mutex (the internal `lock_node` helper tolerates the
//! poison either way). [`LinkPolicy::chaos`] threads deterministic fault injection
//! through every link for soak tests.
//!
//! §Crash recovery (PR 10): with [`Hierarchy::enable_journals`] every
//! level write-ahead journals its mutations ([`crate::sched::journal`])
//! and records its **grant ledger** — the attach roots it granted to its
//! child (`granted_roots`) and the roots it holds from its parent
//! (`boot_roots` + `added_roots`) — as durable journal notes. A killed
//! level ([`Hierarchy::kill_and_restart_level`]) rebuilds from snapshot +
//! replay, re-registers with its parent, and runs the `Reconcile`
//! handshake: parent and child exchange ledgers; **orphaned** parent-side
//! grants (granted, never committed by the child) are released through
//! the ordinary subtractive path, **ghost** child-side subtrees (held,
//! never recorded by the parent) are cancelled. [`Hierarchy::maintain`]
//! half-open trials run the same handshake, so a level coming back from
//! quarantine re-converges its ledgers instead of just proving the link.
//! The cross-level invariant ([`Hierarchy::check_ledgers`]): on every
//! link, parent grants (boot + dynamic) = child claims, exactly.

pub mod report;

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::external::provider::ExternalProvider;
use crate::fault::{
    chaos_handler, panic_message, CircuitBreaker, CrashPlan, CrashPoint, FaultInjector,
    FaultRates, FaultyConn, RetryConn, RetryPolicy,
};
use crate::jobspec::JobSpec;
use crate::resource::graph::JobId;
use crate::resource::jgf::Jgf;
use crate::resource::ResourceGraph;
use crate::rpc::proto::{code, RpcError, SchedOp, SchedReply};
use crate::rpc::transport::{
    handler, Conn, InProcServer, Latency, TcpConn, TcpServer, DEFAULT_DEADLINE,
};
use crate::rpc::{Request, Response};
use crate::sched::{PruneConfig, SchedInstance, SchedService, SnapshotStats};
use crate::telemetry::TelemetrySnapshot;
use crate::util::json::Json;
use crate::util::metrics::Timer;

pub use report::{GrowReport, LevelTiming, RestartReport};

/// How a level talks to its parent.
#[derive(Debug, Clone, Copy)]
pub enum LinkKind {
    /// Same-node parent (paper's levels 2–4): in-process channel.
    InProc,
    /// Cross-node parent (paper's level 1 → level 0): TCP + latency.
    Tcp(Latency),
}

/// Specification of one level below the root: how many nodes it requests
/// from its parent at boot, and the link to the parent.
#[derive(Debug, Clone, Copy)]
pub struct LevelSpec {
    /// Full (2-socket × 16-core) nodes requested from the parent at boot.
    pub boot_nodes: u64,
    /// Transport of the link to the parent.
    pub link: LinkKind,
}

/// The paper's §5.2 testbed: Table 2 levels L1..L4 carved from a Table 2 L0
/// graph; L1 is remote (internode), deeper levels local.
pub fn paper_levels(internode: Latency) -> Vec<LevelSpec> {
    vec![
        LevelSpec {
            boot_nodes: 8,
            link: LinkKind::Tcp(internode),
        },
        LevelSpec {
            boot_nodes: 4,
            link: LinkKind::InProc,
        },
        LevelSpec {
            boot_nodes: 2,
            link: LinkKind::InProc,
        },
        LevelSpec {
            boot_nodes: 1,
            link: LinkKind::InProc,
        },
    ]
}

/// Deterministic fault injection for a hierarchy's links: one master seed
/// from which every link derives an independent client-side and
/// server-side [`FaultInjector`] stream (same config ⇒ byte-for-byte the
/// same fault schedule, the chaos soak's reproducibility contract).
#[derive(Debug, Clone, Copy)]
pub struct ChaosConfig {
    /// Master seed; link `l` draws from seeds `seed ^ (2l+1)` (client) and
    /// `seed ^ (2l+2)` (server).
    pub seed: u64,
    /// Rates for the client-side injectors ([`FaultyConn`] wrapping each
    /// parent connection). Client-side drops fail *instantly* with a
    /// timeout, keeping soak schedules independent of wall-clock timing.
    pub client_rates: FaultRates,
    /// Rates for the server-side injectors ([`chaos_handler`] wrapping each
    /// level's handler). Server-side drops stall the handler for
    /// [`ChaosConfig::stall`], exercising the client's *real* read-timeout
    /// machinery — at the cost of timing-dependent schedules.
    pub server_rates: FaultRates,
    /// How long a server-side `Drop` stalls (set it beyond the link
    /// deadline so the client actually times out).
    pub stall: Duration,
}

impl ChaosConfig {
    /// Client-side-only injection — the deterministic configuration chaos
    /// soaks use (server rates zero, so no real stalls ever overlap ops).
    pub fn client_only(seed: u64, rates: FaultRates) -> ChaosConfig {
        ChaosConfig {
            seed,
            client_rates: rates,
            server_rates: FaultRates::none(),
            stall: Duration::from_millis(50),
        }
    }
}

/// Fault-tolerance policy applied to every parent link when a hierarchy is
/// built ([`Hierarchy::build_with_policy`]). The default is what
/// [`Hierarchy::build`] uses: 5 s deadline, 3 read-only retry attempts
/// with exponential backoff, quarantine after 3 consecutive link failures
/// with a 250 ms half-open cooldown, no fault injection.
#[derive(Debug, Clone)]
pub struct LinkPolicy {
    /// Per-call deadline budget on parent links (`None` = block forever,
    /// the pre-fault-tolerance behavior).
    pub deadline: Option<Duration>,
    /// Bounded-retry policy wrapped around every parent connection
    /// (read-only ops only — see [`RetryConn`] on at-most-once semantics).
    pub retry: RetryPolicy,
    /// Consecutive transport failures before a parent link is quarantined.
    pub breaker_threshold: u32,
    /// Cooldown before a quarantined link half-opens for a trial call.
    pub breaker_cooldown: Duration,
    /// Optional deterministic fault injection on every link.
    pub chaos: Option<ChaosConfig>,
}

impl Default for LinkPolicy {
    fn default() -> LinkPolicy {
        LinkPolicy {
            deadline: Some(DEFAULT_DEADLINE),
            retry: RetryPolicy::default(),
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            chaos: None,
        }
    }
}

/// The structured refusal a quarantined parent link answers with.
fn level_unavailable(level: usize, breaker: &CircuitBreaker) -> RpcError {
    let hint = breaker
        .retry_in()
        .map(|d| format!("; half-open re-probe in ~{}ms", d.as_millis()))
        .unwrap_or_default();
    RpcError::new(
        code::LEVEL_UNAVAILABLE,
        format!("level {level}: parent link quarantined (breaker open{hint})"),
    )
}

/// Poison-tolerant node lock. A panic that unwound while a transport
/// thread held the node mutex is already contained into a typed reply by
/// `node_handler`; the poison flag it leaves must not turn every later op
/// into a second panic — the instance beneath has its own rollback
/// protection ([`crate::sched::SchedService::mutate_contained`] semantics).
fn lock_node(node: &Mutex<NodeState>) -> std::sync::MutexGuard<'_, NodeState> {
    node.lock().unwrap_or_else(|e| e.into_inner())
}

/// Mutable state of one hierarchy node.
struct NodeState {
    level: usize,
    /// The level's scheduler instance behind its concurrent serving layer.
    /// Probes go through the service's cached read path (also reachable
    /// WITHOUT the node mutex — see `node_handler`); mutations take its
    /// write side.
    inst: SchedService,
    /// Connection to the parent (None at L0).
    parent: Option<Box<dyn Conn>>,
    /// Parent-side job id representing THIS node's child instance: grants
    /// descending through this node are charged to that job.
    child_job: Option<JobId>,
    /// The leaf's own running job that grows.
    own_job: Option<JobId>,
    /// External provider consulted when the local match fails. At the top
    /// level this is Algorithm 1 lines 23–27; at a *nested* level it is the
    /// paper's **external resource specialization** (§3): "external
    /// resources E_i are managed by a first-level allocation G_i
    /// independent of the top-level scheduler" — the additive transform is
    /// allowed to invalidate the supergraph inclusion sequence, so burst
    /// resources never ascend past this node.
    external: Option<Box<dyn ExternalProvider>>,
    /// Snapshot for experiment reinitialization.
    snapshot: Option<(ResourceGraph, crate::sched::AllocTable)>,
    /// Attach-root paths of subgraphs this node *dynamically added* (grants
    /// that descended through it). A shrink deletes vertices at these
    /// levels; at the owner level (which matched from its own graph) it
    /// only frees the allocation — physical resources are not deleted.
    added_roots: std::collections::HashSet<String>,
    /// Burst subgraphs this node obtained from ITS provider: attach-root
    /// path -> provider instance ids. A shrink that reaches one of these
    /// roots releases the instances here and stops ascending (the
    /// supergraph never contained them — per-user specialization, §3).
    cloud_grants: Vec<(String, Vec<String>)>,
    /// Quarantine breaker guarding this node's PARENT link (idle at L0):
    /// transport failures trip it open; an open breaker refuses ascents
    /// with [`code::LEVEL_UNAVAILABLE`] until a half-open trial restores
    /// it.
    breaker: CircuitBreaker,
    /// Parent-side grant ledger: attach roots of subgraphs this node
    /// granted DOWN to its child dynamically (through the serve MatchGrow
    /// path). A successful child-initiated shrink removes its root; a
    /// `Reconcile` releases entries the child never committed (orphans).
    granted_roots: std::collections::HashSet<String>,
    /// Attach roots of the boot grant THIS node's graph was built from
    /// (empty at L0). Part of the child-side claim set in reconciliation.
    boot_roots: Vec<String>,
    /// Attach roots of the boot grant this node carved out for its child
    /// at build time (empty at the leaf). Statically granted — a child
    /// claim matching one of these is never a ghost.
    child_boot_roots: Vec<String>,
    /// Scripted crash injection for the hierarchy-level crash sites
    /// (grant splice, grant durability, mid-reconcile). Service-level op
    /// sites are armed separately via `SchedService::set_crash_plan`.
    crash_plan: CrashPlan,
}

impl NodeState {
    /// The match-or-escalate core shared by the RPC handler and the leaf
    /// driver. Returns the granted subgraph plus per-level timing entries
    /// accumulated top-down. Errors keep their structured code across
    /// levels: a parent's (or provider's) [`RpcError`] is propagated
    /// verbatim, so the leaf can still tell `provider_unsatisfiable` from a
    /// local `no_match` after any number of hops.
    fn match_grow(&mut self, spec: &JobSpec) -> Result<(Jgf, Vec<LevelTiming>), RpcError> {
        let child_job = self.child_job;
        // 1. local match attempt + allocation under one write lock (the
        //    lock is scoped so the escalation path's parent RPC below runs
        //    WITHOUT it — concurrent probes are served during the round
        //    trip)
        let local: Result<(Jgf, LevelTiming), (f64, usize)> = {
            let mut guard = self.inst.write();
            let inst = &mut *guard;
            // timer starts AFTER the lock is held: match_s is the paper's
            // match metric, not lock-contention wait behind probe traffic
            let t = Timer::start();
            let m = inst.match_only(spec);
            let match_s = t.elapsed_secs();
            match m {
                Ok(m) => {
                    // matched locally: allocate to the child's job (or a
                    // fresh one at the top when no child asked — defensive
                    // default). Closed form: missing interior ancestors
                    // ride along so a below-node-level grant (T8) can
                    // attach anywhere downstream.
                    let subgraph = Jgf::from_selection_closed(&inst.graph, &m.selection);
                    let tu = Timer::start();
                    match child_job {
                        Some(job) => {
                            inst.allocs
                                .grow(&mut inst.graph, &inst.prune, job, m.selection)
                                .map_err(|e| RpcError::new(code::GROW_FAILED, e.to_string()))?;
                        }
                        None => {
                            inst.allocs
                                .allocate(&mut inst.graph, &inst.prune, m.selection)
                                .map_err(|e| RpcError::new(code::GROW_FAILED, e.to_string()))?;
                        }
                    }
                    let timing = LevelTiming {
                        level: self.level,
                        match_s,
                        match_ok: true,
                        comms_s: 0.0,
                        add_upd_s: tu.elapsed_secs(),
                        visited: m.visited,
                    };
                    Ok((subgraph, timing))
                }
                Err(fail) => {
                    let crate::sched::MatchFail::NoMatch { visited } = fail;
                    Err((match_s, visited))
                }
            }
        };
        match local {
            Ok((subgraph, timing)) => Ok((subgraph, vec![timing])),
            Err((match_s, visited)) => {
                // 2. escalate: a specialized provider at this node wins
                //    over the parent (per-user specialization, §3);
                //    otherwise ascend; the top level falls back to its
                //    site provider. "To a scheduler instance, the external
                //    resource provider is functionally just another
                //    parent."
                let (jgf, upper_levels, comms_s) = match (&mut self.parent, &mut self.external) {
                    (_, Some(provider)) => {
                        let tc = Timer::start();
                        let grant = provider
                            .request(spec)
                            .map_err(|e| RpcError::new(e.code(), e.to_string()))?;
                        // remember which attach roots came from the cloud,
                        // so a later shrink releases the instances here
                        let roots = attach_roots(&grant.subgraph);
                        self.cloud_grants
                            .push((roots.join(","), grant.instance_ids.clone()));
                        (grant.subgraph, Vec::new(), tc.elapsed_secs())
                    }
                    (Some(conn), _) => {
                        // quarantine gate: an open breaker refuses the
                        // ascent outright — a structured error beats
                        // waiting out a deadline on a link known bad
                        if !self.breaker.admit() {
                            return Err(level_unavailable(self.level, &self.breaker));
                        }
                        let tc = Timer::start();
                        let called = conn.call(&Request::new(
                            self.level as u64,
                            SchedOp::MatchGrow { spec: spec.clone() },
                        ));
                        let resp = match called {
                            Ok(resp) => {
                                // any well-formed reply — structured errors
                                // included — proves the LINK is healthy
                                self.breaker.record_success();
                                resp
                            }
                            Err(e) => {
                                let trips = self.breaker.trips();
                                self.breaker.record_failure();
                                if self.breaker.trips() > trips {
                                    self.inst.telemetry().note_breaker_trip();
                                }
                                return Err(RpcError::from_io(
                                    &format!("level {}: match_grow ascent failed", self.level),
                                    &e,
                                ));
                            }
                        };
                        let rtt = tc.elapsed_secs();
                        let (jgf, levels) = match resp.reply {
                            SchedReply::Grown { subgraph, levels } => (subgraph, levels),
                            // the ancestor's structured error descends as-is
                            SchedReply::Error(e) => return Err(e),
                            other => {
                                return Err(RpcError::new(
                                    code::BAD_REPLY,
                                    format!(
                                        "parent sent unexpected '{}' reply to match_grow",
                                        other.name()
                                    ),
                                ))
                            }
                        };
                        // pure inter-level communication time: the round
                        // trip minus the time the ancestors spent working
                        // (they escalate recursively, so the raw RTT of a
                        // deep level contains every upper level's match/
                        // comms/add work — the paper's Fig 1a measures the
                        // link, not the recursion)
                        let upper: f64 = levels.iter().map(LevelTiming::total).sum();
                        let comms_s = (rtt - upper).max(0.0);
                        (jgf, levels, comms_s)
                    }
                    (None, None) => {
                        return Err(RpcError::new(
                            code::MATCH_GROW_FAILED,
                            "top level: no resources and no external provider",
                        ))
                    }
                };
                // crash site: the grant reply arrived (the ancestor already
                // committed and charged it) but this level dies before
                // splicing it in — the classic orphaned-grant window that
                // restart reconciliation must close.
                if self.crash_plan.fires(CrashPoint::PreJournal) {
                    return Err(RpcError::new(
                        code::CRASHED,
                        format!(
                            "injected: level {} crashed before splicing grant (orphan at parent)",
                            self.level
                        ),
                    ));
                }
                // 3. top-down: splice the grant into our graph, charge it to
                //    the child's job (it passes through to the requester).
                //    Re-acquires the write side; a failed splice may still
                //    have mutated the graph, which the epoch records — the
                //    service's probe cache can never serve pre-splice
                //    answers either way.
                let (report, add_upd_s) = {
                    let mut guard = self.inst.write();
                    // timer starts after the lock: add_upd_s measures the
                    // splice, not contention with concurrent probes
                    let ta = Timer::start();
                    let r = guard
                        .accept_grant(&jgf, child_job)
                        .map_err(|e| RpcError::new(code::GROW_FAILED, e.to_string()))?;
                    (r, ta.elapsed_secs())
                };
                for r in attach_roots(&jgf) {
                    self.added_roots.insert(r);
                }
                let _ = report;
                let mut all = upper_levels;
                all.push(LevelTiming {
                    level: self.level,
                    match_s,
                    match_ok: false,
                    comms_s,
                    add_upd_s,
                    visited,
                });
                Ok((jgf, all))
            }
        }
    }
}

impl NodeState {
    /// The subtractive transformation at this level: release + detach the
    /// subtree, then ascend — unless the subtree is a cloud grant obtained
    /// through this node's own provider, in which case the instances are
    /// released here and the shrink stops (the supergraph never saw them).
    fn shrink_return(&mut self, path: &str) -> Result<usize, RpcError> {
        let shrink_err = |e: crate::sched::grow::GrowError| {
            RpcError::new(code::SHRINK_FAILED, e.to_string())
        };
        // cloud-specialized grant? delete, release instances, stop — the
        // supergraph never contained E_i
        if let Some(pos) = self
            .cloud_grants
            .iter()
            .position(|(roots, _)| roots.split(',').any(|r| r == path))
        {
            let removed = self.inst.write().release_subtree(path).map_err(shrink_err)?;
            self.added_roots.remove(path);
            let (_, ids) = self.cloud_grants.remove(pos);
            if let Some(provider) = &mut self.external {
                provider
                    .release(&ids)
                    .map_err(|e| RpcError::new(e.code(), e.to_string()))?;
            }
            return Ok(removed);
        }
        if self.added_roots.contains(path) {
            // this level spliced the subgraph in dynamically: delete it and
            // keep ascending (bottom-up subtractive transformation). The
            // quarantine gate comes FIRST — a refused ascent must leave
            // this level's graph and bookkeeping untouched.
            if self.parent.is_some() && !self.breaker.admit() {
                return Err(level_unavailable(self.level, &self.breaker));
            }
            self.added_roots.remove(path);
            let removed = self.inst.write().release_subtree(path).map_err(shrink_err)?;
            if let Some(conn) = &mut self.parent {
                let called = conn.call(&Request::new(
                    self.level as u64,
                    SchedOp::ShrinkReturn {
                        path: path.to_string(),
                    },
                ));
                let resp = match called {
                    Ok(resp) => {
                        self.breaker.record_success();
                        resp
                    }
                    Err(e) => {
                        let trips = self.breaker.trips();
                        self.breaker.record_failure();
                        if self.breaker.trips() > trips {
                            self.inst.telemetry().note_breaker_trip();
                        }
                        return Err(RpcError::from_io(
                            &format!("level {}: shrink_return ascent failed", self.level),
                            &e,
                        ));
                    }
                };
                match resp.reply {
                    SchedReply::Removed { .. } => {}
                    // the ancestor's structured error descends as-is
                    SchedReply::Error(e) => return Err(e),
                    other => {
                        return Err(RpcError::new(
                            code::BAD_REPLY,
                            format!(
                                "parent sent unexpected '{}' reply to shrink_return",
                                other.name()
                            ),
                        ))
                    }
                }
            }
            Ok(removed)
        } else {
            // owner level: the vertices are part of this graph's physical
            // inventory — free the child's allocation, keep the vertices
            self.inst.write().free_allocations_in(path).map_err(shrink_err)
        }
    }
}

/// Grant-ledger bookkeeping and the parent-child reconciliation handshake
/// (PR 10). The ledger is durable as journal notes: hierarchy mutations go
/// through raw service write guards (no op frames), so each ledger write
/// also forces a journal checkpoint — recovery = latest checkpoint + the
/// last committed "ledger" note, exactly paired.
impl NodeState {
    /// Serialize the grant-ledger state (both sides: what we hold from the
    /// parent, what we granted to the child) as one JSON document.
    fn ledger_json(&self) -> Json {
        let arr = |it: &mut dyn Iterator<Item = &String>| {
            Json::Arr(it.map(|r| Json::from(r.as_str())).collect())
        };
        let sorted_set = |s: &std::collections::HashSet<String>| {
            let mut v: Vec<&String> = s.iter().collect();
            v.sort();
            Json::Arr(v.into_iter().map(|r| Json::from(r.as_str())).collect())
        };
        let cloud = Json::Arr(
            self.cloud_grants
                .iter()
                .map(|(roots, ids)| {
                    Json::obj()
                        .with("roots", Json::from(roots.as_str()))
                        .with("ids", arr(&mut ids.iter()))
                })
                .collect(),
        );
        Json::obj()
            .with("granted", sorted_set(&self.granted_roots))
            .with("child_boot", arr(&mut self.child_boot_roots.iter()))
            .with("boot", arr(&mut self.boot_roots.iter()))
            .with("added", sorted_set(&self.added_roots))
            .with("cloud", cloud)
    }

    /// Restore the ledger from a recovered journal note (inverse of
    /// [`NodeState::ledger_json`]). Unknown/missing fields default empty.
    fn apply_ledger(&mut self, data: &Json) {
        let strs = |key: &str| -> Vec<String> {
            data.get(key)
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|j| j.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default()
        };
        self.granted_roots = strs("granted").into_iter().collect();
        self.child_boot_roots = strs("child_boot");
        self.boot_roots = strs("boot");
        self.added_roots = strs("added").into_iter().collect();
        self.cloud_grants = data
            .get("cloud")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .filter_map(|j| {
                        let roots = j.get("roots")?.as_str()?.to_string();
                        let ids = j
                            .get("ids")
                            .and_then(Json::as_arr)
                            .map(|ids| {
                                ids.iter()
                                    .filter_map(|i| i.as_str().map(str::to_string))
                                    .collect()
                            })
                            .unwrap_or_default();
                        Some((roots, ids))
                    })
                    .collect()
            })
            .unwrap_or_default();
    }

    /// Make the current graph + ledger state durable: checkpoint the op
    /// journal (hier mutations bypass op frames) and append a "ledger"
    /// note. No-op while journaling is off. Must NOT be called while a
    /// service write guard is held (the checkpoint takes one).
    fn journal_ledger(&self) {
        if !self.inst.journal_enabled() {
            return;
        }
        self.inst.journal_checkpoint();
        self.inst.journal_note("ledger", self.ledger_json());
    }

    /// The claim set this node asserts to its PARENT: the boot grant plus
    /// every dynamically spliced root, minus subtrees obtained from this
    /// node's own provider (the parent never saw those — §3 per-user
    /// specialization). Sorted + deduped for deterministic reconciles.
    fn claimed_roots(&self) -> Vec<String> {
        let cloud: std::collections::HashSet<&str> = self
            .cloud_grants
            .iter()
            .flat_map(|(roots, _)| roots.split(','))
            .collect();
        let mut v: Vec<String> = self
            .boot_roots
            .iter()
            .chain(self.added_roots.iter())
            .filter(|r| !cloud.contains(r.as_str()))
            .cloned()
            .collect();
        v.sort();
        v.dedup();
        v
    }

    /// Parent side of the `Reconcile` handshake: the child asserted
    /// `claimed`; release every ledgered grant the child does NOT claim
    /// (orphans — granted, never committed below) through the ordinary
    /// subtractive path, and report back every claim we have no record of
    /// (ghosts — the child cancels those). Per-orphan errors are
    /// tolerated: the entry stays ledgered and a retried reconcile
    /// converges.
    fn serve_reconcile(&mut self, claimed: &[String]) -> SchedReply {
        let claimed_set: std::collections::HashSet<&str> =
            claimed.iter().map(String::as_str).collect();
        let mut orphans: Vec<String> = self
            .granted_roots
            .iter()
            .filter(|r| !claimed_set.contains(r.as_str()))
            .cloned()
            .collect();
        orphans.sort();
        let mut released = 0u64;
        for r in &orphans {
            // shrink_return handles all three positions uniformly: owner
            // (free the allocation), splicer (delete + keep ascending),
            // cloud (release instances here)
            match self.shrink_return(r) {
                Ok(_) => {
                    self.granted_roots.remove(r);
                    released += 1;
                }
                // deterministic local refusal: there is nothing left to
                // release (the grant is already physically gone — e.g. a
                // child shrink that errored after this level's removal
                // kept the entry ledgered). Settle it, don't count it.
                Err(e) if e.code == code::SHRINK_FAILED => {
                    self.granted_roots.remove(r);
                }
                // transient (quarantined / timed-out ascent): keep the
                // entry — a retried reconcile converges once the link does
                Err(_) => {}
            }
        }
        let mut ghosts: Vec<String> = claimed
            .iter()
            .filter(|r| {
                !self.granted_roots.contains(*r) && !self.child_boot_roots.contains(r)
            })
            .cloned()
            .collect();
        ghosts.sort();
        ghosts.dedup();
        if released > 0 {
            self.inst.telemetry().note_orphans_released(released);
            self.journal_ledger();
        }
        SchedReply::Reconciled {
            orphans_released: released,
            ghosts,
        }
    }

    /// Child side of the handshake, breaker-gated. See
    /// [`NodeState::reconcile_admitted`].
    fn reconcile(&mut self) -> Result<(u64, Vec<String>), RpcError> {
        if self.parent.is_none() {
            return Ok((0, Vec::new()));
        }
        if !self.breaker.admit() {
            return Err(level_unavailable(self.level, &self.breaker));
        }
        self.reconcile_admitted()
    }

    /// Send this node's claim set up the parent link and act on the
    /// answer: parent-side orphans were already released over there; ghost
    /// claims (subtrees the parent has no record of) are cancelled here by
    /// deleting the subtree. The crash window between receiving the reply
    /// and cancelling is scripted ([`CrashPoint::MidReconcile`]) — a
    /// retried reconcile re-reports the same ghosts, so the handshake is
    /// idempotent. Assumes the breaker already admitted the call (or the
    /// caller IS the half-open trial).
    fn reconcile_admitted(&mut self) -> Result<(u64, Vec<String>), RpcError> {
        let roots = self.claimed_roots();
        let conn = match &mut self.parent {
            Some(conn) => conn,
            None => return Ok((0, Vec::new())),
        };
        let called = conn.call(&Request::new(
            self.level as u64,
            SchedOp::Reconcile { roots },
        ));
        let resp = match called {
            Ok(resp) => {
                self.breaker.record_success();
                resp
            }
            Err(e) => {
                let trips = self.breaker.trips();
                self.breaker.record_failure();
                if self.breaker.trips() > trips {
                    self.inst.telemetry().note_breaker_trip();
                }
                return Err(RpcError::from_io(
                    &format!("level {}: reconcile ascent failed", self.level),
                    &e,
                ));
            }
        };
        let (orphans_released, ghosts) = match resp.reply {
            SchedReply::Reconciled {
                orphans_released,
                ghosts,
            } => (orphans_released, ghosts),
            SchedReply::Error(e) => return Err(e),
            other => {
                return Err(RpcError::new(
                    code::BAD_REPLY,
                    format!("parent sent unexpected '{}' reply to reconcile", other.name()),
                ))
            }
        };
        self.inst.telemetry().note_reconcile();
        if self.crash_plan.fires(CrashPoint::MidReconcile) {
            return Err(RpcError::new(
                code::CRASHED,
                format!(
                    "injected: level {} crashed mid-reconcile (ghost cancellation pending)",
                    self.level
                ),
            ));
        }
        for g in &ghosts {
            // cancel: the parent never granted this subtree (its crash
            // predates the grant's durability) — delete it outright; the
            // vertices live on in the parent's inventory as free. Best
            // effort: a retried reconcile after a partial cancel must not
            // re-assert the claim, so the root leaves the ledger either way.
            if self.added_roots.remove(g) {
                let _ = self.inst.write().release_subtree(g);
            }
        }
        if !ghosts.is_empty() {
            self.journal_ledger();
        }
        Ok((orphans_released, ghosts))
    }
}

/// Attach-root paths of a JGF document (nodes whose parent path is not in
/// the document). One pass with a path set — grants are checked on every
/// level they descend through, so this runs per level per MatchGrow.
fn attach_roots(jgf: &Jgf) -> Vec<String> {
    let paths: std::collections::HashSet<&str> =
        jgf.nodes.iter().map(|n| n.path.as_str()).collect();
    jgf.nodes
        .iter()
        .filter(|n| {
            n.parent_path()
                .map(|pp| !paths.contains(pp))
                .unwrap_or(true)
        })
        .map(|n| n.path.clone())
        .collect()
}

enum ServerHandle {
    InProc(InProcServer),
    Tcp(TcpServer),
}

/// A built hierarchy: level 0 first. All levels run in this process; links
/// between them are real RPC transports per their [`LevelSpec`].
pub struct Hierarchy {
    nodes: Vec<Arc<Mutex<NodeState>>>,
    /// Each level's `SchedService` handle, cloned out of the node at
    /// build time so read-only traffic ([`Hierarchy::probe_at`]) never
    /// touches the per-node mutex — the same property the transport
    /// handlers get via `node_handler`.
    services: Vec<SchedService>,
    servers: Vec<ServerHandle>,
    /// Per-level `(client, server)` fault injectors when built with
    /// [`LinkPolicy::chaos`] (index = level; level 0 has no parent link).
    injectors: Vec<(Option<FaultInjector>, Option<FaultInjector>)>,
}

impl Hierarchy {
    /// Build a hierarchy from a root graph and per-level boot specs.
    /// Each level requests `boot_nodes` full nodes (2 sockets × 16 cores,
    /// the Table 2 shape) from its parent.
    pub fn build(root_graph: ResourceGraph, levels: &[LevelSpec]) -> Result<Hierarchy, String> {
        Self::build_with_external(root_graph, levels, None)
    }

    /// Like [`Hierarchy::build`] but giving the top level an external
    /// provider for bursting.
    pub fn build_with_external(
        root_graph: ResourceGraph,
        levels: &[LevelSpec],
        external: Option<Box<dyn ExternalProvider>>,
    ) -> Result<Hierarchy, String> {
        Self::build_with_policy(root_graph, levels, external, LinkPolicy::default())
    }

    /// Like [`Hierarchy::build_with_external`] but with an explicit
    /// fault-tolerance [`LinkPolicy`] applied to every parent link:
    /// deadline, bounded retry, quarantine breaker, and (optionally)
    /// deterministic fault injection.
    pub fn build_with_policy(
        root_graph: ResourceGraph,
        levels: &[LevelSpec],
        external: Option<Box<dyn ExternalProvider>>,
        policy: LinkPolicy,
    ) -> Result<Hierarchy, String> {
        let mut nodes = Vec::new();
        let mut services = Vec::new();
        let mut servers = Vec::new();
        let mut injectors: Vec<(Option<FaultInjector>, Option<FaultInjector>)> =
            vec![(None, None)];
        let root_service =
            SchedService::new(SchedInstance::new(root_graph, PruneConfig::default()));
        services.push(root_service.clone());
        let root = Arc::new(Mutex::new(NodeState {
            level: 0,
            inst: root_service,
            parent: None,
            child_job: None,
            own_job: None,
            external,
            snapshot: None,
            added_roots: std::collections::HashSet::new(),
            cloud_grants: Vec::new(),
            breaker: CircuitBreaker::new(policy.breaker_threshold, policy.breaker_cooldown),
            granted_roots: std::collections::HashSet::new(),
            boot_roots: Vec::new(),
            child_boot_roots: Vec::new(),
            crash_plan: CrashPlan::default(),
        }));
        nodes.push(root);

        for (i, spec) in levels.iter().enumerate() {
            let level = i + 1;
            let parent = nodes[i].clone();
            // 1. boot allocation from the parent (direct call: boot is not
            //    part of any measured path)
            let boot_spec = JobSpec::nodes_sockets_cores(spec.boot_nodes, 2, 16);
            let (grant, parent_service) = {
                let mut p = lock_node(&parent);
                let out = p.inst.write().match_allocate(&boot_spec).map_err(|e| {
                    format!("level {level} boot: parent cannot grant {} nodes: {e}", spec.boot_nodes)
                })?;
                p.child_job = Some(out.job);
                // boot ledger: these roots are statically granted — they
                // anchor reconciliation (a child claim over them is never
                // a ghost) but are not releasable orphan candidates
                p.child_boot_roots = attach_roots(&out.subgraph);
                (out.subgraph, p.inst.clone())
            };
            // per-link injectors: each link derives independent client and
            // server streams from the master seed, so one link's draw
            // count never perturbs another's schedule
            let (client_inj, server_inj) = match &policy.chaos {
                Some(c) => (
                    Some(FaultInjector::new(
                        c.seed ^ (level as u64 * 2 + 1),
                        c.client_rates,
                    )),
                    Some(FaultInjector::new(
                        c.seed ^ (level as u64 * 2 + 2),
                        c.server_rates,
                    )),
                ),
                None => (None, None),
            };
            // 2. serve the parent over the requested transport (the handler
            //    gets its own service handle so read-only ops skip the
            //    node mutex), with server-side chaos outside the real
            //    handler when configured
            let h = node_handler(parent.clone(), parent_service);
            let h = match (&server_inj, &policy.chaos) {
                (Some(inj), Some(c)) => chaos_handler(h, inj.clone(), c.stall),
                _ => h,
            };
            let base: Box<dyn Conn> = match spec.link {
                LinkKind::InProc => {
                    let server = InProcServer::spawn(h);
                    let conn = server.connect_with_deadline(policy.deadline);
                    servers.push(ServerHandle::InProc(server));
                    Box::new(conn)
                }
                LinkKind::Tcp(latency) => {
                    let server = TcpServer::spawn(h).map_err(|e| e.to_string())?;
                    let conn = TcpConn::connect_with(server.addr, latency, policy.deadline)
                        .map_err(|e| e.to_string())?;
                    servers.push(ServerHandle::Tcp(server));
                    Box::new(conn)
                }
            };
            // wrap inside-out: faults fire at the link boundary, retries
            // sit above them (a retried probe re-rolls the fault dice)
            let base: Box<dyn Conn> = match &client_inj {
                Some(inj) => Box::new(FaultyConn::new(base, inj.clone())),
                None => base,
            };
            let conn: Box<dyn Conn> = Box::new(RetryConn::new(base, policy.retry.clone()));
            injectors.push((client_inj, server_inj));
            // 3. boot the child instance from the grant
            let inst = SchedService::new(
                SchedInstance::from_jgf(&grant, PruneConfig::default())
                    .map_err(|e| e.to_string())?,
            );
            services.push(inst.clone());
            let boot_roots = attach_roots(&grant);
            nodes.push(Arc::new(Mutex::new(NodeState {
                level,
                inst,
                parent: Some(conn),
                child_job: None,
                own_job: None,
                external: None,
                snapshot: None,
                added_roots: std::collections::HashSet::new(),
                cloud_grants: Vec::new(),
                breaker: CircuitBreaker::new(policy.breaker_threshold, policy.breaker_cooldown),
                granted_roots: std::collections::HashSet::new(),
                boot_roots,
                child_boot_roots: Vec::new(),
                crash_plan: CrashPlan::default(),
            })));
        }

        let h = Hierarchy {
            nodes,
            services,
            servers,
            injectors,
        };
        h.saturate_and_snapshot()?;
        Ok(h)
    }

    /// The client-side [`FaultInjector`] of a level's parent link, when the
    /// hierarchy was built with [`LinkPolicy::chaos`] (level 0 has none).
    /// Tests use this to script faults and read stats.
    pub fn client_injector(&self, level: usize) -> Option<FaultInjector> {
        self.injectors.get(level).and_then(|(c, _)| c.clone())
    }

    /// The server-side [`FaultInjector`] of a level's parent link, when the
    /// hierarchy was built with [`LinkPolicy::chaos`] (level 0 has none).
    pub fn server_injector(&self, level: usize) -> Option<FaultInjector> {
        self.injectors.get(level).and_then(|(_, s)| s.clone())
    }

    /// Fully allocate every level's remaining free resources to local jobs
    /// ("Levels 1–4 are configured to be fully allocated", §5.2), give the
    /// leaf a running job to grow, then snapshot all levels for `reset`.
    fn saturate_and_snapshot(&self) -> Result<(), String> {
        let leaf_idx = self.nodes.len() - 1;
        for (i, node) in self.nodes.iter().enumerate() {
            let mut n = lock_node(node);
            if i > 0 {
                // node-level saturation, then socket-level (the leaf may
                // have had a socket granted away), then core-level
                for (nodes, sockets, cores) in
                    [(1u64, 2u64, 16u64), (0, 1, 16)]
                {
                    loop {
                        let spec = JobSpec::nodes_sockets_cores(nodes, sockets, cores);
                        match n.inst.write().match_allocate(&spec) {
                            Ok(out) => {
                                if i == leaf_idx && n.own_job.is_none() {
                                    n.own_job = Some(out.job);
                                }
                            }
                            Err(_) => break,
                        }
                    }
                }
            }
            let snapshot = {
                let inst = n.inst.read();
                (inst.graph.clone(), inst.allocs.clone())
            };
            n.snapshot = Some(snapshot);
        }
        Ok(())
    }

    /// Issue a `MatchGrow` from the leaf (the paper's helper-script step).
    pub fn grow_from_leaf(&self, spec: &JobSpec) -> Result<GrowReport, String> {
        let leaf = self.nodes.last().expect("hierarchy has levels");
        let mut n = lock_node(leaf);
        let own_job = n.own_job;
        // ensure grants terminate at the leaf's own running job
        n.child_job = own_job;
        let total = Timer::start();
        let (jgf, levels) = n.match_grow(spec).map_err(|e| e.to_string())?;
        let total_s = total.elapsed_secs();
        // the leaf's splice/allocation went through a raw write guard —
        // checkpoint + ledger note make it crash-durable (no-op w/o journal)
        n.journal_ledger();
        Ok(GrowReport {
            subgraph_size: jgf.size(),
            roots: attach_roots(&jgf),
            levels,
            total_s,
        })
    }

    /// Give a *nested* level its own external provider — the paper's
    /// per-user external resource specialization (§3): that level's bursts
    /// are managed independently of the top-level scheduler, and shrinks of
    /// burst subgraphs stop at this level.
    pub fn set_external(&self, level: usize, provider: Box<dyn ExternalProvider>) {
        lock_node(&self.nodes[level]).external = Some(provider);
    }

    /// Shrink: remove the subtree at `path` from the leaf and propagate the
    /// subtractive transformation up the hierarchy (§3 — "a subtractive
    /// transformation moves from the bottom up"). Returns the vertices
    /// removed at the leaf.
    pub fn shrink_from_leaf(&self, path: &str) -> Result<usize, String> {
        let leaf = self.nodes.last().expect("hierarchy has levels");
        let mut n = lock_node(leaf);
        let removed = n.shrink_return(path).map_err(|e| e.to_string())?;
        n.journal_ledger();
        Ok(removed)
    }

    /// Restore every level to its post-boot snapshot (the "helper script
    /// reinitializes the resource graphs at each level" step). Goes
    /// through [`ResourceGraph::restore_from`] so the graph epoch keeps
    /// moving forward — probe results cached against the pre-reset
    /// timeline can never be served against the restored graph.
    ///
    /// Burst bookkeeping is reset too: instances obtained from each node's
    /// own provider are released back to it (best effort — the snapshot
    /// predates every grant, so after the rollback nothing references
    /// them), and `added_roots`/`cloud_grants` are cleared. Without this a
    /// reset would orphan provider instances.
    ///
    /// A reset is a full experiment reinitialization, so the *surrounding*
    /// machinery resets with the graphs: per-level circuit breakers forget
    /// their trip history, telemetry rate windows restart (histograms and
    /// counters are cumulative and survive), fault-injector stats rewind
    /// (the deterministic fault schedule itself keeps advancing), and the
    /// dynamic grant ledgers return to their boot state.
    pub fn reset(&self) {
        for node in &self.nodes {
            let mut n = lock_node(node);
            let grants: Vec<(String, Vec<String>)> = n.cloud_grants.drain(..).collect();
            if let Some(provider) = &mut n.external {
                for (_, ids) in &grants {
                    // best effort: a failed release cannot block the reset
                    let _ = provider.release(ids);
                }
            }
            n.added_roots.clear();
            n.granted_roots.clear();
            n.breaker.reset();
            n.inst.telemetry().reset_rate_windows();
            if let Some((g, a)) = n.snapshot.clone() {
                let mut guard = n.inst.write();
                let inst = &mut *guard;
                inst.graph.restore_from(&g);
                inst.allocs = a;
                // sharded write commits (PR 8): the shard job maps were
                // indexed against the pre-reset table — re-derive them
                // from the restored one
                inst.refresh_write_shards();
            }
            n.journal_ledger();
        }
        for (client, server) in &self.injectors {
            if let Some(inj) = client {
                inj.reset_stats();
            }
            if let Some(inj) = server {
                inj.reset_stats();
            }
        }
    }

    /// Number of levels (root included).
    pub fn depth(&self) -> usize {
        self.nodes.len()
    }

    /// Graph size (vertices + edges) at a level.
    pub fn graph_size(&self, level: usize) -> usize {
        lock_node(&self.nodes[level]).inst.read().graph.size()
    }

    /// Run invariant checks on every level (tests / failure injection).
    pub fn check_all(&self) -> Result<(), String> {
        for node in &self.nodes {
            lock_node(node).inst.read().check()?;
        }
        Ok(())
    }

    /// Quarantine state of a level's parent link: `"closed"`, `"open"`, or
    /// `"half-open"`. The root has no parent link and always reports
    /// `"closed"`.
    pub fn parent_link_state(&self, level: usize) -> &'static str {
        lock_node(&self.nodes[level]).breaker.state_name()
    }

    /// Serving-telemetry snapshot of a level's [`SchedService`]: per-op-kind
    /// latency histograms, throughput windows, cache stats, and the
    /// breaker-trip counter (incremented when that level's parent link — or
    /// a half-open trial in [`Hierarchy::maintain`] — trips into
    /// quarantine). Uses the service handle, not the node mutex, so it is
    /// safe to call while a `MatchGrow` is in flight.
    pub fn telemetry_snapshot_at(&self, level: usize) -> TelemetrySnapshot {
        self.services[level].telemetry_snapshot()
    }

    /// One tick of link maintenance: every level whose parent-link breaker
    /// has finished its cooldown runs a half-open trial through the real
    /// link — since PR 10 the trial is the full `Reconcile` handshake, not
    /// a bare probe: a link that went dark may have dropped grant traffic
    /// mid-flight, so re-admission doubles as ledger re-convergence
    /// (orphans released at the parent, ghosts cancelled here). A
    /// well-formed handshake restores the level (quarantine lifts), a
    /// transport failure re-opens it for another cooldown. Call
    /// periodically (chaos soaks call it between ops). Returns
    /// `(level, state)` for every level below the root, observed after any
    /// trial.
    pub fn maintain(&self) -> Vec<(usize, &'static str)> {
        let mut states = Vec::new();
        for (level, node) in self.nodes.iter().enumerate().skip(1) {
            let mut n = lock_node(node);
            if n.parent.is_some() && n.breaker.state_name() == "half-open" && n.breaker.admit() {
                // reconcile_admitted records breaker success/failure and
                // the trip-delta telemetry itself
                let _ = n.reconcile_admitted();
            }
            states.push((level, n.breaker.state_name()));
        }
        states
    }

    /// Feasibility probe that routes around quarantine: ascend from the
    /// leaf consulting each level's concurrent cached probe path (exactly
    /// [`Hierarchy::probe_at`]), returning the first feasible
    /// `(level, reply)` — or the root's (infeasible) reply if nothing
    /// matches. The walk stops with [`code::LEVEL_UNAVAILABLE`] if it hits
    /// an open parent-link breaker first: every level above a quarantined
    /// link is unreachable from the leaf, so a feasible answer from up
    /// there would be unactionable.
    pub fn probe_up(&self, spec: &JobSpec) -> Result<(usize, SchedReply), RpcError> {
        let mut level = self.depth() - 1;
        loop {
            let reply = self.probe_at(level, spec);
            if matches!(reply, SchedReply::Probed { .. }) {
                return Ok((level, reply));
            }
            if level == 0 {
                return Ok((0, reply));
            }
            {
                let n = lock_node(&self.nodes[level]);
                // non-mutating check on purpose: routing a probe must not
                // consume the breaker's half-open trial admission
                if n.breaker.is_open() {
                    return Err(level_unavailable(level, &n.breaker));
                }
            }
            level -= 1;
        }
    }

    /// Serve a feasibility probe at a level through its concurrent cached
    /// read path — what a remote `probe` op hits, minus the transport.
    /// Uses the service handle captured at build time, NOT the per-node
    /// mutex, so it stays responsive while a multi-level `MatchGrow`
    /// holds that lock for its whole round trip. Since PR 9 the probe is
    /// fully lock-free: it pins that level's latest published RCU snapshot
    /// and never touches the instance `RwLock`, so it also stays
    /// responsive while a writer holds that level's write side.
    pub fn probe_at(&self, level: usize, spec: &JobSpec) -> SchedReply {
        self.services[level].probe(spec)
    }

    /// RCU snapshot lifecycle counters of a level's [`SchedService`]
    /// (pins / publishes / retired / live — see
    /// [`crate::sched::SnapshotStats`]). With no probe in flight `live`
    /// must be exactly 1; the serving harness prints these per level to
    /// show version churn is being reclaimed.
    pub fn snapshot_stats_at(&self, level: usize) -> SnapshotStats {
        self.services[level].snapshot_stats()
    }

    /// Serve a feasibility probe at a level through the **sharded**
    /// intra-match read path ([`SchedService::probe_sharded`]): the
    /// candidate scan splits into up to `shards` top-level subtree ranges
    /// of that level's graph — same bit-identical feasibility and vertex
    /// count as [`Hierarchy::probe_at`], lower latency on wide graphs.
    /// Like `probe_at`, it bypasses the per-node mutex.
    pub fn probe_sharded_at(&self, level: usize, spec: &JobSpec, shards: usize) -> SchedReply {
        self.services[level].probe_sharded(spec, shards)
    }

    /// Enable (or, with `k <= 1`, disable) the OCC subtree-sharded write
    /// path at one level ([`SchedService::set_write_shards`]): the match
    /// half of that level's `MatchAllocate`/`MatchGrowLocal` traffic runs
    /// against a pinned snapshot and commits through subtree-sharded
    /// allocation maps, leaving the write lock held only for the short
    /// commit. Uses
    /// the service handle, not the per-node mutex, so it is safe to toggle
    /// while traffic — even a multi-level `MatchGrow` — is in flight.
    pub fn set_write_shards_at(&self, level: usize, k: usize) {
        self.services[level].set_write_shards(k);
    }

    /// Enable sharded write commits at every level with the same width
    /// (how the chaos soak and the serving benches arm the whole tree).
    pub fn set_write_shards_all(&self, k: usize) {
        for svc in &self.services {
            svc.set_write_shards(k);
        }
    }

    /// Turn on write-ahead journaling at every level
    /// ([`SchedService::enable_journal`]): the journal opens with a
    /// snapshot of the current graph + alloc state and an initial "ledger"
    /// note, so recovery is well-defined from this moment on regardless of
    /// how much history preceded it.
    pub fn enable_journals(&self, snapshot_every: u64) {
        for node in &self.nodes {
            let n = lock_node(node);
            n.inst.enable_journal(snapshot_every);
            n.inst.journal_note("ledger", n.ledger_json());
        }
    }

    /// Arm a level's *hierarchy* crash sites (grant splice, grant
    /// durability, mid-reconcile) with a scripted [`CrashPlan`]. The
    /// service-level op sites (pre-/post-journal around `apply`) are armed
    /// separately via [`Hierarchy::set_service_crash_plan`].
    pub fn set_crash_plan(&self, level: usize, plan: CrashPlan) {
        lock_node(&self.nodes[level]).crash_plan = plan;
    }

    /// Arm a level's service-side crash sites
    /// ([`SchedService::set_crash_plan`]): `PreJournal` kills an op before
    /// its journal append (no trace), `PostJournal` after the append but
    /// before commit (an uncommitted suffix recovery must discard).
    pub fn set_service_crash_plan(&self, level: usize, plan: CrashPlan) {
        self.services[level].set_crash_plan(plan);
    }

    /// Run the child-initiated `Reconcile` handshake on one level's parent
    /// link (no-op Ok at the root). Returns
    /// `(orphans_released_at_parent, ghost_roots_cancelled_here)`.
    pub fn reconcile_level(&self, level: usize) -> Result<(u64, Vec<String>), String> {
        lock_node(&self.nodes[level])
            .reconcile()
            .map_err(|e| e.to_string())
    }

    /// Kill one level and bring it back: the level's live in-memory state
    /// is discarded and replaced by what its write-ahead journal proves —
    /// snapshot + bounded replay of the committed op suffix — then the
    /// grant ledger is restored from the last committed "ledger" note, the
    /// parent-link breaker starts fresh, and the level re-registers by
    /// reconciling with its parent; the level below re-asserts its claims
    /// the same way so grants the crashed level lost are released as
    /// orphans. `matched_live` reports whether the recovered state was
    /// bit-identical to the pre-kill live state (true for a clean kill;
    /// deliberately false when a crash site suppressed durability).
    pub fn kill_and_restart_level(&self, level: usize) -> Result<RestartReport, String> {
        let (replayed, torn, uncommitted, matched_live, mut reconcile_errors) = {
            let mut n = lock_node(&self.nodes[level]);
            let rec = n
                .inst
                .recover_from_journal()
                .ok_or_else(|| format!("level {level}: journaling not enabled"))?;
            let matched_live = {
                let live = n.inst.read();
                crate::sched::states_bit_identical(&rec.inst, &live).is_ok()
            };
            n.inst.install_recovered(&rec.inst);
            if let Some((_, data)) = rec.notes.iter().rev().find(|(tag, _)| tag == "ledger") {
                let data = data.clone();
                n.apply_ledger(&data);
            }
            // the restarted process has no memory of past link failures
            n.breaker.reset();
            n.inst.telemetry().note_journal_replays(rec.replayed);
            let mut errors = Vec::new();
            if let Err(e) = n.reconcile() {
                errors.push(e.to_string());
            }
            (rec.replayed, rec.torn, rec.uncommitted, matched_live, errors)
        };
        // the child below re-asserts its claims against our rebuilt ledger
        // (outside our node lock — its reconcile ascends into us)
        if level + 1 < self.nodes.len() {
            if let Err(e) = lock_node(&self.nodes[level + 1]).reconcile() {
                reconcile_errors.push(e.to_string());
            }
        }
        Ok(RestartReport {
            level,
            replayed,
            torn,
            uncommitted,
            matched_live,
            reconcile_errors,
        })
    }

    /// The cross-level ledger invariant: on every parent-child link, the
    /// parent's grant set (boot + dynamic) must equal the child's claim
    /// set (boot + spliced, minus the child's own provider bursts) — every
    /// grant has exactly one live holder and every held subtree exactly
    /// one grantor. Violated between a crash and its reconcile; must hold
    /// after.
    pub fn check_ledgers(&self) -> Result<(), String> {
        for i in 0..self.nodes.len().saturating_sub(1) {
            let mut parent_side: Vec<String> = {
                let p = lock_node(&self.nodes[i]);
                p.granted_roots
                    .iter()
                    .chain(p.child_boot_roots.iter())
                    .cloned()
                    .collect()
            };
            parent_side.sort();
            parent_side.dedup();
            let child_side = lock_node(&self.nodes[i + 1]).claimed_roots();
            if parent_side != child_side {
                return Err(format!(
                    "ledger divergence on link {}->{}: parent grants {:?} vs child claims {:?}",
                    i,
                    i + 1,
                    parent_side,
                    child_side
                ));
            }
        }
        Ok(())
    }

    /// Stop all servers. Called on drop as well.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        for node in &self.nodes {
            let mut n = lock_node(node);
            n.parent = None; // drop client conns first
        }
        for s in self.servers.drain(..) {
            match s {
                ServerHandle::InProc(s) => s.shutdown(),
                ServerHandle::Tcp(s) => s.shutdown(),
            }
        }
    }
}

impl Drop for Hierarchy {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// RPC handler dispatching to a node's state via the typed serve loop.
///
/// Read-only ops never touch the per-node mutex: they are answered by the
/// node's [`SchedService`] (cached, concurrent read path) from a handle
/// captured at build time, so probes stay responsive while a hierarchical
/// `MatchGrow`/`ShrinkReturn` holds the node lock for its whole multi-level
/// round trip.
fn node_handler(
    node: Arc<Mutex<NodeState>>,
    service: SchedService,
) -> crate::rpc::transport::Handler {
    handler(move |req: Request| {
        if req.op.is_read_only() {
            return Response {
                id: req.id,
                reply: service.apply(&req.op),
            };
        }
        let id = req.id;
        let op_name = req.op.name();
        // panic containment: an unwinding mutating op must answer with a
        // typed error, not kill the transport thread mid-request (the
        // caller would see a disconnect and could never tell why). The
        // node mutex is poisoned by the unwind; `lock_node` tolerates
        // that, and the instance beneath is protected by the service's
        // own write-path rollback.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut n = lock_node(&node);
            serve(&mut n, req)
        }));
        match outcome {
            Ok(resp) => resp,
            Err(payload) => Response::ok(
                id,
                SchedReply::err(
                    code::PANIC,
                    format!(
                        "op '{op_name}' panicked in the hierarchy handler ({})",
                        panic_message(payload.as_ref())
                    ),
                ),
            ),
        }
    })
}

/// One exhaustive dispatch over the typed protocol: the hierarchical ops
/// (`MatchGrow`, `ShrinkReturn`) get the level-aware treatment — escalate /
/// propagate — and the read-only `Probe` delegates to the node's
/// [`SchedService`] concurrent cached path (the transport handler normally
/// short-circuits it before this point; the arm keeps direct callers and
/// the exhaustiveness guarantee honest). Instance-MUTATING ops are
/// refused: they would bypass this node's `added_roots`/`cloud_grants`
/// bookkeeping (e.g. a remote `RemoveSubgraph` of a descended grant would
/// desync a later hierarchical shrink and leak provider instances), so
/// instance administration stays local to the owning level. Deliberately
/// NO wildcard arm: adding a [`SchedOp`] variant is a compile error here
/// until it is served.
fn serve(n: &mut NodeState, req: Request) -> Response {
    match &req.op {
        SchedOp::MatchGrow { spec } => match n.match_grow(spec) {
            Ok((jgf, levels)) => {
                // crash site: the grant reply leaves for the child but this
                // level dies before its ledger write (and the checkpoint
                // that would make the allocation durable) lands — after a
                // restart the child holds a subtree this level has no
                // record of: a ghost the Reconcile handshake cancels.
                if n.crash_plan.fires(CrashPoint::PostJournal) {
                    // skip durability on purpose; the reply still descends
                } else {
                    for r in attach_roots(&jgf) {
                        n.granted_roots.insert(r);
                    }
                    n.journal_ledger();
                }
                Response::ok(
                    req.id,
                    SchedReply::Grown {
                        subgraph: jgf,
                        levels,
                    },
                )
            }
            Err(e) => Response::ok(req.id, SchedReply::Error(e)),
        },
        SchedOp::ShrinkReturn { path } => match n.shrink_return(path) {
            Ok(removed) => {
                // the child returned the subtree — its grant leaves the
                // parent-side ledger (boot grants have no ledger entry)
                if n.granted_roots.remove(path) {
                    n.journal_ledger();
                }
                Response::ok(req.id, SchedReply::Removed { vertices: removed })
            }
            Err(e) => Response::ok(req.id, SchedReply::Error(e)),
        },
        SchedOp::Reconcile { roots } => {
            let reply = n.serve_reconcile(roots);
            Response::ok(req.id, reply)
        }
        SchedOp::Probe { .. } => Response {
            id: req.id,
            reply: n.inst.apply(&req.op),
        },
        op @ (SchedOp::MatchAllocate { .. }
        | SchedOp::MatchGrowLocal { .. }
        | SchedOp::AcceptGrant { .. }
        | SchedOp::FreeJob { .. }
        | SchedOp::ShrinkSubtree { .. }
        | SchedOp::RemoveSubgraph { .. }) => Response::ok(
            req.id,
            SchedReply::err(
                code::UNSUPPORTED_OP,
                format!(
                    "'{}' mutates instance state outside the hierarchy's bookkeeping; \
                     hierarchy links serve 'match_grow', 'shrink_return', and 'probe'",
                    op.name()
                ),
            ),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobspec::table1_jobspec;
    use crate::resource::builder::{table2_graph, UidGen};

    fn paper_hierarchy() -> Hierarchy {
        let root = table2_graph(0, &mut UidGen::new());
        Hierarchy::build(root, &paper_levels(Latency::none())).unwrap()
    }

    #[test]
    fn five_level_grow_t7() {
        let h = paper_hierarchy();
        assert_eq!(h.depth(), 5);
        let report = h.grow_from_leaf(&table1_jobspec("T7")).unwrap();
        // all levels below L0 fail locally; L0 matches
        assert_eq!(report.levels.len(), 5);
        assert_eq!(report.levels[0].level, 0);
        assert!(report.levels[0].match_ok);
        for lt in &report.levels[1..] {
            assert!(!lt.match_ok, "level {} should escalate", lt.level);
            assert!(lt.comms_s > 0.0);
            assert!(lt.add_upd_s > 0.0);
        }
        // T7 grant: 35 vertices + 35 edges
        assert_eq!(report.subgraph_size, 70);
        h.check_all().unwrap();
        h.shutdown();
    }

    #[test]
    fn leaf_graph_grows_by_subgraph_size() {
        let h = paper_hierarchy();
        let leaf = h.depth() - 1;
        let before = h.graph_size(leaf);
        let report = h.grow_from_leaf(&table1_jobspec("T7")).unwrap();
        assert_eq!(h.graph_size(leaf), before + report.subgraph_size);
        h.shutdown();
    }

    #[test]
    fn reset_restores_graphs() {
        let h = paper_hierarchy();
        let sizes: Vec<usize> = (0..h.depth()).map(|l| h.graph_size(l)).collect();
        h.grow_from_leaf(&table1_jobspec("T6")).unwrap();
        assert_ne!(h.graph_size(h.depth() - 1), sizes[h.depth() - 1]);
        h.reset();
        let after: Vec<usize> = (0..h.depth()).map(|l| h.graph_size(l)).collect();
        assert_eq!(after, sizes);
        // and grows work again after reset
        h.grow_from_leaf(&table1_jobspec("T7")).unwrap();
        h.shutdown();
    }

    #[test]
    fn grow_too_large_fails_cleanly() {
        let h = paper_hierarchy();
        // 200 nodes: larger than L0's 128-node cluster
        let spec = JobSpec::nodes_sockets_cores(200, 2, 16);
        assert!(h.grow_from_leaf(&spec).is_err());
        h.check_all().unwrap();
        h.shutdown();
    }

    #[test]
    fn repeated_grows_accumulate_until_exhaustion() {
        let h = paper_hierarchy();
        // L0 has 128 - 8 = 120 free nodes after boot; T1 takes 64
        assert!(h.grow_from_leaf(&table1_jobspec("T1")).is_ok());
        assert!(h.grow_from_leaf(&table1_jobspec("T2")).is_ok()); // 32 more
        assert!(h.grow_from_leaf(&table1_jobspec("T1")).is_err()); // 64 > 24
        h.check_all().unwrap();
        h.shutdown();
    }

    /// Probes hit the concurrent cached read path at every level and stay
    /// consistent across a grow (the epoch-keyed cache must never serve a
    /// pre-grow answer after the grant splices in).
    #[test]
    fn probes_reflect_growth_through_cached_path() {
        let h = paper_hierarchy();
        let leaf = h.depth() - 1;
        // leaf is saturated at boot: a 1-node probe fails (and is cached)
        let spec = JobSpec::nodes_sockets_cores(1, 2, 16);
        let before = h.probe_at(leaf, &spec);
        assert!(before.is_error(), "{before:?}");
        // repeat: identical answer (serveable from cache within the epoch)
        assert_eq!(h.probe_at(leaf, &spec), before);
        // grow a node into the leaf, then the same probe must flip: the
        // grant's vertices arrive allocated to the leaf's own job, but the
        // graph grew — a stale cached reply would still say "error" with
        // the old visited count
        let report = h.grow_from_leaf(&table1_jobspec("T7")).unwrap();
        assert_eq!(report.subgraph_size, 70);
        let after = h.probe_at(leaf, &spec);
        assert_ne!(after, before, "probe must observe the epoch change");
        h.check_all().unwrap();
        h.shutdown();
    }

    /// The sharded probe path at a level agrees with the sequential one on
    /// feasibility and vertex count (root level: 128 node subtrees to
    /// shard across; single-node levels collapse to the K=1 bail).
    /// Sharded runs first so the comparison actually exercises its
    /// traversal (the second call may legitimately hit the shared cache).
    #[test]
    fn sharded_probes_agree_with_sequential_at_every_level() {
        let h = paper_hierarchy();
        let spec = JobSpec::nodes_sockets_cores(2, 2, 16);
        for level in 0..h.depth() {
            let sharded = h.probe_sharded_at(level, &spec, 4);
            let seq = h.probe_at(level, &spec);
            match (&seq, &sharded) {
                (
                    SchedReply::Probed { vertices: a, .. },
                    SchedReply::Probed { vertices: b, .. },
                ) => {
                    assert_eq!(a, b, "level {level}");
                    // independent oracle: 2 nodes × (1 + 2 sockets × 17)
                    assert_eq!(*b, 70, "level {level}");
                }
                _ => assert_eq!(
                    seq.is_error(),
                    sharded.is_error(),
                    "level {level}: {seq:?} vs {sharded:?}"
                ),
            }
        }
        h.check_all().unwrap();
        h.shutdown();
    }

    #[test]
    fn reset_invalidates_cached_probes() {
        let h = paper_hierarchy();
        let leaf = h.depth() - 1;
        let spec = JobSpec::nodes_sockets_cores(1, 2, 16);
        h.grow_from_leaf(&table1_jobspec("T7")).unwrap();
        let grown = h.probe_at(leaf, &spec);
        h.reset();
        // restore_from moved the epoch forward: the post-reset probe is
        // recomputed against the restored graph, not served from the
        // post-grow cache entry
        let restored = h.probe_at(leaf, &spec);
        assert_ne!(restored, grown);
        assert!(restored.is_error(), "leaf is saturated again: {restored:?}");
        h.check_all().unwrap();
        h.shutdown();
    }

    #[test]
    fn two_level_minimal() {
        let root = table2_graph(3, &mut UidGen::new()); // 2 nodes
        let levels = [LevelSpec {
            boot_nodes: 1,
            link: LinkKind::InProc,
        }];
        let h = Hierarchy::build(root, &levels).unwrap();
        let report = h.grow_from_leaf(&table1_jobspec("T7")).unwrap();
        assert_eq!(report.levels.len(), 2);
        assert!(report.levels[0].match_ok);
        h.shutdown();
    }

    /// A policy build with zero-rate chaos behaves exactly like the plain
    /// build (the wrappers are transparent), exposes the injectors, and
    /// reports every link closed.
    #[test]
    fn policy_build_with_idle_chaos_grows_normally() {
        let root = table2_graph(3, &mut UidGen::new()); // 2 nodes
        let levels = [LevelSpec {
            boot_nodes: 1,
            link: LinkKind::InProc,
        }];
        let h = Hierarchy::build_with_policy(
            root,
            &levels,
            None,
            LinkPolicy {
                chaos: Some(ChaosConfig::client_only(42, FaultRates::none())),
                ..LinkPolicy::default()
            },
        )
        .unwrap();
        assert!(h.client_injector(1).is_some());
        assert!(h.server_injector(1).is_some());
        assert!(h.client_injector(0).is_none(), "root has no parent link");
        assert_eq!(h.parent_link_state(0), "closed");
        assert_eq!(h.parent_link_state(1), "closed");
        let report = h.grow_from_leaf(&table1_jobspec("T7")).unwrap();
        assert_eq!(report.levels.len(), 2);
        // the grow's escalation frame passed through the injector
        assert!(h.client_injector(1).unwrap().stats().delivered > 0);
        h.check_all().unwrap();
        h.shutdown();
    }

    /// The quarantine lifecycle end to end: scripted frame drops trip the
    /// breaker, the quarantined link fast-fails with the structured code
    /// (consuming no fault schedule), probe routing refuses the
    /// unreachable upper levels, and a `maintain` half-open trial restores
    /// the link after the cooldown.
    #[test]
    fn quarantined_link_fast_fails_then_recovers() {
        use crate::fault::FrameFault;
        let root = table2_graph(3, &mut UidGen::new()); // 2 nodes
        let levels = [LevelSpec {
            boot_nodes: 1,
            link: LinkKind::InProc,
        }];
        let h = Hierarchy::build_with_policy(
            root,
            &levels,
            None,
            LinkPolicy {
                breaker_threshold: 2,
                // generous cooldown: the assertions between trip and
                // restore must run well inside it even on a loaded machine
                breaker_cooldown: Duration::from_millis(200),
                chaos: Some(ChaosConfig::client_only(7, FaultRates::none())),
                ..LinkPolicy::default()
            },
        )
        .unwrap();
        let inj = h.client_injector(1).unwrap();
        let spec = table1_jobspec("T7"); // leaf is saturated: must escalate
        // two scripted drops = two transport failures = threshold reached
        // (match_grow is mutating, so the retry layer does NOT re-roll)
        inj.push_frame_fault(FrameFault::Drop);
        let e1 = h.grow_from_leaf(&spec).unwrap_err();
        assert!(e1.starts_with(code::TIMEOUT), "{e1}");
        inj.push_frame_fault(FrameFault::Drop);
        let e2 = h.grow_from_leaf(&spec).unwrap_err();
        assert!(e2.starts_with(code::TIMEOUT), "{e2}");
        assert_eq!(h.parent_link_state(1), "open");
        // quarantined: fast structured refusal, no link traffic
        let delivered_before = inj.stats().delivered;
        let e3 = h.grow_from_leaf(&spec).unwrap_err();
        assert!(e3.starts_with(code::LEVEL_UNAVAILABLE), "{e3}");
        assert_eq!(inj.stats().delivered, delivered_before);
        // probe routing: the leaf is saturated and everything above is
        // unreachable — the walk surfaces the quarantine
        let probe_spec = JobSpec::nodes_sockets_cores(1, 2, 16);
        let routed = h.probe_up(&probe_spec).unwrap_err();
        assert_eq!(routed.code, code::LEVEL_UNAVAILABLE);
        // cooldown elapses: maintain's half-open trial probe restores it
        std::thread::sleep(Duration::from_millis(250));
        let states = h.maintain();
        assert_eq!(states, vec![(1, "closed")]);
        // restored: probes route up again and the grow goes through
        let (level, reply) = h.probe_up(&probe_spec).unwrap();
        assert_eq!(level, 0, "free capacity lives at the root");
        assert!(matches!(reply, SchedReply::Probed { .. }));
        h.grow_from_leaf(&spec).unwrap();
        h.check_all().unwrap();
        h.shutdown();
    }

    /// PR 10: the grant ledgers stay balanced through the dynamic
    /// lifecycle, and a clean kill/restart recovers bit-identically from
    /// the write-ahead journal and reconciles without incident.
    #[test]
    fn ledgers_balance_through_grow_and_clean_restart() {
        let h = paper_hierarchy();
        h.enable_journals(8);
        h.check_ledgers().unwrap();
        let report = h.grow_from_leaf(&table1_jobspec("T7")).unwrap();
        h.check_ledgers().unwrap();
        let leaf = h.depth() - 1;
        let r = h.kill_and_restart_level(leaf).unwrap();
        assert!(r.matched_live, "clean kill must recover bit-identically: {r:?}");
        assert!(r.reconcile_errors.is_empty(), "{:?}", r.reconcile_errors);
        assert_eq!(r.torn, 0);
        assert!(h.telemetry_snapshot_at(leaf).reconciles >= 1);
        h.check_ledgers().unwrap();
        h.check_all().unwrap();
        // the restarted leaf still owns its grant: the shrink goes through
        h.shrink_from_leaf(&report.roots[0]).unwrap();
        h.check_ledgers().unwrap();
        h.shutdown();
    }

    /// Satellite (PR 10): `reset` rewinds the surrounding machinery with
    /// the graphs — breakers, injector stats, and the dynamic grant
    /// ledgers all return to boot state.
    #[test]
    fn reset_rewinds_breakers_injector_stats_and_ledgers() {
        let root = table2_graph(3, &mut UidGen::new()); // 2 nodes
        let levels = [LevelSpec {
            boot_nodes: 1,
            link: LinkKind::InProc,
        }];
        let h = Hierarchy::build_with_policy(
            root,
            &levels,
            None,
            LinkPolicy {
                chaos: Some(ChaosConfig::client_only(42, FaultRates::none())),
                ..LinkPolicy::default()
            },
        )
        .unwrap();
        h.grow_from_leaf(&table1_jobspec("T7")).unwrap();
        let inj = h.client_injector(1).unwrap();
        assert!(inj.stats().delivered > 0);
        h.reset();
        assert_eq!(inj.stats().delivered, 0, "reset rewinds injector stats");
        assert_eq!(h.parent_link_state(1), "closed");
        h.check_ledgers().unwrap();
        // the dynamic ledger entries are gone: growing again re-grants
        let report = h.grow_from_leaf(&table1_jobspec("T7")).unwrap();
        assert!(!report.roots.is_empty());
        h.check_ledgers().unwrap();
        h.check_all().unwrap();
        h.shutdown();
    }
}
