//! Timing reports for hierarchical MatchGrow operations — the measurements
//! behind the paper's §5.2 figures and the §6 component models:
//! `t_MG = Σ_i t_match_i + t_comms_i + t_add_upd_i`.

use crate::util::json::{Json, JsonError};

/// One level's contribution to a MatchGrow.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LevelTiming {
    pub level: usize,
    /// Local match attempt time (null match unless `match_ok`).
    pub match_s: f64,
    pub match_ok: bool,
    /// RPC round-trip to the parent (zero at the matching level).
    pub comms_s: f64,
    /// AddSubgraph + UpdateMetadata time (zero at the matching level's own
    /// graph, which allocates rather than attaches).
    pub add_upd_s: f64,
    /// Vertices visited by the local matcher.
    pub visited: usize,
}

impl LevelTiming {
    pub fn total(&self) -> f64 {
        self.match_s + self.comms_s + self.add_upd_s
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("level", Json::from(self.level))
            .with("match_s", Json::from(self.match_s))
            .with("match_ok", Json::from(self.match_ok))
            .with("comms_s", Json::from(self.comms_s))
            .with("add_upd_s", Json::from(self.add_upd_s))
            .with("visited", Json::from(self.visited))
    }

    pub fn from_json(doc: &Json) -> Result<LevelTiming, JsonError> {
        let f = |k: &str| -> Result<f64, JsonError> {
            doc.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| JsonError::Schema(format!("timing missing '{k}'")))
        };
        Ok(LevelTiming {
            level: doc.u64_field("level")? as usize,
            match_s: f("match_s")?,
            match_ok: doc.get("match_ok").and_then(Json::as_bool).unwrap_or(false),
            comms_s: f("comms_s")?,
            add_upd_s: f("add_upd_s")?,
            visited: doc.get("visited").and_then(Json::as_u64).unwrap_or(0) as usize,
        })
    }
}

pub fn levels_to_json(levels: &[LevelTiming]) -> Json {
    Json::Arr(levels.iter().map(LevelTiming::to_json).collect())
}

pub fn levels_from_json(doc: &Json) -> Result<Vec<LevelTiming>, String> {
    doc.as_arr()
        .ok_or("levels is not an array")?
        .iter()
        .map(|d| LevelTiming::from_json(d).map_err(|e| e.to_string()))
        .collect()
}

/// Full report of one leaf-initiated MatchGrow: per-level timings ordered
/// top (L0) to bottom (leaf).
#[derive(Debug, Clone)]
pub struct GrowReport {
    pub subgraph_size: usize,
    pub levels: Vec<LevelTiming>,
    /// Wall-clock total at the leaf.
    pub total_s: f64,
    /// Attach-root paths of the granted subgraph — the handles a later
    /// hierarchical shrink uses.
    pub roots: Vec<String>,
}

impl GrowReport {
    /// Sum of component times across levels — the paper reports this covers
    /// ≥98% of the measured total (§6).
    pub fn component_sum(&self) -> f64 {
        self.levels.iter().map(LevelTiming::total).sum()
    }

    pub fn timing_for(&self, level: usize) -> Option<&LevelTiming> {
        self.levels.iter().find(|t| t.level == level)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_json_roundtrip() {
        let t = LevelTiming {
            level: 2,
            match_s: 0.001,
            match_ok: false,
            comms_s: 0.002,
            add_upd_s: 0.003,
            visited: 42,
        };
        let parsed = LevelTiming::from_json(&t.to_json()).unwrap();
        assert_eq!(parsed, t);
        assert!((t.total() - 0.006).abs() < 1e-12);
    }

    #[test]
    fn levels_array_roundtrip() {
        let ts = vec![
            LevelTiming {
                level: 0,
                match_s: 0.1,
                match_ok: true,
                comms_s: 0.0,
                add_upd_s: 0.0,
                visited: 5,
            },
            LevelTiming {
                level: 1,
                match_s: 0.01,
                match_ok: false,
                comms_s: 0.02,
                add_upd_s: 0.03,
                visited: 9,
            },
        ];
        let parsed = levels_from_json(&levels_to_json(&ts)).unwrap();
        assert_eq!(parsed, ts);
    }

    #[test]
    fn component_sum() {
        let r = GrowReport {
            subgraph_size: 70,
            roots: vec!["/cluster0/node9".into()],
            levels: vec![LevelTiming {
                level: 0,
                match_s: 1.0,
                match_ok: true,
                comms_s: 2.0,
                add_upd_s: 3.0,
                visited: 0,
            }],
            total_s: 6.1,
        };
        assert!((r.component_sum() - 6.0).abs() < 1e-12);
        assert!(r.timing_for(0).is_some());
        assert!(r.timing_for(3).is_none());
    }
}
