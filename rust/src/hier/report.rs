//! Timing reports for hierarchical MatchGrow operations — the measurements
//! behind the paper's §5.2 figures and the §6 component models:
//! `t_MG = Σ_i t_match_i + t_comms_i + t_add_upd_i`.
//!
//! The per-level record itself ([`LevelTiming`]) lives in the wire-protocol
//! module ([`crate::rpc::proto`]) — it is part of the `grown` reply's
//! schema — and is re-exported here for hierarchy callers.

// Part of the wire schema; defined with the protocol, consumed here.
pub use crate::rpc::proto::{levels_from_json, levels_to_json, LevelTiming};

/// Full report of one leaf-initiated MatchGrow: per-level timings ordered
/// top (L0) to bottom (leaf).
#[derive(Debug, Clone)]
pub struct GrowReport {
    /// Granted subgraph size (vertices + edges).
    pub subgraph_size: usize,
    /// Per-level timing entries, top (L0) first.
    pub levels: Vec<LevelTiming>,
    /// Wall-clock total at the leaf.
    pub total_s: f64,
    /// Attach-root paths of the granted subgraph — the handles a later
    /// hierarchical shrink uses.
    pub roots: Vec<String>,
}

/// Outcome of one [`crate::hier::Hierarchy::kill_and_restart_level`]
/// cycle: what the write-ahead journal proved, whether it matched the
/// pre-kill live state, and how the post-restart reconciliation fared.
#[derive(Debug, Clone)]
pub struct RestartReport {
    /// The level that was killed and restarted.
    pub level: usize,
    /// Committed op frames replayed on top of the recovery snapshot.
    pub replayed: u64,
    /// Torn frames discarded from the journal tail.
    pub torn: u64,
    /// Well-formed op frames dropped for lack of a commit frame.
    pub uncommitted: u64,
    /// Whether the recovered state was bit-identical to the pre-kill live
    /// state (true for a clean kill; false when a scripted crash site
    /// suppressed durability, i.e. the journal is legitimately behind).
    pub matched_live: bool,
    /// Errors from the parent/child reconcile handshakes (empty on a
    /// fully converged restart; a later retry converges).
    pub reconcile_errors: Vec<String>,
}

impl GrowReport {
    /// Sum of component times across levels — the paper reports this covers
    /// ≥98% of the measured total (§6).
    pub fn component_sum(&self) -> f64 {
        self.levels.iter().map(LevelTiming::total).sum()
    }

    /// The timing entry of one hierarchy level, if it participated.
    pub fn timing_for(&self, level: usize) -> Option<&LevelTiming> {
        self.levels.iter().find(|t| t.level == level)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_json_roundtrip() {
        let t = LevelTiming {
            level: 2,
            match_s: 0.001,
            match_ok: false,
            comms_s: 0.002,
            add_upd_s: 0.003,
            visited: 42,
        };
        let parsed = LevelTiming::from_json(&t.to_json()).unwrap();
        assert_eq!(parsed, t);
        assert!((t.total() - 0.006).abs() < 1e-12);
    }

    #[test]
    fn levels_array_roundtrip() {
        let ts = vec![
            LevelTiming {
                level: 0,
                match_s: 0.1,
                match_ok: true,
                comms_s: 0.0,
                add_upd_s: 0.0,
                visited: 5,
            },
            LevelTiming {
                level: 1,
                match_s: 0.01,
                match_ok: false,
                comms_s: 0.02,
                add_upd_s: 0.03,
                visited: 9,
            },
        ];
        let parsed = levels_from_json(&levels_to_json(&ts)).unwrap();
        assert_eq!(parsed, ts);
    }

    #[test]
    fn component_sum() {
        let r = GrowReport {
            subgraph_size: 70,
            roots: vec!["/cluster0/node9".into()],
            levels: vec![LevelTiming {
                level: 0,
                match_s: 1.0,
                match_ok: true,
                comms_s: 2.0,
                add_upd_s: 3.0,
                visited: 0,
            }],
            total_s: 6.1,
        };
        assert!((r.component_sum() - 6.0).abs() < 1e-12);
        assert!(r.timing_for(0).is_some());
        assert!(r.timing_for(3).is_none());
    }
}
