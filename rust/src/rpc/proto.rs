//! The typed scheduler protocol: every operation any level of the hierarchy
//! can ask of another is a [`SchedOp`], every answer a [`SchedReply`].
//!
//! The paper's central mechanism is that *all* levels speak the same small
//! set of primitives — `MatchAllocate`, `MatchGrow`, `AddSubgraph` /
//! `RemoveSubgraph`, `UpdateMetadata` (§3). This module is that contract in
//! type form: [`crate::sched::SchedInstance::apply`] interprets the
//! instance-local ops, [`crate::hier`] serves the hierarchical ones over
//! RPC, and both enums carry a canonical JSON encoding so the same op is
//! identical in-process and on the wire.
//!
//! ## Wire encoding
//!
//! An op is a JSON object tagged by `"op"`; a reply is tagged by `"reply"`.
//! Field schemas (see each variant's doc for semantics):
//!
//! | `"op"`             | fields                                         |
//! |--------------------|------------------------------------------------|
//! | `match_allocate`   | `spec` (jobspec doc)                           |
//! | `match_grow_local` | `job` (u64), `spec`                            |
//! | `probe`            | `spec`                                         |
//! | `accept_grant`     | `subgraph` (JGF doc), `job` (u64, optional)    |
//! | `free_job`         | `job`                                          |
//! | `shrink_subtree`   | `path` (string)                                |
//! | `remove_subgraph`  | `path`                                         |
//! | `match_grow`       | `spec`                                         |
//! | `shrink_return`    | `path`                                         |
//! | `reconcile`        | `roots` (array of strings)                     |
//!
//! | `"reply"`   | fields                                                  |
//! |-------------|---------------------------------------------------------|
//! | `allocated` | `job`, `subgraph`, `match_s`, `add_upd_s`, `visited`    |
//! | `probed`    | `visited`, `vertices`                                   |
//! | `accepted`  | `added`, `preexisting`, `add_upd_s`                     |
//! | `freed`     | `vertices`                                              |
//! | `removed`   | `vertices`                                              |
//! | `grown`     | `subgraph`, `levels` (array of level-timing docs)       |
//! | `reconciled`| `orphans_released`, `ghosts` (array of strings)         |
//! | `error`     | `code` (string, see [`code`]), `message`                |
//!
//! Unknown tags are decode errors — there is no extensible escape hatch;
//! extending the protocol means adding a variant, and the exhaustive
//! matches in `SchedInstance::apply` and `hier`'s `serve` make every
//! dispatch site a compile error until it handles the new op.
//!
//! Integer fields (`job`, `id`, counts) travel as JSON numbers, which this
//! crate's [`Json`] backs with `f64`: values are exact up to `2^53 - 1`.
//! The in-tree id generators are small sequential counters, far below that
//! bound; remote implementers minting their own ids (shard/epoch bits)
//! must stay within it or the codec will reject/round them.

use crate::jobspec::JobSpec;
use crate::resource::graph::JobId;
use crate::resource::jgf::Jgf;
use crate::util::json::{Json, JsonError};

/// Stable error codes carried by [`RpcError`]. Messages are free-form and
/// for humans; programs branch on the code.
pub mod code {
    /// The matcher found no satisfying free resources.
    pub const NO_MATCH: &str = "no_match";
    /// AddSubgraph / allocation bookkeeping failed (bad attach point,
    /// double allocation, unknown or completed job, ...).
    pub const GROW_FAILED: &str = "grow_failed";
    /// A subtractive transformation (shrink/remove) failed.
    pub const SHRINK_FAILED: &str = "shrink_failed";
    /// A hierarchical MatchGrow could not be satisfied at any level.
    pub const MATCH_GROW_FAILED: &str = "match_grow_failed";
    /// The external resource provider could not satisfy the request — the
    /// cloud said no, distinct from a local [`NO_MATCH`]
    /// (see [`crate::external::provider::ProviderError::code`]).
    pub const PROVIDER_UNSATISFIABLE: &str = "provider_unsatisfiable";
    /// The external resource provider's API itself failed.
    pub const PROVIDER_API: &str = "provider_api";
    /// The RPC link failed (I/O error, peer gone) — distinct from a
    /// well-formed negative answer. The finer-grained [`TIMEOUT`] and
    /// [`DISCONNECTED`] are preferred where the I/O error kind allows
    /// (see [`super::RpcError::from_io`]); `transport` is the residual.
    pub const TRANSPORT: &str = "transport";
    /// The call exceeded its deadline budget (read timeout, injected drop).
    /// For a mutating op this means *outcome unknown*: the peer may have
    /// committed — callers must not blindly re-send (at-most-once).
    pub const TIMEOUT: &str = "timeout";
    /// The peer vanished mid-call (connection reset, broken pipe, EOF
    /// inside a frame). Like [`TIMEOUT`], a mutating op's outcome is
    /// unknown.
    pub const DISCONNECTED: &str = "disconnected";
    /// The target hierarchy level is quarantined: its link tripped the
    /// circuit breaker after repeated timeouts/disconnects and is refusing
    /// traffic until a half-open re-probe restores it. Structured fast-fail
    /// — the caller did not wait a deadline to learn this.
    pub const LEVEL_UNAVAILABLE: &str = "level_unavailable";
    /// The op panicked inside the serving layer. The instance was rolled
    /// back to its pre-op snapshot (graph epoch advanced, caches
    /// invalidated); the lock is NOT poisoned and the service keeps
    /// serving.
    pub const PANIC: &str = "panic";
    /// The level crashed at a scripted crash point (deterministic crash
    /// injection, see [`crate::fault::CrashPlan`]): in-memory state past
    /// the last durable journal frame is considered lost. The caller must
    /// treat the op's outcome as unknown until the level restarts from its
    /// journal and reconciles grant ledgers with its parent.
    pub const CRASHED: &str = "crashed";
    /// The op is valid but not serviceable by the receiver (e.g. a
    /// hierarchical op sent to a bare `SchedInstance`).
    pub const UNSUPPORTED_OP: &str = "unsupported_op";
    /// The request could not be decoded (malformed JSON, unknown op tag,
    /// missing fields).
    pub const BAD_REQUEST: &str = "bad_request";
    /// The peer answered with a well-formed but wrong-variant reply (a
    /// server-side protocol violation, e.g. `freed` to a `match_grow`) —
    /// the caller's request was fine.
    pub const BAD_REPLY: &str = "bad_reply";
}

/// A structured protocol error: a stable machine-readable `code` plus a
/// human-readable `message`. This is the only error shape on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct RpcError {
    /// Stable machine-readable code (vocabulary: [`code`]).
    pub code: String,
    /// Human-readable description; never branch on it.
    pub message: String,
}

impl RpcError {
    /// Build an error from a [`code`] constant and a message.
    pub fn new(code: &str, message: impl Into<String>) -> RpcError {
        RpcError {
            code: code.to_string(),
            message: message.into(),
        }
    }

    /// Canonical wire encoding: `{"code": ..., "message": ...}`.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("code", Json::from(self.code.as_str()))
            .with("message", Json::from(self.message.as_str()))
    }

    /// Decode the canonical wire encoding.
    pub fn from_json(doc: &Json) -> Result<RpcError, JsonError> {
        Ok(RpcError {
            code: doc.str_field("code")?.to_string(),
            message: doc.str_field("message")?.to_string(),
        })
    }

    /// Classify an I/O failure on an RPC link into the typed vocabulary:
    /// timeout kinds map to [`code::TIMEOUT`] (`WouldBlock` included —
    /// POSIX read timeouts surface as either), peer-gone kinds to
    /// [`code::DISCONNECTED`], everything else to the residual
    /// [`code::TRANSPORT`]. `context` prefixes the message (e.g. which
    /// link failed); it never affects the code.
    pub fn from_io(context: &str, e: &std::io::Error) -> RpcError {
        use std::io::ErrorKind as K;
        let code = match e.kind() {
            K::TimedOut | K::WouldBlock => code::TIMEOUT,
            K::BrokenPipe
            | K::ConnectionReset
            | K::ConnectionAborted
            | K::ConnectionRefused
            | K::UnexpectedEof
            | K::NotConnected => code::DISCONNECTED,
            _ => code::TRANSPORT,
        };
        RpcError::new(code, format!("{context}: {e}"))
    }
}

impl std::fmt::Display for RpcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code, self.message)
    }
}

impl std::error::Error for RpcError {}

impl From<RpcError> for String {
    fn from(e: RpcError) -> String {
        e.to_string()
    }
}

/// One level's contribution to a hierarchical MatchGrow — the `levels`
/// entries of a `grown` reply, and the measurements behind the paper's
/// §5.2 figures and §6 component models
/// (`t_MG = Σ_i t_match_i + t_comms_i + t_add_upd_i`).
///
/// Defined here (not in [`crate::hier`]) because it is part of the wire
/// schema: this module alone pins the protocol's field layouts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LevelTiming {
    /// Hierarchy level this entry measures (0 = top).
    pub level: usize,
    /// Local match attempt time (null match unless `match_ok`).
    pub match_s: f64,
    /// Whether the local match succeeded at this level.
    pub match_ok: bool,
    /// RPC round-trip to the parent (zero at the matching level).
    pub comms_s: f64,
    /// AddSubgraph + UpdateMetadata time (zero at the matching level's own
    /// graph, which allocates rather than attaches).
    pub add_upd_s: f64,
    /// Vertices visited by the local matcher.
    pub visited: usize,
}

impl LevelTiming {
    /// Total seconds this level contributed (`match + comms + add/update`).
    pub fn total(&self) -> f64 {
        self.match_s + self.comms_s + self.add_upd_s
    }

    /// Canonical wire encoding of one timing entry.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("level", Json::from(self.level))
            .with("match_s", Json::from(self.match_s))
            .with("match_ok", Json::from(self.match_ok))
            .with("comms_s", Json::from(self.comms_s))
            .with("add_upd_s", Json::from(self.add_upd_s))
            .with("visited", Json::from(self.visited))
    }

    /// Decode one timing entry.
    pub fn from_json(doc: &Json) -> Result<LevelTiming, JsonError> {
        let f = |k: &str| -> Result<f64, JsonError> {
            doc.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| JsonError::Schema(format!("timing missing '{k}'")))
        };
        Ok(LevelTiming {
            level: doc.u64_field("level")? as usize,
            match_s: f("match_s")?,
            match_ok: doc.get("match_ok").and_then(Json::as_bool).unwrap_or(false),
            comms_s: f("comms_s")?,
            add_upd_s: f("add_upd_s")?,
            visited: doc.get("visited").and_then(Json::as_u64).unwrap_or(0) as usize,
        })
    }
}

/// Encode a per-level timing trail (the `levels` field of a `grown` reply).
pub fn levels_to_json(levels: &[LevelTiming]) -> Json {
    Json::Arr(levels.iter().map(LevelTiming::to_json).collect())
}

/// Decode a per-level timing trail (the `levels` field of a `grown` reply).
pub fn levels_from_json(doc: &Json) -> Result<Vec<LevelTiming>, JsonError> {
    doc.as_arr()
        .ok_or_else(|| JsonError::Schema("levels is not an array".into()))?
        .iter()
        .map(LevelTiming::from_json)
        .collect()
}

/// One scheduler operation — the complete request vocabulary of the system.
///
/// Each variant's doc states the success reply it maps to and the error
/// codes its server may answer with; any op can additionally come back as
/// [`code::UNSUPPORTED_OP`] (wrong receiver), [`code::BAD_REQUEST`]
/// (undecodable frame), or [`code::TRANSPORT`] (link failure).
#[derive(Debug, Clone, PartialEq)]
pub enum SchedOp {
    /// The paper's `MatchAllocate`: match `spec` against the local graph and
    /// allocate the selection to a fresh job.
    ///
    /// Reply: [`SchedReply::Allocated`]. Errors: [`code::NO_MATCH`] (no
    /// satisfying free resources), [`code::GROW_FAILED`] (allocation
    /// bookkeeping failed).
    MatchAllocate {
        /// The hierarchical resource request to satisfy.
        spec: JobSpec,
    },
    /// Local half of `MatchGrow`: match free local resources and attach them
    /// to the running job `job`.
    ///
    /// Reply: [`SchedReply::Allocated`] (with `job` echoed). Errors:
    /// [`code::NO_MATCH`], [`code::GROW_FAILED`] (unknown/completed job).
    MatchGrowLocal {
        /// The running job to extend.
        job: JobId,
        /// The additional resources requested.
        spec: JobSpec,
    },
    /// Match without allocating (feasibility probe). The only **read-only**
    /// op (see [`SchedOp::is_read_only`]): concurrent servers fan probes
    /// across a worker pool and may answer repeats from an epoch-keyed
    /// result cache.
    ///
    /// Reply: [`SchedReply::Probed`]. Errors: [`code::NO_MATCH`].
    Probe {
        /// The request whose feasibility is being tested.
        spec: JobSpec,
    },
    /// `AddSubgraph` + `UpdateMetadata`: splice a granted subgraph into the
    /// local graph, optionally charging the new vertices to `job`.
    ///
    /// Reply: [`SchedReply::Accepted`]. Errors: [`code::GROW_FAILED`] (no
    /// attach point, duplicate vertex, unknown job — note the splice may
    /// have partially completed; the graph epoch reflects any mutation).
    AcceptGrant {
        /// The granted subgraph (JGF), parents-before-children.
        subgraph: Jgf,
        /// Job to charge the new vertices to (`None`: add unallocated).
        job: Option<JobId>,
    },
    /// Release all of a job's resources.
    ///
    /// Reply: [`SchedReply::Freed`]. Errors: [`code::SHRINK_FAILED`]
    /// (unknown or already-completed job).
    FreeJob {
        /// The job to release.
        job: JobId,
    },
    /// Release every allocation inside the subtree at `path`, returning the
    /// resources to the free pool; the subtree stays attached (what the
    /// owning level does when a shrink ascends to it).
    ///
    /// Reply: [`SchedReply::Freed`]. Errors: [`code::SHRINK_FAILED`]
    /// (no vertex at `path`, bookkeeping failure).
    ShrinkSubtree {
        /// Containment path of the subtree root.
        path: String,
    },
    /// Subtractive transformation (§3): release the subtree's allocations,
    /// then detach its vertices.
    ///
    /// Reply: [`SchedReply::Removed`]. Errors: [`code::SHRINK_FAILED`].
    RemoveSubgraph {
        /// Containment path of the subtree root.
        path: String,
    },
    /// Hierarchical `MatchGrow` (Algorithm 1): match locally or escalate to
    /// the parent / external provider; the grant descends back down. Served
    /// by a hierarchy node, not a bare instance.
    ///
    /// Reply: [`SchedReply::Grown`]. Errors: [`code::NO_MATCH`],
    /// [`code::GROW_FAILED`], [`code::MATCH_GROW_FAILED`] (no level could
    /// satisfy it), [`code::PROVIDER_UNSATISFIABLE`] / [`code::PROVIDER_API`]
    /// (external provider), [`code::BAD_REPLY`] (ancestor protocol
    /// violation).
    MatchGrow {
        /// The resource request to satisfy somewhere up the hierarchy.
        spec: JobSpec,
    },
    /// Hierarchical shrink ascending from a child: release the subtree at
    /// `path` and keep propagating upward. Served by a hierarchy node.
    ///
    /// Reply: [`SchedReply::Removed`]. Errors: [`code::SHRINK_FAILED`],
    /// [`code::PROVIDER_API`] (burst-instance release failed),
    /// [`code::BAD_REPLY`].
    ShrinkReturn {
        /// Containment path of the subtree being returned.
        path: String,
    },
    /// Grant-ledger reconciliation, child → parent (the restart protocol's
    /// handshake, also the circuit breaker's half-open trial). `roots` is
    /// the child's believed grant ledger: the attach roots of every
    /// subgraph it holds from this parent (boot grant + dynamic grants;
    /// cloud-burst roots from the child's *own* provider excluded). The
    /// parent compares against its own ledger, releases **orphans** (roots
    /// it granted that the child never committed or lost in a crash) and
    /// reports **ghosts** (roots the child claims that the parent has no
    /// record of granting) for the child to cancel. Served by a hierarchy
    /// node; idempotent — repeating it converges.
    ///
    /// Reply: [`SchedReply::Reconciled`]. Errors: [`code::CRASHED`]
    /// (scripted mid-reconcile crash), [`code::LEVEL_UNAVAILABLE`].
    Reconcile {
        /// The child's grant ledger: attach roots of every subgraph it
        /// holds from this parent.
        roots: Vec<String>,
    },
}

impl SchedOp {
    /// Whether this op is **read-only**: it observes the resource graph
    /// without mutating it (or the allocation table), so a server may run
    /// it concurrently with other read-only ops against a shared graph and
    /// answer it from an epoch-keyed result cache. This classification is
    /// what [`crate::sched::SchedService`] partitions batches by and what
    /// `hier`'s serve loop routes around the per-node mutex.
    ///
    /// Today exactly [`SchedOp::Probe`] (the count-only match); every
    /// other op mutates graph or allocation state somewhere in the
    /// hierarchy. A new variant added here defaults to the safe answer
    /// (`false`) only if its arm says so explicitly — the match is
    /// exhaustive on purpose.
    pub fn is_read_only(&self) -> bool {
        match self {
            SchedOp::Probe { .. } => true,
            SchedOp::MatchAllocate { .. }
            | SchedOp::MatchGrowLocal { .. }
            | SchedOp::AcceptGrant { .. }
            | SchedOp::FreeJob { .. }
            | SchedOp::ShrinkSubtree { .. }
            | SchedOp::RemoveSubgraph { .. }
            | SchedOp::MatchGrow { .. }
            | SchedOp::ShrinkReturn { .. }
            | SchedOp::Reconcile { .. } => false,
        }
    }

    /// Canonical wire tag of this op.
    pub fn name(&self) -> &'static str {
        match self {
            SchedOp::MatchAllocate { .. } => "match_allocate",
            SchedOp::MatchGrowLocal { .. } => "match_grow_local",
            SchedOp::Probe { .. } => "probe",
            SchedOp::AcceptGrant { .. } => "accept_grant",
            SchedOp::FreeJob { .. } => "free_job",
            SchedOp::ShrinkSubtree { .. } => "shrink_subtree",
            SchedOp::RemoveSubgraph { .. } => "remove_subgraph",
            SchedOp::MatchGrow { .. } => "match_grow",
            SchedOp::ShrinkReturn { .. } => "shrink_return",
            SchedOp::Reconcile { .. } => "reconcile",
        }
    }

    /// Canonical wire encoding: a JSON object tagged by `"op"` (see the
    /// module's field-schema table).
    pub fn to_json(&self) -> Json {
        let doc = Json::obj().with("op", Json::from(self.name()));
        match self {
            SchedOp::MatchAllocate { spec }
            | SchedOp::Probe { spec }
            | SchedOp::MatchGrow { spec } => doc.with("spec", spec.to_json()),
            SchedOp::MatchGrowLocal { job, spec } => doc
                .with("job", Json::from(job.0))
                .with("spec", spec.to_json()),
            SchedOp::AcceptGrant { subgraph, job } => {
                let mut doc = doc.with("subgraph", subgraph.to_json());
                if let Some(j) = job {
                    doc.set("job", Json::from(j.0));
                }
                doc
            }
            SchedOp::FreeJob { job } => doc.with("job", Json::from(job.0)),
            SchedOp::ShrinkSubtree { path }
            | SchedOp::RemoveSubgraph { path }
            | SchedOp::ShrinkReturn { path } => doc.with("path", Json::from(path.as_str())),
            SchedOp::Reconcile { roots } => doc.with(
                "roots",
                Json::Arr(roots.iter().map(|r| Json::from(r.as_str())).collect()),
            ),
        }
    }

    /// Decode an op document; unknown tags and missing fields are errors.
    pub fn from_json(doc: &Json) -> Result<SchedOp, JsonError> {
        let spec = |d: &Json| -> Result<JobSpec, JsonError> {
            JobSpec::from_json(
                d.get("spec")
                    .ok_or_else(|| JsonError::Schema("op missing 'spec'".into()))?,
            )
        };
        let path = |d: &Json| -> Result<String, JsonError> {
            Ok(d.str_field("path")?.to_string())
        };
        match doc.str_field("op")? {
            "match_allocate" => Ok(SchedOp::MatchAllocate { spec: spec(doc)? }),
            "match_grow_local" => Ok(SchedOp::MatchGrowLocal {
                job: JobId(doc.u64_field("job")?),
                spec: spec(doc)?,
            }),
            "probe" => Ok(SchedOp::Probe { spec: spec(doc)? }),
            "accept_grant" => Ok(SchedOp::AcceptGrant {
                subgraph: Jgf::from_json(
                    doc.get("subgraph")
                        .ok_or_else(|| JsonError::Schema("op missing 'subgraph'".into()))?,
                )?,
                job: match doc.get("job") {
                    None => None,
                    Some(j) => Some(JobId(j.as_u64().ok_or_else(|| {
                        JsonError::Schema("'job' is not an integer".into())
                    })?)),
                },
            }),
            "free_job" => Ok(SchedOp::FreeJob {
                job: JobId(doc.u64_field("job")?),
            }),
            "shrink_subtree" => Ok(SchedOp::ShrinkSubtree { path: path(doc)? }),
            "remove_subgraph" => Ok(SchedOp::RemoveSubgraph { path: path(doc)? }),
            "match_grow" => Ok(SchedOp::MatchGrow { spec: spec(doc)? }),
            "shrink_return" => Ok(SchedOp::ShrinkReturn { path: path(doc)? }),
            "reconcile" => Ok(SchedOp::Reconcile {
                roots: doc
                    .get("roots")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| JsonError::Schema("op missing 'roots'".into()))?
                    .iter()
                    .map(|r| {
                        r.as_str().map(str::to_string).ok_or_else(|| {
                            JsonError::Schema("'roots' entry is not a string".into())
                        })
                    })
                    .collect::<Result<Vec<String>, JsonError>>()?,
            }),
            other => Err(JsonError::Schema(format!("unknown op '{other}'"))),
        }
    }
}

/// The answer to a [`SchedOp`]. Each success variant names the ops it
/// answers; failures of any op travel as [`SchedReply::Error`].
#[derive(Debug, Clone, PartialEq)]
pub enum SchedReply {
    /// `MatchAllocate` / `MatchGrowLocal` succeeded: the job now holds the
    /// selection, returned as a JGF subgraph (the grant a child boots from).
    Allocated {
        /// The job holding the selection (fresh for `MatchAllocate`,
        /// echoed for `MatchGrowLocal`).
        job: JobId,
        /// The selection as a JGF subgraph.
        subgraph: Jgf,
        /// Seconds spent in the match traversal.
        match_s: f64,
        /// Seconds spent marking the allocation / updating metadata.
        add_upd_s: f64,
        /// Vertices visited by the match traversal.
        visited: usize,
    },
    /// `Probe` succeeded: `vertices` would be selected. Probes served from
    /// a result cache repeat the originally measured counts. `vertices` is
    /// a function of graph state, which the epoch pins; `visited` is a
    /// **cost metric of the path that computed the entry** — a sharded
    /// traversal (`SchedService::probe_sharded`) reports an upper bound on
    /// the sequential count, and either path may have warmed the shared
    /// cache, so never branch on `visited` for determinism.
    Probed {
        /// Vertices visited by the traversal that computed this reply
        /// (sequential count, or the sharded upper bound — see above).
        visited: usize,
        /// Vertices the request would select.
        vertices: usize,
    },
    /// `AcceptGrant` spliced the subgraph: `added` new vertices,
    /// `preexisting` were the identity.
    Accepted {
        /// Newly created vertices.
        added: usize,
        /// Vertices that already existed (the addition was the identity).
        preexisting: usize,
        /// Seconds spent in AddSubgraph + UpdateMetadata.
        add_upd_s: f64,
    },
    /// `FreeJob` / `ShrinkSubtree`: `vertices` released to the free pool.
    Freed {
        /// Vertices released.
        vertices: usize,
    },
    /// `RemoveSubgraph` / hierarchical `ShrinkReturn`: `vertices` removed.
    Removed {
        /// Vertices detached from the graph.
        vertices: usize,
    },
    /// Hierarchical `MatchGrow` grant descending: the subgraph plus the
    /// per-level timing trail accumulated top-down.
    Grown {
        /// The granted subgraph.
        subgraph: Jgf,
        /// Per-level timing entries, topmost level first.
        levels: Vec<LevelTiming>,
    },
    /// `Reconcile` completed: the parent released `orphans_released` grants
    /// the child never committed (or lost in a crash) and reports `ghosts`
    /// — roots the child claims that the parent never granted — for the
    /// child to cancel.
    Reconciled {
        /// Parent-side grants released as orphans during this handshake.
        orphans_released: u64,
        /// Child-claimed roots the parent has no grant record of; the
        /// child cancels these subtrees on receipt.
        ghosts: Vec<String>,
    },
    /// The op failed; see [`code`] for the vocabulary.
    Error(RpcError),
}

impl SchedReply {
    /// Canonical wire tag of this reply.
    pub fn name(&self) -> &'static str {
        match self {
            SchedReply::Allocated { .. } => "allocated",
            SchedReply::Probed { .. } => "probed",
            SchedReply::Accepted { .. } => "accepted",
            SchedReply::Freed { .. } => "freed",
            SchedReply::Removed { .. } => "removed",
            SchedReply::Grown { .. } => "grown",
            SchedReply::Reconciled { .. } => "reconciled",
            SchedReply::Error(_) => "error",
        }
    }

    /// Shorthand error constructor.
    pub fn err(code: &str, message: impl Into<String>) -> SchedReply {
        SchedReply::Error(RpcError::new(code, message))
    }

    /// Whether this reply is the error variant.
    pub fn is_error(&self) -> bool {
        matches!(self, SchedReply::Error(_))
    }

    /// The error, if this reply is one (for callers propagating failures).
    pub fn as_error(&self) -> Option<&RpcError> {
        match self {
            SchedReply::Error(e) => Some(e),
            _ => None,
        }
    }

    /// Canonical wire encoding: a JSON object tagged by `"reply"` (see the
    /// module's field-schema table).
    pub fn to_json(&self) -> Json {
        let doc = Json::obj().with("reply", Json::from(self.name()));
        match self {
            SchedReply::Allocated {
                job,
                subgraph,
                match_s,
                add_upd_s,
                visited,
            } => doc
                .with("job", Json::from(job.0))
                .with("subgraph", subgraph.to_json())
                .with("match_s", Json::from(*match_s))
                .with("add_upd_s", Json::from(*add_upd_s))
                .with("visited", Json::from(*visited)),
            SchedReply::Probed { visited, vertices } => doc
                .with("visited", Json::from(*visited))
                .with("vertices", Json::from(*vertices)),
            SchedReply::Accepted {
                added,
                preexisting,
                add_upd_s,
            } => doc
                .with("added", Json::from(*added))
                .with("preexisting", Json::from(*preexisting))
                .with("add_upd_s", Json::from(*add_upd_s)),
            SchedReply::Freed { vertices } | SchedReply::Removed { vertices } => {
                doc.with("vertices", Json::from(*vertices))
            }
            SchedReply::Grown { subgraph, levels } => doc
                .with("subgraph", subgraph.to_json())
                .with("levels", levels_to_json(levels)),
            SchedReply::Reconciled {
                orphans_released,
                ghosts,
            } => doc
                .with("orphans_released", Json::from(*orphans_released))
                .with(
                    "ghosts",
                    Json::Arr(ghosts.iter().map(|g| Json::from(g.as_str())).collect()),
                ),
            SchedReply::Error(e) => {
                // reuse RpcError's field layout so the bare-reply and
                // envelope encodings cannot drift apart
                let mut d = doc;
                if let Json::Obj(fields) = e.to_json() {
                    for (k, v) in fields {
                        d.set(&k, v);
                    }
                }
                d
            }
        }
    }

    /// Decode a reply document; unknown tags and missing fields are errors.
    pub fn from_json(doc: &Json) -> Result<SchedReply, JsonError> {
        let f64_field = |k: &str| -> Result<f64, JsonError> {
            doc.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| JsonError::Schema(format!("reply missing '{k}'")))
        };
        let usize_field = |k: &str| -> Result<usize, JsonError> {
            Ok(doc.u64_field(k)? as usize)
        };
        let subgraph = || -> Result<Jgf, JsonError> {
            Jgf::from_json(
                doc.get("subgraph")
                    .ok_or_else(|| JsonError::Schema("reply missing 'subgraph'".into()))?,
            )
        };
        match doc.str_field("reply")? {
            "allocated" => Ok(SchedReply::Allocated {
                job: JobId(doc.u64_field("job")?),
                subgraph: subgraph()?,
                match_s: f64_field("match_s")?,
                add_upd_s: f64_field("add_upd_s")?,
                visited: usize_field("visited")?,
            }),
            "probed" => Ok(SchedReply::Probed {
                visited: usize_field("visited")?,
                vertices: usize_field("vertices")?,
            }),
            "accepted" => Ok(SchedReply::Accepted {
                added: usize_field("added")?,
                preexisting: usize_field("preexisting")?,
                add_upd_s: f64_field("add_upd_s")?,
            }),
            "freed" => Ok(SchedReply::Freed {
                vertices: usize_field("vertices")?,
            }),
            "removed" => Ok(SchedReply::Removed {
                vertices: usize_field("vertices")?,
            }),
            "grown" => Ok(SchedReply::Grown {
                subgraph: subgraph()?,
                levels: levels_from_json(
                    doc.get("levels")
                        .ok_or_else(|| JsonError::Schema("reply missing 'levels'".into()))?,
                )?,
            }),
            "reconciled" => Ok(SchedReply::Reconciled {
                orphans_released: doc.u64_field("orphans_released")?,
                ghosts: doc
                    .get("ghosts")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| JsonError::Schema("reply missing 'ghosts'".into()))?
                    .iter()
                    .map(|g| {
                        g.as_str().map(str::to_string).ok_or_else(|| {
                            JsonError::Schema("'ghosts' entry is not a string".into())
                        })
                    })
                    .collect::<Result<Vec<String>, JsonError>>()?,
            }),
            "error" => Ok(SchedReply::Error(RpcError::from_json(doc)?)),
            other => Err(JsonError::Schema(format!("unknown reply '{other}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobspec::table1_jobspec;

    fn roundtrip_op(op: SchedOp) {
        let doc = Json::parse(&op.to_json().dump()).unwrap();
        assert_eq!(SchedOp::from_json(&doc).unwrap(), op);
    }

    fn roundtrip_reply(r: SchedReply) {
        let doc = Json::parse(&r.to_json().dump()).unwrap();
        assert_eq!(SchedReply::from_json(&doc).unwrap(), r);
    }

    #[test]
    fn every_op_variant_roundtrips() {
        let spec = table1_jobspec("T7");
        roundtrip_op(SchedOp::MatchAllocate { spec: spec.clone() });
        roundtrip_op(SchedOp::MatchGrowLocal {
            job: JobId(3),
            spec: spec.clone(),
        });
        roundtrip_op(SchedOp::Probe { spec: spec.clone() });
        roundtrip_op(SchedOp::AcceptGrant {
            subgraph: Jgf::default(),
            job: Some(JobId(9)),
        });
        roundtrip_op(SchedOp::AcceptGrant {
            subgraph: Jgf::default(),
            job: None,
        });
        roundtrip_op(SchedOp::FreeJob { job: JobId(7) });
        roundtrip_op(SchedOp::ShrinkSubtree {
            path: "/c0/node1".into(),
        });
        roundtrip_op(SchedOp::RemoveSubgraph {
            path: "/c0/node2".into(),
        });
        roundtrip_op(SchedOp::MatchGrow { spec });
        roundtrip_op(SchedOp::ShrinkReturn {
            path: "/c0/node3".into(),
        });
        roundtrip_op(SchedOp::Reconcile {
            roots: vec!["/c0/node1".into(), "/c0/node4".into()],
        });
        roundtrip_op(SchedOp::Reconcile { roots: vec![] });
    }

    #[test]
    fn every_reply_variant_roundtrips() {
        roundtrip_reply(SchedReply::Allocated {
            job: JobId(1),
            subgraph: Jgf::default(),
            match_s: 0.00123,
            add_upd_s: 4.5e-5,
            visited: 42,
        });
        roundtrip_reply(SchedReply::Probed {
            visited: 10,
            vertices: 35,
        });
        roundtrip_reply(SchedReply::Accepted {
            added: 35,
            preexisting: 1,
            add_upd_s: 0.25,
        });
        roundtrip_reply(SchedReply::Freed { vertices: 12 });
        roundtrip_reply(SchedReply::Removed { vertices: 70 });
        roundtrip_reply(SchedReply::Grown {
            subgraph: Jgf::default(),
            levels: vec![LevelTiming {
                level: 2,
                match_s: 0.5,
                match_ok: false,
                comms_s: 0.125,
                add_upd_s: 0.0625,
                visited: 8,
            }],
        });
        roundtrip_reply(SchedReply::Reconciled {
            orphans_released: 2,
            ghosts: vec!["/c0/node5".into()],
        });
        roundtrip_reply(SchedReply::Reconciled {
            orphans_released: 0,
            ghosts: vec![],
        });
        roundtrip_reply(SchedReply::err(code::NO_MATCH, "no satisfying resources"));
    }

    #[test]
    fn only_probe_is_read_only() {
        let spec = table1_jobspec("T8");
        assert!(SchedOp::Probe { spec: spec.clone() }.is_read_only());
        for op in [
            SchedOp::MatchAllocate { spec: spec.clone() },
            SchedOp::MatchGrowLocal {
                job: JobId(1),
                spec: spec.clone(),
            },
            SchedOp::AcceptGrant {
                subgraph: Jgf::default(),
                job: None,
            },
            SchedOp::FreeJob { job: JobId(1) },
            SchedOp::ShrinkSubtree { path: "/x".into() },
            SchedOp::RemoveSubgraph { path: "/x".into() },
            SchedOp::MatchGrow { spec },
            SchedOp::ShrinkReturn { path: "/x".into() },
            SchedOp::Reconcile {
                roots: vec!["/x".into()],
            },
        ] {
            assert!(!op.is_read_only(), "{} must not be read-only", op.name());
        }
    }

    #[test]
    fn unknown_tags_are_rejected() {
        let op = Json::parse(r#"{"op":"match_teleport","spec":{}}"#).unwrap();
        assert!(SchedOp::from_json(&op).is_err());
        let reply = Json::parse(r#"{"reply":"teleported"}"#).unwrap();
        assert!(SchedReply::from_json(&reply).is_err());
    }

    #[test]
    fn from_io_classifies_error_kinds() {
        use std::io::{Error, ErrorKind};
        let cases = [
            (ErrorKind::TimedOut, code::TIMEOUT),
            (ErrorKind::WouldBlock, code::TIMEOUT),
            (ErrorKind::BrokenPipe, code::DISCONNECTED),
            (ErrorKind::ConnectionReset, code::DISCONNECTED),
            (ErrorKind::UnexpectedEof, code::DISCONNECTED),
            (ErrorKind::InvalidData, code::TRANSPORT),
            (ErrorKind::Other, code::TRANSPORT),
        ];
        for (kind, want) in cases {
            let e = RpcError::from_io("link L2->L1", &Error::new(kind, "boom"));
            assert_eq!(e.code, want, "{kind:?}");
            assert!(e.message.starts_with("link L2->L1: "), "{}", e.message);
        }
    }

    #[test]
    fn missing_fields_are_rejected() {
        for text in [
            r#"{"op":"match_allocate"}"#,
            r#"{"op":"free_job"}"#,
            r#"{"op":"shrink_subtree"}"#,
            r#"{"op":"reconcile"}"#,
            r#"{"reply":"reconciled","ghosts":[]}"#,
            r#"{"reply":"allocated","job":1}"#,
            r#"{"reply":"error","code":"x"}"#,
        ] {
            let doc = Json::parse(text).unwrap();
            assert!(
                SchedOp::from_json(&doc).is_err() && SchedReply::from_json(&doc).is_err(),
                "should reject {text}"
            );
        }
    }
}
