//! RPC transports: in-process duplex channels (intranode) and TCP with
//! injected latency (standing in for the paper's IPoIB internode hop).
//!
//! Both transports carry a per-call **deadline budget**: a call either
//! resolves within it or fails with `ErrorKind::TimedOut` — no call blocks
//! forever on a stalled peer. After any failed call a [`TcpConn`] drops its
//! stream and reconnects on the next call (a timed-out request may still
//! get a late response; reusing the stream would desync request/response
//! correlation). Retry/backoff policy lives above the transport, in
//! [`crate::fault::RetryConn`].

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::rpc::{encode_frame, read_frame, Request, Response};

/// Default per-call deadline budget ([`TcpConn::connect`] and
/// [`InProcServer::connect`] apply it): generous against any simulated
/// latency in the tree, small enough that a hung peer is a bounded wait.
pub const DEFAULT_DEADLINE: Duration = Duration::from_secs(5);

/// Correlation-id sentinel for error responses to requests whose own id
/// could not be decoded. Real ids are small sequential counters (and the
/// JSON codec rejects anything above 2^53), so the sentinel can never
/// collide with — or silently alias — a real in-flight request the way a
/// `0` fallback would.
pub const UNKNOWN_REQUEST_ID: u64 = u64::MAX;

/// Shared request handler. Deliberately `Fn`, not `FnMut`: transports
/// invoke it concurrently (one thread per TCP connection), so per-request
/// serialization is the HANDLER's choice, not the transport's — e.g.
/// `hier`'s node handler routes read-only ops to the lock-free concurrent
/// probe path and takes its node mutex only for mutating ops. Handlers
/// needing mutable state bring their own interior mutability.
pub type Handler = Arc<dyn Fn(Request) -> Response + Send + Sync>;

/// Wrap a closure as a shareable [`Handler`].
pub fn handler<F: Fn(Request) -> Response + Send + Sync + 'static>(f: F) -> Handler {
    Arc::new(f)
}

/// Synthetic link latency: `base` per message + `per_byte` nanoseconds,
/// applied to each direction. Calibrated in `hier::topology` so the
/// internode (L0↔L1) regression slope/intercept dominate the intranode
/// ones, as in the paper's Table 4.
#[derive(Debug, Clone, Copy, Default)]
pub struct Latency {
    /// Fixed cost per message.
    pub base: Duration,
    /// Additional nanoseconds per payload byte.
    pub per_byte_ns: f64,
}

impl Latency {
    /// Zero injected latency.
    pub fn none() -> Latency {
        Latency::default()
    }

    /// Latency of `base_us` microseconds plus `per_byte_ns` ns/byte.
    pub fn of(base_us: u64, per_byte_ns: f64) -> Latency {
        Latency {
            base: Duration::from_micros(base_us),
            per_byte_ns,
        }
    }

    fn apply(&self, bytes: usize) {
        let extra = Duration::from_nanos((self.per_byte_ns * bytes as f64) as u64);
        let total = self.base + extra;
        if total > Duration::ZERO {
            std::thread::sleep(total);
        }
    }
}

/// A client connection a child holds to its parent.
pub trait Conn: Send {
    /// Send one request and block for its response.
    fn call(&mut self, req: &Request) -> std::io::Result<Response>;
}

// ---------------------------------------------------------------------------
// In-process transport
// ---------------------------------------------------------------------------

enum InProcMsg {
    Call(Request, Sender<Response>),
    Shutdown,
}

/// Client half of the in-process transport. Each call uses a fresh reply
/// channel, so a deadline miss cannot desync later calls: the late reply
/// lands in a dropped receiver.
pub struct InProcConn {
    tx: Sender<InProcMsg>,
    /// Per-call deadline; `None` blocks indefinitely (legacy behavior,
    /// opt-in via [`InProcServer::connect_with_deadline`]).
    deadline: Option<Duration>,
}

impl Conn for InProcConn {
    fn call(&mut self, req: &Request) -> std::io::Result<Response> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(InProcMsg::Call(req.clone(), reply_tx))
            .map_err(|_| std::io::Error::new(std::io::ErrorKind::BrokenPipe, "server gone"))?;
        match self.deadline {
            None => reply_rx
                .recv()
                .map_err(|_| std::io::Error::new(std::io::ErrorKind::BrokenPipe, "server gone")),
            Some(d) => reply_rx.recv_timeout(d).map_err(|e| match e {
                RecvTimeoutError::Timeout => std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    format!("no reply within the {d:?} deadline budget"),
                ),
                RecvTimeoutError::Disconnected => {
                    std::io::Error::new(std::io::ErrorKind::BrokenPipe, "server gone")
                }
            }),
        }
    }
}

/// Server handle; dropping it does not stop the thread — call `shutdown`.
pub struct InProcServer {
    tx: Sender<InProcMsg>,
    thread: Option<JoinHandle<()>>,
}

impl InProcServer {
    /// Spawn a server thread around `handler`; `connect` yields clients.
    pub fn spawn(h: Handler) -> InProcServer {
        let (tx, rx): (Sender<InProcMsg>, Receiver<InProcMsg>) = channel();
        let thread = std::thread::spawn(move || {
            while let Ok(msg) = rx.recv() {
                match msg {
                    InProcMsg::Call(req, reply) => {
                        let resp = h(req);
                        let _ = reply.send(resp);
                    }
                    InProcMsg::Shutdown => break,
                }
            }
        });
        InProcServer {
            tx,
            thread: Some(thread),
        }
    }

    /// A new client connection to this server with the
    /// [`DEFAULT_DEADLINE`] call budget.
    pub fn connect(&self) -> InProcConn {
        self.connect_with_deadline(Some(DEFAULT_DEADLINE))
    }

    /// A new client connection with an explicit per-call deadline
    /// (`None` = block indefinitely).
    pub fn connect_with_deadline(&self, deadline: Option<Duration>) -> InProcConn {
        InProcConn {
            tx: self.tx.clone(),
            deadline,
        }
    }

    /// Stop the server thread and join it.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(InProcMsg::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------------
// TCP transport (simulated internode link)
// ---------------------------------------------------------------------------

/// Client half over TCP. Latency is applied per direction on the client so
/// measured round-trips include the simulated link cost.
///
/// Every call runs under a read-timeout **deadline budget** (socket
/// `SO_RCVTIMEO`): a stalled peer costs at most one deadline, never an
/// infinite block. A failed call (timeout, disconnect, undecodable frame)
/// drops the stream; the next call reconnects on a fresh one — a late
/// response to an abandoned request must never be read as the answer to a
/// newer one.
pub struct TcpConn {
    addr: SocketAddr,
    latency: Latency,
    deadline: Option<Duration>,
    stream: Option<TcpStream>,
}

impl TcpConn {
    /// Connect to a server with the [`DEFAULT_DEADLINE`] call budget,
    /// applying `latency` per direction.
    pub fn connect(addr: SocketAddr, latency: Latency) -> std::io::Result<TcpConn> {
        TcpConn::connect_with(addr, latency, Some(DEFAULT_DEADLINE))
    }

    /// Connect with an explicit per-call deadline (`None` = block
    /// indefinitely — legacy behavior, discouraged outside benches).
    pub fn connect_with(
        addr: SocketAddr,
        latency: Latency,
        deadline: Option<Duration>,
    ) -> std::io::Result<TcpConn> {
        let mut conn = TcpConn {
            addr,
            latency,
            deadline,
            stream: None,
        };
        conn.ensure_stream()?;
        Ok(conn)
    }

    fn ensure_stream(&mut self) -> std::io::Result<&mut TcpStream> {
        if self.stream.is_none() {
            let stream = TcpStream::connect(self.addr)?;
            stream.set_nodelay(true)?;
            stream.set_read_timeout(self.deadline)?;
            self.stream = Some(stream);
        }
        Ok(self.stream.as_mut().expect("just ensured"))
    }
}

/// POSIX surfaces a read timeout as either `WouldBlock` or `TimedOut`
/// depending on platform; normalize to `TimedOut` so callers branch on one
/// kind.
fn normalize_timeout(e: std::io::Error) -> std::io::Error {
    if e.kind() == std::io::ErrorKind::WouldBlock {
        std::io::Error::new(std::io::ErrorKind::TimedOut, e)
    } else {
        e
    }
}

impl Conn for TcpConn {
    fn call(&mut self, req: &Request) -> std::io::Result<Response> {
        let frame = encode_frame(&req.to_json());
        self.latency.apply(frame.len());
        let io: std::io::Result<crate::util::json::Json> = (|| {
            let stream = self.ensure_stream()?;
            stream.write_all(&frame)?;
            read_frame(stream)
        })();
        let doc = match io {
            Ok(doc) => doc,
            Err(e) => {
                // stream state is unknown (half-written frame, response
                // still in flight, or mid-frame garbage): drop it so the
                // next call starts clean on a fresh connection
                self.stream = None;
                return Err(normalize_timeout(e));
            }
        };
        let resp = Response::from_json(&doc)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        // response-direction latency (frame length approximated by re-encode)
        self.latency.apply(encode_frame(&resp.to_json()).len());
        Ok(resp)
    }
}

/// TCP server: accepts connections, one frame-loop thread each.
pub struct TcpServer {
    /// The bound listen address (ephemeral localhost port).
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl TcpServer {
    /// Bind an ephemeral localhost port and serve `h` on it. All listener
    /// setup (bind, addr, nonblocking mode) happens before the accept
    /// thread spawns, so every setup failure surfaces as this function's
    /// `Err` — nothing panics inside a detached thread.
    pub fn spawn(h: Handler) -> std::io::Result<TcpServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        // nonblocking BEFORE the thread: a failure here used to be an
        // .expect() inside the accept thread — a panic the caller could
        // neither see nor handle, with the server left permanently wedged
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let h = h.clone();
                        // detached: a connection thread exits when its peer
                        // closes; joining here would deadlock shutdown while
                        // clients are still connected
                        std::thread::spawn(move || serve_conn(stream, h));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(TcpServer {
            addr,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    /// Stop accepting and join the accept thread (connection threads exit
    /// when their peers close).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn serve_conn(mut stream: TcpStream, h: Handler) {
    let _ = stream.set_nodelay(true);
    loop {
        let doc = match read_frame(&mut stream) {
            Ok(d) => d,
            Err(_) => break, // peer closed
        };
        let resp = match Request::from_json(&doc) {
            Ok(req) => h(req),
            // undecodable request: echo its id when the envelope carried
            // one; otherwise answer under the UNKNOWN_REQUEST_ID sentinel —
            // a 0 fallback would alias a real request 0 and hand its caller
            // someone else's bad_request error
            Err(e) => Response::err(
                doc.u64_field("id").unwrap_or(UNKNOWN_REQUEST_ID),
                crate::rpc::proto::code::BAD_REQUEST,
                format!("bad request: {e}"),
            ),
        };
        if stream.write_all(&encode_frame(&resp.to_json())).is_err() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resource::graph::JobId;
    use crate::rpc::proto::{code, SchedOp, SchedReply};

    fn free_op(job: u64) -> SchedOp {
        SchedOp::FreeJob { job: JobId(job) }
    }

    /// Handler replying `Freed { vertices: <request id> }` — enough to see
    /// both directions of the typed codec cross the transport.
    fn mirror_handler() -> Handler {
        handler(|req: Request| {
            Response::ok(
                req.id,
                SchedReply::Freed {
                    vertices: req.id as usize,
                },
            )
        })
    }

    #[test]
    fn inproc_roundtrip() {
        let server = InProcServer::spawn(mirror_handler());
        let mut conn = server.connect();
        let resp = conn.call(&Request::new(5, free_op(1))).unwrap();
        assert_eq!(resp.reply, SchedReply::Freed { vertices: 5 });
        server.shutdown();
    }

    #[test]
    fn inproc_many_clients_share_state() {
        let counter = handler({
            let n = std::sync::atomic::AtomicUsize::new(0);
            move |req: Request| {
                let v = n.fetch_add(1, Ordering::SeqCst) + 1;
                Response::ok(req.id, SchedReply::Freed { vertices: v })
            }
        });
        let server = InProcServer::spawn(counter);
        let mut c1 = server.connect();
        let mut c2 = server.connect();
        c1.call(&Request::new(1, free_op(1))).unwrap();
        let r = c2.call(&Request::new(2, free_op(2))).unwrap();
        assert_eq!(r.reply, SchedReply::Freed { vertices: 2 });
        server.shutdown();
    }

    #[test]
    fn tcp_roundtrip() {
        let server = TcpServer::spawn(mirror_handler()).unwrap();
        let mut conn = TcpConn::connect(server.addr, Latency::none()).unwrap();
        for i in 0..5u64 {
            let resp = conn.call(&Request::new(i, free_op(i))).unwrap();
            assert_eq!(resp.id, i);
            assert_eq!(resp.reply, SchedReply::Freed { vertices: i as usize });
        }
        server.shutdown();
    }

    #[test]
    fn tcp_latency_injection_slows_calls() {
        let server = TcpServer::spawn(mirror_handler()).unwrap();
        let mut fast = TcpConn::connect(server.addr, Latency::none()).unwrap();
        let mut slow =
            TcpConn::connect(server.addr, Latency::of(2000, 0.0)).unwrap();
        let req = Request::new(1, free_op(1));
        let (_, fast_s) = crate::util::metrics::time_it(|| fast.call(&req).unwrap());
        let (_, slow_s) = crate::util::metrics::time_it(|| slow.call(&req).unwrap());
        assert!(slow_s > fast_s + 0.003, "fast={fast_s} slow={slow_s}");
        server.shutdown();
    }

    /// A handler that stalls only its FIRST request (long enough to blow a
    /// small deadline), then answers instantly.
    fn stall_once_handler(stall: Duration) -> Handler {
        handler({
            let first = AtomicBool::new(true);
            move |req: Request| {
                if first.swap(false, Ordering::SeqCst) {
                    std::thread::sleep(stall);
                }
                Response::ok(req.id, SchedReply::Freed { vertices: req.id as usize })
            }
        })
    }

    #[test]
    fn tcp_call_times_out_on_stalled_peer_then_recovers() {
        let server = TcpServer::spawn(stall_once_handler(Duration::from_millis(400))).unwrap();
        let mut conn =
            TcpConn::connect_with(server.addr, Latency::none(), Some(Duration::from_millis(50)))
                .unwrap();
        let t = std::time::Instant::now();
        let err = conn.call(&Request::new(1, free_op(1))).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
        assert!(
            t.elapsed() < Duration::from_millis(350),
            "deadline bounded the wait: {:?}",
            t.elapsed()
        );
        // next call reconnects on a fresh stream — and must NOT be handed
        // the late response to request 1
        let resp = conn.call(&Request::new(2, free_op(2))).unwrap();
        assert_eq!(resp.id, 2);
        assert_eq!(resp.reply, SchedReply::Freed { vertices: 2 });
        server.shutdown();
    }

    #[test]
    fn inproc_call_times_out_on_stalled_server_then_recovers() {
        let server = InProcServer::spawn(stall_once_handler(Duration::from_millis(300)));
        let mut conn = server.connect_with_deadline(Some(Duration::from_millis(40)));
        let err = conn.call(&Request::new(1, free_op(1))).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
        // the server thread is single-threaded and still sleeping; wait it
        // out — once awake, the late reply goes to a dropped receiver and
        // the next call gets ITS OWN answer
        std::thread::sleep(Duration::from_millis(320));
        let resp = conn.call(&Request::new(2, free_op(2))).unwrap();
        assert_eq!(resp.id, 2);
        server.shutdown();
    }

    #[test]
    fn undecodable_request_answers_with_sentinel_id_not_zero() {
        let server = TcpServer::spawn(mirror_handler()).unwrap();
        let mut stream = TcpStream::connect(server.addr).unwrap();
        // a frame with no id and no op: undecodable as a Request
        let garbage = crate::util::json::Json::obj()
            .with("not_an_envelope", crate::util::json::Json::from(true));
        stream.write_all(&encode_frame(&garbage)).unwrap();
        let doc = read_frame(&mut stream).unwrap();
        let resp = Response::from_json(&doc).unwrap();
        assert_eq!(resp.id, UNKNOWN_REQUEST_ID, "sentinel, never request 0");
        let err = resp.reply.as_error().expect("bad_request error");
        assert_eq!(err.code, code::BAD_REQUEST);
        // a malformed request whose envelope DOES carry an id echoes it
        let with_id = crate::util::json::Json::obj()
            .with("id", crate::util::json::Json::from(41u64))
            .with("op", crate::util::json::Json::obj());
        stream.write_all(&encode_frame(&with_id)).unwrap();
        let doc = read_frame(&mut stream).unwrap();
        let resp = Response::from_json(&doc).unwrap();
        assert_eq!(resp.id, 41);
        assert_eq!(resp.reply.as_error().unwrap().code, code::BAD_REQUEST);
        server.shutdown();
    }

    #[test]
    fn tcp_handler_error_propagates() {
        let server = TcpServer::spawn(handler(|req: Request| {
            Response::err(req.id, code::UNSUPPORTED_OP, "no capacity")
        }))
        .unwrap();
        let mut conn = TcpConn::connect(server.addr, Latency::none()).unwrap();
        let resp = conn.call(&Request::new(9, free_op(3))).unwrap();
        let err = resp.reply.as_error().expect("error reply");
        assert_eq!(err.code, code::UNSUPPORTED_OP);
        assert_eq!(err.message, "no capacity");
        server.shutdown();
    }
}
