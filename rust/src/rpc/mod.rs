//! Parent↔child RPC: the wire form of the typed scheduler protocol.
//!
//! This module header is the **compatibility contract** for anything that
//! talks to a scheduler instance remotely (the paper transmits JGF
//! subgraphs "between parent and child schedulers via Remote Procedure Call
//! functionality", §4). The three layers, outermost first:
//!
//! ## 1. Framing
//!
//! Every message is one frame: a 4-byte **big-endian** length prefix
//! followed by exactly that many bytes of UTF-8 JSON. A reader that hits
//! EOF mid-frame reports an error; bytes of a truncated frame are never
//! interpreted. Transports (see [`transport`]): in-process duplex channels
//! ([`transport::InProcServer`]) for the paper's intranode levels,
//! localhost TCP ([`transport::TcpServer`]) with injected latency for the
//! IPoIB internode hop.
//!
//! ## 2. Envelope
//!
//! A request frame is `{"id": <u64>, "op": <op doc>}` — the `id` is echoed
//! verbatim in the response so callers can correlate over pipelined
//! connections. A response frame is exactly one of
//!
//! - `{"id": <u64>, "result": <reply doc>}` — success;
//! - `{"id": <u64>, "error": {"code": <string>, "message": <string>}}` —
//!   failure, with a stable machine-readable code (vocabulary:
//!   [`proto::code`]).
//!
//! A response carrying **both** `result` and `error` (or neither) is
//! malformed and rejected at decode time — ambiguity is a protocol error,
//! not a client-side guess.
//!
//! ## 3. Payload: typed ops and replies
//!
//! The `<op doc>` / `<reply doc>` payloads are the canonical encodings of
//! [`proto::SchedOp`] and [`proto::SchedReply`] — tagged unions keyed by
//! `"op"` / `"reply"`. The op names, their field schemas, and the error
//! codes are documented exhaustively in [`proto`]; *those tables, plus the
//! envelope and framing above, are the whole protocol.* There is no
//! stringly-typed method dispatch: an op unknown to the decoder is a
//! `bad_request` error, and adding a variant forces every serve loop in the
//! crate to handle it (exhaustive match, no wildcard arms).

pub mod proto;
pub mod transport;

pub use proto::{RpcError, SchedOp, SchedReply};

use crate::util::json::{Json, JsonError};

/// A request: correlation id + typed operation.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Correlation id, echoed verbatim in the response.
    pub id: u64,
    /// The typed operation.
    pub op: SchedOp,
}

/// A response: the echoed id + the typed reply. Protocol-level failures
/// travel as [`SchedReply::Error`]; the envelope keeps success and error
/// mutually exclusive on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    /// The request's correlation id.
    pub id: u64,
    /// The typed reply ([`SchedReply::Error`] for failures).
    pub reply: SchedReply,
}

impl Request {
    /// Build a request.
    pub fn new(id: u64, op: SchedOp) -> Request {
        Request { id, op }
    }

    /// The request envelope: `{"id": ..., "op": {...}}`.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("id", Json::from(self.id))
            .with("op", self.op.to_json())
    }

    /// Decode a request envelope.
    pub fn from_json(doc: &Json) -> Result<Request, JsonError> {
        Ok(Request {
            id: doc.u64_field("id")?,
            op: SchedOp::from_json(
                doc.get("op")
                    .ok_or_else(|| JsonError::Schema("request missing 'op'".into()))?,
            )?,
        })
    }
}

impl Response {
    /// A success response (or in-band error: any reply is accepted).
    pub fn ok(id: u64, reply: SchedReply) -> Response {
        Response { id, reply }
    }

    /// An error response from a [`code`](proto::code) + message.
    pub fn err(id: u64, code: &str, message: impl Into<String>) -> Response {
        Response {
            id,
            reply: SchedReply::Error(RpcError::new(code, message)),
        }
    }

    /// The response envelope: `{"id", "result"}` or `{"id", "error"}` —
    /// never both (see the module contract).
    pub fn to_json(&self) -> Json {
        let doc = Json::obj().with("id", Json::from(self.id));
        match &self.reply {
            SchedReply::Error(e) => doc.with("error", e.to_json()),
            reply => doc.with("result", reply.to_json()),
        }
    }

    /// Decode a response envelope, rejecting result/error ambiguity.
    pub fn from_json(doc: &Json) -> Result<Response, JsonError> {
        let id = doc.u64_field("id")?;
        match (doc.get("result"), doc.get("error")) {
            (Some(_), Some(_)) => Err(JsonError::Schema(
                "response carries both 'result' and 'error'".into(),
            )),
            (None, None) => Err(JsonError::Schema(
                "response missing 'result'/'error'".into(),
            )),
            (Some(r), None) => {
                let reply = SchedReply::from_json(r)?;
                if reply.is_error() {
                    // an error reply must travel under the 'error' key;
                    // anything else is an encoder bug or tampering
                    return Err(JsonError::Schema(
                        "error reply under 'result'".into(),
                    ));
                }
                Ok(Response { id, reply })
            }
            (None, Some(e)) => Ok(Response {
                id,
                reply: SchedReply::Error(RpcError::from_json(e)?),
            }),
        }
    }
}

/// Encode a JSON document into a length-prefixed frame.
pub fn encode_frame(doc: &Json) -> Vec<u8> {
    let body = doc.dump().into_bytes();
    let mut frame = Vec::with_capacity(4 + body.len());
    frame.extend_from_slice(&(body.len() as u32).to_be_bytes());
    frame.extend_from_slice(&body);
    frame
}

/// Decode one frame from a reader.
pub fn read_frame(r: &mut impl std::io::Read) -> std::io::Result<Json> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_be_bytes(len_buf) as usize;
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    let text = String::from_utf8(body)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    Json::parse(&text).map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobspec::table1_jobspec;

    #[test]
    fn request_roundtrip() {
        let req = Request::new(
            7,
            SchedOp::MatchGrow {
                spec: table1_jobspec("T7"),
            },
        );
        let parsed = Request::from_json(&Json::parse(&req.to_json().dump()).unwrap()).unwrap();
        assert_eq!(parsed, req);
    }

    #[test]
    fn response_roundtrips_both_arms() {
        let ok = Response::ok(1, SchedReply::Freed { vertices: 4 });
        assert_eq!(Response::from_json(&ok.to_json()).unwrap(), ok);
        let err = Response::err(2, proto::code::NO_MATCH, "nope");
        assert_eq!(Response::from_json(&err.to_json()).unwrap(), err);
    }

    #[test]
    fn response_with_result_and_error_is_malformed() {
        let doc = Json::obj()
            .with("id", Json::from(3u64))
            .with("result", SchedReply::Freed { vertices: 1 }.to_json())
            .with(
                "error",
                RpcError::new(proto::code::NO_MATCH, "conflict").to_json(),
            );
        assert!(Response::from_json(&doc).is_err());
    }

    #[test]
    fn response_with_neither_arm_is_malformed() {
        let doc = Json::obj().with("id", Json::from(3u64));
        assert!(Response::from_json(&doc).is_err());
    }

    #[test]
    fn response_error_must_be_structured() {
        // the legacy bare-string error shape is rejected
        let doc = Json::obj()
            .with("id", Json::from(1u64))
            .with("error", Json::from("denied"));
        assert!(Response::from_json(&doc).is_err());
    }

    #[test]
    fn frame_roundtrip() {
        let doc = Json::obj().with("k", Json::from("v"));
        let frame = encode_frame(&doc);
        let mut cursor = std::io::Cursor::new(frame);
        let parsed = read_frame(&mut cursor).unwrap();
        assert_eq!(parsed, doc);
    }

    #[test]
    fn frame_rejects_truncation() {
        let doc = Json::obj().with("k", Json::from("v"));
        let mut frame = encode_frame(&doc);
        frame.truncate(frame.len() - 2);
        let mut cursor = std::io::Cursor::new(frame);
        assert!(read_frame(&mut cursor).is_err());
    }
}
