//! Parent↔child RPC: length-prefixed JSON messages over two transports.
//!
//! The paper transmits JGF subgraphs "between parent and child schedulers
//! via Remote Procedure Call functionality built into the Flux RJMS
//! framework" (§4). We reproduce the same pairwise request/response pattern
//! with two interchangeable transports:
//!
//! - [`transport::Transport::InProc`] — an in-process duplex channel (the
//!   paper's *intranode* levels 2–4, which share node1);
//! - [`transport::Transport::Tcp`] — a localhost TCP socket with optional
//!   injected per-message + per-byte latency, standing in for the paper's
//!   IPoIB *internode* hop between level 1 and level 0 (see DESIGN.md
//!   "Substitutions").
//!
//! Framing: 4-byte big-endian length + UTF-8 JSON body.

pub mod transport;

use crate::util::json::{Json, JsonError};

/// A request: method name + params document.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub method: String,
    pub params: Json,
}

/// A response: either a result document or an error string.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub result: Result<Json, String>,
}

impl Request {
    pub fn new(id: u64, method: &str, params: Json) -> Request {
        Request {
            id,
            method: method.to_string(),
            params,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("id", Json::from(self.id))
            .with("method", Json::from(self.method.as_str()))
            .with("params", self.params.clone())
    }

    pub fn from_json(doc: &Json) -> Result<Request, JsonError> {
        Ok(Request {
            id: doc.u64_field("id")?,
            method: doc.str_field("method")?.to_string(),
            params: doc.get("params").cloned().unwrap_or(Json::Null),
        })
    }
}

impl Response {
    pub fn ok(id: u64, result: Json) -> Response {
        Response {
            id,
            result: Ok(result),
        }
    }

    pub fn err(id: u64, msg: impl Into<String>) -> Response {
        Response {
            id,
            result: Err(msg.into()),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj().with("id", Json::from(self.id));
        match &self.result {
            Ok(v) => doc.set("result", v.clone()),
            Err(e) => doc.set("error", Json::from(e.as_str())),
        };
        doc
    }

    pub fn from_json(doc: &Json) -> Result<Response, JsonError> {
        let id = doc.u64_field("id")?;
        if let Some(e) = doc.get("error").and_then(Json::as_str) {
            Ok(Response::err(id, e))
        } else {
            Ok(Response::ok(
                id,
                doc.get("result")
                    .cloned()
                    .ok_or_else(|| JsonError::Schema("response missing result/error".into()))?,
            ))
        }
    }
}

/// Encode a JSON document into a length-prefixed frame.
pub fn encode_frame(doc: &Json) -> Vec<u8> {
    let body = doc.dump().into_bytes();
    let mut frame = Vec::with_capacity(4 + body.len());
    frame.extend_from_slice(&(body.len() as u32).to_be_bytes());
    frame.extend_from_slice(&body);
    frame
}

/// Decode one frame from a reader.
pub fn read_frame(r: &mut impl std::io::Read) -> std::io::Result<Json> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_be_bytes(len_buf) as usize;
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    let text = String::from_utf8(body)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    Json::parse(&text).map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let req = Request::new(7, "matchgrow", Json::obj().with("x", Json::from(1u64)));
        let parsed = Request::from_json(&req.to_json()).unwrap();
        assert_eq!(parsed, req);
    }

    #[test]
    fn response_roundtrips_both_arms() {
        let ok = Response::ok(1, Json::from("fine"));
        assert_eq!(Response::from_json(&ok.to_json()).unwrap(), ok);
        let err = Response::err(2, "nope");
        assert_eq!(Response::from_json(&err.to_json()).unwrap(), err);
    }

    #[test]
    fn frame_roundtrip() {
        let doc = Json::obj().with("k", Json::from("v"));
        let frame = encode_frame(&doc);
        let mut cursor = std::io::Cursor::new(frame);
        let parsed = read_frame(&mut cursor).unwrap();
        assert_eq!(parsed, doc);
    }

    #[test]
    fn frame_rejects_truncation() {
        let doc = Json::obj().with("k", Json::from("v"));
        let mut frame = encode_frame(&doc);
        frame.truncate(frame.len() - 2);
        let mut cursor = std::io::Cursor::new(frame);
        assert!(read_frame(&mut cursor).is_err());
    }
}
