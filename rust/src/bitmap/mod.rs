//! Bitmap-based baseline scheduler — the traditional resource model the
//! paper argues against (§2.2, §5.3).
//!
//! "Slurm and PBS Pro base their resource data models on simplistic, rigid
//! representation schemes such as bitmaps. A bitmap is a rigid
//! representation of a set of homogeneous compute nodes and their states
//! where each bit represents whether a node is allocated or free."
//!
//! This module implements that model faithfully — node-type partitions with
//! word-packed free/allocated bitmaps and bitwise idle-node scans — plus the
//! **static cloud configuration generator** that reproduces the paper's
//! blowup: encoding 300 instance types × 77 availability zones × 128
//! instances/type yields a 2,958,600-node partition that a static-config
//! scheduler must enumerate up front, while the graph model binds the same
//! resources dynamically per request.

pub mod config;

use std::collections::HashMap;

/// A plain word-packed bit set, reused by the matcher's scratch state for
/// its tentative-selection marks (`sched::matcher::MatchScratch`): the same
/// packed representation the baseline scheduler uses for node states, here
/// as a general-purpose container indexed by arbitrary ids.
#[derive(Debug, Clone, Default)]
pub struct BitSet {
    words: Vec<u64>,
}

impl BitSet {
    /// An empty bitset (grows on demand via `ensure`).
    pub fn new() -> BitSet {
        BitSet::default()
    }

    /// Grow (never shrink) to hold at least `nbits` bits; new bits are 0.
    pub fn ensure(&mut self, nbits: usize) {
        let words = nbits.div_ceil(64);
        if self.words.len() < words {
            self.words.resize(words, 0);
        }
    }

    #[inline]
    /// Whether bit `i` is set (false beyond the backing words).
    pub fn get(&self, i: usize) -> bool {
        self.words
            .get(i / 64)
            .map(|w| w & (1u64 << (i % 64)) != 0)
            .unwrap_or(false)
    }

    /// Set bit `i`. Callers must have `ensure`d capacity.
    #[inline]
    pub fn set(&mut self, i: usize) {
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    #[inline]
    /// Clear bit `i` (no-op beyond the backing words).
    pub fn clear(&mut self, i: usize) {
        if let Some(w) = self.words.get_mut(i / 64) {
            *w &= !(1u64 << (i % 64));
        }
    }

    /// Zero every bit, keeping the backing capacity.
    pub fn clear_all(&mut self) {
        for w in &mut self.words {
            *w = 0;
        }
    }

    /// Number of set bits.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// OR every bit of `other` into `self`, growing (never shrinking) the
    /// backing words to cover `other`. One word-wise pass — this is the
    /// merge primitive of the sharded match path
    /// (`sched::matcher::run_shard` seeds each shard-local selection from
    /// the dispatcher's already-merged set with it).
    pub fn union_with(&mut self, other: &BitSet) {
        if self.words.len() < other.words.len() {
            self.words.resize(other.words.len(), 0);
        }
        for (w, o) in self.words.iter_mut().zip(&other.words) {
            *w |= *o;
        }
    }

    /// Backing word count (capacity telemetry for scratch-reuse tests).
    pub fn words_len(&self) -> usize {
        self.words.len()
    }
}

/// A homogeneous node-type partition with a free bitmap.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Partition (node-type) name.
    pub name: String,
    /// CPUs per node.
    pub cpus_per_node: u64,
    /// Memory per node in MiB.
    pub mem_mib_per_node: u64,
    /// Configured node count.
    pub nodes: usize,
    /// Bit i set = node i is FREE. Word-packed, as real bitmap schedulers do.
    free: Vec<u64>,
}

impl Partition {
    /// A fully-free partition of `nodes` identical nodes.
    pub fn new(name: &str, nodes: usize, cpus: u64, mem_mib: u64) -> Partition {
        let words = nodes.div_ceil(64);
        let mut free = vec![u64::MAX; words];
        // clear the tail bits beyond `nodes`
        let tail = nodes % 64;
        if tail != 0 {
            free[words - 1] = (1u64 << tail) - 1;
        }
        Partition {
            name: name.to_string(),
            cpus_per_node: cpus,
            mem_mib_per_node: mem_mib,
            nodes,
            free,
        }
    }

    /// Number of idle nodes.
    pub fn free_count(&self) -> usize {
        self.free.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Find and claim `k` idle nodes with bitwise scans ("a few bitwise
    /// operators to find idle nodes"). Returns their indices, or None
    /// without claiming anything if fewer than `k` are free.
    pub fn allocate(&mut self, k: usize) -> Option<Vec<usize>> {
        if self.free_count() < k {
            return None;
        }
        let mut picked = Vec::with_capacity(k);
        'outer: for (wi, word) in self.free.iter_mut().enumerate() {
            while *word != 0 {
                let bit = word.trailing_zeros() as usize;
                *word &= *word - 1; // clear lowest set bit
                picked.push(wi * 64 + bit);
                if picked.len() == k {
                    break 'outer;
                }
            }
        }
        Some(picked)
    }

    /// Release nodes back to the pool.
    pub fn release(&mut self, indices: &[usize]) {
        for &i in indices {
            assert!(i < self.nodes, "release out of range");
            self.free[i / 64] |= 1u64 << (i % 64);
        }
    }

    /// Memory footprint of the bitmap itself (bytes).
    pub fn bitmap_bytes(&self) -> usize {
        self.free.len() * 8
    }
}

/// The bitmap scheduler: static partitions defined entirely up front.
/// Adding a new node *type* requires regenerating the configuration and
/// re-initializing — the rigidity the paper contrasts with graph editing.
#[derive(Debug, Default)]
pub struct BitmapScheduler {
    /// All partitions, in configuration order.
    pub partitions: Vec<Partition>,
    index: HashMap<String, usize>,
}

impl BitmapScheduler {
    /// A scheduler with no partitions.
    pub fn new() -> BitmapScheduler {
        BitmapScheduler::default()
    }

    /// Append a partition.
    pub fn add_partition(&mut self, p: Partition) {
        self.index.insert(p.name.clone(), self.partitions.len());
        self.partitions.push(p);
    }

    /// Look up a partition by name.
    pub fn partition(&self, name: &str) -> Option<&Partition> {
        self.index.get(name).map(|&i| &self.partitions[i])
    }

    /// Mutable lookup of a partition by name.
    pub fn partition_mut(&mut self, name: &str) -> Option<&mut Partition> {
        let i = *self.index.get(name)?;
        Some(&mut self.partitions[i])
    }

    /// Total configured nodes across partitions.
    pub fn total_nodes(&self) -> usize {
        self.partitions.iter().map(|p| p.nodes).sum()
    }

    /// Allocate `k` nodes with ≥ cpus/mem per node, scanning partitions in
    /// definition order (first fit — Slurm's default without weights).
    pub fn allocate(
        &mut self,
        k: usize,
        min_cpus: u64,
        min_mem_mib: u64,
    ) -> Option<(String, Vec<usize>)> {
        for p in &mut self.partitions {
            if p.cpus_per_node >= min_cpus && p.mem_mib_per_node >= min_mem_mib {
                if let Some(nodes) = p.allocate(k) {
                    return Some((p.name.clone(), nodes));
                }
            }
        }
        None
    }

    /// Total bitmap memory (bytes) — what the static model costs even when
    /// idle, before daemon state multiplies it.
    pub fn bitmap_bytes(&self) -> usize {
        self.partitions.iter().map(Partition::bitmap_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitset_roundtrip() {
        let mut b = BitSet::new();
        b.ensure(130);
        assert_eq!(b.words_len(), 3);
        assert!(!b.get(0) && !b.get(129));
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129));
        assert_eq!(b.count(), 3);
        b.clear(64);
        assert!(!b.get(64));
        b.clear_all();
        assert_eq!(b.count(), 0);
        assert_eq!(b.words_len(), 3, "clear keeps capacity");
        // out-of-range reads are false, never a panic
        assert!(!b.get(100_000));
        // ensure never shrinks
        b.ensure(10);
        assert_eq!(b.words_len(), 3);
    }

    #[test]
    fn union_with_merges_and_grows() {
        let mut a = BitSet::new();
        a.ensure(64);
        a.set(3);
        let mut b = BitSet::new();
        b.ensure(130);
        b.set(3);
        b.set(129);
        a.union_with(&b);
        assert!(a.get(3) && a.get(129));
        assert_eq!(a.count(), 2);
        assert_eq!(a.words_len(), 3, "union grows to cover the other set");
        // union with a smaller set neither shrinks nor clears
        let small = BitSet::new();
        a.union_with(&small);
        assert_eq!(a.count(), 2);
        assert_eq!(a.words_len(), 3);
        // self-union idempotence via an equal set
        let c = a.clone();
        a.union_with(&c);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn fresh_partition_all_free() {
        let p = Partition::new("batch", 100, 32, 64_000);
        assert_eq!(p.free_count(), 100);
        assert_eq!(p.bitmap_bytes(), 16); // 2 words
    }

    #[test]
    fn allocate_release_roundtrip() {
        let mut p = Partition::new("batch", 130, 32, 64_000);
        let nodes = p.allocate(70).unwrap();
        assert_eq!(nodes.len(), 70);
        assert_eq!(p.free_count(), 60);
        p.release(&nodes);
        assert_eq!(p.free_count(), 130);
    }

    #[test]
    fn over_allocation_fails_atomically() {
        let mut p = Partition::new("batch", 10, 32, 64_000);
        assert!(p.allocate(11).is_none());
        assert_eq!(p.free_count(), 10); // nothing claimed
        assert!(p.allocate(10).is_some());
        assert!(p.allocate(1).is_none());
    }

    #[test]
    fn tail_bits_not_allocatable() {
        let mut p = Partition::new("batch", 65, 1, 1);
        let nodes = p.allocate(65).unwrap();
        assert!(nodes.iter().all(|&n| n < 65));
        assert_eq!(p.free_count(), 0);
    }

    #[test]
    fn scheduler_first_fit_by_constraints() {
        let mut s = BitmapScheduler::new();
        s.add_partition(Partition::new("small", 4, 2, 4_000));
        s.add_partition(Partition::new("big", 4, 64, 512_000));
        let (part, _) = s.allocate(1, 32, 0).unwrap();
        assert_eq!(part, "big");
        let (part, _) = s.allocate(1, 1, 0).unwrap();
        assert_eq!(part, "small");
        assert!(s.allocate(1, 128, 0).is_none());
    }

    #[test]
    fn release_via_scheduler() {
        let mut s = BitmapScheduler::new();
        s.add_partition(Partition::new("p", 8, 4, 1000));
        let (_, nodes) = s.allocate(8, 1, 1).unwrap();
        assert!(s.allocate(1, 1, 1).is_none());
        s.partition_mut("p").unwrap().release(&nodes);
        assert!(s.allocate(1, 1, 1).is_some());
    }
}
