//! Lock-cheap serving telemetry: per-op-kind latency histograms + counters.
//!
//! The serving layer ([`crate::sched::SchedService`], [`crate::hier`]) needs
//! latency observability that costs nothing on the op path: recording one
//! latency is two `Instant` reads plus a handful of `Relaxed` atomic
//! increments — no locks, no allocation, O(1) bucket arithmetic — so the
//! gated `batch/*` hotpath rows (which run on the raw
//! [`crate::sched::SchedInstance`] anyway) cannot regress from it.
//!
//! Three pieces:
//!
//! - [`LatencyHistogram`] — an HDR-style **log-linear** histogram: exact
//!   buckets below 16 ns, then 16 sub-buckets per power-of-two octave up to
//!   `u64::MAX` ns (≤ 6.25 % relative error), each bucket an `AtomicU64`.
//!   Quantiles (p50/p95/p99/…) are reconstructed from bucket midpoints at
//!   snapshot time, clamped into the exact recorded `[min, max]`.
//! - [`Telemetry`] — a set of histograms keyed by op kind (the ten
//!   [`SchedOp`] wire names by default, or any caller-supplied kind list),
//!   plus global counters (cache hits/misses, pre-check rejections,
//!   retries, breaker trips, rollbacks, journal appends/replays,
//!   reconciles) and sustained-throughput windows.
//! - [`TelemetrySnapshot`] — a point-in-time copy with percentile
//!   accessors and a JSON export ([`TelemetrySnapshot::to_json`]) that the
//!   serving bench folds into `BENCH_serving.json` rows.
//!
//! Built on [`crate::util::stats`] ([`Summary`] synthesis for bench rows)
//! and the same zero-external-deps discipline as
//! [`crate::util::metrics`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::rpc::proto::SchedOp;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Sub-bucket resolution: 2^4 = 16 linear sub-buckets per octave, giving a
/// worst-case relative error of 1/16 = 6.25 % on reconstructed quantiles.
const SUB_BITS: u32 = 4;
/// Sub-buckets per octave.
const SUB: usize = 1 << SUB_BITS;
/// Total bucket count: `SUB` exact buckets for values `< SUB`, then `SUB`
/// sub-buckets for each of the `64 - SUB_BITS` octaves up to `u64::MAX`.
pub const BUCKETS: usize = SUB * (64 - SUB_BITS as usize) + SUB;

/// Bucket index of a nanosecond value (O(1): a leading-zeros count and two
/// shifts). Values below `2 * SUB` map to exact single-value buckets.
pub fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros();
    let sub = ((v >> (msb - SUB_BITS)) as usize) & (SUB - 1);
    SUB * (msb - SUB_BITS) as usize + SUB + sub
}

/// Inclusive `[lo, hi]` value range of a bucket — the inverse of
/// [`bucket_index`] (every `v` in the returned range maps back to `index`).
pub fn bucket_bounds(index: usize) -> (u64, u64) {
    assert!(index < BUCKETS, "bucket index {index} out of range");
    if index < SUB {
        return (index as u64, index as u64);
    }
    let octave = ((index - SUB) / SUB) as u32;
    let sub = (index % SUB) as u64;
    let lo = ((SUB as u64) + sub) << octave;
    let hi = lo + (1u64 << octave) - 1;
    (lo, hi)
}

/// A concurrent log-linear latency histogram in nanoseconds. Recording is
/// wait-free: one bucket `fetch_add` plus count/sum/min/max updates, all
/// `Relaxed` (per-op ordering is irrelevant to a distribution).
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
    /// `u64::MAX` until the first record.
    min_ns: AtomicU64,
}

impl LatencyHistogram {
    /// An empty histogram (allocates its bucket array once, up front — the
    /// record path never allocates).
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
            min_ns: AtomicU64::new(u64::MAX),
        }
    }

    /// Record one latency.
    pub fn record(&self, latency: Duration) {
        let ns = u64::try_from(latency.as_nanos()).unwrap_or(u64::MAX);
        self.record_ns(ns);
    }

    /// Record one latency given directly in nanoseconds.
    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
        self.min_ns.fetch_min(ns, Ordering::Relaxed);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of the distribution. Concurrent recording keeps
    /// the snapshot *approximately* consistent (bucket loads are not one
    /// atomic transaction); totals are re-derived from the copied buckets
    /// so the snapshot is internally consistent with itself.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let buckets: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let count = buckets.iter().sum();
        HistogramSnapshot {
            count,
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            max_ns: self.max_ns.load(Ordering::Relaxed),
            min_ns: match self.min_ns.load(Ordering::Relaxed) {
                u64::MAX => 0,
                v => v,
            },
            buckets,
        }
    }
}

impl Default for LatencyHistogram {
    fn default() -> LatencyHistogram {
        LatencyHistogram::new()
    }
}

/// A point-in-time copy of a [`LatencyHistogram`]: quantile reconstruction,
/// [`Summary`] synthesis for bench rows, JSON export.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Samples recorded (sum of bucket counts at snapshot time).
    pub count: u64,
    /// Sum of all recorded values, in nanoseconds.
    pub sum_ns: u64,
    /// Largest recorded value (exact, not bucket-quantized).
    pub max_ns: u64,
    /// Smallest recorded value (exact; 0 when empty).
    pub min_ns: u64,
    buckets: Vec<u64>,
}

impl HistogramSnapshot {
    /// The quantile `q ∈ [0, 1]` reconstructed from bucket midpoints and
    /// clamped into the exact recorded `[min, max]` range. Returns 0 for an
    /// empty snapshot — never panics, never NaN.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        // 1-based rank of the target observation, nearest-rank style
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                let (lo, hi) = bucket_bounds(i);
                let mid = lo + (hi - lo) / 2;
                return mid.clamp(self.min_ns, self.max_ns);
            }
        }
        self.max_ns
    }

    /// [`Self::quantile_ns`] in seconds.
    pub fn quantile_s(&self, q: f64) -> f64 {
        self.quantile_ns(q) as f64 * 1e-9
    }

    /// Median latency in nanoseconds.
    pub fn p50_ns(&self) -> u64 {
        self.quantile_ns(0.50)
    }

    /// 95th-percentile latency in nanoseconds.
    pub fn p95_ns(&self) -> u64 {
        self.quantile_ns(0.95)
    }

    /// 99th-percentile latency in nanoseconds.
    pub fn p99_ns(&self) -> u64 {
        self.quantile_ns(0.99)
    }

    /// Mean latency in nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Synthesize a [`Summary`] (in **seconds**, the bench-row unit) from
    /// the bucketed distribution: quartiles from bucket midpoints, mean
    /// from the exact sum, std approximated from bucket midpoints. An empty
    /// snapshot yields the all-zero `n = 0` summary — no NaN anywhere.
    pub fn to_summary(&self) -> Summary {
        if self.count == 0 {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                q1: 0.0,
                median: 0.0,
                q3: 0.0,
                max: 0.0,
            };
        }
        let mean_s = self.mean_ns() * 1e-9;
        let mut var_acc = 0.0f64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let (lo, hi) = bucket_bounds(i);
            let mid_s = (lo + (hi - lo) / 2) as f64 * 1e-9;
            var_acc += c as f64 * (mid_s - mean_s) * (mid_s - mean_s);
        }
        let std = if self.count > 1 {
            (var_acc / (self.count - 1) as f64).sqrt()
        } else {
            0.0
        };
        Summary {
            n: self.count as usize,
            mean: mean_s,
            std,
            min: self.min_ns as f64 * 1e-9,
            q1: self.quantile_s(0.25),
            median: self.quantile_s(0.50),
            q3: self.quantile_s(0.75),
            max: self.max_ns as f64 * 1e-9,
        }
    }

    /// Merge another snapshot's distribution into this one (exact: buckets
    /// add, min/max/sum/count combine). Used to aggregate per-level or
    /// per-phase snapshots into one report row.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        let self_empty = self.count == 0;
        let other_empty = other.count == 0;
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
        // min_ns is 0 for an empty side, which would wrongly win the min
        self.min_ns = match (self_empty, other_empty) {
            (true, true) => 0,
            (true, false) => other.min_ns,
            (false, true) => self.min_ns,
            (false, false) => self.min_ns.min(other.min_ns),
        };
    }
}

/// Stable wire names of the ten [`SchedOp`] kinds, in [`kind_index`]
/// order — the default kind set of [`Telemetry::new`].
pub static KIND_NAMES: [&str; 10] = [
    "match_allocate",
    "match_grow_local",
    "probe",
    "accept_grant",
    "free_job",
    "shrink_subtree",
    "remove_subgraph",
    "match_grow",
    "shrink_return",
    "reconcile",
];

/// Index of the `probe` kind in [`KIND_NAMES`] (the one read-only op; the
/// service's probe paths record under it directly).
pub const KIND_PROBE: usize = 2;

/// The [`KIND_NAMES`] index of an op (total over all ten kinds).
pub fn kind_index(op: &SchedOp) -> usize {
    match op {
        SchedOp::MatchAllocate { .. } => 0,
        SchedOp::MatchGrowLocal { .. } => 1,
        SchedOp::Probe { .. } => 2,
        SchedOp::AcceptGrant { .. } => 3,
        SchedOp::FreeJob { .. } => 4,
        SchedOp::ShrinkSubtree { .. } => 5,
        SchedOp::RemoveSubgraph { .. } => 6,
        SchedOp::MatchGrow { .. } => 7,
        SchedOp::ShrinkReturn { .. } => 8,
        SchedOp::Reconcile { .. } => 9,
    }
}

/// Per-kind series: one histogram plus op/error counters.
struct KindStats {
    hist: LatencyHistogram,
    ops: AtomicU64,
    errors: AtomicU64,
}

/// Sustained-throughput windows: ops are counted into fixed-width time
/// slots from the telemetry's start instant; the last slot absorbs
/// overflow so recording never fails (a soak longer than the horizon just
/// blurs its tail window).
struct RateWindows {
    window_ms: u64,
    slots: Vec<AtomicU64>,
}

impl RateWindows {
    fn new(window_ms: u64, max_windows: usize) -> RateWindows {
        RateWindows {
            window_ms: window_ms.max(1),
            slots: (0..max_windows.max(1)).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    fn record(&self, elapsed_ms: u64) {
        let idx = ((elapsed_ms / self.window_ms) as usize).min(self.slots.len() - 1);
        self.slots[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Zero every slot. The window origin cannot be rebased behind `&self`
    /// (slot indices still derive from the telemetry's start instant), but
    /// counts recorded before the reset no longer leak into later
    /// snapshots — the stale-rate fix [`Telemetry::reset_rate_windows`]
    /// rides on.
    fn reset(&self) {
        for s in &self.slots {
            s.store(0, Ordering::Relaxed);
        }
    }

    fn snapshot(&self, elapsed_ms: u64) -> ThroughputSnapshot {
        let complete = ((elapsed_ms / self.window_ms) as usize).min(self.slots.len());
        let total_all: u64 = self.slots.iter().map(|s| s.load(Ordering::Relaxed)).sum();
        let per_window_to_per_sec = 1000.0 / self.window_ms as f64;
        let mut peak = 0u64;
        let mut in_complete = 0u64;
        for s in self.slots.iter().take(complete) {
            let v = s.load(Ordering::Relaxed);
            peak = peak.max(v);
            in_complete += v;
        }
        let sustained = if complete > 0 {
            in_complete as f64 / (complete as f64 * self.window_ms as f64 / 1000.0)
        } else if elapsed_ms > 0 {
            total_all as f64 / (elapsed_ms as f64 / 1000.0)
        } else {
            0.0
        };
        ThroughputSnapshot {
            window_ms: self.window_ms,
            windows_complete: complete,
            peak_window_ops_per_sec: peak as f64 * per_window_to_per_sec,
            sustained_ops_per_sec: sustained,
        }
    }
}

/// Point-in-time throughput figures derived from the rate windows.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ThroughputSnapshot {
    /// Window width the series was counted at.
    pub window_ms: u64,
    /// Fully elapsed windows at snapshot time (partial tail excluded).
    pub windows_complete: usize,
    /// Busiest complete window, scaled to ops/sec (0 if none complete).
    pub peak_window_ops_per_sec: f64,
    /// Mean rate over the complete windows (falls back to total/elapsed
    /// when the run is shorter than one window).
    pub sustained_ops_per_sec: f64,
}

/// Default rate-window width.
const DEFAULT_WINDOW_MS: u64 = 250;
/// Default rate-window horizon (250 ms × 2400 = 10 minutes).
const DEFAULT_MAX_WINDOWS: usize = 2400;

/// Serving telemetry: per-kind latency histograms + op/error counters,
/// global counters, and throughput windows. All recording is lock-free and
/// allocation-free; `&Telemetry` is shared freely across threads.
pub struct Telemetry {
    names: &'static [&'static str],
    kinds: Vec<KindStats>,
    start: Instant,
    rate: RateWindows,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    precheck_rejections: AtomicU64,
    retries: AtomicU64,
    breaker_trips: AtomicU64,
    rollbacks: AtomicU64,
    shard_commits: AtomicU64,
    shard_conflicts: AtomicU64,
    spine_contentions: AtomicU64,
    journal_appends: AtomicU64,
    journal_replays: AtomicU64,
    reconciles: AtomicU64,
    orphans_released: AtomicU64,
}

impl Telemetry {
    /// Telemetry over the ten [`SchedOp`] kinds ([`KIND_NAMES`]) with the
    /// default 250 ms / 10 min rate windows.
    pub fn new() -> Telemetry {
        Telemetry::with_kinds(&KIND_NAMES)
    }

    /// Telemetry over a caller-supplied kind list (the serving harness uses
    /// its five workload kinds); indices into `names` are the
    /// [`Telemetry::record_kind`] keys.
    pub fn with_kinds(names: &'static [&'static str]) -> Telemetry {
        Telemetry {
            names,
            kinds: (0..names.len())
                .map(|_| KindStats {
                    hist: LatencyHistogram::new(),
                    ops: AtomicU64::new(0),
                    errors: AtomicU64::new(0),
                })
                .collect(),
            start: Instant::now(),
            rate: RateWindows::new(DEFAULT_WINDOW_MS, DEFAULT_MAX_WINDOWS),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            precheck_rejections: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            breaker_trips: AtomicU64::new(0),
            rollbacks: AtomicU64::new(0),
            shard_commits: AtomicU64::new(0),
            shard_conflicts: AtomicU64::new(0),
            spine_contentions: AtomicU64::new(0),
            journal_appends: AtomicU64::new(0),
            journal_replays: AtomicU64::new(0),
            reconciles: AtomicU64::new(0),
            orphans_released: AtomicU64::new(0),
        }
    }

    /// Record one completed op by its [`kind_index`]. Only valid for the
    /// default kind set.
    pub fn record(&self, op: &SchedOp, latency: Duration, error: bool) {
        self.record_kind(kind_index(op), latency, error);
    }

    /// Record one completed op under kind `kind` (an index into the kind
    /// list this telemetry was built with).
    pub fn record_kind(&self, kind: usize, latency: Duration, error: bool) {
        let k = &self.kinds[kind];
        k.hist.record(latency);
        k.ops.fetch_add(1, Ordering::Relaxed);
        if error {
            k.errors.fetch_add(1, Ordering::Relaxed);
        }
        let elapsed_ms = u64::try_from(self.start.elapsed().as_millis()).unwrap_or(u64::MAX);
        self.rate.record(elapsed_ms);
    }

    /// Total ops recorded across every kind.
    pub fn ops_total(&self) -> u64 {
        self.kinds.iter().map(|k| k.ops.load(Ordering::Relaxed)).sum()
    }

    /// Ops recorded under one kind index.
    pub fn ops_of(&self, kind: usize) -> u64 {
        self.kinds[kind].ops.load(Ordering::Relaxed)
    }

    /// Count one probe-cache hit (stamped in by the service at snapshot
    /// time or noted live by a harness).
    pub fn note_cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one probe-cache miss.
    pub fn note_cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one count-only pre-check rejection (a `MatchAllocate` /
    /// `MatchGrowLocal` turned away from the cache without the write lock).
    pub fn note_precheck_rejection(&self) {
        self.precheck_rejections.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one retry (re-issue of a failed op; the harness and the RPC
    /// retry layers call this, the service itself never retries).
    pub fn note_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one circuit-breaker trip (Closed/HalfOpen → Open transition on
    /// a hierarchy link).
    pub fn note_breaker_trip(&self) {
        self.breaker_trips.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one panic-containment rollback on the write path.
    pub fn note_rollback(&self) {
        self.rollbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one write op committed through the sharded (prepare-outside-
    /// the-write-lock) commit path without needing a serial rematch.
    pub fn note_shard_commit(&self) {
        self.shard_commits.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one sharded-commit conflict: the prepared selection was
    /// invalidated by a concurrent commit and the op fell back to a full
    /// serial rematch under the write lock.
    pub fn note_shard_conflict(&self) {
        self.shard_conflicts.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one spine contention: the graph epoch moved between prepare
    /// and commit but the prepared selection still validated — the commit
    /// proceeded after only the short spine critical section.
    pub fn note_spine_contention(&self) {
        self.spine_contentions.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one write-ahead journal append (an op frame written before its
    /// commit, see [`crate::sched::OpJournal`]).
    pub fn note_journal_append(&self) {
        self.journal_appends.fetch_add(1, Ordering::Relaxed);
    }

    /// Count `n` journal ops replayed during a snapshot-plus-replay
    /// recovery (one restart contributes its whole replayed suffix).
    pub fn note_journal_replays(&self, n: u64) {
        self.journal_replays.fetch_add(n, Ordering::Relaxed);
    }

    /// Count one grant-ledger reconciliation handshake initiated by this
    /// level (restart re-registration or a breaker half-open trial).
    pub fn note_reconcile(&self) {
        self.reconciles.fetch_add(1, Ordering::Relaxed);
    }

    /// Count `n` orphaned parent-side grants released while serving one
    /// `Reconcile` (grants the child never committed or lost in a crash).
    pub fn note_orphans_released(&self, n: u64) {
        self.orphans_released.fetch_add(n, Ordering::Relaxed);
    }

    /// Zero the throughput rate windows. The window origin stays the
    /// telemetry's start instant (it cannot be rebased behind `&self`),
    /// but counts recorded before the reset stop leaking into later
    /// snapshots — `crate::hier::Hierarchy::reset` calls this so one test
    /// run's op rates do not bleed into the next.
    pub fn reset_rate_windows(&self) {
        self.rate.reset();
    }

    /// Point-in-time copy of every series. Cache counters here are the
    /// *noted* ones; [`crate::sched::SchedService::telemetry_snapshot`]
    /// overwrites them with the authoritative cache stats.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let elapsed = self.start.elapsed();
        let elapsed_ms = u64::try_from(elapsed.as_millis()).unwrap_or(u64::MAX);
        TelemetrySnapshot {
            uptime_s: elapsed.as_secs_f64(),
            kinds: self
                .names
                .iter()
                .zip(&self.kinds)
                .map(|(name, k)| KindSnapshot {
                    name,
                    ops: k.ops.load(Ordering::Relaxed),
                    errors: k.errors.load(Ordering::Relaxed),
                    hist: k.hist.snapshot(),
                })
                .collect(),
            throughput: self.rate.snapshot(elapsed_ms),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            cache_invalidations: 0,
            cache_entries: 0,
            precheck_rejections: self.precheck_rejections.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            breaker_trips: self.breaker_trips.load(Ordering::Relaxed),
            rollbacks: self.rollbacks.load(Ordering::Relaxed),
            shard_commits: self.shard_commits.load(Ordering::Relaxed),
            shard_conflicts: self.shard_conflicts.load(Ordering::Relaxed),
            spine_contentions: self.spine_contentions.load(Ordering::Relaxed),
            journal_appends: self.journal_appends.load(Ordering::Relaxed),
            journal_replays: self.journal_replays.load(Ordering::Relaxed),
            reconciles: self.reconciles.load(Ordering::Relaxed),
            orphans_released: self.orphans_released.load(Ordering::Relaxed),
            snapshot_pins: 0,
            snapshot_publishes: 0,
            snapshots_retired: 0,
        }
    }
}

impl Default for Telemetry {
    fn default() -> Telemetry {
        Telemetry::new()
    }
}

/// One kind's series at snapshot time.
#[derive(Debug, Clone)]
pub struct KindSnapshot {
    /// Kind name (a [`KIND_NAMES`] entry, or a harness kind).
    pub name: &'static str,
    /// Ops recorded under this kind.
    pub ops: u64,
    /// Of those, how many answered with an error reply.
    pub errors: u64,
    /// The latency distribution.
    pub hist: HistogramSnapshot,
}

/// Point-in-time copy of a [`Telemetry`]: per-kind distributions, global
/// counters, throughput, JSON export.
#[derive(Debug, Clone)]
pub struct TelemetrySnapshot {
    /// Seconds since the telemetry was created.
    pub uptime_s: f64,
    /// Every kind's series (kinds with `ops == 0` included; JSON export
    /// skips them).
    pub kinds: Vec<KindSnapshot>,
    /// Throughput over the rate windows.
    pub throughput: ThroughputSnapshot,
    /// Probe-cache hits (authoritative when stamped by the service).
    pub cache_hits: u64,
    /// Probe-cache misses.
    pub cache_misses: u64,
    /// Probe-cache whole-map clears.
    pub cache_invalidations: u64,
    /// Probe-cache resident entries at snapshot time.
    pub cache_entries: u64,
    /// Count-only pre-check rejections.
    pub precheck_rejections: u64,
    /// Retries (harness / RPC layer re-issues).
    pub retries: u64,
    /// Circuit-breaker trips on hierarchy links.
    pub breaker_trips: u64,
    /// Panic-containment rollbacks on the write path.
    pub rollbacks: u64,
    /// Write ops committed through the sharded commit path (prepared
    /// outside the write lock, committed without a serial rematch).
    pub shard_commits: u64,
    /// Sharded commits whose prepared selection was invalidated by a
    /// concurrent commit and re-matched serially under the write lock.
    pub shard_conflicts: u64,
    /// Sharded commits that saw the epoch move between prepare and commit
    /// but still validated (only the short spine section was contended).
    pub spine_contentions: u64,
    /// Write-ahead journal op frames appended before commit.
    pub journal_appends: u64,
    /// Journal ops replayed by snapshot-plus-replay recoveries.
    pub journal_replays: u64,
    /// Grant-ledger reconciliation handshakes initiated by this level.
    pub reconciles: u64,
    /// Orphaned parent-side grants released while serving `Reconcile` ops.
    pub orphans_released: u64,
    /// RCU snapshot pins taken by the lock-free read path (stamped by the
    /// service from its [`crate::sched::SnapshotStats`], like the cache
    /// counters above; 0 from a raw [`Telemetry::snapshot`]).
    pub snapshot_pins: u64,
    /// Snapshot versions published by the write side (beyond the initial
    /// one).
    pub snapshot_publishes: u64,
    /// Superseded snapshot versions fully retired (dropped by their last
    /// pinner) — `publishes - retired` is the reclamation backlog.
    pub snapshots_retired: u64,
}

impl TelemetrySnapshot {
    /// Total ops across every kind.
    pub fn ops_total(&self) -> u64 {
        self.kinds.iter().map(|k| k.ops).sum()
    }

    /// Total error replies across every kind.
    pub fn errors_total(&self) -> u64 {
        self.kinds.iter().map(|k| k.errors).sum()
    }

    /// The series of a kind by name, if present.
    pub fn kind(&self, name: &str) -> Option<&KindSnapshot> {
        self.kinds.iter().find(|k| k.name == name)
    }

    /// The snapshot as a JSON document:
    /// `{uptime_s, throughput: {...}, counters: {...}, kinds: [...]}` with
    /// per-kind `ops`, `errors`, and `p50_s`/`p95_s`/`p99_s`/`mean_s`/
    /// `max_s` percentile fields (kinds that recorded nothing are omitted).
    pub fn to_json(&self) -> Json {
        let kinds: Vec<Json> = self
            .kinds
            .iter()
            .filter(|k| k.ops > 0)
            .map(|k| {
                Json::obj()
                    .with("name", Json::from(k.name))
                    .with("ops", Json::from(k.ops))
                    .with("errors", Json::from(k.errors))
                    .with("mean_s", Json::from(k.hist.mean_ns() * 1e-9))
                    .with("p50_s", Json::from(k.hist.quantile_s(0.50)))
                    .with("p95_s", Json::from(k.hist.quantile_s(0.95)))
                    .with("p99_s", Json::from(k.hist.quantile_s(0.99)))
                    .with("max_s", Json::from(k.hist.max_ns as f64 * 1e-9))
            })
            .collect();
        Json::obj()
            .with("uptime_s", Json::from(self.uptime_s))
            .with(
                "throughput",
                Json::obj()
                    .with("window_ms", Json::from(self.throughput.window_ms))
                    .with(
                        "windows_complete",
                        Json::from(self.throughput.windows_complete as u64),
                    )
                    .with(
                        "peak_window_ops_per_sec",
                        Json::from(self.throughput.peak_window_ops_per_sec),
                    )
                    .with(
                        "sustained_ops_per_sec",
                        Json::from(self.throughput.sustained_ops_per_sec),
                    ),
            )
            .with(
                "counters",
                Json::obj()
                    .with("cache_hits", Json::from(self.cache_hits))
                    .with("cache_misses", Json::from(self.cache_misses))
                    .with("cache_invalidations", Json::from(self.cache_invalidations))
                    .with("cache_entries", Json::from(self.cache_entries))
                    .with("precheck_rejections", Json::from(self.precheck_rejections))
                    .with("retries", Json::from(self.retries))
                    .with("breaker_trips", Json::from(self.breaker_trips))
                    .with("rollbacks", Json::from(self.rollbacks))
                    .with("shard_commits", Json::from(self.shard_commits))
                    .with("shard_conflicts", Json::from(self.shard_conflicts))
                    .with("spine_contentions", Json::from(self.spine_contentions))
                    .with("journal_appends", Json::from(self.journal_appends))
                    .with("journal_replays", Json::from(self.journal_replays))
                    .with("reconciles", Json::from(self.reconciles))
                    .with("orphans_released", Json::from(self.orphans_released))
                    .with("snapshot_pins", Json::from(self.snapshot_pins))
                    .with("snapshot_publishes", Json::from(self.snapshot_publishes))
                    .with("snapshots_retired", Json::from(self.snapshots_retired)),
            )
            .with("kinds", Json::Arr(kinds))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_round_trips_bounds() {
        // every bucket's own bounds map back to it, across the full range
        for index in 0..BUCKETS {
            let (lo, hi) = bucket_bounds(index);
            assert_eq!(bucket_index(lo), index, "lo of bucket {index}");
            assert_eq!(bucket_index(hi), index, "hi of bucket {index}");
        }
        // adjacent buckets tile the u64 range with no gaps
        for index in 0..BUCKETS - 1 {
            let (_, hi) = bucket_bounds(index);
            let (lo_next, _) = bucket_bounds(index + 1);
            assert_eq!(hi + 1, lo_next, "gap after bucket {index}");
        }
        assert_eq!(bucket_bounds(0).0, 0);
        assert_eq!(bucket_bounds(BUCKETS - 1).1, u64::MAX);
    }

    #[test]
    fn small_values_are_exact() {
        for v in 0..32u64 {
            let (lo, hi) = bucket_bounds(bucket_index(v));
            assert_eq!((lo, hi), (v, v), "value {v} must be exact");
        }
    }

    #[test]
    fn relative_error_bounded() {
        // reconstructed midpoint is within 6.25 % of any recorded value
        for v in [100u64, 1_000, 10_000, 123_456, 7_654_321, 1 << 40] {
            let (lo, hi) = bucket_bounds(bucket_index(v));
            assert!(lo <= v && v <= hi);
            let width = (hi - lo + 1) as f64;
            assert!(width / lo as f64 <= 1.0 / 16.0 + 1e-9, "value {v}");
        }
    }

    #[test]
    fn quantiles_from_known_distribution() {
        let h = LatencyHistogram::new();
        for i in 1..=100u64 {
            h.record_ns(i * 1_000); // 1 µs .. 100 µs
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.min_ns, 1_000);
        assert_eq!(s.max_ns, 100_000);
        let p50 = s.p50_ns();
        assert!(
            (p50 as f64 - 50_000.0).abs() / 50_000.0 < 0.07,
            "p50 {p50}"
        );
        let p99 = s.p99_ns();
        assert!(
            (p99 as f64 - 99_000.0).abs() / 99_000.0 < 0.07,
            "p99 {p99}"
        );
        assert!(s.quantile_ns(1.0) == 100_000, "q1.0 clamps to exact max");
        assert_eq!(s.quantile_ns(0.0), 1_000, "q0.0 clamps to exact min");
    }

    #[test]
    fn empty_snapshot_is_nan_free() {
        let s = LatencyHistogram::new().snapshot();
        assert_eq!(s.quantile_ns(0.5), 0);
        assert_eq!(s.mean_ns(), 0.0);
        let sum = s.to_summary();
        assert_eq!(sum.n, 0);
        assert!(sum.mean == 0.0 && sum.std == 0.0 && sum.max == 0.0);
    }

    #[test]
    fn summary_synthesis_matches_distribution() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record_ns(i * 100);
        }
        let sum = h.snapshot().to_summary();
        assert_eq!(sum.n, 1000);
        assert!((sum.mean - 50.05e-6).abs() / 50.05e-6 < 0.01, "{}", sum.mean);
        assert!((sum.median - 50e-6).abs() / 50e-6 < 0.07, "{}", sum.median);
        assert!(sum.min <= sum.q1 && sum.q1 <= sum.median);
        assert!(sum.median <= sum.q3 && sum.q3 <= sum.max);
    }

    #[test]
    fn merge_adds_distributions() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        a.record_ns(1_000);
        b.record_ns(9_000);
        let mut s = a.snapshot();
        s.merge(&b.snapshot());
        assert_eq!(s.count, 2);
        assert_eq!(s.min_ns, 1_000);
        assert_eq!(s.max_ns, 9_000);
    }

    #[test]
    fn telemetry_kinds_and_counters() {
        let t = Telemetry::new();
        let spec = crate::jobspec::JobSpec::nodes_sockets_cores(1, 2, 16);
        let op = SchedOp::Probe { spec };
        t.record(&op, Duration::from_micros(3), false);
        t.record(&op, Duration::from_micros(5), true);
        t.note_retry();
        t.note_breaker_trip();
        t.note_rollback();
        t.note_precheck_rejection();
        t.note_shard_commit();
        t.note_shard_commit();
        t.note_shard_conflict();
        t.note_spine_contention();
        t.note_journal_append();
        t.note_journal_append();
        t.note_journal_replays(5);
        t.note_reconcile();
        t.note_orphans_released(3);
        let s = t.snapshot();
        assert_eq!(s.ops_total(), 2);
        assert_eq!(s.errors_total(), 1);
        let probe = s.kind("probe").unwrap();
        assert_eq!(probe.ops, 2);
        assert_eq!(probe.hist.count, 2);
        assert_eq!(s.retries, 1);
        assert_eq!(s.breaker_trips, 1);
        assert_eq!(s.rollbacks, 1);
        assert_eq!(s.precheck_rejections, 1);
        assert_eq!(s.shard_commits, 2);
        assert_eq!(s.shard_conflicts, 1);
        assert_eq!(s.spine_contentions, 1);
        assert_eq!(s.journal_appends, 2);
        assert_eq!(s.journal_replays, 5);
        assert_eq!(s.reconciles, 1);
        assert_eq!(s.orphans_released, 3);
        // JSON export includes only the recorded kind
        let doc = crate::util::json::Json::parse(&s.to_json().dump()).unwrap();
        let kinds = doc.get("kinds").and_then(|k| k.as_arr()).unwrap();
        assert_eq!(kinds.len(), 1);
        assert_eq!(kinds[0].get("name").and_then(|n| n.as_str()), Some("probe"));
        assert!(kinds[0].get("p99_s").and_then(|p| p.as_f64()).unwrap() > 0.0);
    }

    #[test]
    fn rate_window_reset_forgets_prior_counts() {
        let t = Telemetry::new();
        for _ in 0..100 {
            t.record_kind(0, Duration::from_nanos(10), false);
        }
        t.reset_rate_windows();
        let s = t.snapshot();
        // the windows hold nothing recorded before the reset (peak is a
        // max over the zeroed slots, so it is immune to elapsed-time skew)
        assert_eq!(s.throughput.peak_window_ops_per_sec, 0.0);
        // histograms and op counters are intentionally untouched
        assert_eq!(s.ops_total(), 100);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let t = std::sync::Arc::new(Telemetry::with_kinds(&["a", "b"]));
        let threads: Vec<_> = (0..4)
            .map(|ti| {
                let t = t.clone();
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        t.record_kind((ti % 2) as usize, Duration::from_nanos(i + 1), false);
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        let s = t.snapshot();
        assert_eq!(s.ops_total(), 4000);
        assert_eq!(s.kinds[0].hist.count + s.kinds[1].hist.count, 4000);
    }
}
