//! `repro` — the fluxion-rs coordinator CLI.
//!
//! Subcommands drive the paper's experiments (DESIGN.md's experiment index)
//! and a small interactive scheduler loop. Hand-rolled argument parsing
//! (clap is unavailable offline).

use fluxion::experiments::{e2e, ec2, kubeflux, models, nested, single_level, ExpConfig};
use fluxion::perfmodel::FitBackend;
use fluxion::workload::WorkloadSpec;

fn usage() -> ! {
    eprintln!(
        "repro — dynamic hierarchical resource model (Milroy et al. 2021 reproduction)

USAGE: repro <command> [options]

COMMANDS
  exp single-level     E1  (§5.1)  MA vs MG single-scheduler overhead
  exp nested           E2-4 (§5.2) five-level MatchGrow timings (Figs 1a/1b)
  exp ec2              E5  (§5.3)  EC2 creation times by type (Fig 2)
  exp fleet            E6  (§5.3)  Fleet dynamic binding vs static config
  exp kubeflux         E7  (§5.4)  ReplicaSet MA vs MG on OpenShift graph
  exp models           E8-10 (§6)  component models, Table 4/5, bound
  exp e2e              E11 end-to-end elastic-vs-rigid workload replay
  exp all              run everything in sequence
  serve                demo scheduler loop on stdin jobspecs

OPTIONS
  --iters N            repetitions per case (default 30; paper used 100)
  --paper              paper-scale repetitions (100)
  --time-scale X       provider latency scale (default 1e-3; 1.0 = real)
  --jobs N             e2e trace length (default 40)
"
    );
    std::process::exit(2);
}

fn parse_config(args: &[String]) -> ExpConfig {
    let mut cfg = ExpConfig::default();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--iters" => {
                cfg.iters = args
                    .get(i + 1)
                    .and_then(|v| v.parse().ok())
                    .unwrap_or_else(|| usage());
                i += 1;
            }
            "--paper" => cfg.iters = 100,
            "--time-scale" => {
                cfg.time_scale = args
                    .get(i + 1)
                    .and_then(|v| v.parse().ok())
                    .unwrap_or_else(|| usage());
                i += 1;
            }
            _ => {}
        }
        i += 1;
    }
    cfg
}

fn opt_usize(args: &[String], flag: &str, default: usize) -> usize {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    match cmd.as_str() {
        "exp" => {
            let Some(which) = args.get(1) else { usage() };
            let rest = &args[2..];
            let cfg = parse_config(rest);
            run_experiment(which, &cfg, rest);
        }
        "serve" => serve(),
        _ => usage(),
    }
}

fn run_experiment(which: &str, cfg: &ExpConfig, rest: &[String]) {
    match which {
        "single-level" => {
            println!("{}", single_level::run(cfg).table());
        }
        "nested" => {
            let tests = nested::default_tests();
            let r = nested::run(cfg, &tests);
            for t in &tests {
                println!("{}", r.figure1_table(t));
            }
            println!("{}", r.recorder.table());
        }
        "ec2" => {
            let reps = opt_usize(rest, "--reps", 20);
            println!("{}", ec2::run_creation(cfg, reps).figure2_table());
        }
        "fleet" => {
            // paper scale: 10 fleets × 10 instances; static 300×77×128
            let r = ec2::run_fleet(cfg, 10, 10, 300, 77, 128);
            println!("{}", r.table());
        }
        "kubeflux" => {
            println!("{}", kubeflux::run(cfg, 100).table());
        }
        "models" => {
            let tests = nested::default_tests();
            let data = nested::run(cfg, &tests);
            let backend = FitBackend::best();
            println!("fit backend: {}", backend.name());
            let model = models::fit_models(&data, &backend);
            println!("E8 (Table 4)\n{}", model.table4());
            println!("{}", models::figure34_table(&data, &model));
            println!("{}", models::apply_model(cfg, &model).table());
            let (obs, bound, factor) = models::validate_bound(&data, "T7");
            println!(
                "E10 — §6.3 bound: observed total match {obs:.6}s <= bound {bound:.6}s (factor {factor:.3})"
            );
            println!("{}", models::bound_ablation());
        }
        "e2e" => {
            let spec = WorkloadSpec {
                jobs: opt_usize(rest, "--jobs", 40),
                ..WorkloadSpec::default()
            };
            let results = e2e::run(cfg, &spec);
            println!("{}", e2e::comparison_table(&results));
        }
        "all" => {
            for w in ["single-level", "nested", "ec2", "fleet", "kubeflux", "models", "e2e"] {
                println!("\n================ exp {w} ================");
                run_experiment(w, cfg, rest);
            }
        }
        _ => usage(),
    }
}

/// Minimal interactive loop: read jobspec JSON lines from stdin, print the
/// allocation decision (a smoke-testable "server").
fn serve() {
    use fluxion::jobspec::JobSpec;
    use fluxion::resource::builder::{table2_graph, UidGen};
    use fluxion::sched::{PruneConfig, SchedInstance};
    use std::io::BufRead;

    let mut inst = SchedInstance::new(table2_graph(0, &mut UidGen::new()), PruneConfig::default());
    eprintln!("repro serve: 128-node cluster ready; one jobspec JSON per line");
    for line in std::io::stdin().lock().lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        match JobSpec::parse(&line) {
            Ok(spec) => match inst.match_allocate(&spec) {
                Ok(out) => println!(
                    "{{\"job\":{},\"vertices\":{},\"match_s\":{:.6}}}",
                    out.job.0,
                    out.subgraph.nodes.len(),
                    out.timing.match_s
                ),
                Err(e) => println!("{{\"error\":\"{e}\"}}"),
            },
            Err(e) => println!("{{\"error\":\"bad jobspec: {e}\"}}"),
        }
    }
}
