//! The dynamic directed resource graph.
//!
//! This is the paper's core data structure (§3): a containment tree of typed
//! resource vertices with
//!
//! - a **path index** (`path -> VertexId`) so a subgraph's attaching point is
//!   located in O(1), making `AddSubgraph` O(n+m) in the subgraph size —
//!   the "localization" technique that keeps dynamic edits scalable;
//! - **per-vertex scheduling metadata** that is a function only of the vertex
//!   and its subtree (allocations + pruning aggregates), so attaching a
//!   subgraph only requires updating its `p` ancestors, giving
//!   `UpdateMetadata` O(n+m+p);
//! - tombstoned removal so `VertexId`s stay stable across shrink operations.
//!
//! The underlying structure replaces Fluxion's Boost Graph Library with an
//! adjacency-list digraph: the paper uses only add/remove vertex/edge plus
//! indexed lookup, which this provides at the same complexity.
//!
//! §Perf: the graph owns a [`TypeTable`] and every vertex stores an interned
//! [`TypeId`] — type checks on the match hot path are integer compares, and
//! dynamic `Other` type names are stored once per graph. Vertices also cache
//! their containment `depth` (maintained on `add_child`) so topological
//! ordering of a selection never re-derives depth from the path string.
//!
//! §Concurrency: the graph carries a monotonic **epoch**
//! ([`ResourceGraph::epoch`]) that every mutation bumps — structural edits
//! (`add_root`,
//! `add_child`, `remove_leaf`) and any `vertex_mut`/`types_mut` access
//! (which is how allocation marks and pruning aggregates change). Read-only
//! results computed against the graph (e.g. the scheduler's probe cache,
//! `sched::service`) are keyed by the epoch they were computed at and are
//! valid exactly while the epoch is unchanged. The epoch is deliberately
//! conservative: it may advance more than once per logical operation, which
//! costs a cache entry but never serves a stale answer. Restoring a
//! snapshot must go through [`ResourceGraph::restore_from`], which moves
//! the epoch *forward* past both timelines so a rewound counter can never
//! alias two different graph states.
//!
//! §Snapshots (PR 9): vertex storage is **copy-on-write at subtree
//! granularity**. The arena is a vector of fixed-size `Arc`-shared chunks
//! ([`CHUNK_SIZE`] vertices each; arena ids are assigned in build/DFS
//! order, so one chunk covers a contiguous slice of one or a few adjacent
//! subtrees), and the containment topology (parent/child links + path
//! index) sits behind its own `Arc`. `ResourceGraph::clone` is therefore
//! O(chunks) reference-count bumps — the RCU snapshot publication in
//! `sched::snapshot` and the write path's rollback snapshots both lean on
//! this. A writer mutating a freshly cloned graph lazily copies only the
//! chunks (subtrees) it actually touches via `Arc::make_mut`; while a
//! graph is unshared (the single-threaded [`crate::sched::SchedInstance`]
//! steady state), `make_mut` is a refcount check and mutation cost is
//! unchanged. The epoch doubles as the **snapshot version**: equal epochs
//! imply identical observable state, so a published snapshot is fully
//! identified by the epoch it was cloned at.

use std::collections::HashMap;
use std::fmt;
use std::sync::Arc;

use crate::resource::types::{ResourceType, TypeId, TypeTable};

/// Stable handle to a vertex. Indexes into the graph's vertex arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VertexId(
    /// Raw arena index (always `< ResourceGraph::arena_len()`).
    pub u32,
);

/// Job identifier for allocation metadata.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(
    /// Raw id as minted by `AllocTable::fresh_job_id` (or a remote peer).
    pub u64,
);

/// Allocation state of a vertex.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AllocInfo {
    /// Jobs holding this vertex (exclusive vertices have at most one).
    pub jobs: Vec<JobId>,
}

impl AllocInfo {
    /// Whether any job currently holds this vertex.
    pub fn is_allocated(&self) -> bool {
        !self.jobs.is_empty()
    }
}

/// A vertex under construction: everything the caller specifies, before the
/// graph assigns interned/derived state (type id, depth, aggregates).
/// [`make_vertex`] returns one of these; `add_root`/`add_child` consume it.
#[derive(Debug, Clone)]
pub struct VertexProto {
    /// Resource type (interned by the graph on insertion).
    pub rtype: ResourceType,
    /// Basename, e.g. `core`; instance name is `basename + id`.
    pub basename: String,
    /// Sibling index, e.g. the `3` in `core3`.
    pub id: u64,
    /// Globally unique id (JGF `uniq_id`).
    pub uniq_id: u64,
    /// MPI-style rank hint; -1 when not applicable.
    pub rank: i64,
    /// Capacity units this vertex provides (1 for discrete resources).
    pub size: u64,
    /// Unit label for `size` (empty for discrete resources).
    pub unit: String,
    /// Containment path, e.g. `/cluster0/rack0/node3/socket0/core7`.
    pub path: String,
}

/// A typed resource vertex plus its scheduling metadata.
#[derive(Debug, Clone)]
pub struct Vertex {
    /// Interned resource type (resolve through the graph's [`TypeTable`]).
    pub tid: TypeId,
    /// Basename, e.g. `core`; instance name is `basename + id`.
    pub basename: String,
    /// Sibling index, e.g. the `3` in `core3`.
    pub id: u64,
    /// Globally unique id (JGF `uniq_id`); preserved across levels so the
    /// same physical resource has the same identity in every instance graph.
    pub uniq_id: u64,
    /// MPI-style rank hint; -1 when not applicable (Fluxion convention).
    pub rank: i64,
    /// Capacity units this vertex provides (1 for discrete resources).
    pub size: u64,
    /// Unit label for `size` (empty for discrete resources).
    pub unit: String,
    /// Containment path, e.g. `/cluster0/rack0/node3/socket0/core7`.
    pub path: String,
    /// Containment depth, maintained incrementally on `add_child`. The root
    /// has depth 1, matching the path's `'/'` count, so sort keys are
    /// identical to the path-derived ones they replace.
    pub depth: u32,
    /// Allocation state: which jobs hold this vertex.
    pub alloc: AllocInfo,
    /// Pruning aggregate: free units in the subtree rooted here, one slot
    /// per tracked type of the active `PruneConfig` (dense, slot-indexed —
    /// see `sched::pruning`). Empty until aggregates are initialized.
    pub agg_free: Vec<i64>,
    /// Tombstone: true once removed. Ids are never reused.
    pub dead: bool,
}

impl Vertex {
    /// Instance name: `basename + id`, e.g. `core3`.
    pub fn name(&self) -> String {
        format!("{}{}", self.basename, self.id)
    }

    /// Aggregate for a pruning slot; 0 when aggregates are uninitialized.
    #[inline]
    pub fn agg_slot(&self, slot: usize) -> i64 {
        self.agg_free.get(slot).copied().unwrap_or(0)
    }

    /// Add a delta to a pruning slot, growing the dense vector to `nslots`
    /// on first touch (vertices attached after init start empty).
    #[inline]
    pub fn agg_add_slot(&mut self, slot: usize, nslots: usize, delta: i64) {
        if self.agg_free.len() < nslots {
            self.agg_free.resize(nslots, 0);
        }
        self.agg_free[slot] += delta;
    }
}

const CHUNK_BITS: usize = 6;

/// Vertices per copy-on-write arena chunk (see the module §Snapshots notes).
/// 64 keeps a chunk within one or a few adjacent subtrees of the paper's
/// node-level graphs, so a writer touching one node's cores copies one chunk.
pub const CHUNK_SIZE: usize = 1 << CHUNK_BITS;

/// Containment topology: parent/child links plus the localization index.
/// Shared behind one `Arc` — structural edits are rare next to allocation
/// marks, so snapshots almost always share the whole topology and a
/// structural writer pays one lazy copy per publish interval.
#[derive(Debug, Clone, Default)]
struct Topology {
    parent: Vec<Option<VertexId>>,
    children: Vec<Vec<VertexId>>,
    /// containment path -> vertex (the localization index).
    path_index: HashMap<String, VertexId>,
}

/// The dynamic resource graph: a containment tree (per the paper's "we assume
/// the scheduling hierarchy is a tree") with O(1) path lookup.
///
/// Storage is copy-on-write (module §Snapshots): `clone` is O(chunks)
/// refcount bumps and mutation lazily un-shares only the touched chunks,
/// which is what makes RCU snapshot publication and rollback snapshots
/// cheap enough to take on every write.
#[derive(Debug, Clone, Default)]
pub struct ResourceGraph {
    /// COW vertex arena: fixed-size chunks, each behind its own `Arc`.
    /// All chunks except the last are exactly `CHUNK_SIZE` long.
    chunks: Vec<Arc<Vec<Vertex>>>,
    /// Arena length (live + tombstoned), cached across the chunk split.
    len: usize,
    /// Containment topology, shared whole until a structural edit.
    topo: Arc<Topology>,
    /// Interned resource types for every vertex in this graph.
    types: TypeTable,
    root: Option<VertexId>,
    live_vertices: usize,
    live_edges: usize,
    /// Monotonic mutation counter (see the module §Concurrency notes).
    /// Cloning copies it, so a snapshot remembers the epoch it was taken
    /// at; [`ResourceGraph::restore_from`] is the only sanctioned way to
    /// swap a snapshot back in.
    epoch: u64,
}

/// Errors returned by the graph's structural mutations.
#[derive(Debug)]
pub enum GraphError {
    /// A vertex with the same containment path already exists.
    DuplicatePath(String),
    /// No vertex at the given containment path.
    NoSuchPath(String),
    /// The referenced vertex has been tombstoned.
    Dead(VertexId),
    /// `add_root` on a graph that already has a root.
    RootExists,
    /// `remove_leaf` on a vertex that still has live children.
    HasChildren(String),
}

impl fmt::Display for GraphError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GraphError::DuplicatePath(p) => write!(f, "vertex path '{p}' already exists"),
            GraphError::NoSuchPath(p) => write!(f, "no vertex at path '{p}'"),
            GraphError::Dead(v) => write!(f, "vertex {v:?} is dead"),
            GraphError::RootExists => write!(f, "graph already has a root"),
            GraphError::HasChildren(p) => {
                write!(f, "cannot remove vertex with live children: {p}")
            }
        }
    }
}

impl std::error::Error for GraphError {}

impl ResourceGraph {
    /// An empty graph (no root, epoch 0).
    pub fn new() -> ResourceGraph {
        ResourceGraph::default()
    }

    // ---- chunked COW internals ------------------------------------------

    /// Shared view of the vertex at raw arena index `i`.
    #[inline]
    fn v(&self, i: usize) -> &Vertex {
        &self.chunks[i >> CHUNK_BITS][i & (CHUNK_SIZE - 1)]
    }

    /// Exclusive view of the vertex at raw arena index `i`, lazily
    /// un-sharing (copying) its chunk if a snapshot still holds it.
    #[inline]
    fn v_mut(&mut self, i: usize) -> &mut Vertex {
        &mut Arc::make_mut(&mut self.chunks[i >> CHUNK_BITS])[i & (CHUNK_SIZE - 1)]
    }

    // ---- accessors -------------------------------------------------------

    /// The root vertex, if the graph has one.
    pub fn root(&self) -> Option<VertexId> {
        self.root
    }

    /// Immutable access to a vertex (live or tombstoned).
    pub fn vertex(&self, id: VertexId) -> &Vertex {
        self.v(id.0 as usize)
    }

    /// Mutable access to a vertex. Bumps the [epoch](ResourceGraph::epoch):
    /// callers take `&mut Vertex` exactly to change scheduling-relevant
    /// state (allocation marks, pruning aggregates), so any cached
    /// read-only result must be invalidated. Conservative by design — a
    /// no-op write costs a cache entry, never correctness.
    pub fn vertex_mut(&mut self, id: VertexId) -> &mut Vertex {
        self.epoch += 1;
        self.v_mut(id.0 as usize)
    }

    /// The graph's type intern table.
    pub fn types(&self) -> &TypeTable {
        &self.types
    }

    /// Mutable access to the intern table (bumps the epoch — interning is
    /// only reachable from mutating operations).
    pub fn types_mut(&mut self) -> &mut TypeTable {
        self.epoch += 1;
        &mut self.types
    }

    /// Monotonic mutation counter: advances on every mutation (structural
    /// edits, allocation marks, aggregate updates). Two reads of the graph
    /// separated by an unchanged epoch are guaranteed to observe identical
    /// scheduling state — the invariant the scheduler's epoch-keyed probe
    /// cache ([`crate::sched::SchedService`]) is built on.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Advance the epoch by `n` without touching any vertex. Used by the
    /// sharded write-commit path ([`crate::sched::alloc`]): coalescing
    /// per-shard spine deltas makes *fewer* `vertex_mut` calls than the
    /// serial mark/bubble walk would, and the sharded commit compensates
    /// with the difference so a fixed op stream lands on the **same final
    /// epoch** as serial application (part of the PR 5 determinism
    /// contract). Moving the counter forward is always safe — it can only
    /// cost a cache entry, never serve a stale answer.
    pub fn bump_epochs(&mut self, n: u64) {
        self.epoch += n;
    }

    /// Replace this graph's contents with a snapshot while keeping the
    /// epoch moving **forward**: the restored graph's epoch is one past the
    /// maximum of both timelines. A plain `*g = snapshot.clone()` would
    /// rewind the counter and let a later mutation re-reach an epoch value
    /// that cached results were keyed under — with different state.
    pub fn restore_from(&mut self, snapshot: &ResourceGraph) {
        let epoch = self.epoch.max(snapshot.epoch) + 1;
        *self = snapshot.clone();
        self.epoch = epoch;
    }

    /// Resolved resource type of a vertex.
    pub fn rtype(&self, id: VertexId) -> &ResourceType {
        self.types.get(self.vertex(id).tid)
    }

    /// Type name of a vertex (resolved through the intern table).
    pub fn type_name(&self, id: VertexId) -> &str {
        self.types.name(self.vertex(id).tid)
    }

    /// Containment parent of a vertex (`None` at the root).
    pub fn parent_of(&self, id: VertexId) -> Option<VertexId> {
        self.topo.parent[id.0 as usize]
    }

    /// Containment children of a vertex, in insertion order.
    pub fn children_of(&self, id: VertexId) -> &[VertexId] {
        &self.topo.children[id.0 as usize]
    }

    /// O(1) containment-path lookup (the localization index).
    pub fn lookup_path(&self, path: &str) -> Option<VertexId> {
        self.topo.path_index.get(path).copied()
    }

    /// Live vertex count.
    pub fn num_vertices(&self) -> usize {
        self.live_vertices
    }

    /// Live (containment) edge count.
    pub fn num_edges(&self) -> usize {
        self.live_edges
    }

    /// "Graph size" in the paper's sense: vertices + edges.
    pub fn size(&self) -> usize {
        self.num_vertices() + self.num_edges()
    }

    /// Arena length (live + tombstoned). `VertexId.0` is always < this, so
    /// callers can size side tables indexed by raw id.
    pub fn arena_len(&self) -> usize {
        self.len
    }

    /// Iterate live vertex ids.
    pub fn iter_live(&self) -> impl Iterator<Item = VertexId> + '_ {
        (0..self.len)
            .filter(move |&i| !self.v(i).dead)
            .map(|i| VertexId(i as u32))
    }

    /// Ancestors from the vertex's parent up to the root.
    ///
    /// Allocates; hot paths should walk `parent_of` directly instead.
    pub fn ancestors(&self, id: VertexId) -> Vec<VertexId> {
        let mut out = Vec::new();
        let mut cur = self.parent_of(id);
        while let Some(p) = cur {
            out.push(p);
            cur = self.parent_of(p);
        }
        out
    }

    /// Depth-first preorder walk of the subtree rooted at `id`.
    pub fn dfs(&self, id: VertexId) -> Vec<VertexId> {
        let mut out = Vec::new();
        let mut stack = vec![id];
        while let Some(v) = stack.pop() {
            if self.v(v.0 as usize).dead {
                continue;
            }
            out.push(v);
            // push in reverse so children come out in insertion order
            for &c in self.topo.children[v.0 as usize].iter().rev() {
                stack.push(c);
            }
        }
        out
    }

    // ---- mutation --------------------------------------------------------

    /// Add a root vertex (no parent edge).
    pub fn add_root(&mut self, v: VertexProto) -> Result<VertexId, GraphError> {
        if self.root.is_some() {
            return Err(GraphError::RootExists);
        }
        let id = self.push_vertex(v, 1)?;
        self.root = Some(id);
        Ok(id)
    }

    /// Add a vertex as a child of `parent` (adds the containment edge).
    /// O(1) amortized — this is the primitive `AddSubgraph` loops over.
    /// Interns the vertex type and assigns `depth = parent.depth + 1`.
    pub fn add_child(&mut self, parent: VertexId, v: VertexProto) -> Result<VertexId, GraphError> {
        if self.vertex(parent).dead {
            return Err(GraphError::Dead(parent));
        }
        let depth = self.vertex(parent).depth + 1;
        let id = self.push_vertex(v, depth)?;
        let topo = Arc::make_mut(&mut self.topo);
        topo.parent[id.0 as usize] = Some(parent);
        topo.children[parent.0 as usize].push(id);
        self.live_edges += 1;
        Ok(id)
    }

    fn push_vertex(&mut self, v: VertexProto, depth: u32) -> Result<VertexId, GraphError> {
        if self.topo.path_index.contains_key(&v.path) {
            return Err(GraphError::DuplicatePath(v.path));
        }
        self.epoch += 1;
        let tid = self.types.intern(&v.rtype);
        let id = VertexId(self.len as u32);
        let topo = Arc::make_mut(&mut self.topo);
        topo.path_index.insert(v.path.clone(), id);
        topo.parent.push(None);
        topo.children.push(Vec::new());
        if self.len & (CHUNK_SIZE - 1) == 0 {
            self.chunks.push(Arc::new(Vec::with_capacity(CHUNK_SIZE)));
        }
        let chunk = Arc::make_mut(self.chunks.last_mut().expect("fresh chunk"));
        chunk.push(Vertex {
            tid,
            basename: v.basename,
            id: v.id,
            uniq_id: v.uniq_id,
            rank: v.rank,
            size: v.size,
            unit: v.unit,
            path: v.path,
            depth,
            alloc: AllocInfo::default(),
            agg_free: Vec::new(),
            dead: false,
        });
        self.len += 1;
        self.live_vertices += 1;
        Ok(id)
    }

    /// Remove a leaf (or recursively a whole subtree with `remove_subtree`).
    /// Tombstones the vertex; ids remain stable.
    pub fn remove_leaf(&mut self, id: VertexId) -> Result<(), GraphError> {
        let i = id.0 as usize;
        if self.v(i).dead {
            return Err(GraphError::Dead(id));
        }
        if self.topo.children[i]
            .iter()
            .any(|c| !self.v(c.0 as usize).dead)
        {
            return Err(GraphError::HasChildren(self.v(i).path.clone()));
        }
        let path = self.v(i).path.clone();
        let parent = self.topo.parent[i];
        self.epoch += 1;
        let topo = Arc::make_mut(&mut self.topo);
        topo.path_index.remove(&path);
        if let Some(p) = parent {
            topo.children[p.0 as usize].retain(|&c| c != id);
            self.live_edges -= 1;
        }
        self.v_mut(i).dead = true;
        self.live_vertices -= 1;
        if self.root == Some(id) {
            self.root = None;
        }
        Ok(())
    }

    /// Remove an entire subtree bottom-up (the paper's subtractive
    /// transformation). Returns the number of removed vertices.
    pub fn remove_subtree(&mut self, id: VertexId) -> Result<usize, GraphError> {
        let order = self.dfs(id);
        for &v in order.iter().rev() {
            self.remove_leaf(v)?;
        }
        Ok(order.len())
    }

    /// Validate internal invariants (tests + failure injection):
    /// path index maps exactly the live vertices; parent/child links agree;
    /// cached depths are consistent; live counts are consistent.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut live = 0usize;
        let mut edges = 0usize;
        if self.topo.parent.len() != self.len || self.topo.children.len() != self.len {
            return Err("topology tables out of step with arena".to_string());
        }
        let counted: usize = self.chunks.iter().map(|c| c.len()).sum();
        if counted != self.len {
            return Err(format!(
                "chunk lengths sum to {counted}, cached arena len {}",
                self.len
            ));
        }
        for (ci, c) in self.chunks.iter().enumerate() {
            if c.len() != CHUNK_SIZE && ci + 1 != self.chunks.len() {
                return Err(format!("non-terminal chunk {ci} is not full"));
            }
        }
        for i in 0..self.len {
            let v = self.v(i);
            let id = VertexId(i as u32);
            if v.tid.index() >= self.types.len() {
                return Err(format!("vertex {} has out-of-table type id", v.path));
            }
            if v.dead {
                if self.topo.path_index.get(&v.path) == Some(&id) {
                    return Err(format!("dead vertex {} still indexed", v.path));
                }
                continue;
            }
            live += 1;
            if self.topo.path_index.get(&v.path) != Some(&id) {
                return Err(format!("live vertex {} not indexed", v.path));
            }
            match self.topo.parent[i] {
                Some(p) => {
                    if self.v(p.0 as usize).dead {
                        return Err(format!("{} has dead parent", v.path));
                    }
                    if !self.topo.children[p.0 as usize].contains(&id) {
                        return Err(format!("{} missing from parent's children", v.path));
                    }
                    if v.depth != self.v(p.0 as usize).depth + 1 {
                        return Err(format!(
                            "{} depth {} != parent depth + 1",
                            v.path, v.depth
                        ));
                    }
                    edges += 1;
                }
                None => {
                    if v.depth != 1 {
                        return Err(format!("root {} has depth {} != 1", v.path, v.depth));
                    }
                }
            }
            for &c in &self.topo.children[i] {
                if self.v(c.0 as usize).dead {
                    return Err(format!("{} has dead child", v.path));
                }
                if self.topo.parent[c.0 as usize] != Some(id) {
                    return Err(format!("child of {} disagrees on parent", v.path));
                }
            }
        }
        if live != self.live_vertices {
            return Err(format!(
                "live count mismatch: counted {live}, cached {}",
                self.live_vertices
            ));
        }
        if edges != self.live_edges {
            return Err(format!(
                "edge count mismatch: counted {edges}, cached {}",
                self.live_edges
            ));
        }
        if self.topo.path_index.len() != live {
            return Err("path index size != live vertices".to_string());
        }
        Ok(())
    }
}

/// Builder for a vertex with sensible defaults.
pub fn make_vertex(
    rtype: ResourceType,
    basename: &str,
    id: u64,
    uniq_id: u64,
    path: &str,
) -> VertexProto {
    VertexProto {
        rtype,
        basename: basename.to_string(),
        id,
        uniq_id,
        rank: -1,
        size: 1,
        unit: String::new(),
        path: path.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> (ResourceGraph, VertexId, VertexId, VertexId) {
        let mut g = ResourceGraph::new();
        let root = g
            .add_root(make_vertex(ResourceType::Cluster, "cluster", 0, 0, "/cluster0"))
            .unwrap();
        let n0 = g
            .add_child(
                root,
                make_vertex(ResourceType::Node, "node", 0, 1, "/cluster0/node0"),
            )
            .unwrap();
        let c0 = g
            .add_child(
                n0,
                make_vertex(ResourceType::Core, "core", 0, 2, "/cluster0/node0/core0"),
            )
            .unwrap();
        (g, root, n0, c0)
    }

    #[test]
    fn build_and_lookup() {
        let (g, root, n0, c0) = tiny();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.size(), 5);
        assert_eq!(g.lookup_path("/cluster0/node0"), Some(n0));
        assert_eq!(g.parent_of(c0), Some(n0));
        assert_eq!(g.children_of(root), &[n0]);
        assert_eq!(g.ancestors(c0), vec![n0, root]);
        g.check_invariants().unwrap();
    }

    #[test]
    fn types_interned_and_depth_cached() {
        let (g, root, n0, c0) = tiny();
        assert_eq!(g.vertex(root).tid, TypeId::CLUSTER);
        assert_eq!(g.vertex(c0).tid, TypeId::CORE);
        assert_eq!(g.type_name(n0), "node");
        assert_eq!(g.rtype(c0), &ResourceType::Core);
        assert_eq!(g.vertex(root).depth, 1);
        assert_eq!(g.vertex(n0).depth, 2);
        assert_eq!(g.vertex(c0).depth, 3);
    }

    #[test]
    fn dynamic_types_share_one_interned_entry() {
        let mut g = ResourceGraph::new();
        let root = g
            .add_root(make_vertex(
                ResourceType::from_name("enclave"),
                "enclave",
                0,
                0,
                "/enclave0",
            ))
            .unwrap();
        let a = g
            .add_child(
                root,
                make_vertex(
                    ResourceType::from_name("smartnic"),
                    "smartnic",
                    0,
                    1,
                    "/enclave0/smartnic0",
                ),
            )
            .unwrap();
        let b = g
            .add_child(
                root,
                make_vertex(
                    ResourceType::from_name("smartnic"),
                    "smartnic",
                    1,
                    2,
                    "/enclave0/smartnic1",
                ),
            )
            .unwrap();
        assert_eq!(g.vertex(a).tid, g.vertex(b).tid);
        assert_ne!(g.vertex(a).tid, g.vertex(root).tid);
        assert_eq!(g.type_name(a), "smartnic");
        assert_eq!(g.types().lookup_name("smartnic"), Some(g.vertex(a).tid));
        // two dynamic types + eight builtins
        assert_eq!(g.types().len(), 10);
    }

    #[test]
    fn duplicate_path_rejected() {
        let (mut g, root, _, _) = tiny();
        let err = g.add_child(
            root,
            make_vertex(ResourceType::Node, "node", 0, 9, "/cluster0/node0"),
        );
        assert!(err.is_err());
    }

    #[test]
    fn dfs_preorder() {
        let (mut g, root, n0, _) = tiny();
        let c1 = g
            .add_child(
                n0,
                make_vertex(ResourceType::Core, "core", 1, 3, "/cluster0/node0/core1"),
            )
            .unwrap();
        let order = g.dfs(root);
        assert_eq!(order.len(), 4);
        assert_eq!(order[0], root);
        assert_eq!(order[1], n0);
        assert!(order.contains(&c1));
    }

    #[test]
    fn remove_leaf_and_reattach() {
        let (mut g, _, n0, c0) = tiny();
        g.remove_leaf(c0).unwrap();
        assert_eq!(g.num_vertices(), 2);
        assert_eq!(g.lookup_path("/cluster0/node0/core0"), None);
        g.check_invariants().unwrap();
        // same path can be re-added after removal (grow after shrink)
        let c0b = g
            .add_child(
                n0,
                make_vertex(ResourceType::Core, "core", 0, 7, "/cluster0/node0/core0"),
            )
            .unwrap();
        assert_ne!(c0b, c0, "tombstoned ids are not reused");
        g.check_invariants().unwrap();
    }

    #[test]
    fn remove_nonleaf_rejected() {
        let (mut g, _, n0, _) = tiny();
        assert!(g.remove_leaf(n0).is_err());
    }

    #[test]
    fn remove_subtree() {
        let (mut g, _, n0, _) = tiny();
        let removed = g.remove_subtree(n0).unwrap();
        assert_eq!(removed, 2);
        assert_eq!(g.num_vertices(), 1);
        assert_eq!(g.num_edges(), 0);
        g.check_invariants().unwrap();
    }

    #[test]
    fn epoch_advances_on_every_mutation_kind() {
        let (mut g, root, _, c0) = tiny();
        let e0 = g.epoch();
        // structural add
        g.add_child(
            root,
            make_vertex(ResourceType::Node, "node", 1, 9, "/cluster0/node1"),
        )
        .unwrap();
        let e1 = g.epoch();
        assert!(e1 > e0);
        // vertex metadata write (how allocation marks / aggregates change)
        g.vertex_mut(c0).alloc.jobs.push(JobId(1));
        let e2 = g.epoch();
        assert!(e2 > e1);
        // structural removal
        g.remove_leaf(c0).unwrap();
        let e3 = g.epoch();
        assert!(e3 > e2);
        // reads do not advance it
        let _ = g.vertex(root);
        let _ = g.lookup_path("/cluster0/node1");
        let _ = g.dfs(root);
        assert_eq!(g.epoch(), e3);
    }

    #[test]
    fn failed_mutations_leave_state_consistent_with_epoch() {
        // a rejected add may or may not bump (conservative is allowed), but
        // it must never change the graph without bumping: equal epochs
        // imply identical state.
        let (mut g, root, _, _) = tiny();
        let before_epoch = g.epoch();
        let before_n = g.num_vertices();
        let err = g.add_child(
            root,
            make_vertex(ResourceType::Node, "node", 0, 9, "/cluster0/node0"),
        );
        assert!(err.is_err());
        if g.epoch() == before_epoch {
            assert_eq!(g.num_vertices(), before_n);
        }
        g.check_invariants().unwrap();
    }

    #[test]
    fn restore_from_moves_epoch_forward() {
        let (mut g, root, _, c0) = tiny();
        let snapshot = g.clone();
        let snap_vertices = snapshot.num_vertices();
        // diverge: mutate past the snapshot
        g.vertex_mut(c0).alloc.jobs.push(JobId(7));
        g.add_child(
            root,
            make_vertex(ResourceType::Node, "node", 1, 9, "/cluster0/node1"),
        )
        .unwrap();
        let diverged = g.epoch();
        assert!(diverged > snapshot.epoch());
        // restore: content rewinds, epoch does not
        g.restore_from(&snapshot);
        assert_eq!(g.num_vertices(), snap_vertices);
        assert!(!g.vertex(c0).alloc.is_allocated());
        assert!(g.epoch() > diverged, "epoch must never rewind");
        g.check_invariants().unwrap();
    }

    #[test]
    fn clone_shares_chunks_and_mutation_isolates() {
        // build past one chunk boundary so the clone shares multiple chunks
        let (mut g, _, n0, _) = tiny();
        for i in 1..(CHUNK_SIZE + 8) as u64 {
            g.add_child(
                n0,
                make_vertex(
                    ResourceType::Core,
                    "core",
                    i,
                    100 + i,
                    &format!("/cluster0/node0/core{i}"),
                ),
            )
            .unwrap();
        }
        let snap = g.clone();
        assert!(
            g.chunks
                .iter()
                .zip(snap.chunks.iter())
                .all(|(a, b)| Arc::ptr_eq(a, b)),
            "clone must share every chunk"
        );
        assert!(Arc::ptr_eq(&g.topo, &snap.topo), "clone must share topology");

        // mutate one vertex in the original: only that chunk un-shares,
        // and the snapshot keeps observing the pre-mutation state
        let c5 = g.lookup_path("/cluster0/node0/core5").unwrap();
        g.vertex_mut(c5).alloc.jobs.push(JobId(9));
        let touched = (c5.0 as usize) >> CHUNK_BITS;
        for (ci, (a, b)) in g.chunks.iter().zip(snap.chunks.iter()).enumerate() {
            assert_eq!(
                !Arc::ptr_eq(a, b),
                ci == touched,
                "exactly the touched chunk must un-share (chunk {ci})"
            );
        }
        assert!(g.vertex(c5).alloc.is_allocated());
        assert!(!snap.vertex(c5).alloc.is_allocated());
        assert!(Arc::ptr_eq(&g.topo, &snap.topo), "metadata write keeps topology shared");
        g.check_invariants().unwrap();
        snap.check_invariants().unwrap();
    }

    #[test]
    fn structural_edit_unshares_topology_only_once() {
        let (mut g, _, n0, c0) = tiny();
        let snap = g.clone();
        g.remove_leaf(c0).unwrap();
        assert!(!Arc::ptr_eq(&g.topo, &snap.topo));
        assert_eq!(snap.lookup_path("/cluster0/node0/core0"), Some(c0));
        assert_eq!(g.lookup_path("/cluster0/node0/core0"), None);
        // second structural edit hits the already-unshared topology
        g.add_child(
            n0,
            make_vertex(ResourceType::Core, "core", 9, 99, "/cluster0/node0/core9"),
        )
        .unwrap();
        g.check_invariants().unwrap();
        snap.check_invariants().unwrap();
    }

    #[test]
    fn agg_slot_helpers() {
        let (mut g, root, _, _) = tiny();
        g.vertex_mut(root).agg_add_slot(0, 2, 5);
        g.vertex_mut(root).agg_add_slot(0, 2, -2);
        assert_eq!(g.vertex(root).agg_slot(0), 3);
        assert_eq!(g.vertex(root).agg_slot(1), 0);
        // reading past the dense vector is 0, never a panic
        assert_eq!(g.vertex(root).agg_slot(7), 0);
    }
}
