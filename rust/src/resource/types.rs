//! Resource types for the directed graph model.
//!
//! The paper's model is deliberately open-ended — "new resource types and
//! relationships" must not require a static configuration (§1, §2.2). Common
//! HPC/cloud types are interned as enum variants for cheap comparison; any
//! other type round-trips through [`ResourceType::Other`], so a subgraph
//! arriving from an external provider can introduce types this scheduler has
//! never seen (e.g. an EC2 availability-zone vertex).
//!
//! On the scheduling hot path types are compared millions of times, so each
//! graph owns a [`TypeTable`] that interns every `ResourceType` it has seen
//! into a dense [`TypeId`] — type equality becomes a `u16` compare and
//! `Other` strings are stored once per table instead of cloned per vertex.
//! Built-in types have fixed ids in every table; `Other` ids are
//! per-table, which is why the JGF wire format carries type *names* and the
//! receiver re-interns on attach.

use std::collections::HashMap;
use std::fmt;

/// A resource vertex type. Ordering follows typical containment depth.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ResourceType {
    /// Top-level cluster container.
    Cluster,
    /// Cloud availability zone — interposed between cluster and node for
    /// externally provided resources (§4: "EC2 zone vertex").
    Zone,
    /// Rack container.
    Rack,
    /// Compute node.
    Node,
    /// CPU socket.
    Socket,
    /// CPU core.
    Core,
    /// GPU device.
    Gpu,
    /// Memory in 1 GiB units; a vertex per unit (see DESIGN.md on how this
    /// reproduces Table 3's subgraph sizes).
    Memory,
    /// Any type not known at compile time (dynamic heterogeneity).
    Other(String),
}

impl ResourceType {
    /// Resolve a type name (unknown names become [`ResourceType::Other`]).
    pub fn from_name(name: &str) -> ResourceType {
        match name {
            "cluster" => ResourceType::Cluster,
            "zone" => ResourceType::Zone,
            "rack" => ResourceType::Rack,
            "node" => ResourceType::Node,
            "socket" => ResourceType::Socket,
            "core" => ResourceType::Core,
            "gpu" => ResourceType::Gpu,
            "memory" => ResourceType::Memory,
            other => ResourceType::Other(other.to_string()),
        }
    }

    /// Canonical lowercase name (what JGF carries on the wire).
    pub fn name(&self) -> &str {
        match self {
            ResourceType::Cluster => "cluster",
            ResourceType::Zone => "zone",
            ResourceType::Rack => "rack",
            ResourceType::Node => "node",
            ResourceType::Socket => "socket",
            ResourceType::Core => "core",
            ResourceType::Gpu => "gpu",
            ResourceType::Memory => "memory",
            ResourceType::Other(s) => s,
        }
    }
}

impl fmt::Display for ResourceType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Interned handle for a [`ResourceType`] within one [`TypeTable`].
///
/// Built-in types have the same id in every table (the `CLUSTER`..`MEMORY`
/// constants); `Other` types get the next free id in interning order.
/// `u16::MAX` is reserved as an "absent" sentinel and never allocated.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TypeId(pub u16);

impl TypeId {
    /// Fixed id of [`ResourceType::Cluster`] in every table.
    pub const CLUSTER: TypeId = TypeId(0);
    /// Fixed id of [`ResourceType::Zone`] in every table.
    pub const ZONE: TypeId = TypeId(1);
    /// Fixed id of [`ResourceType::Rack`] in every table.
    pub const RACK: TypeId = TypeId(2);
    /// Fixed id of [`ResourceType::Node`] in every table.
    pub const NODE: TypeId = TypeId(3);
    /// Fixed id of [`ResourceType::Socket`] in every table.
    pub const SOCKET: TypeId = TypeId(4);
    /// Fixed id of [`ResourceType::Core`] in every table.
    pub const CORE: TypeId = TypeId(5);
    /// Fixed id of [`ResourceType::Gpu`] in every table.
    pub const GPU: TypeId = TypeId(6);
    /// Fixed id of [`ResourceType::Memory`] in every table.
    pub const MEMORY: TypeId = TypeId(7);

    /// The id as a dense array index.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

fn builtin_id(t: &ResourceType) -> Option<TypeId> {
    match t {
        ResourceType::Cluster => Some(TypeId::CLUSTER),
        ResourceType::Zone => Some(TypeId::ZONE),
        ResourceType::Rack => Some(TypeId::RACK),
        ResourceType::Node => Some(TypeId::NODE),
        ResourceType::Socket => Some(TypeId::SOCKET),
        ResourceType::Core => Some(TypeId::CORE),
        ResourceType::Gpu => Some(TypeId::GPU),
        ResourceType::Memory => Some(TypeId::MEMORY),
        ResourceType::Other(_) => None,
    }
}

fn builtin_id_by_name(name: &str) -> Option<TypeId> {
    match name {
        "cluster" => Some(TypeId::CLUSTER),
        "zone" => Some(TypeId::ZONE),
        "rack" => Some(TypeId::RACK),
        "node" => Some(TypeId::NODE),
        "socket" => Some(TypeId::SOCKET),
        "core" => Some(TypeId::CORE),
        "gpu" => Some(TypeId::GPU),
        "memory" => Some(TypeId::MEMORY),
        _ => None,
    }
}

/// Per-graph intern table: `TypeId -> ResourceType` plus a name index for
/// `Other` types. Always seeded with the built-ins so their ids are stable.
#[derive(Debug, Clone)]
pub struct TypeTable {
    types: Vec<ResourceType>,
    /// Name index for `Other` types only (built-ins resolve via `match`,
    /// no hashing on the hot path).
    other: HashMap<String, TypeId>,
}

impl Default for TypeTable {
    fn default() -> TypeTable {
        TypeTable {
            types: vec![
                ResourceType::Cluster,
                ResourceType::Zone,
                ResourceType::Rack,
                ResourceType::Node,
                ResourceType::Socket,
                ResourceType::Core,
                ResourceType::Gpu,
                ResourceType::Memory,
            ],
            other: HashMap::new(),
        }
    }
}

impl TypeTable {
    /// A table pre-seeded with the built-in types at their fixed ids.
    pub fn new() -> TypeTable {
        TypeTable::default()
    }

    /// Number of distinct interned types (built-ins included).
    pub fn len(&self) -> usize {
        self.types.len()
    }

    /// Whether the table holds no types (never true after `new`).
    pub fn is_empty(&self) -> bool {
        self.types.is_empty()
    }

    /// Resolve an id to its type.
    pub fn get(&self, id: TypeId) -> &ResourceType {
        &self.types[id.index()]
    }

    /// Resolve an id to its canonical name.
    pub fn name(&self, id: TypeId) -> &str {
        self.types[id.index()].name()
    }

    /// Intern a type, returning its stable id for this table.
    pub fn intern(&mut self, t: &ResourceType) -> TypeId {
        if let Some(id) = builtin_id(t) {
            return id;
        }
        if let Some(&id) = self.other.get(t.name()) {
            return id;
        }
        self.push_other(t.name())
    }

    /// Intern by name (used when decoding wire formats).
    pub fn intern_name(&mut self, name: &str) -> TypeId {
        if let Some(id) = builtin_id_by_name(name) {
            return id;
        }
        if let Some(&id) = self.other.get(name) {
            return id;
        }
        self.push_other(name)
    }

    fn push_other(&mut self, name: &str) -> TypeId {
        assert!(
            self.types.len() < u16::MAX as usize,
            "type table overflow (u16::MAX is reserved)"
        );
        let id = TypeId(self.types.len() as u16);
        self.types.push(ResourceType::Other(name.to_string()));
        self.other.insert(name.to_string(), id);
        id
    }

    /// Resolve a type without interning (read-only paths like matching).
    pub fn lookup(&self, t: &ResourceType) -> Option<TypeId> {
        match builtin_id(t) {
            Some(id) => Some(id),
            None => self.other.get(t.name()).copied(),
        }
    }

    /// Resolve a type name without interning.
    pub fn lookup_name(&self, name: &str) -> Option<TypeId> {
        match builtin_id_by_name(name) {
            Some(id) => Some(id),
            None => self.other.get(name).copied(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_known() {
        for n in ["cluster", "zone", "rack", "node", "socket", "core", "gpu", "memory"] {
            assert_eq!(ResourceType::from_name(n).name(), n);
        }
    }

    #[test]
    fn roundtrip_dynamic() {
        let t = ResourceType::from_name("smartnic");
        assert_eq!(t, ResourceType::Other("smartnic".to_string()));
        assert_eq!(t.name(), "smartnic");
    }

    #[test]
    fn builtins_have_fixed_ids() {
        let mut a = TypeTable::new();
        let b = TypeTable::new();
        assert_eq!(a.intern(&ResourceType::Core), TypeId::CORE);
        assert_eq!(b.lookup(&ResourceType::Core), Some(TypeId::CORE));
        assert_eq!(a.lookup_name("node"), Some(TypeId::NODE));
        assert_eq!(a.name(TypeId::GPU), "gpu");
    }

    #[test]
    fn other_interned_once() {
        let mut t = TypeTable::new();
        let a = t.intern(&ResourceType::from_name("smartnic"));
        let b = t.intern_name("smartnic");
        assert_eq!(a, b);
        assert_eq!(t.name(a), "smartnic");
        assert_eq!(t.len(), 9);
        // a different dynamic type gets a different id
        let c = t.intern_name("fpga");
        assert_ne!(a, c);
        assert_eq!(t.lookup_name("fpga"), Some(c));
        assert_eq!(t.lookup_name("absent"), None);
    }
}
