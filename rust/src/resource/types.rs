//! Resource types for the directed graph model.
//!
//! The paper's model is deliberately open-ended — "new resource types and
//! relationships" must not require a static configuration (§1, §2.2). Common
//! HPC/cloud types are interned as enum variants for cheap comparison; any
//! other type round-trips through [`ResourceType::Other`], so a subgraph
//! arriving from an external provider can introduce types this scheduler has
//! never seen (e.g. an EC2 availability-zone vertex).

use std::fmt;

/// A resource vertex type. Ordering follows typical containment depth.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ResourceType {
    Cluster,
    /// Cloud availability zone — interposed between cluster and node for
    /// externally provided resources (§4: "EC2 zone vertex").
    Zone,
    Rack,
    Node,
    Socket,
    Core,
    Gpu,
    /// Memory in 1 GiB units; a vertex per unit (see DESIGN.md on how this
    /// reproduces Table 3's subgraph sizes).
    Memory,
    /// Any type not known at compile time (dynamic heterogeneity).
    Other(String),
}

impl ResourceType {
    pub fn from_name(name: &str) -> ResourceType {
        match name {
            "cluster" => ResourceType::Cluster,
            "zone" => ResourceType::Zone,
            "rack" => ResourceType::Rack,
            "node" => ResourceType::Node,
            "socket" => ResourceType::Socket,
            "core" => ResourceType::Core,
            "gpu" => ResourceType::Gpu,
            "memory" => ResourceType::Memory,
            other => ResourceType::Other(other.to_string()),
        }
    }

    pub fn name(&self) -> &str {
        match self {
            ResourceType::Cluster => "cluster",
            ResourceType::Zone => "zone",
            ResourceType::Rack => "rack",
            ResourceType::Node => "node",
            ResourceType::Socket => "socket",
            ResourceType::Core => "core",
            ResourceType::Gpu => "gpu",
            ResourceType::Memory => "memory",
            ResourceType::Other(s) => s,
        }
    }
}

impl fmt::Display for ResourceType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_known() {
        for n in ["cluster", "zone", "rack", "node", "socket", "core", "gpu", "memory"] {
            assert_eq!(ResourceType::from_name(n).name(), n);
        }
    }

    #[test]
    fn roundtrip_dynamic() {
        let t = ResourceType::from_name("smartnic");
        assert_eq!(t, ResourceType::Other("smartnic".to_string()));
        assert_eq!(t.name(), "smartnic");
    }
}
