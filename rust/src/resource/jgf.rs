//! JSON Graph Format (JGF) encoding of resource (sub)graphs.
//!
//! JGF is the paper's interchange format: "Subgraphs to be added or removed
//! are encoded in JSON Graph Format which can then be transmitted between
//! parent and child schedulers via RPC" (§4). Vertex identity across
//! scheduler instances is the containment **path** (the localization index),
//! so a receiver can attach a subgraph in O(n+m) without global knowledge.
//!
//! A subgraph's JGF contains one edge per node — its containment in-edge —
//! including the root's *attach edge* whose source vertex is not part of the
//! document. This makes the paper's "graph size" (vertices + edges) of a
//! subgraph exactly `2n`, matching Table 1 (e.g. T7: 35 vertices, size 70)
//! and Table 3 (t2.micro: 3 vertices, size 6).

use crate::resource::graph::{make_vertex, GraphError, ResourceGraph, VertexId, VertexProto};
use crate::resource::types::ResourceType;
use crate::util::json::{Json, JsonError};

/// One JGF node (a resource vertex in wire form).
#[derive(Debug, Clone, PartialEq)]
pub struct JgfNode {
    /// Globally unique resource id.
    pub uniq_id: u64,
    /// Resource type (carried by name on the wire).
    pub rtype: ResourceType,
    /// Basename, e.g. `core`.
    pub basename: String,
    /// Sibling index.
    pub id: u64,
    /// MPI-style rank hint; -1 when not applicable.
    pub rank: i64,
    /// Capacity units (1 for discrete resources).
    pub size: u64,
    /// Unit label for `size` (empty for discrete resources).
    pub unit: String,
    /// Containment path (vertex identity across instances).
    pub path: String,
}

impl JgfNode {
    /// Wire form of one graph vertex; the interned type id is resolved back
    /// to a named `ResourceType` (ids are per-graph, names are universal).
    pub fn from_vertex(g: &ResourceGraph, vid: VertexId) -> JgfNode {
        let v = g.vertex(vid);
        JgfNode {
            uniq_id: v.uniq_id,
            rtype: g.rtype(vid).clone(),
            basename: v.basename.clone(),
            id: v.id,
            rank: v.rank,
            size: v.size,
            unit: v.unit.clone(),
            path: v.path.clone(),
        }
    }

    /// Convert back to a vertex prototype for attachment.
    pub fn to_vertex(&self) -> VertexProto {
        let mut v = make_vertex(
            self.rtype.clone(),
            &self.basename,
            self.id,
            self.uniq_id,
            &self.path,
        );
        v.rank = self.rank;
        v.size = self.size;
        v.unit = self.unit.clone();
        v
    }

    /// Containment path of this node's parent (everything before the last
    /// `/` component), or None for a bare root like `/cluster0`.
    pub fn parent_path(&self) -> Option<&str> {
        let idx = self.path.rfind('/')?;
        if idx == 0 {
            None
        } else {
            Some(&self.path[..idx])
        }
    }
}

/// A JGF document: nodes in topological (parent-before-child) order plus
/// containment edges `(source uniq_id, target uniq_id)`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Jgf {
    /// Nodes in parents-before-children order.
    pub nodes: Vec<JgfNode>,
    /// Containment edges as `(source uniq_id, target uniq_id)` pairs.
    pub edges: Vec<(u64, u64)>,
}

impl Jgf {
    /// Paper-style size: vertices + edges.
    pub fn size(&self) -> usize {
        self.nodes.len() + self.edges.len()
    }

    /// Encode an entire graph.
    pub fn from_graph(g: &ResourceGraph) -> Jgf {
        match g.root() {
            Some(root) => Self::from_subtree(g, root),
            None => Jgf::default(),
        }
    }

    /// Encode the subtree rooted at `root` (attach edge included if the
    /// subtree root has a parent).
    pub fn from_subtree(g: &ResourceGraph, root: VertexId) -> Jgf {
        Self::from_selection(g, &g.dfs(root))
    }

    /// Encode a selection of vertices (must be parent-before-child closed
    /// upward within the selection; `dfs` order satisfies this). Each
    /// selected vertex contributes its in-edge; sources outside the
    /// selection become attach edges.
    pub fn from_selection(g: &ResourceGraph, selection: &[VertexId]) -> Jgf {
        let mut jgf = Jgf::default();
        for &vid in selection {
            jgf.nodes.push(JgfNode::from_vertex(g, vid));
            if let Some(p) = g.parent_of(vid) {
                jgf.edges.push((g.vertex(p).uniq_id, g.vertex(vid).uniq_id));
            }
        }
        jgf
    }

    /// Like [`Jgf::from_selection`] but prepending the selection's missing
    /// *interior* ancestors (everything between a selected vertex and the
    /// graph root, exclusive). A grant whose root is below node level
    /// (e.g. the paper's T8: one socket + 16 cores) would otherwise have no
    /// attach point in a child that never saw that node — the ancestors
    /// ride along as structural (unallocated) vertices, and `add_subgraph`
    /// treats already-present ones as the identity. With the interposed
    /// node this makes T8's wire size exactly Table 1's 36.
    pub fn from_selection_closed(g: &ResourceGraph, selection: &[VertexId]) -> Jgf {
        use std::collections::HashSet;
        let sel: HashSet<VertexId> = selection.iter().copied().collect();
        let root = g.root();
        let mut extra: Vec<VertexId> = Vec::new();
        let mut seen: HashSet<VertexId> = HashSet::new();
        for &vid in selection {
            for a in g.ancestors(vid) {
                if Some(a) == root || sel.contains(&a) {
                    continue;
                }
                if seen.insert(a) {
                    extra.push(a);
                }
            }
        }
        // deepest-last so parents precede children after the sort below
        // (depth is cached on the vertex; no ancestor walk per key)
        extra.sort_by_key(|&v| g.vertex(v).depth);
        let mut all: Vec<VertexId> = extra;
        all.extend_from_slice(selection);
        Self::from_selection(g, &all)
    }

    /// Canonical JGF document (`{"graph": {"nodes": ..., "edges": ...}}`).
    pub fn to_json(&self) -> Json {
        // Wire-size discipline (§Perf): default-valued fields (rank −1,
        // size 1, empty unit) and derivable ones (name = basename+id) are
        // omitted; the decoder restores the defaults. A T1-sized grant
        // shrinks ~45% and every serialize/parse/copy on the MatchGrow
        // path shrinks with it.
        let nodes: Vec<Json> = self
            .nodes
            .iter()
            .map(|n| {
                let paths = Json::obj().with("containment", Json::from(n.path.as_str()));
                let mut meta = Json::obj()
                    .with("type", Json::from(n.rtype.name()))
                    .with("basename", Json::from(n.basename.as_str()))
                    .with("id", Json::from(n.id))
                    .with("uniq_id", Json::from(n.uniq_id));
                if n.rank != -1 {
                    meta.set("rank", Json::from(n.rank));
                }
                if n.size != 1 {
                    meta.set("size", Json::from(n.size));
                }
                if !n.unit.is_empty() {
                    meta.set("unit", Json::from(n.unit.as_str()));
                }
                meta.set("paths", paths);
                Json::obj()
                    .with("id", Json::from(n.uniq_id.to_string()))
                    .with("metadata", meta)
            })
            .collect();
        let edges: Vec<Json> = self
            .edges
            .iter()
            .map(|(s, t)| {
                Json::obj()
                    .with("source", Json::from(s.to_string()))
                    .with("target", Json::from(t.to_string()))
            })
            .collect();
        Json::obj().with(
            "graph",
            Json::obj()
                .with("nodes", Json::Arr(nodes))
                .with("edges", Json::Arr(edges)),
        )
    }

    /// Decode a JGF document.
    pub fn from_json(doc: &Json) -> Result<Jgf, JsonError> {
        let graph = doc
            .get("graph")
            .ok_or_else(|| JsonError::Schema("missing 'graph'".into()))?;
        let nodes_json = graph
            .get("nodes")
            .and_then(Json::as_arr)
            .ok_or_else(|| JsonError::Schema("missing 'graph.nodes'".into()))?;
        let edges_json = graph
            .get("edges")
            .and_then(Json::as_arr)
            .ok_or_else(|| JsonError::Schema("missing 'graph.edges'".into()))?;
        let mut jgf = Jgf::default();
        for n in nodes_json {
            let meta = n
                .get("metadata")
                .ok_or_else(|| JsonError::Schema("node missing metadata".into()))?;
            let paths = meta
                .get("paths")
                .ok_or_else(|| JsonError::Schema("node missing paths".into()))?;
            jgf.nodes.push(JgfNode {
                uniq_id: meta.u64_field("uniq_id")?,
                rtype: ResourceType::from_name(meta.str_field("type")?),
                basename: meta.str_field("basename")?.to_string(),
                id: meta.u64_field("id")?,
                rank: meta.get("rank").and_then(Json::as_i64).unwrap_or(-1),
                size: meta.get("size").and_then(Json::as_u64).unwrap_or(1),
                unit: meta
                    .get("unit")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                path: paths.str_field("containment")?.to_string(),
            });
        }
        for e in edges_json {
            let s = e
                .str_field("source")?
                .parse::<u64>()
                .map_err(|_| JsonError::Schema("edge source not an id".into()))?;
            let t = e
                .str_field("target")?
                .parse::<u64>()
                .map_err(|_| JsonError::Schema("edge target not an id".into()))?;
            jgf.edges.push((s, t));
        }
        Ok(jgf)
    }

    /// Compact wire text of the JGF document.
    pub fn dump(&self) -> String {
        self.to_json().dump()
    }

    /// Parse JGF wire text.
    pub fn parse(text: &str) -> Result<Jgf, JsonError> {
        Jgf::from_json(&Json::parse(text)?)
    }

    /// Materialize this JGF as a standalone graph (used when a child
    /// instance initializes its resource graph from the subgraph its parent
    /// granted — "each instance initializes its resource graph with only
    /// those resources within its purview", §3).
    ///
    /// Nodes whose parent path is absent from the document become roots —
    /// but a standalone graph needs exactly one, so callers pass
    /// `synthesize_root=true` to interpose a cluster root when the document
    /// contains a forest (e.g. two nodes granted from a larger cluster).
    pub fn build_graph(&self, synthesize_root: bool) -> Result<ResourceGraph, GraphError> {
        let mut g = ResourceGraph::new();
        let mut roots: Vec<&JgfNode> = Vec::new();
        for n in &self.nodes {
            match n.parent_path() {
                Some(pp) if g.lookup_path(pp).is_some() => {}
                _ => roots.push(n),
            }
        }
        let needs_synth = synthesize_root
            && (roots.len() != 1 || roots[0].parent_path().is_some());
        if needs_synth {
            // Root path: the common prefix component of all node paths.
            let prefix = self
                .nodes
                .first()
                .and_then(|n| n.path.split('/').nth(1))
                .unwrap_or("cluster0")
                .to_string();
            let root_path = format!("/{prefix}");
            if self.nodes.iter().all(|n| n.path != root_path) {
                g.add_root(make_vertex(
                    ResourceType::Cluster,
                    prefix.trim_end_matches(char::is_numeric),
                    0,
                    u64::MAX, // synthetic id; not a wire identity
                    &root_path,
                ))?;
            }
        }
        for n in &self.nodes {
            let v = n.to_vertex();
            match n.parent_path().and_then(|pp| g.lookup_path(pp)) {
                Some(p) => {
                    g.add_child(p, v)?;
                }
                None => {
                    g.add_root(v)?;
                }
            }
        }
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resource::builder::{ClusterSpec, UidGen};

    fn sample_graph() -> ResourceGraph {
        ClusterSpec::new("cluster", 2, 2, 4).build(&mut UidGen::new())
    }

    #[test]
    fn whole_graph_roundtrip() {
        let g = sample_graph();
        let jgf = Jgf::from_graph(&g);
        assert_eq!(jgf.nodes.len(), g.num_vertices());
        assert_eq!(jgf.edges.len(), g.num_edges());
        let parsed = Jgf::parse(&jgf.dump()).unwrap();
        assert_eq!(parsed, jgf);
    }

    #[test]
    fn subtree_has_attach_edge() {
        let g = sample_graph();
        let node0 = g.lookup_path("/cluster0/node0").unwrap();
        let jgf = Jgf::from_subtree(&g, node0);
        // node + 2 sockets + 8 cores = 11 vertices, 11 edges (attach incl.)
        assert_eq!(jgf.nodes.len(), 11);
        assert_eq!(jgf.edges.len(), 11);
        assert_eq!(jgf.size(), 22);
        // attach edge's source (cluster) is not among the nodes
        let ids: Vec<u64> = jgf.nodes.iter().map(|n| n.uniq_id).collect();
        assert!(jgf.edges.iter().any(|(s, _)| !ids.contains(s)));
    }

    #[test]
    fn build_graph_from_subtree_synthesizes_root() {
        let g = sample_graph();
        let node0 = g.lookup_path("/cluster0/node0").unwrap();
        let jgf = Jgf::from_subtree(&g, node0);
        let child = jgf.build_graph(true).unwrap();
        assert!(child.root().is_some());
        assert_eq!(child.num_vertices(), 12); // 11 + synthetic cluster root
        assert!(child.lookup_path("/cluster0/node0/socket1/core3").is_some());
        child.check_invariants().unwrap();
    }

    #[test]
    fn build_graph_whole_cluster_no_synth_needed() {
        let g = sample_graph();
        let jgf = Jgf::from_graph(&g);
        let rebuilt = jgf.build_graph(true).unwrap();
        assert_eq!(rebuilt.num_vertices(), g.num_vertices());
        assert_eq!(rebuilt.num_edges(), g.num_edges());
        rebuilt.check_invariants().unwrap();
    }

    #[test]
    fn parent_path() {
        let g = sample_graph();
        let jgf = Jgf::from_graph(&g);
        let root = &jgf.nodes[0];
        assert_eq!(root.parent_path(), None);
        let leaf = jgf.nodes.last().unwrap();
        assert!(leaf.parent_path().unwrap().starts_with("/cluster0/node"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Jgf::parse("{}").is_err());
        assert!(Jgf::parse(r#"{"graph":{"nodes":[{"id":"0"}],"edges":[]}}"#).is_err());
    }
}
