//! The dynamic directed-graph resource model (paper §3): typed vertices,
//! containment tree with a path index, JGF interchange, and builders for the
//! paper's test configurations.

pub mod builder;
pub mod graph;
pub mod jgf;
pub mod types;

pub use graph::{JobId, ResourceGraph, Vertex, VertexId, VertexProto};
pub use jgf::Jgf;
pub use types::{ResourceType, TypeId, TypeTable};
