//! Cluster graph builders for the paper's test configurations.
//!
//! Table 2's level graphs are `nodes × sockets × cores` trees under a
//! cluster root; EC2 instances are `node → {core, gpu, memory-GiB}` subtrees
//! (Table 3); the KubeFlux OpenShift cluster is
//! `cluster → node → socket → {core, gpu}` (§5 testbed).

use crate::resource::graph::{make_vertex, ResourceGraph, VertexId};
use crate::resource::types::ResourceType;

/// Monotonic `uniq_id` allocator. A single generator is shared by every
/// graph in one experiment so resource identity is globally unique, as the
/// paper's multi-level instances require.
#[derive(Debug, Default, Clone)]
pub struct UidGen {
    next: u64,
}

impl UidGen {
    /// Start ids at 0.
    pub fn new() -> UidGen {
        UidGen { next: 0 }
    }

    /// Start ids at `next` (disjoint ranges for independent graphs).
    pub fn starting_at(next: u64) -> UidGen {
        UidGen { next }
    }

    /// Mint the next unique id.
    pub fn next(&mut self) -> u64 {
        let v = self.next;
        self.next += 1;
        v
    }
}

/// Homogeneous-cluster spec: `nodes × sockets/node × cores/socket`, with
/// optional per-socket GPUs and per-node memory (GiB vertices).
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Cluster basename (root path is `/<name>0`).
    pub name: String,
    /// Node count.
    pub nodes: usize,
    /// Sockets per node.
    pub sockets_per_node: usize,
    /// Cores per socket.
    pub cores_per_socket: usize,
    /// GPUs per socket (0 for CPU-only clusters).
    pub gpus_per_socket: usize,
    /// Memory vertices (GiB each) per node.
    pub mem_gib_per_node: usize,
    /// First node index (so different levels get distinct node names when
    /// carved from one cluster).
    pub node_base: usize,
}

impl ClusterSpec {
    /// A CPU-only homogeneous cluster spec.
    pub fn new(name: &str, nodes: usize, sockets: usize, cores: usize) -> ClusterSpec {
        ClusterSpec {
            name: name.to_string(),
            nodes,
            sockets_per_node: sockets,
            cores_per_socket: cores,
            gpus_per_socket: 0,
            mem_gib_per_node: 0,
            node_base: 0,
        }
    }

    /// Add per-socket GPUs (builder).
    pub fn with_gpus(mut self, gpus_per_socket: usize) -> ClusterSpec {
        self.gpus_per_socket = gpus_per_socket;
        self
    }

    /// Add per-node memory vertices (builder).
    pub fn with_memory(mut self, mem_gib_per_node: usize) -> ClusterSpec {
        self.mem_gib_per_node = mem_gib_per_node;
        self
    }

    /// Offset node naming (builder; see the `node_base` field).
    pub fn with_node_base(mut self, base: usize) -> ClusterSpec {
        self.node_base = base;
        self
    }

    /// Total core count.
    pub fn total_cores(&self) -> usize {
        self.nodes * self.sockets_per_node * self.cores_per_socket
    }

    /// Expected vertex count.
    pub fn total_vertices(&self) -> usize {
        1 + self.nodes
            * (1
                + self.sockets_per_node
                    * (1 + self.cores_per_socket + self.gpus_per_socket)
                + self.mem_gib_per_node)
    }

    /// Materialize the cluster graph.
    pub fn build(&self, uids: &mut UidGen) -> ResourceGraph {
        let mut g = ResourceGraph::new();
        let cluster_path = format!("/{}0", self.name);
        let root = g
            .add_root(make_vertex(
                ResourceType::Cluster,
                &self.name,
                0,
                uids.next(),
                &cluster_path,
            ))
            .expect("fresh graph has no root");
        for ni in 0..self.nodes {
            let n = ni + self.node_base;
            let node_path = format!("{cluster_path}/node{n}");
            let node = g
                .add_child(
                    root,
                    make_vertex(ResourceType::Node, "node", n as u64, uids.next(), &node_path),
                )
                .unwrap();
            for s in 0..self.sockets_per_node {
                let sock_path = format!("{node_path}/socket{s}");
                let sock = g
                    .add_child(
                        node,
                        make_vertex(
                            ResourceType::Socket,
                            "socket",
                            s as u64,
                            uids.next(),
                            &sock_path,
                        ),
                    )
                    .unwrap();
                for c in 0..self.cores_per_socket {
                    g.add_child(
                        sock,
                        make_vertex(
                            ResourceType::Core,
                            "core",
                            c as u64,
                            uids.next(),
                            &format!("{sock_path}/core{c}"),
                        ),
                    )
                    .unwrap();
                }
                for gi in 0..self.gpus_per_socket {
                    g.add_child(
                        sock,
                        make_vertex(
                            ResourceType::Gpu,
                            "gpu",
                            gi as u64,
                            uids.next(),
                            &format!("{sock_path}/gpu{gi}"),
                        ),
                    )
                    .unwrap();
                }
            }
            for m in 0..self.mem_gib_per_node {
                let mut v = make_vertex(
                    ResourceType::Memory,
                    "memory",
                    m as u64,
                    uids.next(),
                    &format!("{node_path}/memory{m}"),
                );
                v.unit = "GiB".to_string();
                g.add_child(node, v).unwrap();
            }
        }
        g
    }
}

/// Table 2 configurations: (level, nodes, sockets/node, cores/socket).
/// Graph sizes in our counting are `2·V − 1` (unidirectional containment
/// edges); the paper's Fluxion counts differ by a small bookkeeping constant
/// (see EXPERIMENTS.md §E2).
pub const TABLE2_LEVELS: [(usize, usize, usize, usize); 5] = [
    (0, 128, 2, 16), // L0: 128 nodes, 256 sockets, 4096 cores
    (1, 8, 2, 16),   // L1: 8 nodes, 16 sockets, 256 cores
    (2, 4, 2, 16),   // L2
    (3, 2, 2, 16),   // L3
    (4, 1, 2, 16),   // L4
];

/// Build the level-`l` graph of Table 2.
pub fn table2_graph(level: usize, uids: &mut UidGen) -> ResourceGraph {
    let (_, nodes, sockets, cores) = TABLE2_LEVELS
        .iter()
        .copied()
        .find(|(l, ..)| *l == level)
        .expect("level 0..=4");
    ClusterSpec::new("cluster", nodes, sockets, cores).build(uids)
}

/// An attachable subtree for one "node" shaped like the Table 1 requests:
/// used to fabricate grant subgraphs in unit tests.
pub fn node_subtree(
    g: &mut ResourceGraph,
    parent: VertexId,
    node_idx: usize,
    sockets: usize,
    cores_per_socket: usize,
    uids: &mut UidGen,
) -> VertexId {
    let ppath = g.vertex(parent).path.clone();
    let node_path = format!("{ppath}/node{node_idx}");
    let node = g
        .add_child(
            parent,
            make_vertex(
                ResourceType::Node,
                "node",
                node_idx as u64,
                uids.next(),
                &node_path,
            ),
        )
        .unwrap();
    for s in 0..sockets {
        let sock_path = format!("{node_path}/socket{s}");
        let sock = g
            .add_child(
                node,
                make_vertex(ResourceType::Socket, "socket", s as u64, uids.next(), &sock_path),
            )
            .unwrap();
        for c in 0..cores_per_socket {
            g.add_child(
                sock,
                make_vertex(
                    ResourceType::Core,
                    "core",
                    c as u64,
                    uids.next(),
                    &format!("{sock_path}/core{c}"),
                ),
            )
            .unwrap();
        }
    }
    node
}

/// The KubeFlux OpenShift testbed graph (§5): 26 nodes, 2 sockets × 10
/// Power8 cores with SMT8 (160 hardware threads/node, modeled as cores),
/// 4 GPUs per node (2 per socket). 4343 vertices in our counting vs the
/// paper's 4344 (one bookkeeping vertex); edges unidirectional.
pub fn kubeflux_graph(uids: &mut UidGen) -> ResourceGraph {
    ClusterSpec::new("openshift", 26, 2, 80)
        .with_gpus(2)
        .build(uids)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_sizes() {
        // Our counting: size = 2·V − 1.
        let expected_vertices = [4481usize, 281, 141, 71, 36];
        for (i, (level, ..)) in TABLE2_LEVELS.iter().enumerate() {
            let g = table2_graph(*level, &mut UidGen::new());
            assert_eq!(g.num_vertices(), expected_vertices[i], "level {level}");
            assert_eq!(g.size(), 2 * expected_vertices[i] - 1);
            g.check_invariants().unwrap();
        }
    }

    #[test]
    fn uids_are_globally_unique_across_graphs() {
        let mut uids = UidGen::new();
        let a = table2_graph(4, &mut uids);
        let b = table2_graph(3, &mut uids);
        let mut seen = std::collections::HashSet::new();
        for g in [&a, &b] {
            for vid in g.iter_live() {
                assert!(seen.insert(g.vertex(vid).uniq_id), "duplicate uniq_id");
            }
        }
    }

    #[test]
    fn gpus_and_memory() {
        let g = ClusterSpec::new("c", 1, 2, 4)
            .with_gpus(1)
            .with_memory(8)
            .build(&mut UidGen::new());
        // 1 cluster + 1 node + 2 sockets + 8 cores + 2 gpus + 8 mem = 22
        assert_eq!(g.num_vertices(), 22);
        assert!(g.lookup_path("/c0/node0/socket1/gpu0").is_some());
        assert!(g.lookup_path("/c0/node0/memory7").is_some());
    }

    #[test]
    fn total_vertices_formula_matches() {
        for spec in [
            ClusterSpec::new("c", 3, 2, 5),
            ClusterSpec::new("c", 1, 1, 1).with_gpus(2).with_memory(4),
        ] {
            let g = spec.build(&mut UidGen::new());
            assert_eq!(g.num_vertices(), spec.total_vertices());
        }
    }

    #[test]
    fn node_base_offsets_names() {
        let g = ClusterSpec::new("c", 2, 1, 1).with_node_base(5).build(&mut UidGen::new());
        assert!(g.lookup_path("/c0/node5").is_some());
        assert!(g.lookup_path("/c0/node6").is_some());
        assert!(g.lookup_path("/c0/node0").is_none());
    }

    #[test]
    fn kubeflux_size() {
        let g = kubeflux_graph(&mut UidGen::new());
        // paper: 4344 vertices / 8686 bidirectional edges; ours: 4343 / 4342
        assert_eq!(g.num_vertices(), 4343);
        assert_eq!(g.num_edges(), 4342);
    }
}
