//! Fault injection + fault-tolerance primitives for hierarchy serving.
//!
//! The paper's model only holds value if the hierarchy stays correct while
//! the hierarchy itself misbehaves: member instances appear and vanish
//! mid-run (Flux Operator), and at converged-computing scale transient
//! provider/API failures are the steady state (CMS SI). This module carries
//! both halves of that story:
//!
//! - **Injection** — a deterministic, seeded harness ([`FaultInjector`])
//!   that wraps the RPC client side ([`FaultyConn`]), the server side
//!   ([`chaos_handler`]), and external providers ([`FaultyProvider`]) to
//!   drop, delay, truncate, or corrupt frames and to fail or spot-reclaim
//!   grants — either by seeded rates or on an explicit scripted schedule.
//!   Same seed + same call sequence ⇒ byte-for-byte the same fault
//!   schedule ([`crate::util::rng::Rng`] underneath).
//! - **Tolerance** — the policies the serving stack defends itself with:
//!   bounded retry with exponential backoff + deterministic jitter
//!   ([`RetryPolicy`], [`Backoff`], [`RetryConn`], [`RetryingProvider`])
//!   and the quarantine circuit breaker ([`CircuitBreaker`]) that
//!   `hier` attaches to every parent link.
//! - **Crashes** — scripted whole-level kills ([`CrashPlan`]) that fire at
//!   the journal/reconcile lifecycle points ([`CrashPoint`]) where crash
//!   recovery (PR 10, [`crate::sched::journal`]) has something to prove:
//!   orphaned grants, uncommitted journal suffixes, interrupted
//!   reconciliation.
//!
//! ## Retry semantics (at-most-once for mutations)
//!
//! [`RetryConn`] transparently retries only requests whose op is
//! **read-only** ([`crate::rpc::proto::SchedOp::is_read_only`]): a timed-out
//! `match_grow` may have committed on the peer, so re-sending it could
//! double-allocate. Mutating-op transport failures surface to the caller,
//! whose circuit breaker decides whether the level is still worth talking
//! to. The same split holds for providers: [`RetryingProvider`] retries
//! [`ProviderError::Api`] (transient, and providers fail atomically — see
//! its doc) but never [`ProviderError::Unsatisfiable`] (a well-formed "no"
//! that retrying cannot change).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::external::provider::{ExternalGrant, ExternalProvider, ProviderError};
use crate::jobspec::JobSpec;
use crate::rpc::transport::{Conn, Handler};
use crate::rpc::{Request, Response};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Fault vocabulary
// ---------------------------------------------------------------------------

/// What happens to one RPC call (client side) or one served request
/// (server side).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FrameFault {
    /// No fault: the call proceeds normally.
    Deliver,
    /// The frame vanishes: the caller observes a timeout
    /// (`ErrorKind::TimedOut`); server-side it models a stalled peer.
    Drop,
    /// The frame is held for the given duration, then delivered.
    Delay(Duration),
    /// The frame is cut mid-body: the caller observes
    /// `ErrorKind::UnexpectedEof` (framing rejects partial bodies).
    Truncate,
    /// The frame arrives with flipped bytes: the caller observes
    /// `ErrorKind::InvalidData` (the JSON layer rejects it).
    Corrupt,
}

/// What happens to one external-provider request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ProviderFault {
    /// No fault: the request goes through to the wrapped provider.
    Deliver,
    /// The provider API fails transiently ([`ProviderError::Api`]); nothing
    /// is created.
    Api,
    /// The provider answers a well-formed "no"
    /// ([`ProviderError::Unsatisfiable`]).
    Unsatisfiable,
    /// The request *succeeds* on the wrapped provider, then the capacity is
    /// reclaimed before the grant reaches the caller (spot interruption):
    /// the created instances are released on the inner provider and the
    /// caller sees [`ProviderError::Api`]. No orphaned `instance_ids`.
    Reclaim,
}

/// Per-fault-class probabilities for rate-driven injection. All rates are
/// independent probabilities in `[0, 1]`, drawn cumulatively from a single
/// uniform sample per decision (so their sum should stay ≤ 1).
#[derive(Debug, Clone, Copy)]
pub struct FaultRates {
    /// Probability a frame is dropped ([`FrameFault::Drop`]).
    pub drop: f64,
    /// Probability a frame is delayed by [`FaultRates::delay_for`].
    pub delay: f64,
    /// Injected delay duration for [`FrameFault::Delay`] draws.
    pub delay_for: Duration,
    /// Probability a frame is truncated.
    pub truncate: f64,
    /// Probability a frame is corrupted.
    pub corrupt: f64,
    /// Probability a provider request fails with [`ProviderFault::Api`].
    pub provider_api: f64,
    /// Probability a provider request fails with
    /// [`ProviderFault::Unsatisfiable`].
    pub provider_unsat: f64,
    /// Probability a provider grant is spot-reclaimed mid-request.
    pub provider_reclaim: f64,
}

impl FaultRates {
    /// All-zero rates: every decision is [`FrameFault::Deliver`] /
    /// [`ProviderFault::Deliver`] unless a script overrides it.
    pub fn none() -> FaultRates {
        FaultRates {
            drop: 0.0,
            delay: 0.0,
            delay_for: Duration::ZERO,
            truncate: 0.0,
            corrupt: 0.0,
            provider_api: 0.0,
            provider_unsat: 0.0,
            provider_reclaim: 0.0,
        }
    }
}

impl Default for FaultRates {
    fn default() -> FaultRates {
        FaultRates::none()
    }
}

/// Counters of every decision an injector has made. Cheap `Copy` snapshot —
/// tests assert on these to prove faults actually fired.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultStats {
    /// Frame decisions that delivered normally.
    pub delivered: u64,
    /// Frames dropped.
    pub dropped: u64,
    /// Frames delayed.
    pub delayed: u64,
    /// Frames truncated.
    pub truncated: u64,
    /// Frames corrupted.
    pub corrupted: u64,
    /// Provider requests failed with an API error.
    pub provider_api: u64,
    /// Provider requests answered unsatisfiable.
    pub provider_unsat: u64,
    /// Provider grants spot-reclaimed.
    pub provider_reclaims: u64,
}

struct InjectorState {
    rng: Rng,
    rates: FaultRates,
    frame_script: VecDeque<FrameFault>,
    provider_script: VecDeque<ProviderFault>,
    stats: FaultStats,
}

/// Deterministic, seeded fault source. Cloneable handle (`Arc` inside): the
/// same injector can drive a [`FaultyConn`], a [`chaos_handler`], and a
/// [`FaultyProvider`] while tests keep a handle for scripting and stats.
///
/// Decisions come from an explicit script first (FIFO, pushed via
/// [`FaultInjector::push_frame_fault`] / `push_provider_fault`), then from
/// the seeded [`FaultRates`]. With rates of zero and an empty script every
/// decision is `Deliver` — the wrappers become transparent.
#[derive(Clone)]
pub struct FaultInjector {
    state: Arc<Mutex<InjectorState>>,
}

impl FaultInjector {
    /// Build an injector with a seed and rate table.
    pub fn new(seed: u64, rates: FaultRates) -> FaultInjector {
        FaultInjector {
            state: Arc::new(Mutex::new(InjectorState {
                rng: Rng::new(seed),
                rates,
                frame_script: VecDeque::new(),
                provider_script: VecDeque::new(),
                stats: FaultStats::default(),
            })),
        }
    }

    /// Queue an explicit frame fault; scripts win over rates, FIFO.
    pub fn push_frame_fault(&self, f: FrameFault) {
        self.lock().frame_script.push_back(f);
    }

    /// Queue an explicit provider fault; scripts win over rates, FIFO.
    pub fn push_provider_fault(&self, f: ProviderFault) {
        self.lock().provider_script.push_back(f);
    }

    /// Decide the fate of one frame (script first, then rates) and record
    /// it in the stats.
    pub fn frame_fault(&self) -> FrameFault {
        let mut s = self.lock();
        let fault = match s.frame_script.pop_front() {
            Some(f) => f,
            None => {
                // one uniform draw, cumulative thresholds: deterministic
                // and keeps the per-class rates independent of draw order
                let r = s.rng.f64();
                let FaultRates {
                    drop,
                    delay,
                    delay_for,
                    truncate,
                    corrupt,
                    ..
                } = s.rates;
                if r < drop {
                    FrameFault::Drop
                } else if r < drop + truncate {
                    FrameFault::Truncate
                } else if r < drop + truncate + corrupt {
                    FrameFault::Corrupt
                } else if r < drop + truncate + corrupt + delay {
                    FrameFault::Delay(delay_for)
                } else {
                    FrameFault::Deliver
                }
            }
        };
        match fault {
            FrameFault::Deliver => s.stats.delivered += 1,
            FrameFault::Drop => s.stats.dropped += 1,
            FrameFault::Delay(_) => s.stats.delayed += 1,
            FrameFault::Truncate => s.stats.truncated += 1,
            FrameFault::Corrupt => s.stats.corrupted += 1,
        }
        fault
    }

    /// Decide the fate of one provider request (script first, then rates)
    /// and record it in the stats.
    pub fn provider_fault(&self) -> ProviderFault {
        let mut s = self.lock();
        let fault = match s.provider_script.pop_front() {
            Some(f) => f,
            None => {
                let r = s.rng.f64();
                let FaultRates {
                    provider_api,
                    provider_unsat,
                    provider_reclaim,
                    ..
                } = s.rates;
                if r < provider_api {
                    ProviderFault::Api
                } else if r < provider_api + provider_unsat {
                    ProviderFault::Unsatisfiable
                } else if r < provider_api + provider_unsat + provider_reclaim {
                    ProviderFault::Reclaim
                } else {
                    ProviderFault::Deliver
                }
            }
        };
        match fault {
            ProviderFault::Deliver => {}
            ProviderFault::Api => s.stats.provider_api += 1,
            ProviderFault::Unsatisfiable => s.stats.provider_unsat += 1,
            ProviderFault::Reclaim => s.stats.provider_reclaims += 1,
        }
        fault
    }

    /// Snapshot of every decision made so far.
    pub fn stats(&self) -> FaultStats {
        self.lock().stats
    }

    /// Zero the decision counters (scripts, rates, and rng position are
    /// untouched — this resets *bookkeeping*, not behaviour).
    /// `Hierarchy::reset` calls this so stats don't leak across runs.
    pub fn reset_stats(&self) {
        self.lock().stats = FaultStats::default();
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, InjectorState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

// ---------------------------------------------------------------------------
// Client-side frame injection
// ---------------------------------------------------------------------------

/// A [`Conn`] wrapper that consults a [`FaultInjector`] before each call.
///
/// Faults are *simulated at the client boundary*: a `Drop` returns
/// `ErrorKind::TimedOut` immediately (the caller's deadline outcome without
/// the wall-clock wait — keeps chaos soaks fast and their fault schedule
/// independent of real timing), `Truncate`/`Corrupt` return the error the
/// framing/JSON layers would produce, and `Delay` sleeps, then forwards.
/// Pair with [`chaos_handler`] when a test needs the *real* read-timeout
/// machinery to fire instead.
pub struct FaultyConn {
    inner: Box<dyn Conn>,
    injector: FaultInjector,
}

impl FaultyConn {
    /// Wrap a connection with an injector.
    pub fn new(inner: Box<dyn Conn>, injector: FaultInjector) -> FaultyConn {
        FaultyConn { inner, injector }
    }
}

impl Conn for FaultyConn {
    fn call(&mut self, req: &Request) -> std::io::Result<Response> {
        use std::io::{Error, ErrorKind};
        match self.injector.frame_fault() {
            FrameFault::Deliver => self.inner.call(req),
            FrameFault::Delay(d) => {
                std::thread::sleep(d);
                self.inner.call(req)
            }
            FrameFault::Drop => Err(Error::new(
                ErrorKind::TimedOut,
                "injected: frame dropped, deadline exceeded",
            )),
            FrameFault::Truncate => Err(Error::new(
                ErrorKind::UnexpectedEof,
                "injected: frame truncated mid-body",
            )),
            FrameFault::Corrupt => Err(Error::new(
                ErrorKind::InvalidData,
                "injected: frame corrupted",
            )),
        }
    }
}

/// Wrap a server-side [`Handler`] with latency-class fault injection: a
/// `Delay(d)` draw sleeps `d` before handling; a `Drop` draw sleeps
/// `stall` (modeling a hung peer — with `stall` beyond the client's read
/// deadline, the client's *real* timeout machinery fires). Byte-level
/// faults (`Truncate`/`Corrupt`) cannot be expressed through the typed
/// handler and are treated as `Deliver`; inject those client-side with
/// [`FaultyConn`].
pub fn chaos_handler(h: Handler, injector: FaultInjector, stall: Duration) -> Handler {
    crate::rpc::transport::handler(move |req: Request| {
        match injector.frame_fault() {
            FrameFault::Delay(d) => std::thread::sleep(d),
            FrameFault::Drop => std::thread::sleep(stall),
            FrameFault::Deliver | FrameFault::Truncate | FrameFault::Corrupt => {}
        }
        h(req)
    })
}

// ---------------------------------------------------------------------------
// Provider injection
// ---------------------------------------------------------------------------

/// An [`ExternalProvider`] wrapper that consults a [`FaultInjector`] before
/// each request. Generic (not boxed) so tests keep concrete access to the
/// wrapped provider via [`FaultyProvider::inner`] — e.g. to assert
/// `live_instances()` is empty after a reclaim.
///
/// `Reclaim` is the interesting case: the request **succeeds** on the inner
/// provider, then the instances are immediately released there and the
/// caller sees an [`ProviderError::Api`] — the spot-interruption shape.
/// Because the release happens before the error surfaces, a reclaim can
/// never orphan `instance_ids`.
pub struct FaultyProvider<P: ExternalProvider> {
    inner: P,
    injector: FaultInjector,
}

impl<P: ExternalProvider> FaultyProvider<P> {
    /// Wrap a provider with an injector.
    pub fn new(inner: P, injector: FaultInjector) -> FaultyProvider<P> {
        FaultyProvider { inner, injector }
    }

    /// The wrapped provider (for test assertions on its internal state).
    pub fn inner(&self) -> &P {
        &self.inner
    }
}

impl<P: ExternalProvider> ExternalProvider for FaultyProvider<P> {
    fn name(&self) -> &str {
        "faulty"
    }

    fn request(&mut self, spec: &JobSpec) -> Result<ExternalGrant, ProviderError> {
        match self.injector.provider_fault() {
            ProviderFault::Deliver => self.inner.request(spec),
            ProviderFault::Api => Err(ProviderError::Api(
                "injected: provider API failure".into(),
            )),
            ProviderFault::Unsatisfiable => Err(ProviderError::Unsatisfiable(
                "injected: provider out of capacity".into(),
            )),
            ProviderFault::Reclaim => {
                let grant = self.inner.request(spec)?;
                // release before erroring: the reclaim leaves no orphans
                self.inner.release(&grant.instance_ids)?;
                Err(ProviderError::Api(format!(
                    "injected: spot capacity reclaimed mid-grant ({} instances returned)",
                    grant.instance_ids.len()
                )))
            }
        }
    }

    fn release(&mut self, instance_ids: &[String]) -> Result<(), ProviderError> {
        // releases pass through un-faulted: failing them would leak
        // bookkeeping in the *caller*, which is not the failure mode this
        // harness models (request-path faults are)
        self.inner.release(instance_ids)
    }
}

// ---------------------------------------------------------------------------
// Backoff + retry policies
// ---------------------------------------------------------------------------

/// Exponential backoff with bounded deterministic jitter:
/// `delay(n) = min(base · factor^n, max) · (1 ± jitter)`, the jitter drawn
/// from the caller's seeded [`Rng`] so retry timing reproduces run to run.
#[derive(Debug, Clone, Copy)]
pub struct Backoff {
    /// First retry delay (attempt 0).
    pub base: Duration,
    /// Multiplier per attempt.
    pub factor: f64,
    /// Cap on the exponential term.
    pub max: Duration,
    /// Relative jitter half-width in `[0, 1]` (0.2 ⇒ ±20%).
    pub jitter: f64,
}

impl Backoff {
    /// Delay before retry number `attempt` (0-based), jittered via `rng`.
    pub fn delay(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let exp = self.base.as_secs_f64() * self.factor.powi(attempt.min(63) as i32);
        let capped = exp.min(self.max.as_secs_f64());
        let j = 1.0 + self.jitter * (2.0 * rng.f64() - 1.0);
        Duration::from_secs_f64((capped * j).max(0.0))
    }
}

impl Default for Backoff {
    fn default() -> Backoff {
        Backoff {
            base: Duration::from_millis(10),
            factor: 2.0,
            max: Duration::from_secs(1),
            jitter: 0.2,
        }
    }
}

/// Bounded-retry policy for RPC calls and provider requests.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts including the first (1 = no retries).
    pub max_attempts: u32,
    /// Backoff shape between attempts.
    pub backoff: Backoff,
    /// Retry *mutating* ops too. Default `false`: a timed-out mutation may
    /// have committed on the peer (at-most-once), so only turn this on for
    /// idempotent custom protocols.
    pub retry_mutating: bool,
    /// Seed for the jitter stream (deterministic retry timing).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 3,
            backoff: Backoff::default(),
            retry_mutating: false,
            seed: 0xB0FF,
        }
    }
}

impl RetryPolicy {
    /// A policy that never retries (single attempt, no backoff).
    pub fn no_retry() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 1,
            ..RetryPolicy::default()
        }
    }
}

/// A [`Conn`] wrapper applying a [`RetryPolicy`]: transport failures on
/// **read-only** requests are retried with backoff; mutating requests get
/// exactly one attempt (unless `retry_mutating`) and surface their error to
/// the caller — see the module doc on at-most-once semantics.
pub struct RetryConn {
    inner: Box<dyn Conn>,
    policy: RetryPolicy,
    rng: Rng,
}

impl RetryConn {
    /// Wrap a connection with a retry policy.
    pub fn new(inner: Box<dyn Conn>, policy: RetryPolicy) -> RetryConn {
        let rng = Rng::new(policy.seed);
        RetryConn { inner, policy, rng }
    }
}

impl Conn for RetryConn {
    fn call(&mut self, req: &Request) -> std::io::Result<Response> {
        let retryable = req.op.is_read_only() || self.policy.retry_mutating;
        let attempts = if retryable {
            self.policy.max_attempts.max(1)
        } else {
            1
        };
        let mut last_err = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                std::thread::sleep(self.policy.backoff.delay(attempt - 1, &mut self.rng));
            }
            match self.inner.call(req) {
                Ok(resp) => return Ok(resp),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.expect("at least one attempt"))
    }
}

/// An [`ExternalProvider`] wrapper applying a [`RetryPolicy`] to requests:
/// [`ProviderError::Api`] failures (transient by contract) are retried with
/// backoff; [`ProviderError::Unsatisfiable`] — a well-formed "no" — is
/// returned immediately.
///
/// Retrying after an `Api` failure is safe only because providers fail
/// **atomically**: anything created before the error must be released
/// before it surfaces ([`crate::external::ec2::Ec2Provider`] creates
/// nothing before its failure points; [`FaultyProvider`]'s reclaim releases
/// before erroring). A provider that can orphan instances on `Api` must
/// not be wrapped in this.
pub struct RetryingProvider<P: ExternalProvider> {
    inner: P,
    policy: RetryPolicy,
    rng: Rng,
}

impl<P: ExternalProvider> RetryingProvider<P> {
    /// Wrap a provider with a retry policy.
    pub fn new(inner: P, policy: RetryPolicy) -> RetryingProvider<P> {
        let rng = Rng::new(policy.seed);
        RetryingProvider { inner, policy, rng }
    }

    /// The wrapped provider (for test assertions on its internal state).
    pub fn inner(&self) -> &P {
        &self.inner
    }
}

impl<P: ExternalProvider> ExternalProvider for RetryingProvider<P> {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn request(&mut self, spec: &JobSpec) -> Result<ExternalGrant, ProviderError> {
        let attempts = self.policy.max_attempts.max(1);
        let mut last_err = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                std::thread::sleep(self.policy.backoff.delay(attempt - 1, &mut self.rng));
            }
            match self.inner.request(spec) {
                Ok(grant) => return Ok(grant),
                Err(e @ ProviderError::Unsatisfiable(_)) => return Err(e),
                Err(e @ ProviderError::Api(_)) => last_err = Some(e),
            }
        }
        Err(last_err.expect("at least one attempt"))
    }

    fn release(&mut self, instance_ids: &[String]) -> Result<(), ProviderError> {
        self.inner.release(instance_ids)
    }
}

// ---------------------------------------------------------------------------
// Panic containment
// ---------------------------------------------------------------------------

/// Best-effort extraction of a panic payload's message (`panic!` with a
/// string literal or a formatted `String`; anything else reports opaquely).
/// Shared by every containment site that turns a caught unwind into a typed
/// [`crate::rpc::proto::code::PANIC`] error.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

// ---------------------------------------------------------------------------
// Sharded-commit fault injection
// ---------------------------------------------------------------------------

/// Scripted fault plan for the subtree-sharded write-commit path (PR 8):
/// one entry is consumed per sharded commit, and a `Some(shard)` entry
/// makes the commit panic the moment it starts processing that shard
/// bucket — mid-commit, after earlier shards' marks have landed — which is
/// exactly the torn state the service's panic containment must roll back
/// without poisoning sibling shards. Deterministic by construction (a
/// plain FIFO, no randomness), so chaos tests can target "panic while
/// committing shard 2 of op 7" exactly.
#[derive(Debug, Clone, Default)]
pub struct CommitFaultPlan {
    script: VecDeque<Option<usize>>,
}

impl CommitFaultPlan {
    /// A plan that injects the scripted faults in order, then nothing:
    /// entry `i` applies to the `i`-th sharded commit; `Some(s)` panics
    /// when shard bucket `s` starts processing, `None` lets the commit
    /// through untouched.
    pub fn script(faults: &[Option<usize>]) -> CommitFaultPlan {
        CommitFaultPlan {
            script: faults.iter().copied().collect(),
        }
    }

    /// Consume the next commit's fault decision (`None` once the script is
    /// drained — the plan then never fires again).
    pub fn next_commit(&mut self) -> Option<usize> {
        self.script.pop_front().flatten()
    }

    /// Whether the script still holds undelivered entries.
    pub fn is_exhausted(&self) -> bool {
        self.script.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Scripted level crashes
// ---------------------------------------------------------------------------

/// Where in an op's lifecycle a scripted crash fires (PR 10). Each point
/// pins one distinct recovery obligation:
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrashPoint {
    /// Before the op's journal append: the crash leaves **no trace** — on
    /// restart the op simply never happened. In the hierarchy this is the
    /// child dying after its parent granted but before the child journaled
    /// the splice, i.e. an **orphaned parent-side grant**.
    PreJournal,
    /// After the journal append but before the mutation commits: restart
    /// finds an op frame with no commit frame and must **discard the
    /// uncommitted suffix**. In the hierarchy this is the parent dying
    /// after serving a grant without journaling it — the child holds a
    /// **ghost job** the restarted parent has no record of.
    PostJournal,
    /// Mid-reconcile: the handshake reply was computed but the crash hits
    /// before the initiator acts on it. The retried reconcile must be
    /// idempotent and still converge.
    MidReconcile,
}

/// Scripted, deterministic level-kill plan: a FIFO of [`CrashPoint`]s.
/// Code at each crash site asks [`CrashPlan::fires`] whether the front of
/// the script names *its* point; only then is the entry consumed and the
/// crash simulated (the op aborts with [`crate::rpc::proto::code::CRASHED`]
/// and the harness kills + restarts the level). A plain FIFO with no
/// randomness — like [`CommitFaultPlan`] — so tests can say "crash exactly
/// at the journal append of the 1st mutating op" and replay it from a
/// `RECOVERY_SEED`.
#[derive(Debug, Clone, Default)]
pub struct CrashPlan {
    script: VecDeque<CrashPoint>,
}

impl CrashPlan {
    /// A plan that fires the scripted points in order, then never again.
    pub fn script(points: &[CrashPoint]) -> CrashPlan {
        CrashPlan {
            script: points.iter().copied().collect(),
        }
    }

    /// A single scripted crash.
    pub fn once(point: CrashPoint) -> CrashPlan {
        CrashPlan::script(&[point])
    }

    /// Does the crash fire *here*? Consumes the front entry only when it
    /// matches `point`; a non-matching front stays queued for its own
    /// site (sites poll in lifecycle order, so the front decides which
    /// site dies first).
    pub fn fires(&mut self, point: CrashPoint) -> bool {
        if self.script.front() == Some(&point) {
            self.script.pop_front();
            true
        } else {
            false
        }
    }

    /// Whether every scripted crash has fired.
    pub fn is_exhausted(&self) -> bool {
        self.script.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Quarantine circuit breaker
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
enum BreakerState {
    Closed,
    Open { until: Instant },
    HalfOpen,
}

/// The quarantine state machine `hier` attaches to each parent link:
///
/// ```text
///            failure (count >= threshold)
///   Closed ─────────────────────────────▶ Open {until: now + cooldown}
///     ▲                                      │
///     │ success                              │ cooldown elapses
///     │                                      ▼ (admit() grants ONE trial)
///     └────────────────────────────────── HalfOpen
///                 ▲        │
///                 └────────┘ trial failure reopens immediately
/// ```
///
/// `Closed` admits everything; `Open` refuses ([`CircuitBreaker::admit`]
/// returns `false`) until the cooldown elapses, at which point the breaker
/// turns `HalfOpen` and admits a trial; a trial success closes it (a
/// *restore*), a trial failure reopens it for another cooldown without
/// waiting for the threshold.
#[derive(Debug)]
pub struct CircuitBreaker {
    threshold: u32,
    cooldown: Duration,
    failures: u32,
    state: BreakerState,
    trips: u64,
    restores: u64,
}

impl CircuitBreaker {
    /// Open after `threshold` consecutive failures; re-probe after
    /// `cooldown`. `threshold` is clamped to ≥ 1.
    pub fn new(threshold: u32, cooldown: Duration) -> CircuitBreaker {
        CircuitBreaker {
            threshold: threshold.max(1),
            cooldown,
            failures: 0,
            state: BreakerState::Closed,
            trips: 0,
            restores: 0,
        }
    }

    /// May a call go out now? `Open` with an unexpired cooldown refuses;
    /// an expired cooldown flips to `HalfOpen` and admits the trial.
    pub fn admit(&mut self) -> bool {
        match self.state {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open { until } => {
                if Instant::now() >= until {
                    self.state = BreakerState::HalfOpen;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record a healthy round trip (any well-formed reply, including a
    /// structured error — the *link* worked). Closes the breaker; counts a
    /// restore when it was recovering.
    pub fn record_success(&mut self) {
        if matches!(self.state, BreakerState::HalfOpen) {
            self.restores += 1;
        }
        self.failures = 0;
        self.state = BreakerState::Closed;
    }

    /// Record a transport-level failure (timeout, disconnect). Trips to
    /// `Open` at the threshold, or immediately when a half-open trial
    /// fails.
    pub fn record_failure(&mut self) {
        self.failures += 1;
        let reopen =
            matches!(self.state, BreakerState::HalfOpen) || self.failures >= self.threshold;
        if reopen {
            self.state = BreakerState::Open {
                until: Instant::now() + self.cooldown,
            };
            self.trips += 1;
        }
    }

    /// Is the breaker currently refusing traffic (open, cooldown pending)?
    pub fn is_open(&self) -> bool {
        matches!(self.state, BreakerState::Open { until } if Instant::now() < until)
    }

    /// Current state as a stable string: `"closed"`, `"open"`, or
    /// `"half-open"` (an expired-cooldown `Open` reports `"half-open"` —
    /// the next [`CircuitBreaker::admit`] would grant a trial).
    pub fn state_name(&self) -> &'static str {
        match self.state {
            BreakerState::Closed => "closed",
            BreakerState::HalfOpen => "half-open",
            BreakerState::Open { until } => {
                if Instant::now() >= until {
                    "half-open"
                } else {
                    "open"
                }
            }
        }
    }

    /// Time until the cooldown expires (`None` unless open-and-pending).
    pub fn retry_in(&self) -> Option<Duration> {
        match self.state {
            BreakerState::Open { until } => {
                let now = Instant::now();
                (now < until).then(|| until - now)
            }
            _ => None,
        }
    }

    /// How many times the breaker has tripped open.
    pub fn trips(&self) -> u64 {
        self.trips
    }

    /// How many times a half-open trial restored the link.
    pub fn restores(&self) -> u64 {
        self.restores
    }

    /// Forget all history: back to `Closed` with zero failures, trips, and
    /// restores. Used by `Hierarchy::reset` (stale breaker state must not
    /// leak across test runs) and after a level restart (the rebuilt level
    /// starts with a clean link).
    pub fn reset(&mut self) {
        self.failures = 0;
        self.state = BreakerState::Closed;
        self.trips = 0;
        self.restores = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resource::graph::JobId;
    use crate::rpc::proto::{SchedOp, SchedReply};
    use crate::rpc::transport::{handler, InProcServer};
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn probe_req(id: u64) -> Request {
        Request::new(
            id,
            SchedOp::Probe {
                spec: JobSpec::nodes_sockets_cores(1, 1, 1),
            },
        )
    }

    fn mutate_req(id: u64) -> Request {
        Request::new(id, SchedOp::FreeJob { job: JobId(1) })
    }

    fn counting_server() -> (InProcServer, Arc<AtomicUsize>) {
        let calls = Arc::new(AtomicUsize::new(0));
        let c2 = calls.clone();
        let server = InProcServer::spawn(handler(move |req: Request| {
            c2.fetch_add(1, Ordering::SeqCst);
            Response::ok(req.id, SchedReply::Freed { vertices: 1 })
        }));
        (server, calls)
    }

    #[test]
    fn injector_is_deterministic_per_seed() {
        let rates = FaultRates {
            drop: 0.2,
            delay: 0.2,
            delay_for: Duration::from_millis(1),
            truncate: 0.1,
            corrupt: 0.1,
            ..FaultRates::none()
        };
        let a = FaultInjector::new(7, rates);
        let b = FaultInjector::new(7, rates);
        let seq_a: Vec<FrameFault> = (0..64).map(|_| a.frame_fault()).collect();
        let seq_b: Vec<FrameFault> = (0..64).map(|_| b.frame_fault()).collect();
        assert_eq!(seq_a, seq_b);
        assert_eq!(a.stats(), b.stats());
        // with these rates, 64 draws virtually surely include faults
        let s = a.stats();
        assert!(s.dropped + s.delayed + s.truncated + s.corrupted > 0);
    }

    #[test]
    fn script_wins_over_rates() {
        let inj = FaultInjector::new(1, FaultRates::none());
        inj.push_frame_fault(FrameFault::Corrupt);
        inj.push_frame_fault(FrameFault::Drop);
        assert_eq!(inj.frame_fault(), FrameFault::Corrupt);
        assert_eq!(inj.frame_fault(), FrameFault::Drop);
        assert_eq!(inj.frame_fault(), FrameFault::Deliver);
    }

    #[test]
    fn faulty_conn_maps_faults_to_io_errors() {
        let (server, _) = counting_server();
        let inj = FaultInjector::new(1, FaultRates::none());
        inj.push_frame_fault(FrameFault::Drop);
        inj.push_frame_fault(FrameFault::Truncate);
        inj.push_frame_fault(FrameFault::Corrupt);
        let mut conn = FaultyConn::new(Box::new(server.connect()), inj);
        use std::io::ErrorKind;
        assert_eq!(conn.call(&probe_req(1)).unwrap_err().kind(), ErrorKind::TimedOut);
        assert_eq!(
            conn.call(&probe_req(2)).unwrap_err().kind(),
            ErrorKind::UnexpectedEof
        );
        assert_eq!(
            conn.call(&probe_req(3)).unwrap_err().kind(),
            ErrorKind::InvalidData
        );
        // script exhausted: delivers
        assert!(conn.call(&probe_req(4)).is_ok());
        server.shutdown();
    }

    #[test]
    fn retry_conn_retries_read_only_until_success() {
        let (server, calls) = counting_server();
        let inj = FaultInjector::new(1, FaultRates::none());
        inj.push_frame_fault(FrameFault::Drop);
        inj.push_frame_fault(FrameFault::Drop);
        // third attempt delivers
        let faulty = FaultyConn::new(Box::new(server.connect()), inj);
        let mut conn = RetryConn::new(
            Box::new(faulty),
            RetryPolicy {
                max_attempts: 3,
                backoff: Backoff {
                    base: Duration::from_millis(1),
                    ..Backoff::default()
                },
                ..RetryPolicy::default()
            },
        );
        let resp = conn.call(&probe_req(1)).expect("third attempt succeeds");
        assert_eq!(resp.id, 1);
        assert_eq!(calls.load(Ordering::SeqCst), 1, "inner handler ran once");
        server.shutdown();
    }

    #[test]
    fn retry_conn_is_bounded() {
        let (server, calls) = counting_server();
        let inj = FaultInjector::new(1, FaultRates::none());
        for _ in 0..10 {
            inj.push_frame_fault(FrameFault::Drop);
        }
        let faulty = FaultyConn::new(Box::new(server.connect()), inj);
        let mut conn = RetryConn::new(
            Box::new(faulty),
            RetryPolicy {
                max_attempts: 3,
                backoff: Backoff {
                    base: Duration::from_millis(1),
                    ..Backoff::default()
                },
                ..RetryPolicy::default()
            },
        );
        assert!(conn.call(&probe_req(1)).is_err());
        assert_eq!(calls.load(Ordering::SeqCst), 0, "all attempts dropped");
        server.shutdown();
    }

    #[test]
    fn retry_conn_never_retries_mutating_ops() {
        let (server, calls) = counting_server();
        let inj = FaultInjector::new(1, FaultRates::none());
        inj.push_frame_fault(FrameFault::Drop);
        let faulty = FaultyConn::new(Box::new(server.connect()), inj);
        let mut conn = RetryConn::new(Box::new(faulty), RetryPolicy::default());
        // a mutating op's transport failure surfaces after ONE attempt even
        // though the policy would allow 3
        assert!(conn.call(&mutate_req(1)).is_err());
        assert_eq!(calls.load(Ordering::SeqCst), 0);
        // the next (fault-free) mutating call works
        assert!(conn.call(&mutate_req(2)).is_ok());
        assert_eq!(calls.load(Ordering::SeqCst), 1);
        server.shutdown();
    }

    #[test]
    fn backoff_grows_exponentially_within_jitter() {
        let b = Backoff {
            base: Duration::from_millis(10),
            factor: 2.0,
            max: Duration::from_secs(1),
            jitter: 0.2,
        };
        let mut rng = Rng::new(5);
        for attempt in 0..6u32 {
            let nominal = 0.010 * 2f64.powi(attempt as i32);
            let d = b.delay(attempt, &mut rng).as_secs_f64();
            assert!(
                d >= nominal * 0.8 - 1e-9 && d <= nominal * 1.2 + 1e-9,
                "attempt {attempt}: {d} vs nominal {nominal}"
            );
        }
        // and the cap binds eventually
        let mut rng = Rng::new(5);
        assert!(b.delay(30, &mut rng).as_secs_f64() <= 1.2);
    }

    #[test]
    fn backoff_is_deterministic_per_seed() {
        let b = Backoff::default();
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        for attempt in 0..8u32 {
            assert_eq!(b.delay(attempt, &mut r1), b.delay(attempt, &mut r2));
        }
    }

    #[test]
    fn breaker_trips_half_opens_and_restores() {
        let mut b = CircuitBreaker::new(2, Duration::from_millis(20));
        assert_eq!(b.state_name(), "closed");
        assert!(b.admit());
        b.record_failure();
        assert_eq!(b.state_name(), "closed", "below threshold");
        b.record_failure();
        assert_eq!(b.state_name(), "open");
        assert!(!b.admit(), "open refuses");
        assert!(b.retry_in().is_some());
        assert_eq!(b.trips(), 1);
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(b.state_name(), "half-open");
        assert!(b.admit(), "cooldown elapsed: trial admitted");
        b.record_success();
        assert_eq!(b.state_name(), "closed");
        assert_eq!(b.restores(), 1);
    }

    #[test]
    fn breaker_half_open_failure_reopens_immediately() {
        let mut b = CircuitBreaker::new(3, Duration::from_millis(10));
        for _ in 0..3 {
            b.record_failure();
        }
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.admit());
        b.record_failure(); // trial fails: straight back to open
        assert_eq!(b.state_name(), "open");
        assert_eq!(b.trips(), 2);
    }

    #[test]
    fn breaker_reset_forgets_all_history() {
        let mut b = CircuitBreaker::new(1, Duration::from_secs(60));
        b.record_failure();
        assert_eq!(b.state_name(), "open");
        assert_eq!(b.trips(), 1);
        b.reset();
        assert_eq!(b.state_name(), "closed");
        assert!(b.admit());
        assert_eq!(b.trips(), 0);
        assert_eq!(b.restores(), 0);
        // and the failure count really is zeroed: one failure trips a
        // threshold-1 breaker again, not an inherited count
        b.record_failure();
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn injector_reset_stats_keeps_rng_position() {
        let rates = FaultRates {
            drop: 0.3,
            corrupt: 0.3,
            ..FaultRates::none()
        };
        let a = FaultInjector::new(9, rates);
        let b = FaultInjector::new(9, rates);
        for _ in 0..16 {
            a.frame_fault();
            b.frame_fault();
        }
        a.reset_stats();
        assert_eq!(a.stats(), FaultStats::default());
        // behaviour is untouched: both injectors keep making the same
        // decisions after one of them reset its counters
        for _ in 0..16 {
            assert_eq!(a.frame_fault(), b.frame_fault());
        }
    }

    #[test]
    fn crash_plan_fires_only_at_the_front_point() {
        let mut p = CrashPlan::script(&[CrashPoint::PostJournal, CrashPoint::PreJournal]);
        // front is PostJournal: the PreJournal site must NOT consume it
        assert!(!p.fires(CrashPoint::PreJournal));
        assert!(!p.fires(CrashPoint::MidReconcile));
        assert!(p.fires(CrashPoint::PostJournal));
        // now PreJournal is the front
        assert!(!p.fires(CrashPoint::PostJournal));
        assert!(p.fires(CrashPoint::PreJournal));
        assert!(p.is_exhausted());
        assert!(!p.fires(CrashPoint::PreJournal), "exhausted plans never fire");
        assert!(CrashPlan::default().is_exhausted());
        assert!(!CrashPlan::once(CrashPoint::MidReconcile).is_exhausted());
    }
}
