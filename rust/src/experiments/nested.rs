//! E2/E3/E4 — §5.2 nested MatchGrow on the five-level hierarchy:
//! inter-level communication times (Fig 1a), subgraph add+update times
//! (Fig 1b), and per-level null-match times (§5.2.3), for the Table 1
//! request sizes.
//!
//! Protocol (paper §5.2): L0 holds the 128-node cluster graph; L1..L4 boot
//! with 8/4/2/1 nodes and are fully allocated; a helper issues an MG at the
//! leaf; the request escalates to L0, and the granted subgraph descends
//! with each level adding + updating. Each test is repeated (100× in the
//! paper) with graph reinitialization between runs. L1↔L0 crosses the
//! simulated internode link; deeper pairs are intranode.

use std::collections::BTreeMap;

use crate::experiments::ExpConfig;
use crate::hier::{paper_levels, Hierarchy};
use crate::jobspec::{table1_jobspec, TABLE1_TESTS};
use crate::resource::builder::{table2_graph, UidGen};
use crate::util::metrics::Recorder;
use crate::util::stats::Summary;

/// All samples from a nested run, organized for both the boxplot figures
/// and the §6 regressions.
#[derive(Debug, Clone)]
pub struct NestedResult {
    /// Series: `comms/L{level}/{test}`, `add_upd/L{level}/{test}`,
    /// `match/L{level}/{test}`; values in seconds.
    pub recorder: Recorder,
    /// Subgraph size per test name.
    pub sizes: BTreeMap<String, usize>,
    /// Which tests ran.
    pub tests: Vec<String>,
}

impl NestedResult {
    /// (x = subgraph size, y = seconds) points for the comms regressions,
    /// split internode (L1) / intranode (L2+).
    pub fn comms_points(&self) -> (Vec<(f64, f64)>, Vec<(f64, f64)>) {
        let mut inter = Vec::new();
        let mut intra = Vec::new();
        for test in &self.tests {
            let n = self.sizes[test] as f64;
            for level in 1..=4usize {
                if let Some(xs) = self.recorder.get(&format!("comms/L{level}/{test}")) {
                    let bucket = if level == 1 { &mut inter } else { &mut intra };
                    bucket.extend(xs.iter().map(|&y| (n, y)));
                }
            }
        }
        (inter, intra)
    }

    /// (x, y) points for the add+update regression (all levels pooled, as
    /// Fig 1b shows level-independence).
    pub fn add_upd_points(&self) -> Vec<(f64, f64)> {
        let mut pts = Vec::new();
        for test in &self.tests {
            let n = self.sizes[test] as f64;
            for level in 1..=4usize {
                if let Some(xs) = self.recorder.get(&format!("add_upd/L{level}/{test}")) {
                    pts.extend(xs.iter().map(|&y| (n, y)));
                }
            }
        }
        pts
    }

    /// Median-aggregated comms points (one per test × level), robust to
    /// scheduling noise — what tests assert on; the full-sample variant
    /// feeds the real regression.
    pub fn comms_medians(&self) -> (Vec<(f64, f64)>, Vec<(f64, f64)>) {
        let mut inter = Vec::new();
        let mut intra = Vec::new();
        for test in &self.tests {
            let n = self.sizes[test] as f64;
            for level in 1..=4usize {
                if let Some(s) = self.recorder.summary(&format!("comms/L{level}/{test}")) {
                    if level == 1 {
                        inter.push((n, s.median));
                    } else {
                        intra.push((n, s.median));
                    }
                }
            }
        }
        (inter, intra)
    }

    /// Match-time summary per level for one test (§5.2.3 analysis).
    pub fn match_summary(&self, level: usize, test: &str) -> Option<Summary> {
        self.recorder.summary(&format!("match/L{level}/{test}"))
    }

    /// Fig 1a/1b-style table for one test.
    pub fn figure1_table(&self, test: &str) -> String {
        let mut out = format!(
            "E2/E3 (Fig 1a/1b) — test {test}, subgraph size {}\n{:<10} {:>12} {:>12} {:>12} | {:>12} {:>12} {:>12}\n",
            self.sizes.get(test).copied().unwrap_or(0),
            "level",
            "comms med",
            "comms q1",
            "comms q3",
            "addupd med",
            "addupd q1",
            "addupd q3",
        );
        for level in 1..=4usize {
            let c = self.recorder.summary(&format!("comms/L{level}/{test}"));
            let a = self.recorder.summary(&format!("add_upd/L{level}/{test}"));
            if let (Some(c), Some(a)) = (c, a) {
                out.push_str(&format!(
                    "L{level:<9} {:>12.6} {:>12.6} {:>12.6} | {:>12.6} {:>12.6} {:>12.6}\n",
                    c.median, c.q1, c.q3, a.median, a.q1, a.q3
                ));
            }
        }
        out
    }
}

/// Run the nested experiment over the given Table 1 test names
/// (default: T2..T8 — T1's 64 nodes exceed what L0 can grant repeatedly).
pub fn run(cfg: &ExpConfig, tests: &[&str]) -> NestedResult {
    let root = table2_graph(0, &mut UidGen::new());
    let h = Hierarchy::build(root, &paper_levels(cfg.internode)).expect("hierarchy");
    let mut rec = Recorder::new();
    let mut sizes = BTreeMap::new();

    // iterations are interleaved across tests (round-robin) so slowly
    // varying machine load cannot masquerade as a size effect in the
    // regressions
    for _ in 0..cfg.iters {
        for &test in tests {
            let spec = table1_jobspec(test);
            let report = h.grow_from_leaf(&spec).expect("grow succeeds after reset");
            sizes.insert(test.to_string(), report.subgraph_size);
            for lt in &report.levels {
                rec.record(&format!("match/L{}/{}", lt.level, test), lt.match_s);
                if lt.level > 0 {
                    rec.record(&format!("comms/L{}/{}", lt.level, test), lt.comms_s);
                    rec.record(&format!("add_upd/L{}/{}", lt.level, test), lt.add_upd_s);
                }
            }
            h.reset();
        }
    }
    h.shutdown();
    NestedResult {
        recorder: rec,
        sizes,
        tests: tests.iter().map(|s| s.to_string()).collect(),
    }
}

/// The default test set (paper runs T1–T8; T1 needs 64 of L0's 120 free
/// nodes, fine for a single grow per reset).
pub fn default_tests() -> Vec<&'static str> {
    TABLE1_TESTS.iter().map(|(name, ..)| *name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested_run_produces_paper_shapes() {
        let _t = crate::experiments::timing_lock();
        let cfg = ExpConfig::smoke();
        let r = run(&cfg, &["T6", "T7"]);
        // subgraph sizes match Table 1
        assert_eq!(r.sizes["T7"], 70);
        assert_eq!(r.sizes["T6"], 140);
        // every level reported comms + add/upd for every iteration
        for level in 1..=4 {
            for test in ["T6", "T7"] {
                let s = r
                    .recorder
                    .summary(&format!("comms/L{level}/{test}"))
                    .unwrap();
                assert_eq!(s.n, cfg.iters);
            }
        }
        // Fig 1a shape: L1 (internode) slower than L2-4 (intranode)
        let l1 = r.recorder.summary("comms/L1/T7").unwrap().median;
        let l3 = r.recorder.summary("comms/L3/T7").unwrap().median;
        assert!(l1 > l3, "internode {l1} should exceed intranode {l3}");
        // regression point extraction works
        let (inter, intra) = r.comms_points();
        assert_eq!(inter.len(), 2 * cfg.iters);
        assert_eq!(intra.len(), 3 * 2 * cfg.iters);
        assert!(!r.add_upd_points().is_empty());
        assert!(r.figure1_table("T7").contains("L1"));
    }

    #[test]
    fn match_times_recorded_at_all_levels() {
        let _t = crate::experiments::timing_lock();
        let r = run(&ExpConfig::smoke(), &["T7"]);
        for level in 0..=4 {
            assert!(
                r.match_summary(level, "T7").is_some(),
                "missing match series at L{level}"
            );
        }
        // §5.2.3: null match at L1 (8-node graph) visits more vertices than
        // at L4 (1-node graph) — reflected in time ordering on average
        let l0 = r.match_summary(0, "T7").unwrap();
        assert!(l0.mean > 0.0);
    }
}
