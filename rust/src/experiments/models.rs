//! E8/E9/E10 — §6: fit the component models from nested-run data (Table 4,
//! Figs 3/4), apply the composite Eq. 6 model to a held-out complex request
//! (Table 5), and validate the §6.3 match-time bound.

use crate::experiments::nested::NestedResult;
use crate::experiments::ExpConfig;
use crate::hier::{paper_levels, Hierarchy, LevelSpec, LinkKind};
use crate::jobspec::{JobSpec, ResourceReq};
use crate::perfmodel::{bound_factor, match_time_bound, ComponentModel, FitBackend, MgModel};
use crate::resource::builder::{ClusterSpec, UidGen};
use crate::util::stats;

fn unzip(pts: &[(f64, f64)]) -> (Vec<f64>, Vec<f64>) {
    (
        pts.iter().map(|p| p.0).collect(),
        pts.iter().map(|p| p.1).collect(),
    )
}

/// E8: fit the three component models from a nested run (all raw samples,
/// the paper's §6.1/§6.2 procedure).
pub fn fit_models(nested: &NestedResult, backend: &FitBackend) -> MgModel {
    let (inter, intra) = nested.comms_points();
    let attach = nested.add_upd_points();
    let (xi, yi) = unzip(&inter);
    let (xa, ya) = unzip(&intra);
    let (xu, yu) = unzip(&attach);
    MgModel {
        comms_inter: ComponentModel::fit("L0 comm", backend, &xi, &yi, false),
        comms_intra: ComponentModel::fit("L1-4 comm", backend, &xa, &ya, false),
        add_upd: ComponentModel::fit("attach", backend, &xu, &yu, true),
    }
}

/// E8 (robust variant): fit on per-(test, level) medians. Our shared-CI
/// testbed has heavy-tailed scheduling noise the authors' dedicated
/// cluster didn't; medians recover the paper's near-1 R² (see
/// EXPERIMENTS.md §E8).
pub fn fit_models_median(nested: &NestedResult, backend: &FitBackend) -> MgModel {
    let (inter, intra) = nested.comms_medians();
    // median add-upd points pooled across levels
    let mut attach = Vec::new();
    for test in &nested.tests {
        let n = nested.sizes[test] as f64;
        for level in 1..=4usize {
            if let Some(s) = nested
                .recorder
                .summary(&format!("add_upd/L{level}/{test}"))
            {
                attach.push((n, s.median));
            }
        }
    }
    let (xi, yi) = unzip(&inter);
    let (xa, ya) = unzip(&intra);
    let (xu, yu) = unzip(&attach);
    MgModel {
        comms_inter: ComponentModel::fit("L0 comm", backend, &xi, &yi, false),
        comms_intra: ComponentModel::fit("L1-4 comm", backend, &xa, &ya, false),
        add_upd: ComponentModel::fit("attach", backend, &xu, &yu, true),
    }
}

/// Fig 3 / Fig 4 series: per-test median observed vs model prediction.
pub fn figure34_table(nested: &NestedResult, model: &MgModel) -> String {
    let mut out = String::from(
        "E8 (Figs 3/4) — observed medians vs fitted models by subgraph size\n",
    );
    out.push_str(&format!(
        "{:<6} {:>8} {:>13} {:>13} {:>13} {:>13} {:>13} {:>13}\n",
        "test", "size", "inter obs", "inter fit", "intra obs", "intra fit", "attach obs", "attach fit"
    ));
    for test in &nested.tests {
        let n = nested.sizes[test] as f64;
        let inter_obs = nested
            .recorder
            .summary(&format!("comms/L1/{test}"))
            .map(|s| s.median)
            .unwrap_or(f64::NAN);
        let intra_obs = nested
            .recorder
            .summary(&format!("comms/L3/{test}"))
            .map(|s| s.median)
            .unwrap_or(f64::NAN);
        let attach_obs = nested
            .recorder
            .summary(&format!("add_upd/L2/{test}"))
            .map(|s| s.median)
            .unwrap_or(f64::NAN);
        out.push_str(&format!(
            "{:<6} {:>8} {:>13.6} {:>13.6} {:>13.6} {:>13.6} {:>13.6} {:>13.6}\n",
            test,
            n as usize,
            inter_obs,
            model.comms_inter.predict(n),
            intra_obs,
            model.comms_intra.predict(n),
            attach_obs,
            model.add_upd.predict(n),
        ));
    }
    out
}

/// E9 result: component MAPEs on the held-out complex request (Table 5).
#[derive(Debug, Clone)]
pub struct ApplyResult {
    /// Size of the held-out request's granted subgraph.
    pub subgraph_size: usize,
    /// Match-component mean absolute percentage error.
    pub match_mape: f64,
    /// Comms-component mean absolute percentage error.
    pub comms_mape: f64,
    /// Add/update-component mean absolute percentage error.
    pub add_upd_mape: f64,
    /// Component-sum share of total measured time (paper: ≥98.2%).
    pub component_share: f64,
    /// Eq. 6 predicted total seconds.
    pub predicted_total_s: f64,
    /// Measured total seconds.
    pub observed_total_s: f64,
}

impl ApplyResult {
    /// Render the Table 5 component-MAPE table.
    pub fn table(&self) -> String {
        format!(
            "E9 (Table 5) — Eq. 6 applied to the held-out GPU+memory request (size {})\n\
             {:<22} {:>12}\n{:<22} {:>12.6}\n{:<22} {:>12.6}\n{:<22} {:>12.6}\n\
             component share of total: {:.1}% (paper: >=98.2%)\n\
             predicted {:.6}s vs observed {:.6}s\n",
            self.subgraph_size,
            "component",
            "MAPE",
            "t_match (bound)",
            self.match_mape,
            "t_comms",
            self.comms_mape,
            "t_add_upd",
            self.add_upd_mape,
            100.0 * self.component_share,
            self.predicted_total_s,
            self.observed_total_s,
        )
    }
}

/// The held-out §6.4 request: one node with 4 GPUs, two sockets of 16
/// CPUs, and 4 GiB memory (paper subgraph size 94; ours 86 — counting
/// differences documented in EXPERIMENTS.md).
pub fn complex_jobspec() -> JobSpec {
    JobSpec::new(vec![ResourceReq::new("node", 1)
        .with_child(
            ResourceReq::new("socket", 2)
                .with_child(ResourceReq::new("core", 16))
                .with_child(ResourceReq::new("gpu", 2)),
        )
        .with_child(ResourceReq::new("memory", 4))])
}

/// E9: run the complex request through a GPU+memory hierarchy and compare
/// observed component times against the fitted models.
pub fn apply_model(cfg: &ExpConfig, model: &MgModel) -> ApplyResult {
    // a Table-2-shaped cluster with per-socket GPUs and per-node memory
    let root = ClusterSpec::new("cluster", 128, 2, 16)
        .with_gpus(2)
        .with_memory(4)
        .build(&mut UidGen::new());
    let h = Hierarchy::build(root, &paper_levels(cfg.internode)).expect("hierarchy");
    let spec = complex_jobspec();

    let mut obs_match = Vec::new();
    let mut obs_comms = Vec::new(); // (level, seconds)
    let mut obs_add = Vec::new();
    let mut totals = Vec::new();
    let mut comp_sums = Vec::new();
    let mut size = 0usize;
    let mut t0s = Vec::new();
    for _ in 0..cfg.iters {
        let report = h.grow_from_leaf(&spec).expect("complex grow");
        size = report.subgraph_size;
        for lt in &report.levels {
            if lt.level == 0 {
                t0s.push(lt.match_s);
            }
            obs_match.push(lt.match_s);
            if lt.level > 0 {
                obs_comms.push((lt.level, lt.comms_s));
                obs_add.push(lt.add_upd_s);
            }
        }
        totals.push(report.total_s);
        comp_sums.push(report.component_sum());
        h.reset();
    }
    h.shutdown();

    let n = size as f64;
    // per-level comms predictions: L1 inter, deeper intra
    let comms_pred: Vec<f64> = obs_comms
        .iter()
        .map(|&(level, _)| {
            if level == 1 {
                model.comms_inter.predict(n)
            } else {
                model.comms_intra.predict(n)
            }
        })
        .collect();
    let comms_obs: Vec<f64> = obs_comms.iter().map(|&(_, s)| s).collect();
    let add_pred: Vec<f64> = obs_add.iter().map(|_| model.add_upd.predict(n)).collect();

    // match model: the §6.3 bound with t0 = this run's L0 match time
    let t0 = stats::mean(&t0s);
    let total_match_obs: f64 = stats::mean(&obs_match) * obs_match.len() as f64
        / cfg.iters as f64;
    let match_pred = match_time_bound(t0, model.comms_intra.fit.beta0.max(1e-6), 2.0, 8961.0);
    let match_mape = ((match_pred - total_match_obs) / total_match_obs).abs();

    let predicted_total =
        model.predict(n, 1, 3, 4, t0);
    ApplyResult {
        subgraph_size: size,
        match_mape,
        comms_mape: stats::mape(&comms_obs, &comms_pred),
        add_upd_mape: stats::mape(&obs_add, &add_pred),
        component_share: stats::mean(&comp_sums) / stats::mean(&totals),
        predicted_total_s: predicted_total,
        observed_total_s: stats::mean(&totals),
    }
}

/// E10: the §6.3 bound on real nested match data. Returns
/// (observed total match seconds, bound seconds, bound factor).
pub fn validate_bound(nested: &NestedResult, test: &str) -> (f64, f64, f64) {
    // observed: sum of per-level mean match times for the test
    let mut total = 0.0;
    let mut t0 = 0.0;
    for level in 0..=4usize {
        if let Some(s) = nested.match_summary(level, test) {
            total += s.mean;
            if level == 0 {
                t0 = s.mean;
            }
        }
    }
    let s0 = 8961.0; // our L0 graph size
    let bound = match_time_bound(t0, 1e-5, 2.0, s0);
    (total, bound, bound_factor(2.0, s0))
}

/// E10 ablation: bound tightness across branching factors (the paper's
/// b = 2 case plus wider trees).
pub fn bound_ablation() -> String {
    let mut out = String::from("E10 ablation — bound factor b(1-1/s0)/(b-1) by branching\n");
    for b in [2.0, 4.0, 8.0, 16.0] {
        out.push_str(&format!(
            "  b={b:<4} s0=8961: factor {:.4}\n",
            bound_factor(b, 8961.0)
        ));
    }
    out
}

/// Build a minimal 2-level hierarchy for bound tests with branching b — the
/// lemma's tree shape (used by unit tests).
pub fn two_level(levels: usize) -> Vec<LevelSpec> {
    (0..levels)
        .map(|_| LevelSpec {
            boot_nodes: 1,
            link: LinkKind::InProc,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::nested;

    fn smoke_nested() -> NestedResult {
        nested::run(&ExpConfig::smoke(), &["T6", "T7", "T8"])
    }

    #[test]
    fn fitted_models_have_positive_slopes() {
        let _t = crate::experiments::timing_lock();
        let n = smoke_nested();
        let model = fit_models(&n, &FitBackend::Native);
        // assert on median-aggregated fits: raw-sample slopes are exercised
        // by the bench at 50 iterations; a parallel test run is too noisy
        // for 5-iteration raw OLS
        let (inter_med, intra_med) = n.comms_medians();
        let fit_of = |pts: &[(f64, f64)]| {
            let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
            let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
            crate::util::stats::ols(&xs, &ys)
        };
        let inter = fit_of(&inter_med);
        let intra = fit_of(&intra_med);
        assert!(inter.beta > 0.0, "{inter:?}");
        assert!(model.add_upd.fit.beta > 0.0, "{:?}", model.add_upd.fit);
        // Table 4 regime split: the internode link costs more at any size
        // in the tested range (intercept + slope dominate)
        let mid = 500.0;
        assert!(
            inter.predict(mid) > intra.predict(mid),
            "inter {:?} vs intra {:?}",
            inter,
            intra
        );
        assert!(figure34_table(&n, &model).contains("T7"));
    }

    #[test]
    fn apply_complex_request() {
        let _t = crate::experiments::timing_lock();
        let cfg = ExpConfig::smoke();
        let n = smoke_nested();
        let model = fit_models(&n, &FitBackend::Native);
        let r = apply_model(&cfg, &model);
        // 1 node + 2 sockets + 32 cores + 4 gpus + 4 mem = 43 vertices -> 86
        assert_eq!(r.subgraph_size, 86);
        // comms/add models generalize (the paper's point): errors bounded.
        // Bounds are loose — 5-iteration smoke data under a parallel test
        // run; the bench reports the real MAPEs at 50 iterations.
        assert!(r.comms_mape < 5.0, "comms mape {}", r.comms_mape);
        assert!(r.add_upd_mape < 10.0, "add mape {}", r.add_upd_mape);
        // component sum explains most of the measured total (paper ≥98.2%)
        assert!(r.component_share > 0.5, "share {}", r.component_share);
        assert!(r.table().contains("Table 5"));
    }

    #[test]
    fn bound_holds_on_measured_data() {
        let _t = crate::experiments::timing_lock();
        let n = smoke_nested();
        let (observed, bound, factor) = validate_bound(&n, "T7");
        assert!(
            observed <= bound * 1.5,
            "observed {observed} vs bound {bound}"
        );
        assert!((factor - 2.0).abs() < 0.01);
        assert!(bound_ablation().contains("b=2"));
    }
}
