//! E11 — end-to-end driver: replay an elastic ensemble-workflow trace
//! against the dynamic graph scheduler (with EC2 bursting when the cluster
//! saturates) and against a rigid allocate-peak-up-front baseline.
//!
//! This is the headline composition: all three of the paper's capabilities
//! on one workload — RJMS dynamism (grow/shrink per phase), external
//! resource specialization (bursting through the provider-selected Fleet
//! path, scored by the AOT XLA artifact when built), and graph-scheduler
//! task binding. Virtual time drives job arrivals/holds; every scheduler
//! operation (match, allocate, grow, add-subgraph) is executed and timed
//! for real.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::experiments::ExpConfig;
use crate::external::ec2::{Ec2Provider, Ec2SimConfig};
use crate::external::provider::ExternalProvider;
use crate::jobspec::{JobSpec, ResourceReq};
use crate::resource::builder::{table2_graph, UidGen};
use crate::resource::graph::{JobId, VertexId};
use crate::sched::{PruneConfig, SchedInstance};
use crate::util::metrics::{Recorder, Timer};
use crate::workload::{demand_summary, generate, ElasticJob, Phase, WorkloadSpec};

/// Scheduling mode under test.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mode {
    /// Grow/shrink per phase; burst to EC2 when the cluster is full.
    Elastic { burst: bool },
    /// Allocate the job's peak up front, hold until completion.
    Rigid,
}

/// Result of one replay.
#[derive(Debug, Clone)]
pub struct ReplayResult {
    /// Mode label ("elastic+burst", "elastic", "rigid").
    pub mode: String,
    /// Jobs that ran to completion.
    pub jobs_completed: usize,
    /// Virtual seconds from first arrival to last completion.
    pub makespan_s: f64,
    /// Σ queue wait (virtual seconds).
    pub total_wait_s: f64,
    /// Useful demand / (cluster capacity × makespan).
    pub utilization: f64,
    /// Cloud node·seconds consumed (elastic+burst only).
    pub cloud_node_s: f64,
    /// Real measured scheduler-operation latencies.
    pub recorder: Recorder,
}

impl ReplayResult {
    /// One formatted summary line for the comparison table.
    pub fn table(&self) -> String {
        let grow = self
            .recorder
            .summary("op/grow")
            .map(|s| format!("{:.6}s", s.mean))
            .unwrap_or_else(|| "-".into());
        format!(
            "{:<18} jobs={:<4} makespan={:<9.2}s wait={:<9.2}s util={:<6.3} cloud={:<9.1} grow_op={}\n",
            self.mode,
            self.jobs_completed,
            self.makespan_s,
            self.total_wait_s,
            self.utilization,
            self.cloud_node_s,
            grow
        )
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    Arrive(usize),
    /// Advance job to its next phase (index into phases; usize::MAX = base
    /// phase end).
    PhaseDone(usize, usize),
    Complete(usize),
}

/// Virtual-time event. Ordered by time (f64 bits — times are finite).
#[derive(Debug, Clone, Copy, PartialEq)]
struct Event {
    at: f64,
    seq: u64,
    ev: Ev,
}

impl Eq for Event {}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at
            .partial_cmp(&other.at)
            .expect("finite times")
            .then(self.seq.cmp(&other.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// A cloud-backed grow grant: what must be torn down on shrink/complete,
/// and the accounting to charge node·seconds on release.
struct CloudGrant {
    subgraph_roots: Vec<String>,
    instance_ids: Vec<String>,
    nodes: u64,
    since: f64,
}

struct JobState {
    job: Option<JobId>,
    /// Stack of grow grants (vertex sets), popped on shrink.
    grows: Vec<Vec<VertexId>>,
    /// Cloud metadata per grow (None = grown from local resources).
    cloud: Vec<Option<CloudGrant>>,
    queued_at: Option<f64>,
}

/// Replay `jobs` in the given mode on a fresh 128-node cluster.
pub fn replay(cfg: &ExpConfig, jobs: &[ElasticJob], mode: Mode) -> ReplayResult {
    let mut inst = SchedInstance::new(table2_graph(0, &mut UidGen::new()), PruneConfig::default());
    let cluster_nodes = 128u64;
    let mut provider = Ec2Provider::new(Ec2SimConfig {
        time_scale: cfg.time_scale,
        ..Ec2SimConfig::default()
    });
    let mut rec = Recorder::new();

    let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
    let mut seq = 0u64;
    let push = |heap: &mut BinaryHeap<Reverse<Event>>, seq: &mut u64, at: f64, ev: Ev| {
        *seq += 1;
        heap.push(Reverse(Event { at, seq: *seq, ev }));
    };
    for j in jobs {
        push(&mut heap, &mut seq, j.arrival_s, Ev::Arrive(j.id));
    }

    let mut states: Vec<JobState> = jobs
        .iter()
        .map(|_| JobState {
            job: None,
            grows: Vec::new(),
            cloud: Vec::new(),
            queued_at: None,
        })
        .collect();
    let mut queue: Vec<usize> = Vec::new(); // FIFO of waiting job ids
    // bounded grow retries: a saturated non-burst cluster must not livelock
    // (all jobs stuck in grow -> nobody completes); after MAX_GROW_RETRIES
    // the phase proceeds without the extra nodes (the ensemble runs
    // degraded), which is how real workflow managers behave
    const MAX_GROW_RETRIES: u32 = 8;
    let mut grow_retries: std::collections::HashMap<(usize, usize), u32> =
        std::collections::HashMap::new();
    let mut completed = 0usize;
    let mut total_wait = 0.0;
    let mut cloud_node_s = 0.0;
    let mut makespan: f64 = 0.0;

    let node_req = |nodes: u64| -> JobSpec {
        JobSpec::new(vec![
            ResourceReq::new("node", nodes).with_child(ResourceReq::new("core", 16))
        ])
    };

    while let Some(Reverse(Event { at: now, ev, .. })) = heap.pop() {
        makespan = makespan.max(now);
        match ev {
            Ev::Arrive(id) => {
                let want = match mode {
                    Mode::Rigid => jobs[id].peak_nodes(),
                    Mode::Elastic { .. } => jobs[id].base_nodes,
                };
                let t = Timer::start();
                let outcome = inst.match_allocate(&node_req(want));
                rec.record("op/allocate", t.elapsed_secs());
                match outcome {
                    Ok(out) => {
                        let st = &mut states[id];
                        st.job = Some(out.job);
                        if let Some(q) = st.queued_at.take() {
                            total_wait += now - q;
                        }
                        schedule_first_phase(&jobs[id], now, &mut heap, &mut seq, mode);
                    }
                    Err(_) => {
                        let st = &mut states[id];
                        if st.queued_at.is_none() {
                            st.queued_at = Some(now);
                        }
                        queue.push(id);
                    }
                }
            }
            Ev::PhaseDone(id, phase_idx) => {
                let job = states[id].job.expect("running job");
                let phase = jobs[id].phases.get(phase_idx).copied();
                // rigid jobs reserved their peak at arrival: phases only
                // advance virtual time, no resource operations
                if mode == Mode::Rigid {
                    match phase {
                        Some(Phase::Grow { hold_s, .. }) | Some(Phase::Shrink { hold_s }) => {
                            push(&mut heap, &mut seq, now + hold_s, Ev::PhaseDone(id, phase_idx + 1));
                        }
                        None => push(&mut heap, &mut seq, now, Ev::Complete(id)),
                    }
                    continue;
                }
                match phase {
                    Some(Phase::Grow { nodes, hold_s }) => {
                        let t = Timer::start();
                        let local = inst.match_only(&node_req(nodes));
                        let (selection, cloud_meta) = match local {
                            Ok(m) => (m.selection, None),
                            Err(_) => {
                                let burst = matches!(mode, Mode::Elastic { burst: true });
                                if !burst {
                                    rec.record("op/grow_blocked", t.elapsed_secs());
                                    let retries =
                                        grow_retries.entry((id, phase_idx)).or_insert(0);
                                    *retries += 1;
                                    if *retries <= MAX_GROW_RETRIES {
                                        // back off at least a quarter-second
                                        // of virtual time, then retry
                                        let delay = hold_s.max(0.25);
                                        push(&mut heap, &mut seq, now + delay, Ev::PhaseDone(id, phase_idx));
                                    } else {
                                        // give up on this grow: run the
                                        // phase degraded and move on
                                        push(&mut heap, &mut seq, now + hold_s, Ev::PhaseDone(id, phase_idx + 1));
                                        states[id].grows.push(Vec::new());
                                        states[id].cloud.push(None);
                                    }
                                    continue;
                                }
                                // burst: provider-selected nodes via EC2
                                let spec = JobSpec::new(vec![ResourceReq::new("node", nodes)
                                    .with_child(ResourceReq::new("core", 16))]);
                                let grant = provider.request(&spec).expect("burst");
                                let (report, _) =
                                    inst.accept_grant(&grant.subgraph, None).expect("splice");
                                let roots: Vec<String> = report
                                    .added
                                    .iter()
                                    .filter(|&&v| {
                                        inst.graph
                                            .parent_of(v)
                                            .map(|p| !report.added.contains(&p))
                                            .unwrap_or(true)
                                    })
                                    .map(|&v| inst.graph.vertex(v).path.clone())
                                    .collect();
                                let m = inst
                                    .match_only(&node_req(nodes))
                                    .expect("burst made capacity");
                                (
                                    m.selection,
                                    Some(CloudGrant {
                                        subgraph_roots: roots,
                                        instance_ids: grant.instance_ids,
                                        nodes,
                                        since: now,
                                    }),
                                )
                            }
                        };
                        inst.allocs
                            .grow(&mut inst.graph, &inst.prune, job, selection.clone())
                            .expect("grow");
                        rec.record("op/grow", t.elapsed_secs());
                        let st = &mut states[id];
                        st.grows.push(selection);
                        st.cloud.push(cloud_meta);
                        push(&mut heap, &mut seq, now + hold_s, Ev::PhaseDone(id, phase_idx + 1));
                    }
                    Some(Phase::Shrink { hold_s }) => {
                        let st = &mut states[id];
                        if let Some(victims) = st.grows.pop() {
                            let t = Timer::start();
                            inst.allocs
                                .shrink(&mut inst.graph, &inst.prune, job, &victims)
                                .expect("shrink");
                            // cloud grants: remove the subgraph + release
                            if let Some(Some(grant)) = st.cloud.pop() {
                                for root in &grant.subgraph_roots {
                                    let _ = crate::sched::grow::remove_subgraph(
                                        &mut inst.graph,
                                        &inst.prune,
                                        root,
                                    );
                                }
                                provider.release(&grant.instance_ids).expect("release burst");
                                cloud_node_s += grant.nodes as f64 * (now - grant.since);
                            }
                            rec.record("op/shrink", t.elapsed_secs());
                        }
                        push(&mut heap, &mut seq, now + hold_s, Ev::PhaseDone(id, phase_idx + 1));
                    }
                    None => {
                        push(&mut heap, &mut seq, now, Ev::Complete(id));
                    }
                }
            }
            Ev::Complete(id) => {
                let job = states[id].job.take().expect("completing job");
                let t = Timer::start();
                inst.free_job(job).expect("free");
                // drop any remaining cloud subgraphs
                let st = &mut states[id];
                for grant in st.cloud.drain(..).flatten() {
                    for root in &grant.subgraph_roots {
                        let _ =
                            crate::sched::grow::remove_subgraph(&mut inst.graph, &inst.prune, root);
                    }
                    provider
                        .release(&grant.instance_ids)
                        .expect("release at completion");
                    cloud_node_s += grant.nodes as f64 * (now - grant.since);
                }
                rec.record("op/free", t.elapsed_secs());
                completed += 1;
                // wake the queue (FIFO retry)
                let waiting = std::mem::take(&mut queue);
                for w in waiting {
                    push(&mut heap, &mut seq, now, Ev::Arrive(w));
                }
            }
        }
    }

    let (elastic_demand, _) = demand_summary(jobs);
    ReplayResult {
        mode: match mode {
            Mode::Elastic { burst: true } => "elastic+burst".into(),
            Mode::Elastic { burst: false } => "elastic".into(),
            Mode::Rigid => "rigid".into(),
        },
        jobs_completed: completed,
        makespan_s: makespan,
        total_wait_s: total_wait,
        utilization: elastic_demand / (cluster_nodes as f64 * makespan.max(1e-9)),
        cloud_node_s,
        recorder: rec,
    }
}

fn schedule_first_phase(
    job: &ElasticJob,
    now: f64,
    heap: &mut BinaryHeap<Reverse<Event>>,
    seq: &mut u64,
    _mode: Mode,
) {
    *seq += 1;
    heap.push(Reverse(Event {
        at: now + job.base_hold_s,
        seq: *seq,
        ev: Ev::PhaseDone(job.id, 0),
    }));
}

/// Run the full E11 comparison: elastic+burst vs elastic vs rigid.
pub fn run(cfg: &ExpConfig, spec: &WorkloadSpec) -> Vec<ReplayResult> {
    let jobs = generate(spec);
    vec![
        replay(cfg, &jobs, Mode::Elastic { burst: true }),
        replay(cfg, &jobs, Mode::Elastic { burst: false }),
        replay(cfg, &jobs, Mode::Rigid),
    ]
}

/// Render the elastic-vs-rigid comparison (experiment E11).
pub fn comparison_table(results: &[ReplayResult]) -> String {
    let mut out = String::from("E11 — elastic vs rigid on the ensemble trace\n");
    for r in results {
        out.push_str(&r.table());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> WorkloadSpec {
        WorkloadSpec {
            jobs: 12,
            mean_interarrival_s: 1.0,
            ..WorkloadSpec::default()
        }
    }

    #[test]
    fn all_modes_complete_all_jobs() {
        let cfg = ExpConfig::smoke();
        let results = run(&cfg, &small_spec());
        for r in &results {
            assert_eq!(r.jobs_completed, 12, "{}: {r:?}", r.mode);
            assert!(r.makespan_s > 0.0);
        }
        assert!(comparison_table(&results).contains("rigid"));
    }

    #[test]
    fn rigid_waits_at_least_as_long() {
        // rigid reserves peaks -> queueing can only be worse (or equal on
        // an uncontended trace)
        let cfg = ExpConfig::smoke();
        let spec = WorkloadSpec {
            jobs: 30,
            mean_interarrival_s: 0.2, // contended
            base_nodes: (4, 8),
            grow_nodes: (8, 16),
            ..WorkloadSpec::default()
        };
        let jobs = generate(&spec);
        let elastic = replay(&cfg, &jobs, Mode::Elastic { burst: false });
        let rigid = replay(&cfg, &jobs, Mode::Rigid);
        assert!(
            rigid.total_wait_s >= elastic.total_wait_s,
            "rigid wait {} < elastic wait {}",
            rigid.total_wait_s,
            elastic.total_wait_s
        );
    }

    #[test]
    fn burst_uses_cloud_under_contention() {
        let cfg = ExpConfig::smoke();
        let spec = WorkloadSpec {
            jobs: 20,
            mean_interarrival_s: 0.2,
            base_nodes: (8, 16),
            grow_nodes: (16, 32),
            ..WorkloadSpec::default()
        };
        let jobs = generate(&spec);
        let burst = replay(&cfg, &jobs, Mode::Elastic { burst: true });
        assert_eq!(burst.jobs_completed, 20);
        // grows actually happened
        assert!(burst.recorder.get("op/grow").map(|g| g.len()).unwrap_or(0) > 0);
    }
}
