//! E7 — §5.4 KubeFlux: pod binding through the graph scheduler on the
//! OpenShift testbed graph; MA for the first ReplicaSet pod, MG for the
//! scale-up to 100 (paper: MA 0.101810 s, MG 0.100299 s — i.e. MG ≈ MA).

use crate::experiments::ExpConfig;
use crate::orchestrator::{Management, PodSpec, ReplicaSet};
use crate::util::metrics::Recorder;

#[derive(Debug, Clone)]
/// E7 results: KubeFlux-style ReplicaSet scheduling measurements.
pub struct KubefluxResult {
    /// Vertices in the cluster graph after pod binding.
    pub graph_vertices: usize,
    /// Edges in the cluster graph after pod binding.
    pub graph_edges: usize,
    /// Mean MatchAllocate seconds per pod.
    pub ma_mean_s: f64,
    /// Mean MatchGrow seconds per pod.
    pub mg_mean_s: f64,
    /// Pods successfully bound to nodes.
    pub pods_bound: usize,
    /// Raw per-operation latency samples.
    pub recorder: Recorder,
}

impl KubefluxResult {
    /// Render the E7 summary table.
    pub fn table(&self) -> String {
        format!(
            "E7 — KubeFlux ReplicaSet scheduling (paper: MA 0.101810s, MG 0.100299s)\n\
             resource graph: {} vertices / {} edges (paper: 4344 / 8686 bidirectional)\n\
             MA (first pod)  mean: {:.6}s\n\
             MG (scale-up)   mean: {:.6}s over {} pods\n\
             MG/MA ratio: {:.3} (paper: 0.985)\n",
            self.graph_vertices,
            self.graph_edges,
            self.ma_mean_s,
            self.mg_mean_s,
            self.pods_bound,
            self.mg_mean_s / self.ma_mean_s
        )
    }
}

/// Deploy a 1-pod ReplicaSet, then scale to `replicas`, repeated
/// `cfg.iters` times on fresh clusters.
pub fn run(cfg: &ExpConfig, replicas: usize) -> KubefluxResult {
    let mut rec = Recorder::new();
    let mut vertices = 0;
    let mut edges = 0;
    let mut pods = 0usize;
    for _ in 0..cfg.iters {
        let mut mgmt = Management::openshift(1);
        vertices = mgmt.rqs[0].inst.graph.num_vertices();
        edges = mgmt.rqs[0].inst.graph.num_edges();
        let rs = ReplicaSet {
            replicas,
            pod: PodSpec {
                cpu_milli: 1000,
                mem_mib: 512,
                gpus: 0,
            },
        };
        let (first, grows) = mgmt.deploy_replicaset(&rs).expect("deploy");
        rec.record("kubeflux/ma", first.seconds);
        for g in &grows {
            rec.record("kubeflux/mg", g.seconds);
        }
        pods += 1 + grows.len();
    }
    KubefluxResult {
        graph_vertices: vertices,
        graph_edges: edges,
        ma_mean_s: rec.summary("kubeflux/ma").unwrap().mean,
        mg_mean_s: rec.summary("kubeflux/mg").unwrap().mean,
        pods_bound: pods,
        recorder: rec,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kubeflux_mg_comparable_to_ma() {
        let _t = crate::experiments::timing_lock();
        let mut cfg = ExpConfig::smoke();
        cfg.iters = 3;
        let r = run(&cfg, 20);
        assert_eq!(r.graph_vertices, 4343);
        assert_eq!(r.pods_bound, 3 * 20);
        // §5.4 shape (the paper's claim): MG is NOT slower than MA. Ours is
        // considerably faster (warm allocation vs cold full traversal), so
        // only bound it from above.
        let ratio = r.mg_mean_s / r.ma_mean_s;
        assert!(ratio < 5.0, "ratio={ratio}");
        assert!(r.mg_mean_s > 0.0);
        assert!(r.table().contains("E7"));
    }
}
