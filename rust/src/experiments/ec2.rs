//! E5/E6 — §5.3 bursting to EC2: instance-creation timing by type (Fig 2 +
//! Table 3), EC2 Fleet requests through dynamic binding, and the static-
//! configuration comparison against the bitmap baseline.

use crate::bitmap::config::{build_scheduler, generate_cloud_config, parse_config};
use crate::experiments::ExpConfig;
use crate::external::ec2::{Ec2Provider, Ec2SimConfig, EC2_CATALOG};
use crate::external::fleet::FleetRequest;
use crate::external::provider::ExternalProvider;
use crate::jobspec::{JobSpec, ResourceReq};
use crate::resource::builder::{table2_graph, UidGen};
use crate::sched::{grow, PruneConfig, SchedInstance};
use crate::util::metrics::{current_rss_kb, Recorder, Timer};

/// E5 results: per-type creation-time distributions + overhead fractions.
#[derive(Debug, Clone)]
pub struct Ec2Result {
    /// Per-type creation-time samples (`create/<type>` keys).
    pub recorder: Recorder,
    /// Mean jobspec→request mapping time as a fraction of creation time
    /// (paper: <1%).
    pub map_fraction: f64,
    /// Mean JGF encode time as a fraction of creation time (paper: ≈1.6%).
    pub encode_fraction: f64,
    /// Number of simulated EC2 requests issued.
    pub requests_run: usize,
}

impl Ec2Result {
    /// Render the Figure 2 creation-time table.
    pub fn figure2_table(&self) -> String {
        let mut out = String::from(
            "E5 (Fig 2) — EC2 instance creation times by type (all request sizes pooled)\n",
        );
        out.push_str(&format!(
            "{:<14} {:>6} {:>12} {:>12} {:>12} {:>12}\n",
            "type", "n", "median(s)", "q1(s)", "q3(s)", "mean(s)"
        ));
        for t in EC2_CATALOG.iter() {
            if let Some(s) = self.recorder.summary(&format!("create/{}", t.name)) {
                out.push_str(&format!(
                    "{:<14} {:>6} {:>12.4} {:>12.4} {:>12.4} {:>12.4}\n",
                    t.name, s.n, s.median, s.q1, s.q3, s.mean
                ));
            }
        }
        out.push_str(&format!(
            "jobspec->request mapping: {:.3}% of creation (paper: <1%)\n\
             JGF encode overhead:      {:.3}% of creation (paper: ~1.6%)\n",
            100.0 * self.map_fraction,
            100.0 * self.encode_fraction
        ));
        out
    }
}

/// E5: request 1/2/4/8 instances of each Table 3 type, `reps` times each
/// (paper: 20 reps → 640 total requests).
pub fn run_creation(cfg: &ExpConfig, reps: usize) -> Ec2Result {
    let mut provider = Ec2Provider::new(Ec2SimConfig {
        time_scale: cfg.time_scale,
        ..Ec2SimConfig::default()
    });
    let mut rec = Recorder::new();
    let mut map_fracs = Vec::new();
    let mut encode_fracs = Vec::new();
    let mut runs = 0usize;
    for itype in EC2_CATALOG.iter() {
        for count in [1u64, 2, 4, 8] {
            for _ in 0..reps {
                let spec = JobSpec::new(vec![ResourceReq::new("node", count)
                    .with_attr("instance_type", itype.name)]);
                let grant = provider.request(&spec).expect("catalog request");
                // unscale so the report reads in real EC2 seconds
                rec.record(
                    &format!("create/{}", itype.name),
                    grant.creation_s / cfg.time_scale,
                );
                let ph = provider.last_phases;
                map_fracs.push(ph.map_s / grant.creation_s);
                encode_fracs.push(ph.encode_s / grant.creation_s);
                provider.release(&grant.instance_ids).expect("release");
                runs += 1;
            }
        }
    }
    Ec2Result {
        recorder: rec,
        map_fraction: crate::util::stats::mean(&map_fracs),
        encode_fraction: crate::util::stats::mean(&encode_fracs),
        requests_run: runs,
    }
}

/// E6 results: fleet timing + the static-config blowup numbers.
#[derive(Debug, Clone)]
pub struct FleetResult {
    /// Mean request→subgraph-integrated time per fleet (paper: 6.24 s for
    /// 10×10), in unscaled (real) seconds.
    pub fleet_mean_s: f64,
    /// Subgraph sizes of each fleet request.
    pub fleet_sizes: Vec<usize>,
    /// Static config: definitions, nodes, generate+parse+init seconds, RSS
    /// growth in kB.
    pub static_defs: usize,
    /// Nodes in the static configuration.
    pub static_nodes: usize,
    /// Static-config generate + parse + init seconds.
    pub static_init_s: f64,
    /// Static-config RSS growth in kB.
    pub static_rss_kb: u64,
    /// Fluxion-side: graph size growth for the same resources, add time.
    pub dynamic_add_s: f64,
    /// Graph-size growth from the dynamic add.
    pub dynamic_added_size: usize,
}

impl FleetResult {
    /// Render the E6 fleet-vs-static comparison table.
    pub fn table(&self) -> String {
        format!(
            "E6 — EC2 Fleet dynamic binding vs static configuration\n\
             fleet requests: mean request->graph-add {:.3}s (paper: 6.24s), subgraph sizes {:?}\n\
             static config: {} node-type definitions, {} nodes, init {:.3}s, +{} kB RSS\n\
             dynamic graph: added {} vertices+edges in {:.6}s — no pre-enumeration\n",
            self.fleet_mean_s,
            self.fleet_sizes,
            self.static_defs,
            self.static_nodes,
            self.static_init_s,
            self.static_rss_kb,
            self.dynamic_added_size,
            self.dynamic_add_s
        )
    }
}

/// E6: `fleets` Fleet requests of `per_fleet` instances each, integrated
/// into a Fluxion graph; then the Slurm-style static enumeration at
/// `types × zones × instances_per_type` scale.
pub fn run_fleet(
    cfg: &ExpConfig,
    fleets: usize,
    per_fleet: u64,
    static_types: usize,
    static_zones: usize,
    static_instances: usize,
) -> FleetResult {
    // --- dynamic binding: Fleet → JGF → AddSubgraph ----------------------
    let mut provider = Ec2Provider::new(Ec2SimConfig {
        time_scale: cfg.time_scale,
        ..Ec2SimConfig::default()
    });
    let mut inst = SchedInstance::new(table2_graph(3, &mut UidGen::new()), PruneConfig::default());
    let mut totals = Vec::new();
    let mut sizes = Vec::new();
    let mut add_s_acc = 0.0;
    let mut added_size = 0usize;
    for _ in 0..fleets {
        let t = Timer::start();
        let grant = provider
            .request_fleet(&FleetRequest {
                total_instances: per_fleet,
                allowed_types: Vec::new(), // any (capped at 300, like the paper)
                on_demand: true,
                min_zones: 2,
            })
            .expect("fleet request");
        let before = inst.graph.size();
        let (_, add_s) = inst.accept_grant(&grant.subgraph, None).expect("add fleet");
        // total: creation (unscaled to real seconds) + our real overheads
        let real_total =
            grant.creation_s / cfg.time_scale + (t.elapsed_secs() - grant.creation_s);
        totals.push(real_total);
        sizes.push(grant.subgraph.size());
        add_s_acc += add_s;
        added_size += inst.graph.size() - before;
    }

    // --- static enumeration: generate + parse + build bitmaps ------------
    let rss_before = current_rss_kb();
    let t = Timer::start();
    let config = generate_cloud_config(static_types, static_zones, static_instances);
    let defs = parse_config(&config).expect("own config parses");
    let sched = build_scheduler(&defs);
    let static_init_s = t.elapsed_secs();
    let static_rss_kb = current_rss_kb().saturating_sub(rss_before);
    let static_nodes = sched.total_nodes();

    FleetResult {
        fleet_mean_s: crate::util::stats::mean(&totals),
        fleet_sizes: sizes,
        static_defs: defs.len(),
        static_nodes,
        static_init_s,
        static_rss_kb,
        dynamic_add_s: add_s_acc / fleets as f64,
        dynamic_added_size: added_size,
    }
}

/// Bonus ablation: how long does the *graph* model take to absorb the same
/// node count the static config enumerates? (Dynamic binding only pays for
/// what it uses.)
pub fn dynamic_equivalent_cost(nodes: usize) -> f64 {
    let mut provider = Ec2Provider::new(Ec2SimConfig {
        time_scale: 0.0, // no creation latency: measure graph work only
        ..Ec2SimConfig::default()
    });
    let mut inst = SchedInstance::new(table2_graph(4, &mut UidGen::new()), PruneConfig::default());
    let spec = JobSpec::new(vec![ResourceReq::new("node", nodes as u64)
        .with_attr("instance_type", "t2.micro")]);
    let grant = provider.request(&spec).expect("bulk request");
    let t = Timer::start();
    grow::add_subgraph(&mut inst.graph, &grant.subgraph).expect("add");
    t.elapsed_secs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creation_times_flat_across_types() {
        let _t = crate::experiments::timing_lock();
        let cfg = ExpConfig::smoke();
        let r = run_creation(&cfg, 2);
        assert_eq!(r.requests_run, 8 * 4 * 2);
        // Fig 2 shape: per-type medians within a tight band (±40%)
        let medians: Vec<f64> = EC2_CATALOG
            .iter()
            .filter_map(|t| r.recorder.summary(&format!("create/{}", t.name)))
            .map(|s| s.median)
            .collect();
        let lo = medians.iter().cloned().fold(f64::MAX, f64::min);
        let hi = medians.iter().cloned().fold(0.0, f64::max);
        assert!(hi / lo < 1.8, "creation times should be ~constant: {medians:?}");
        // overhead fractions small relative to creation; the paper-scale
        // fractions (<1%, ~1.6%) are reproduced by the bench at
        // time_scale 1e-3 — smoke scale (1e-4) inflates them 10×
        assert!(r.map_fraction < 0.10, "{}", r.map_fraction);
        assert!(r.encode_fraction < 0.50, "{}", r.encode_fraction);
        assert!(r.figure2_table().contains("t2.micro"));
    }

    #[test]
    fn fleet_and_static_comparison() {
        let cfg = ExpConfig::smoke();
        // small-scale static enumeration (full scale runs in the bench)
        let r = run_fleet(&cfg, 3, 10, 20, 10, 16);
        assert_eq!(r.fleet_sizes.len(), 3);
        assert!(r.fleet_sizes.iter().all(|&s| s > 0));
        assert_eq!(r.static_defs, 200);
        assert_eq!(r.static_nodes, 200 * 16);
        assert!(r.static_init_s > 0.0);
        assert!(r.dynamic_added_size > 0);
        assert!(r.table().contains("E6"));
    }

    #[test]
    fn dynamic_cost_scales_with_use_not_catalog() {
        let small = dynamic_equivalent_cost(10);
        let big = dynamic_equivalent_cost(100);
        assert!(big > small * 2.0, "add cost should grow with nodes used");
        // and both are far below a second — no enumeration of 23k types
        assert!(big < 1.0);
    }
}
