//! E1 — §5.1 single-level overhead: MatchAllocate vs MatchGrow on one
//! scheduler instance.
//!
//! Paper protocol: a baseline run initializes the L3 graph (143 v+e) and
//! issues two MAs of T7; the MG run initializes the L4 graph (73), MAs all
//! of it, then MGs a T7 subgraph into it — ending with the same graph
//! content but one job. Reported: match times (≈ equal: 0.002871 vs
//! 0.002883 s), the MG-only subgraph add+update time (0.005592 s), and
//! comparable max RSS (5776 vs 5840 kB).

use crate::experiments::ExpConfig;
use crate::jobspec::{table1_jobspec, JobSpec};
use crate::resource::builder::{table2_graph, UidGen};
use crate::resource::jgf::Jgf;
use crate::sched::{PruneConfig, SchedInstance};
use crate::util::metrics::{current_rss_kb, Recorder};

/// Results of the single-level experiment.
#[derive(Debug, Clone)]
pub struct SingleLevelResult {
    /// Mean MatchAllocate match seconds.
    pub ma_match_mean_s: f64,
    /// Mean MatchGrow local-match seconds.
    pub mg_match_mean_s: f64,
    /// Mean MatchGrow AddSubgraph + UpdateMetadata seconds.
    pub mg_add_upd_mean_s: f64,
    /// RSS after the MatchAllocate configuration, in kB.
    pub ma_rss_kb: u64,
    /// RSS after the MatchGrow configuration, in kB.
    pub mg_rss_kb: u64,
    /// Raw per-operation latency samples.
    pub recorder: Recorder,
}

impl SingleLevelResult {
    /// Render the E1 summary table.
    pub fn table(&self) -> String {
        format!(
            "E1 single-level overhead (paper: MA 0.002871s, MG 0.002883s, add/upd 0.005592s)\n\
             {:<24} {:>12.6}s\n{:<24} {:>12.6}s\n{:<24} {:>12.6}s\n\
             {:<24} {:>9} kB\n{:<24} {:>9} kB\n",
            "MA match (mean)",
            self.ma_match_mean_s,
            "MG match (mean)",
            self.mg_match_mean_s,
            "MG add+update (mean)",
            self.mg_add_upd_mean_s,
            "MA config RSS",
            self.ma_rss_kb,
            "MG config RSS",
            self.mg_rss_kb
        )
    }
}

/// Run experiment E1: single-level MA vs MG overhead (paper §5.1).
pub fn run(cfg: &ExpConfig) -> SingleLevelResult {
    let mut rec = Recorder::new();
    let t7 = table1_jobspec("T7");

    // --- baseline configuration: L3 graph, two MAs of T7 ----------------
    let mut ma_rss = 0u64;
    for _ in 0..cfg.iters {
        let mut inst = SchedInstance::new(table2_graph(3, &mut UidGen::new()), PruneConfig::default());
        let out1 = inst.match_allocate(&t7).expect("L3 fits one T7");
        let out2 = inst.match_allocate(&t7).expect("L3 fits two T7s");
        rec.record("ma/match", out1.timing.match_s);
        rec.record("ma/match", out2.timing.match_s);
        ma_rss = ma_rss.max(current_rss_kb());
    }

    // --- MG configuration: L4 graph fully allocated, grow a T7 in -------
    let mut mg_rss = 0u64;
    for _ in 0..cfg.iters {
        let mut uids = UidGen::new();
        let mut inst = SchedInstance::new(table2_graph(4, &mut uids), PruneConfig::default());
        // allocate everything (1 node / 2 sockets / 32 cores)
        let own = inst
            .match_allocate(&JobSpec::nodes_sockets_cores(1, 2, 16))
            .expect("L4 boot");
        // fabricate the incoming T7 subgraph (a parent grant): a fresh node
        // under this cluster root
        let mut donor = crate::resource::ResourceGraph::new();
        let root = donor
            .add_root(crate::resource::graph::make_vertex(
                crate::resource::ResourceType::Cluster,
                "cluster",
                0,
                u64::MAX - 1,
                "/cluster0",
            ))
            .unwrap();
        let node = crate::resource::builder::node_subtree(&mut donor, root, 99, 2, 16, &mut uids);
        let grant = Jgf::from_subtree(&donor, node);

        // MG = match attempt (fails locally: everything allocated) ... the
        // local match phase is what §5.1 compares against MA's:
        let t = crate::util::metrics::Timer::start();
        let _ = inst.match_only(&t7);
        rec.record("mg/match", t.elapsed_secs());
        // ...then the subgraph add+update of the granted resources:
        let (_, add_s) = inst.accept_grant(&grant, Some(own.job)).expect("grow");
        rec.record("mg/add_upd", add_s);
        mg_rss = mg_rss.max(current_rss_kb());
    }

    SingleLevelResult {
        ma_match_mean_s: rec.summary("ma/match").unwrap().mean,
        mg_match_mean_s: rec.summary("mg/match").unwrap().mean,
        mg_add_upd_mean_s: rec.summary("mg/add_upd").unwrap().mean,
        ma_rss_kb: ma_rss,
        mg_rss_kb: mg_rss,
        recorder: rec,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_level_shapes_hold() {
        let _t = crate::experiments::timing_lock();
        let r = run(&ExpConfig::smoke());
        // the §5.1 shape: MA match and MG match within the same order of
        // magnitude; add+update nonzero; RSS comparable
        assert!(r.ma_match_mean_s > 0.0);
        assert!(r.mg_match_mean_s > 0.0);
        assert!(r.mg_add_upd_mean_s > 0.0);
        // our null match is much faster than the paper's (pruning skips the
        // fully-allocated graph immediately), so the band is wide
        let ratio = r.mg_match_mean_s / r.ma_match_mean_s;
        assert!(ratio < 20.0 && ratio > 1e-4, "ratio={ratio}");
        assert!(r.table().contains("E1"));
    }
}
