//! Experiment drivers: one module per paper table/figure (see DESIGN.md's
//! experiment index). Each driver returns structured results *and* renders
//! the paper-style table, and is callable from both the `repro` CLI and the
//! cargo benches, so `cargo bench` regenerates every figure.

pub mod e2e;
pub mod ec2;
pub mod kubeflux;
pub mod models;
pub mod nested;
pub mod single_level;

use crate::rpc::transport::Latency;

/// Serializes timing-sensitive experiment tests: statistical assertions on
/// measured latencies are unreliable when a dozen test threads contend for
/// cores. Production code never takes this lock.
#[cfg(test)]
pub(crate) fn timing_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Shared experiment configuration.
#[derive(Debug, Clone, Copy)]
pub struct ExpConfig {
    /// Repetitions per measured case (paper: 100).
    pub iters: usize,
    /// Simulated-provider time scale (1.0 = realistic EC2 seconds).
    pub time_scale: f64,
    /// Injected internode link latency for the L0↔L1 hop, calibrated so
    /// the inter/intra regression regimes separate as in Table 4.
    pub internode: Latency,
}

impl Default for ExpConfig {
    fn default() -> ExpConfig {
        ExpConfig {
            iters: 30,
            time_scale: 1e-3,
            internode: Latency::of(1400, 60.0),
        }
    }
}

impl ExpConfig {
    /// The paper's full repetition count (slower).
    pub fn paper() -> ExpConfig {
        ExpConfig {
            iters: 100,
            ..ExpConfig::default()
        }
    }

    /// Fast smoke configuration for tests. The internode per-byte latency
    /// is deliberately strong (150 ns/B) so the inter-vs-intra regression
    /// split is detectable from only 5 iterations under test-runner load.
    pub fn smoke() -> ExpConfig {
        ExpConfig {
            iters: 5,
            time_scale: 1e-4,
            internode: Latency::of(200, 150.0),
        }
    }
}
