//! Jobspec: the hierarchical resource-request specification.
//!
//! A jobspec is "a resource match request specification" (§3) — the argument
//! to both `MatchAllocate` and `MatchGrow`. It mirrors Fluxion's canonical
//! jobspec: a tree of typed resource requests with counts, e.g.
//!
//! ```json
//! {"version": 1, "resources": [
//!   {"type": "node", "count": 4, "with": [
//!     {"type": "socket", "count": 2, "with": [
//!       {"type": "core", "count": 16}]}]}]}
//! ```
//!
//! plus optional per-request attributes used by the external provider
//! translation (e.g. `"zone": "us-east-1a"`, `"instance_type": "t2.micro"`).

use crate::util::json::{Json, JsonError};

/// One level of a hierarchical resource request.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceReq {
    /// Requested type name (kept as a string: requests may name types the
    /// local graph has never seen — dynamic heterogeneity).
    pub rtype: String,
    /// How many vertices of this type to select per parent candidate.
    pub count: u64,
    /// Exclusive requests claim the matched vertex; non-exclusive requests
    /// use it only as traversal scope (Fluxion's exclusivity flag — how
    /// KubeFlux pods share nodes, §5.4).
    pub exclusive: bool,
    /// Nested requirements per matched vertex of this type.
    pub with: Vec<ResourceReq>,
    /// Free-form attribute constraints (provider hints, zone pinning, ...).
    pub attrs: Vec<(String, String)>,
}

impl ResourceReq {
    /// An exclusive request for `count` vertices of `rtype`.
    pub fn new(rtype: &str, count: u64) -> ResourceReq {
        ResourceReq {
            rtype: rtype.to_string(),
            count,
            exclusive: true,
            with: Vec::new(),
            attrs: Vec::new(),
        }
    }

    /// Make this request non-exclusive (scope-only container).
    pub fn shared(mut self) -> ResourceReq {
        self.exclusive = false;
        self
    }

    /// Nest a requirement under each matched vertex (builder).
    pub fn with_child(mut self, child: ResourceReq) -> ResourceReq {
        self.with.push(child);
        self
    }

    /// Attach an attribute constraint (builder).
    pub fn with_attr(mut self, key: &str, val: &str) -> ResourceReq {
        self.attrs.push((key.to_string(), val.to_string()));
        self
    }

    /// Value of an attribute constraint, if present.
    pub fn attr(&self, key: &str) -> Option<&str> {
        self.attrs
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Total vertices this request will select (itself × nested;
    /// non-exclusive scopes contribute traversal only, not selection).
    pub fn total_vertices(&self) -> u64 {
        let inner: u64 = self.with.iter().map(ResourceReq::total_vertices).sum();
        let own = if self.exclusive { 1 } else { 0 };
        self.count * (own + inner)
    }
}

/// A complete job request.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Jobspec schema version (canonical jobspecs use 1).
    pub version: u64,
    /// Top-level resource requests.
    pub resources: Vec<ResourceReq>,
    /// System-level attributes (duration, user, provider selection...).
    pub attrs: Vec<(String, String)>,
}

impl JobSpec {
    /// A version-1 jobspec over the given requests.
    pub fn new(resources: Vec<ResourceReq>) -> JobSpec {
        JobSpec {
            version: 1,
            resources,
            attrs: Vec::new(),
        }
    }

    /// Attach a system-level attribute (builder).
    pub fn with_attr(mut self, key: &str, val: &str) -> JobSpec {
        self.attrs.push((key.to_string(), val.to_string()));
        self
    }

    /// Value of a system-level attribute, if present.
    pub fn attr(&self, key: &str) -> Option<&str> {
        self.attrs
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// The paper's Table 1 request shape: `nodes × sockets/node ×
    /// cores/socket`. When `nodes == 0`, request sockets directly (T8).
    pub fn nodes_sockets_cores(nodes: u64, sockets: u64, cores: u64) -> JobSpec {
        let core = ResourceReq::new("core", cores);
        let socket = ResourceReq::new("socket", sockets).with_child(core);
        if nodes == 0 {
            JobSpec::new(vec![socket])
        } else {
            JobSpec::new(vec![ResourceReq::new("node", nodes).with_child(socket)])
        }
    }

    /// Expected subgraph size (vertices + edges = 2·vertices, each selected
    /// vertex contributing its in-edge; cf. Table 1's "graph size" column).
    pub fn subgraph_size(&self) -> u64 {
        2 * self.resources.iter().map(ResourceReq::total_vertices).sum::<u64>()
    }

    /// Total count of a resource type across the request tree
    /// (e.g. total cores for the pruning pre-check).
    pub fn total_of(&self, rtype: &str) -> u64 {
        fn walk(r: &ResourceReq, rtype: &str) -> u64 {
            let nested: u64 = r.with.iter().map(|c| walk(c, rtype)).sum();
            if r.rtype == rtype {
                r.count + r.count * nested
            } else {
                r.count * nested
            }
        }
        self.resources.iter().map(|r| walk(r, rtype)).sum()
    }

    /// Canonical jobspec document (defaults omitted — see the module doc).
    pub fn to_json(&self) -> Json {
        fn req_to_json(r: &ResourceReq) -> Json {
            let mut o = Json::obj()
                .with("type", Json::from(r.rtype.as_str()))
                .with("count", Json::from(r.count));
            if !r.exclusive {
                o.set("exclusive", Json::from(false));
            }
            if !r.with.is_empty() {
                o.set(
                    "with",
                    Json::Arr(r.with.iter().map(req_to_json).collect()),
                );
            }
            if !r.attrs.is_empty() {
                let mut attrs = Json::obj();
                for (k, v) in &r.attrs {
                    attrs.set(k, Json::from(v.as_str()));
                }
                o.set("attributes", attrs);
            }
            o
        }
        let mut doc = Json::obj()
            .with("version", Json::from(self.version))
            .with(
                "resources",
                Json::Arr(self.resources.iter().map(req_to_json).collect()),
            );
        if !self.attrs.is_empty() {
            let mut attrs = Json::obj();
            for (k, v) in &self.attrs {
                attrs.set(k, Json::from(v.as_str()));
            }
            doc.set("attributes", attrs);
        }
        doc
    }

    /// Decode a jobspec document.
    pub fn from_json(doc: &Json) -> Result<JobSpec, JsonError> {
        fn req_from_json(o: &Json) -> Result<ResourceReq, JsonError> {
            let mut r = ResourceReq::new(o.str_field("type")?, o.u64_field("count")?);
            if let Some(false) = o.get("exclusive").and_then(Json::as_bool) {
                r.exclusive = false;
            }
            if let Some(with) = o.get("with").and_then(Json::as_arr) {
                for c in with {
                    r.with.push(req_from_json(c)?);
                }
            }
            if let Some(attrs) = o.get("attributes").and_then(Json::as_obj) {
                for (k, v) in attrs {
                    if let Some(s) = v.as_str() {
                        r.attrs.push((k.clone(), s.to_string()));
                    }
                }
            }
            Ok(r)
        }
        let resources = doc
            .get("resources")
            .and_then(Json::as_arr)
            .ok_or_else(|| JsonError::Schema("jobspec missing 'resources'".into()))?;
        let mut spec = JobSpec {
            version: doc.get("version").and_then(Json::as_u64).unwrap_or(1),
            resources: resources
                .iter()
                .map(req_from_json)
                .collect::<Result<_, _>>()?,
            attrs: Vec::new(),
        };
        if let Some(attrs) = doc.get("attributes").and_then(Json::as_obj) {
            for (k, v) in attrs {
                if let Some(s) = v.as_str() {
                    spec.attrs.push((k.clone(), s.to_string()));
                }
            }
        }
        Ok(spec)
    }

    /// Compact wire text of the jobspec.
    pub fn dump(&self) -> String {
        self.to_json().dump()
    }

    /// Parse jobspec wire text.
    pub fn parse(text: &str) -> Result<JobSpec, JsonError> {
        JobSpec::from_json(&Json::parse(text)?)
    }
}

/// The paper's Table 1 test requests T1..T8 as (name, nodes, sockets, cores).
pub const TABLE1_TESTS: [(&str, u64, u64, u64); 8] = [
    ("T1", 64, 2, 16),
    ("T2", 32, 2, 16),
    ("T3", 16, 2, 16),
    ("T4", 8, 2, 16),
    ("T5", 4, 2, 16),
    ("T6", 2, 2, 16),
    ("T7", 1, 2, 16),
    ("T8", 0, 1, 16),
];

/// Build the Table 1 test jobspec by name.
pub fn table1_jobspec(name: &str) -> JobSpec {
    let (_, n, s, c) = TABLE1_TESTS
        .iter()
        .copied()
        .find(|(t, ..)| *t == name)
        .unwrap_or_else(|| panic!("unknown test {name}"));
    JobSpec::nodes_sockets_cores(n, s, c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_subgraph_sizes() {
        // Our counting is 2 × total vertices. T7 (1 node, 2 sockets/node,
        // 16 cores/socket) = 35 vertices → 70, matching the paper exactly.
        let expected = [4480u64, 2240, 1120, 560, 280, 140, 70, 34];
        for ((name, ..), want) in TABLE1_TESTS.iter().zip(expected) {
            let spec = table1_jobspec(name);
            assert_eq!(spec.subgraph_size(), want, "{name}");
        }
    }

    #[test]
    fn total_of_counts_nested() {
        let spec = JobSpec::nodes_sockets_cores(4, 2, 16);
        assert_eq!(spec.total_of("core"), 4 * 2 * 16);
        assert_eq!(spec.total_of("socket"), 8);
        assert_eq!(spec.total_of("node"), 4);
        assert_eq!(spec.total_of("gpu"), 0);
    }

    #[test]
    fn json_roundtrip() {
        let spec = JobSpec::nodes_sockets_cores(2, 2, 8)
            .with_attr("user", "alice");
        let parsed = JobSpec::parse(&spec.dump()).unwrap();
        assert_eq!(parsed, spec);
    }

    #[test]
    fn attrs_roundtrip() {
        let spec = JobSpec::new(vec![ResourceReq::new("node", 1)
            .with_attr("instance_type", "t2.micro")
            .with_attr("zone", "us-east-1a")]);
        let parsed = JobSpec::parse(&spec.dump()).unwrap();
        assert_eq!(parsed.resources[0].attr("zone"), Some("us-east-1a"));
    }

    #[test]
    fn t8_requests_socket_directly() {
        let spec = table1_jobspec("T8");
        assert_eq!(spec.resources[0].rtype, "socket");
        assert_eq!(spec.total_of("core"), 16);
    }

    #[test]
    fn parse_rejects_missing_resources() {
        assert!(JobSpec::parse(r#"{"version":1}"#).is_err());
    }
}
