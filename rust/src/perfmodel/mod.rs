//! The paper's §6 analysis: component models for MatchGrow time, their
//! cross-validation (Table 4), the composite model (Eq. 6), and the §6.3
//! nested-match upper bound.
//!
//! `t_MG = Σ_i t_match_i + t_comms_i + t_add_upd_i`; comms and add-update
//! are linear in the transmitted subgraph's size n (vertices + edges), with
//! distinct inter-node and intra-node comms regimes. Fits run through the
//! AOT XLA linreg artifact when available (exercising the three-layer
//! stack on the paper's own analysis) with the rust-native OLS as fallback
//! and oracle.

use crate::util::stats::{self, CvResult, LinFit};

/// Which engine fits the regressions.
pub enum FitBackend {
    /// AOT `linreg_fit` artifact via the XLA service.
    Xla(crate::runtime::linreg::XlaLinReg),
    /// rust-native closed-form OLS.
    Native,
}

impl FitBackend {
    /// Prefer the XLA artifact, falling back to native when artifacts are
    /// not built.
    pub fn best() -> FitBackend {
        match crate::runtime::linreg::XlaLinReg::load() {
            Ok(reg) => FitBackend::Xla(reg),
            Err(_) => FitBackend::Native,
        }
    }

    /// Backend label for reports ("xla" or "native").
    pub fn name(&self) -> &'static str {
        match self {
            FitBackend::Xla(_) => "xla",
            FitBackend::Native => "native",
        }
    }

    /// Ordinary least squares through this backend (XLA falls back to
    /// native on error or oversized samples).
    pub fn fit(&self, xs: &[f64], ys: &[f64]) -> LinFit {
        match self {
            FitBackend::Xla(reg) if xs.len() <= crate::runtime::linreg::NSAMP => {
                reg.fit(xs, ys).unwrap_or_else(|_| stats::ols(xs, ys))
            }
            _ => stats::ols(xs, ys),
        }
    }
}

/// One fitted component model plus its five-fold CV metrics — a Table 4 row.
#[derive(Debug, Clone)]
pub struct ComponentModel {
    /// Component name (match / comms / add_upd).
    pub name: String,
    /// The fitted line.
    pub fit: LinFit,
    /// Five-fold cross-validation metrics.
    pub cv: CvResult,
}

impl ComponentModel {
    /// Fit + five-fold cross-validate, reproducing the paper's §6.1/§6.2
    /// procedure. `zero_intercept` applies the paper's add-update
    /// convention (a small negative intercept is unphysical; clamp to 0).
    pub fn fit(
        name: &str,
        backend: &FitBackend,
        xs: &[f64],
        ys: &[f64],
        zero_intercept: bool,
    ) -> ComponentModel {
        let mut fit = backend.fit(xs, ys);
        if zero_intercept {
            fit = fit.clamp_intercept();
        }
        let cv = stats::cross_validate(xs, ys, 5, 0xC0FFEE, zero_intercept);
        ComponentModel {
            name: name.to_string(),
            fit,
            cv,
        }
    }

    /// Predict the component cost at `n` high-level resources.
    pub fn predict(&self, n: f64) -> f64 {
        self.fit.predict(n)
    }

    /// MAPE of this model against held-out observations (Table 5).
    pub fn mape_against(&self, xs: &[f64], ys: &[f64]) -> f64 {
        let pred: Vec<f64> = xs.iter().map(|&x| self.predict(x)).collect();
        stats::mape(ys, &pred)
    }

    /// Render as a Table 4 row.
    pub fn table_row(&self) -> String {
        format!(
            "{:<12} {:>12.7} {:>10.5} {:>14.5e} {:>12.5e}",
            self.name, self.cv.avg_mape, self.cv.avg_r2, self.fit.beta, self.fit.beta0
        )
    }
}

/// The full §6 model set.
pub struct MgModel {
    /// Inter-node comms (paper: "L0 comm").
    pub comms_inter: ComponentModel,
    /// Intra-node comms (paper: "L1-4 comm").
    pub comms_intra: ComponentModel,
    /// Subgraph attach + metadata update (paper: "attach").
    pub add_upd: ComponentModel,
}

impl MgModel {
    /// Eq. 6: predicted MatchGrow time for a request subgraph of size `n`
    /// through a hierarchy with `m` inter-node parent-child pairs, `p`
    /// intra-node pairs, and `q` nested levels performing add+update,
    /// given the matching level's time `t0` (bounded by 2·t0, §6.3).
    pub fn predict(&self, n: f64, m: usize, p: usize, q: usize, t0: f64) -> f64 {
        2.0 * t0
            + m as f64 * self.comms_inter.predict(n)
            + p as f64 * self.comms_intra.predict(n)
            + q as f64 * self.add_upd.predict(n)
    }

    /// Table 4 text block.
    pub fn table4(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<12} {:>12} {:>10} {:>14} {:>12}\n",
            "model", "avg MAPE", "avg R2", "beta", "beta0"
        ));
        for m in [&self.comms_inter, &self.comms_intra, &self.add_upd] {
            out.push_str(&m.table_row());
            out.push('\n');
        }
        out
    }
}

/// §6.3: the geometric-sum bound for total nested match time.
///
/// For branching factor b > 1 and top-level graph size s0, the sum of the
/// per-level match terms is bounded by
/// `t0 · b(1 − 1/s0)/(b − 1) + β0·log_b(s0)`; for large s0 and b = 2 this
/// is ≈ 2·t0.
pub fn match_time_bound(t0: f64, beta0: f64, b: f64, s0: f64) -> f64 {
    assert!(b > 1.0 && s0 > 1.0);
    let levels = s0.log(b);
    t0 * b * (1.0 - 1.0 / s0) / (b - 1.0) + beta0 * levels
}

/// The bound's asymptotic form for b = 2, large s0: 2·t0 (plus the
/// vanishing β0 term) — what the paper quotes.
pub fn bound_factor(b: f64, s0: f64) -> f64 {
    b * (1.0 - 1.0 / s0) / (b - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn synthetic(beta: f64, beta0: f64, noise: f64, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let xs: Vec<f64> = (0..160).map(|_| rng.uniform(30.0, 4500.0)).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|&x| beta * x + beta0 + rng.normal(0.0, noise))
            .collect();
        (xs, ys)
    }

    fn paper_like_model(backend: &FitBackend) -> MgModel {
        // Table 4 coefficients as ground truth for synthetic data
        let (xi, yi) = synthetic(1.5829e-5, 2.0992e-3, 2e-5, 1);
        let (xa, ya) = synthetic(9.0824e-6, 6.3196e-4, 1e-5, 2);
        let (xu, yu) = synthetic(3.4583e-5, 0.0, 2e-5, 3);
        MgModel {
            comms_inter: ComponentModel::fit("L0 comm", backend, &xi, &yi, false),
            comms_intra: ComponentModel::fit("L1-4 comm", backend, &xa, &ya, false),
            add_upd: ComponentModel::fit("attach", backend, &xu, &yu, true),
        }
    }

    #[test]
    fn recovers_paper_coefficients_natively() {
        let m = paper_like_model(&FitBackend::Native);
        assert!((m.comms_inter.fit.beta - 1.5829e-5).abs() < 1e-6);
        assert!((m.comms_intra.fit.beta - 9.0824e-6).abs() < 1e-6);
        assert!((m.add_upd.fit.beta - 3.4583e-5).abs() < 1e-6);
        assert!(m.add_upd.fit.beta0 >= 0.0, "intercept clamped");
        // CV quality like Table 4: small MAPE, R2 ~ 1
        for c in [&m.comms_inter, &m.comms_intra, &m.add_upd] {
            assert!(c.cv.avg_mape < 0.05, "{}: {}", c.name, c.cv.avg_mape);
            assert!(c.cv.avg_r2 > 0.99, "{}: {}", c.name, c.cv.avg_r2);
        }
    }

    #[test]
    fn xla_backend_matches_native() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let (xs, ys) = synthetic(9.08e-6, 6.3e-4, 1e-5, 7);
        let native = FitBackend::Native.fit(&xs, &ys);
        let xla = FitBackend::best();
        assert_eq!(xla.name(), "xla");
        let fitted = xla.fit(&xs, &ys);
        assert!((fitted.beta - native.beta).abs() / native.beta < 2e-2);
    }

    #[test]
    fn eq6_composition() {
        let m = paper_like_model(&FitBackend::Native);
        // paper's experiment shape: m=1 internode pair, p=3 intranode,
        // q=4 nested levels, subgraph n=94
        let t0 = 0.003;
        let pred = m.predict(94.0, 1, 3, 4, t0);
        let manual = 2.0 * t0
            + m.comms_inter.predict(94.0)
            + 3.0 * m.comms_intra.predict(94.0)
            + 4.0 * m.add_upd.predict(94.0);
        assert!((pred - manual).abs() < 1e-12);
        assert!(pred > 2.0 * t0);
    }

    #[test]
    fn bound_is_about_2t0_for_b2() {
        // large s0, b=2 -> factor ≈ 2
        assert!((bound_factor(2.0, 18_061.0) - 2.0).abs() < 1e-3);
        // the full bound exceeds the factor-only part by the beta0 term
        let with_b0 = match_time_bound(0.003, 1e-4, 2.0, 18_061.0);
        assert!(with_b0 > 0.006);
        assert!(with_b0 < 0.006 + 1e-4 * 15.0);
    }

    #[test]
    fn bound_decreases_with_branching() {
        let b2 = bound_factor(2.0, 1e4);
        let b4 = bound_factor(4.0, 1e4);
        let b16 = bound_factor(16.0, 1e4);
        assert!(b2 > b4 && b4 > b16);
        assert!(b16 > 1.0);
    }

    #[test]
    fn table4_renders() {
        let m = paper_like_model(&FitBackend::Native);
        let t = m.table4();
        assert!(t.contains("L0 comm"));
        assert!(t.contains("attach"));
    }

    #[test]
    fn mape_against_heldout() {
        let m = paper_like_model(&FitBackend::Native);
        let (xs, ys) = synthetic(9.0824e-6, 6.3196e-4, 1e-5, 99);
        let mape = m.comms_intra.mape_against(&xs, &ys);
        assert!(mape < 0.05, "mape={mape}");
    }
}
