//! Deterministic open-loop multi-tenant **op traces** for the serving
//! harness ([`crate::serving`]).
//!
//! Where the sibling elastic-workflow generator models whole job lifetimes,
//! this module generates the *request stream* a scheduler front door sees:
//! a seeded sequence of probe/allocate/grow/shrink/free ops with
//! exponential interarrival times at a configured offered rate. The stream
//! is **open-loop**: arrival times are fixed up front and never adapt to
//! how fast the target serves, so queueing delay under saturation shows up
//! in the measured latencies instead of silently throttling the load (the
//! coordinated-omission trap).
//!
//! Generation is a pure function of the spec ([`generate_ops`]): same seed
//! ⇒ identical `Vec<PlannedOp>`, which is what makes harness reruns
//! byte-comparable and the issued-per-kind counters replayable.

use crate::util::rng::Rng;

/// The five workload op kinds a tenant issues against the serving front
/// door. They map onto [`crate::rpc::proto::SchedOp`]s at replay time
/// (see [`crate::serving`] for the exact mapping per target).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Read-only feasibility probe.
    Probe,
    /// New allocation (`MatchAllocate` / a leaf-escalated grow).
    Allocate,
    /// Grow an existing allocation (`MatchGrowLocal`).
    Grow,
    /// Release the *oldest* live allocation this tenant holds.
    Shrink,
    /// Release the *newest* live allocation this tenant holds.
    Free,
}

/// Number of [`OpKind`] variants.
pub const OP_KINDS: usize = 5;

/// Kind names in [`OpKind::index`] order (the harness telemetry's kind
/// list).
pub static OP_KIND_NAMES: [&str; OP_KINDS] =
    ["probe", "allocate", "grow", "shrink", "free"];

impl OpKind {
    /// Stable index of this kind (into [`OP_KIND_NAMES`]).
    pub fn index(&self) -> usize {
        match self {
            OpKind::Probe => 0,
            OpKind::Allocate => 1,
            OpKind::Grow => 2,
            OpKind::Shrink => 3,
            OpKind::Free => 4,
        }
    }

    /// Stable wire-ish name of this kind.
    pub fn name(&self) -> &'static str {
        OP_KIND_NAMES[self.index()]
    }
}

/// Relative weights of the five op kinds in a trace. Weights are integers
/// so mixes are exactly reproducible; they need not sum to anything in
/// particular.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpMix {
    /// Weight of [`OpKind::Probe`].
    pub probe: u32,
    /// Weight of [`OpKind::Allocate`].
    pub allocate: u32,
    /// Weight of [`OpKind::Grow`].
    pub grow: u32,
    /// Weight of [`OpKind::Shrink`].
    pub shrink: u32,
    /// Weight of [`OpKind::Free`].
    pub free: u32,
}

impl OpMix {
    /// Converged-computing front-door traffic: dominated by feasibility
    /// probes (the paper's capacity queries), light churn.
    pub fn probe_heavy() -> OpMix {
        OpMix {
            probe: 90,
            allocate: 6,
            grow: 0,
            shrink: 0,
            free: 4,
        }
    }

    /// Balanced read/write traffic.
    pub fn balanced() -> OpMix {
        OpMix {
            probe: 50,
            allocate: 20,
            grow: 10,
            shrink: 5,
            free: 15,
        }
    }

    /// Allocation-churn traffic: mostly mutations (the write-lock
    /// worst case).
    pub fn churn() -> OpMix {
        OpMix {
            probe: 10,
            allocate: 35,
            grow: 15,
            shrink: 10,
            free: 30,
        }
    }

    /// Pure allocate pressure — the retry-storm mix against a saturated
    /// instance (every op contends for capacity that is not there).
    pub fn allocate_only() -> OpMix {
        OpMix {
            probe: 0,
            allocate: 100,
            grow: 0,
            shrink: 0,
            free: 0,
        }
    }

    fn total(&self) -> u64 {
        self.probe as u64
            + self.allocate as u64
            + self.grow as u64
            + self.shrink as u64
            + self.free as u64
    }

    /// Draw one kind according to the weights.
    fn draw(&self, rng: &mut Rng) -> OpKind {
        let total = self.total();
        assert!(total > 0, "OpMix with all-zero weights");
        let mut v = rng.below(total);
        for (kind, w) in [
            (OpKind::Probe, self.probe as u64),
            (OpKind::Allocate, self.allocate as u64),
            (OpKind::Grow, self.grow as u64),
            (OpKind::Shrink, self.shrink as u64),
            (OpKind::Free, self.free as u64),
        ] {
            if v < w {
                return kind;
            }
            v -= w;
        }
        unreachable!("draw below total covers all weights")
    }
}

/// Parameters of one deterministic op trace.
#[derive(Debug, Clone, PartialEq)]
pub struct OpTraceSpec {
    /// Ops in the trace.
    pub ops: usize,
    /// RNG seed (same seed ⇒ identical trace).
    pub seed: u64,
    /// Offered open-loop arrival rate, ops per second (exponential
    /// interarrivals with this mean rate).
    pub rate_ops_per_sec: f64,
    /// Kind weights.
    pub mix: OpMix,
    /// Tenants round-tripping through the front door (each op carries a
    /// tenant tag; per-tenant live allocations back grow/shrink/free).
    pub tenants: usize,
    /// Inclusive node-count range for probe/allocate/grow requests.
    pub nodes: (u64, u64),
}

impl Default for OpTraceSpec {
    fn default() -> OpTraceSpec {
        OpTraceSpec {
            ops: 10_000,
            seed: 0x5E21CE,
            rate_ops_per_sec: 5_000.0,
            mix: OpMix::balanced(),
            tenants: 4,
            nodes: (1, 4),
        }
    }
}

/// One op of the planned stream: what to issue, when (nanoseconds from
/// trace start), how big, and for whom.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedOp {
    /// Scheduled arrival, nanoseconds from trace start.
    pub at_ns: u64,
    /// Workload kind.
    pub kind: OpKind,
    /// Requested full nodes (probe/allocate/grow; ignored by
    /// shrink/free).
    pub nodes: u64,
    /// Issuing tenant index in `0..spec.tenants`.
    pub tenant: usize,
}

/// Generate the deterministic op stream of a spec: exponential
/// interarrivals at `rate_ops_per_sec`, kinds drawn from the mix, node
/// counts uniform in `nodes`, tenants uniform. Pure in the spec — two
/// calls with equal specs return equal vectors.
pub fn generate_ops(spec: &OpTraceSpec) -> Vec<PlannedOp> {
    assert!(spec.tenants >= 1, "need at least one tenant");
    assert!(
        spec.rate_ops_per_sec > 0.0,
        "offered rate must be positive"
    );
    assert!(spec.nodes.0 >= 1 && spec.nodes.0 <= spec.nodes.1);
    let mut rng = Rng::new(spec.seed);
    let mut t_ns = 0u64;
    let mut out = Vec::with_capacity(spec.ops);
    for _ in 0..spec.ops {
        let gap_s = rng.exponential(spec.rate_ops_per_sec);
        t_ns = t_ns.saturating_add((gap_s * 1e9) as u64);
        out.push(PlannedOp {
            at_ns: t_ns,
            kind: spec.mix.draw(&mut rng),
            nodes: rng.range(spec.nodes.0, spec.nodes.1),
            tenant: rng.below(spec.tenants as u64) as usize,
        });
    }
    out
}

/// Issued-op counts per kind, indexed by [`OpKind::index`] — the
/// plan-determined totals the harness determinism contract is stated over.
pub fn count_by_kind(ops: &[PlannedOp]) -> [u64; OP_KINDS] {
    let mut counts = [0u64; OP_KINDS];
    for op in ops {
        counts[op.kind.index()] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_identical_stream() {
        let spec = OpTraceSpec::default();
        assert_eq!(generate_ops(&spec), generate_ops(&spec));
    }

    #[test]
    fn different_seed_differs() {
        let a = OpTraceSpec::default();
        let b = OpTraceSpec {
            seed: a.seed + 1,
            ..a.clone()
        };
        assert_ne!(generate_ops(&a), generate_ops(&b));
    }

    #[test]
    fn arrivals_monotone_and_rate_plausible() {
        let spec = OpTraceSpec {
            ops: 20_000,
            rate_ops_per_sec: 10_000.0,
            ..OpTraceSpec::default()
        };
        let ops = generate_ops(&spec);
        for w in ops.windows(2) {
            assert!(w[0].at_ns <= w[1].at_ns);
        }
        // mean interarrival ≈ 100 µs at 10k ops/s
        let span_s = ops.last().unwrap().at_ns as f64 * 1e-9;
        let rate = ops.len() as f64 / span_s;
        assert!(
            (rate - 10_000.0).abs() / 10_000.0 < 0.05,
            "observed rate {rate}"
        );
    }

    #[test]
    fn mix_weights_respected() {
        let spec = OpTraceSpec {
            ops: 50_000,
            mix: OpMix::probe_heavy(),
            ..OpTraceSpec::default()
        };
        let counts = count_by_kind(&generate_ops(&spec));
        let total: u64 = counts.iter().sum();
        assert_eq!(total, 50_000);
        let probe_frac = counts[OpKind::Probe.index()] as f64 / total as f64;
        assert!(
            (probe_frac - 0.90).abs() < 0.02,
            "probe fraction {probe_frac}"
        );
        assert_eq!(counts[OpKind::Grow.index()], 0, "zero-weight kind");
    }

    #[test]
    fn fields_in_bounds() {
        let spec = OpTraceSpec {
            ops: 2_000,
            tenants: 3,
            nodes: (2, 5),
            ..OpTraceSpec::default()
        };
        for op in generate_ops(&spec) {
            assert!(op.tenant < 3);
            assert!((2..=5).contains(&op.nodes));
        }
    }
}
