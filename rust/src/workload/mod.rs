//! Synthetic elastic-workflow traces: the workload side of the end-to-end
//! driver (DESIGN.md E11).
//!
//! The paper motivates dynamism with ensemble workflows (MuMMI, AMPL) whose
//! stages "change resource requirements at runtime" (§2.1): a base
//! allocation followed by grow phases (ensemble fan-out) and shrink phases
//! (analysis/reduction). This module generates deterministic traces with
//! that shape; `experiments::e2e` replays them against the hierarchical
//! scheduler and against a rigid (allocate-peak-up-front) baseline.
//!
//! The [`optrace`] submodule generates the other trace family: open-loop
//! per-op request streams (probe/allocate/grow/shrink/free mixes with
//! exponential interarrivals) that the serving harness ([`crate::serving`])
//! replays against a live `SchedService` or `Hierarchy`.

pub mod optrace;

use crate::util::rng::Rng;

/// One elasticity phase of a job's lifetime.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Phase {
    /// Grow by `nodes` full nodes and hold for `hold_s`.
    Grow { nodes: u64, hold_s: f64 },
    /// Release the most recent grow and hold for `hold_s`.
    Shrink { hold_s: f64 },
}

/// An elastic ensemble job.
#[derive(Debug, Clone)]
pub struct ElasticJob {
    /// Stable job index within the trace.
    pub id: usize,
    /// Arrival time in trace seconds.
    pub arrival_s: f64,
    /// Base allocation in full nodes (2 sockets × 16 cores, Table 2 shape).
    pub base_nodes: u64,
    /// Hold time of the base phase before the first elastic phase.
    pub base_hold_s: f64,
    /// Elastic phases after the base hold, in order.
    pub phases: Vec<Phase>,
}

impl ElasticJob {
    /// Peak simultaneous node demand — what a rigid scheduler must reserve
    /// for the job's whole lifetime.
    pub fn peak_nodes(&self) -> u64 {
        let mut cur = self.base_nodes;
        let mut peak = cur;
        for p in &self.phases {
            match p {
                Phase::Grow { nodes, .. } => {
                    cur += nodes;
                    peak = peak.max(cur);
                }
                Phase::Shrink { .. } => {
                    // shrink releases the most recent grow
                }
            }
        }
        peak
    }

    /// Total lifetime (sum of holds).
    pub fn lifetime_s(&self) -> f64 {
        self.base_hold_s
            + self
                .phases
                .iter()
                .map(|p| match p {
                    Phase::Grow { hold_s, .. } | Phase::Shrink { hold_s } => *hold_s,
                })
                .sum::<f64>()
    }

    /// Node·seconds actually used (elastic execution).
    pub fn node_seconds_elastic(&self) -> f64 {
        let mut cur = self.base_nodes as f64;
        let mut acc = cur * self.base_hold_s;
        let mut grow_stack: Vec<u64> = Vec::new();
        for p in &self.phases {
            match p {
                Phase::Grow { nodes, hold_s } => {
                    grow_stack.push(*nodes);
                    cur += *nodes as f64;
                    acc += cur * hold_s;
                }
                Phase::Shrink { hold_s } => {
                    if let Some(n) = grow_stack.pop() {
                        cur -= n as f64;
                    }
                    acc += cur * hold_s;
                }
            }
        }
        acc
    }

    /// Node·seconds a rigid scheduler charges (peak × lifetime).
    pub fn node_seconds_rigid(&self) -> f64 {
        self.peak_nodes() as f64 * self.lifetime_s()
    }
}

/// Trace generation parameters.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Number of jobs in the trace.
    pub jobs: usize,
    /// RNG seed (traces are deterministic per seed).
    pub seed: u64,
    /// Mean interarrival (exponential), in trace seconds.
    pub mean_interarrival_s: f64,
    /// Base allocation range in nodes.
    pub base_nodes: (u64, u64),
    /// Grow burst size range in nodes.
    pub grow_nodes: (u64, u64),
    /// Elastic phases per job (grow/shrink pairs).
    pub phase_pairs: (u64, u64),
    /// Mean phase hold, in trace seconds.
    pub mean_hold_s: f64,
}

impl Default for WorkloadSpec {
    fn default() -> WorkloadSpec {
        // contended enough on a 128-node cluster that rigid reservation
        // queues and elastic grows occasionally need the cloud
        WorkloadSpec {
            jobs: 40,
            seed: 0xE2E,
            mean_interarrival_s: 1.0,
            base_nodes: (2, 8),
            grow_nodes: (4, 24),
            phase_pairs: (1, 3),
            mean_hold_s: 6.0,
        }
    }
}

/// Generate a deterministic trace, sorted by arrival.
pub fn generate(spec: &WorkloadSpec) -> Vec<ElasticJob> {
    let mut rng = Rng::new(spec.seed);
    let mut t = 0.0;
    let mut jobs = Vec::with_capacity(spec.jobs);
    for id in 0..spec.jobs {
        t += rng.exponential(1.0 / spec.mean_interarrival_s);
        let pairs = rng.range(spec.phase_pairs.0, spec.phase_pairs.1);
        let mut phases = Vec::new();
        for _ in 0..pairs {
            phases.push(Phase::Grow {
                nodes: rng.range(spec.grow_nodes.0, spec.grow_nodes.1),
                hold_s: rng.exponential(1.0 / spec.mean_hold_s),
            });
            phases.push(Phase::Shrink {
                hold_s: rng.exponential(1.0 / spec.mean_hold_s),
            });
        }
        jobs.push(ElasticJob {
            id,
            arrival_s: t,
            base_nodes: rng.range(spec.base_nodes.0, spec.base_nodes.1),
            base_hold_s: rng.exponential(1.0 / spec.mean_hold_s),
            phases,
        });
    }
    jobs
}

/// Aggregate elastic-vs-rigid demand over a trace: the headline utilization
/// argument for RJMS dynamism.
pub fn demand_summary(jobs: &[ElasticJob]) -> (f64, f64) {
    let elastic: f64 = jobs.iter().map(ElasticJob::node_seconds_elastic).sum();
    let rigid: f64 = jobs.iter().map(ElasticJob::node_seconds_rigid).sum();
    (elastic, rigid)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let spec = WorkloadSpec::default();
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.phases, y.phases);
        }
    }

    #[test]
    fn arrivals_are_sorted() {
        let jobs = generate(&WorkloadSpec::default());
        for w in jobs.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
    }

    #[test]
    fn peak_accounts_for_stacked_grows() {
        let job = ElasticJob {
            id: 0,
            arrival_s: 0.0,
            base_nodes: 2,
            base_hold_s: 1.0,
            phases: vec![
                Phase::Grow { nodes: 3, hold_s: 1.0 },
                Phase::Grow { nodes: 4, hold_s: 1.0 },
                Phase::Shrink { hold_s: 1.0 },
                Phase::Shrink { hold_s: 1.0 },
            ],
        };
        assert_eq!(job.peak_nodes(), 9);
        assert!((job.lifetime_s() - 5.0).abs() < 1e-12);
        // elastic: 2 + 5 + 9 + 5 + 2 node·s = 23; rigid: 9 × 5 = 45
        assert!((job.node_seconds_elastic() - 23.0).abs() < 1e-12);
        assert!((job.node_seconds_rigid() - 45.0).abs() < 1e-12);
    }

    #[test]
    fn elastic_demand_below_rigid() {
        let jobs = generate(&WorkloadSpec::default());
        let (elastic, rigid) = demand_summary(&jobs);
        assert!(elastic < rigid, "elastic {elastic} >= rigid {rigid}");
        assert!(elastic > 0.0);
    }
}
