//! # fluxion-rs — a dynamic, hierarchical resource model for converged computing
//!
//! Reproduction of Milroy, Herbein, Misale & Ahn, *"A Dynamic, Hierarchical
//! Resource Model for Converged Computing"* (2021): a directed-graph resource
//! model with **fully hierarchical scheduling**, dynamic subgraph grow/shrink
//! (`MatchGrow`, Algorithm 1), external-provider bursting (EC2/Fleet), and a
//! Kubernetes-orchestrator integration (KubeFlux).
//!
//! ## Layer map (see `ARCHITECTURE.md` at the repo root for the full tour)
//!
//! - [`resource`] — the dynamic resource graph: interned-type vertices, O(1)
//!   path localization, pruning aggregates, a monotonic mutation **epoch**,
//!   and the JGF wire format subgraphs travel in.
//! - [`jobspec`] — the hierarchical resource-request specification.
//! - [`sched`] — the scheduler core: pruned match traversal
//!   ([`sched::matcher`]), allocation bookkeeping ([`sched::alloc`]),
//!   grow/shrink transformations ([`sched::grow`]), the single-threaded
//!   [`sched::SchedInstance`], and the concurrent serving layer
//!   [`sched::SchedService`] (read/write-partitioned instance, per-worker
//!   match scratches, epoch-keyed probe cache).
//! - [`rpc`] — the typed protocol ([`rpc::proto`]: `SchedOp`/`SchedReply`),
//!   framing, and transports (in-proc channels, TCP with injected latency).
//! - [`hier`] — fully hierarchical scheduling: chains of instances speaking
//!   the protocol, Algorithm 1's bottom-up/top-down `MatchGrow`, shrink
//!   propagation, external-provider escalation, and per-link quarantine
//!   (circuit breakers with half-open re-probe).
//! - [`fault`] — deterministic fault injection (seeded frame/provider fault
//!   schedules) and the tolerance policies the stack runs with: bounded
//!   retry + backoff, and the quarantine circuit breaker.
//! - [`telemetry`] — lock-cheap serving observability: per-op-kind
//!   log-bucketed latency histograms (p50/p95/p99/max), throughput windows,
//!   and cache/pre-check/retry/breaker/rollback counters, threaded through
//!   [`sched::SchedService`] and [`hier`].
//! - [`serving`] — the open-loop traffic harness: deterministic seeded
//!   multi-tenant op streams ([`workload::optrace`]) replayed from N client
//!   threads against a service or hierarchy, reported as percentile rows
//!   (`BENCH_serving.json` via `cargo bench --bench serving`).
//! - [`external`], [`orchestrator`], [`workload`], [`perfmodel`],
//!   [`experiments`] — cloud providers, the KubeFlux-style orchestrator
//!   model, workload generators, the §6 performance model, and the paper's
//!   experiment drivers.
//!
//! Architecture (three layers, Python never on the request path):
//! - **L3 (this crate)** — the coordinator: resource graph, matcher,
//!   hierarchy, RPC, external providers, baselines, experiments.
//! - **L2 (python/compile/model.py)** — JAX compute graphs (fleet scoring,
//!   regression fit/predict), AOT-lowered to HLO text at build time.
//! - **L1 (python/compile/kernels/)** — Pallas kernels called by L2.
//!
//! The rust side loads the AOT artifacts through [`runtime`] (PJRT CPU
//! client) and drives them from scheduling decisions.

// Documentation is part of this crate's public surface: every public item
// must carry rustdoc, and `scripts/verify.sh` builds the docs with
// warnings-as-errors.
#![warn(missing_docs)]

pub mod util;

pub mod resource;
pub mod jobspec;
pub mod sched;
pub mod rpc;
pub mod fault;
pub mod telemetry;
pub mod hier;
pub mod serving;
pub mod external;
pub mod bitmap;
pub mod orchestrator;
pub mod runtime;
pub mod perfmodel;
pub mod workload;
pub mod experiments;
