//! # fluxion-rs — a dynamic, hierarchical resource model for converged computing
//!
//! Reproduction of Milroy, Herbein, Misale & Ahn, *"A Dynamic, Hierarchical
//! Resource Model for Converged Computing"* (2021): a directed-graph resource
//! model with **fully hierarchical scheduling**, dynamic subgraph grow/shrink
//! (`MatchGrow`, Algorithm 1), external-provider bursting (EC2/Fleet), and a
//! Kubernetes-orchestrator integration (KubeFlux).
//!
//! Architecture (three layers, Python never on the request path):
//! - **L3 (this crate)** — the coordinator: resource graph, matcher,
//!   hierarchy, RPC, external providers, baselines, experiments.
//! - **L2 (python/compile/model.py)** — JAX compute graphs (fleet scoring,
//!   regression fit/predict), AOT-lowered to HLO text at build time.
//! - **L1 (python/compile/kernels/)** — Pallas kernels called by L2.
//!
//! The rust side loads the AOT artifacts through [`runtime`] (PJRT CPU
//! client) and drives them from scheduling decisions.

pub mod util;

pub mod resource;
pub mod jobspec;
pub mod sched;
pub mod rpc;
pub mod hier;
pub mod external;
pub mod bitmap;
pub mod orchestrator;
pub mod runtime;
pub mod perfmodel;
pub mod workload;
pub mod experiments;
