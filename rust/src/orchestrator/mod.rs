//! KubeFlux simulator: scheduling cloud-orchestrator tasks through the
//! graph scheduler (paper §2.2, §5.4).
//!
//! "KubeFlux is composed of three main parts: 1) Fluxion management level,
//! 2) Fluxion daemons (FluxRQ), and 3) the resource graph. The management
//! level ... defines how the resource graph is partitioned among FluxRQ
//! instances. ... Upon receiving a binding request, FluxRQs build the
//! Fluxion jobspec ... and submit a MA allocation query to get the target
//! node for pod binding."
//!
//! We reproduce the same structure: a [`Management`] front end partitioning
//! a cluster graph among [`FluxRq`] instances, pod-spec → jobspec
//! translation, MatchAllocate binding, and the paper's extension —
//! MatchGrow-based ReplicaSet scale-up so an allocation can grow without
//! re-binding existing pods (§5.4's MA-vs-MG measurement).

use crate::jobspec::{JobSpec, ResourceReq};
use crate::resource::builder::{kubeflux_graph, UidGen};
use crate::resource::graph::JobId;
use crate::sched::{PruneConfig, SchedInstance};
use crate::util::metrics::Timer;

/// A Kubernetes pod resource request (the fields KubeFlux encodes into the
/// Fluxion jobspec).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PodSpec {
    /// CPU request in millicores.
    pub cpu_milli: u64,
    /// Memory request in MiB.
    pub mem_mib: u64,
    /// GPU count.
    pub gpus: u64,
}

impl PodSpec {
    /// Translate the pod spec into a Fluxion jobspec: whole cores (ceil of
    /// millicores) and GPUs under a *shared* node/socket scope — pods pack
    /// onto nodes, they do not own them (Kubernetes semantics).
    pub fn to_jobspec(&self) -> JobSpec {
        let cores = self.cpu_milli.div_ceil(1000).max(1);
        let mut socket = ResourceReq::new("socket", 1)
            .shared()
            .with_child(ResourceReq::new("core", cores));
        if self.gpus > 0 {
            socket = socket.with_child(ResourceReq::new("gpu", self.gpus));
        }
        JobSpec::new(vec![ResourceReq::new("node", 1).shared().with_child(socket)])
    }
}

/// A ReplicaSet: n identical pods.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaSet {
    /// Number of identical pods.
    pub replicas: usize,
    /// The pod template.
    pub pod: PodSpec,
}

/// A pod bound to a node.
#[derive(Debug, Clone)]
pub struct Binding {
    /// Index of the pod within its ReplicaSet.
    pub pod_index: usize,
    /// Containment path of the node it landed on.
    pub node_path: String,
    /// The allocation backing the binding.
    pub job: JobId,
    /// Seconds the binding query took.
    pub seconds: f64,
}

/// One FluxRQ daemon: owns a partition of the cluster as its resource graph
/// and answers binding queries with MatchAllocate / MatchGrow.
pub struct FluxRq {
    /// Partition name, e.g. `rq0`.
    pub name: String,
    /// The partition's scheduler instance.
    pub inst: SchedInstance,
}

impl FluxRq {
    /// Bind one pod via MatchAllocate. Returns the binding (target node =
    /// the matched node vertex) and the query time.
    pub fn bind_ma(&mut self, pod_index: usize, pod: &PodSpec) -> Result<Binding, String> {
        let spec = pod.to_jobspec();
        let t = Timer::start();
        let out = self.inst.match_allocate(&spec).map_err(|e| e.to_string())?;
        let seconds = t.elapsed_secs();
        let node_path = node_path_of(&out.subgraph).ok_or("match contained no node path")?;
        Ok(Binding {
            pod_index,
            node_path,
            job: out.job,
            seconds,
        })
    }

    /// Bind one more pod into an *existing* allocation via MatchGrow — the
    /// elasticity extension this paper adds to KubeFlux.
    pub fn bind_mg(
        &mut self,
        pod_index: usize,
        pod: &PodSpec,
        job: JobId,
    ) -> Result<Binding, String> {
        let spec = pod.to_jobspec();
        let t = Timer::start();
        let out = self
            .inst
            .match_grow_local(job, &spec)
            .map_err(|e| e.to_string())?;
        let seconds = t.elapsed_secs();
        let node_path = node_path_of(&out.subgraph).ok_or("match contained no node path")?;
        Ok(Binding {
            pod_index,
            node_path,
            job,
            seconds,
        })
    }

    /// Release a pod's resources (scale-down / pod deletion).
    pub fn unbind(&mut self, job: JobId) -> Result<(), String> {
        self.inst.free_job(job).map(|_| ()).map_err(|e| e.to_string())
    }
}

/// Target node of a pod binding: the `/nodeN` prefix of any matched vertex
/// (pods match shared-scope cores, so the node itself is not in the JGF).
fn node_path_of(subgraph: &crate::resource::jgf::Jgf) -> Option<String> {
    let n = subgraph.nodes.first()?;
    // path shape: /<cluster>/node<N>/...
    let mut parts = n.path.split('/');
    let _ = parts.next(); // leading empty
    let cluster = parts.next()?;
    let node = parts.next()?;
    Some(format!("/{cluster}/{node}"))
}

/// The management level: partitions the cluster among FluxRQ instances and
/// routes binding requests (round-robin, like the KubeFlux prototype's
/// partition dispatch).
pub struct Management {
    /// The FluxRQ partitions, in round-robin order.
    pub rqs: Vec<FluxRq>,
    next: usize,
}

impl Management {
    /// Build the §5.4 testbed: the 26-node OpenShift graph split among
    /// `partitions` FluxRQ instances.
    pub fn openshift(partitions: usize) -> Management {
        assert!(partitions >= 1);
        let mut uids = UidGen::new();
        let full = kubeflux_graph(&mut uids);
        // partition: carve node subtrees round-robin into per-RQ graphs
        let jgf = crate::resource::jgf::Jgf::from_graph(&full);
        let mut rqs = Vec::new();
        for p in 0..partitions {
            // take every `partitions`-th node subtree
            let mut keep = vec![];
            let mut node_idx = 0usize;
            for n in &jgf.nodes {
                if n.rtype.name() == "cluster" {
                    keep.push(n.clone());
                    continue;
                }
                if n.rtype.name() == "node" {
                    node_idx = n.id as usize;
                }
                if node_idx % partitions == p {
                    keep.push(n.clone());
                }
            }
            let sub = crate::resource::jgf::Jgf {
                edges: Vec::new(), // rebuilt from paths
                nodes: keep,
            };
            let graph = sub.build_graph(true).expect("partition graph");
            let prune = PruneConfig::all_of(&[
                crate::resource::ResourceType::Core,
                crate::resource::ResourceType::Gpu,
            ]);
            rqs.push(FluxRq {
                name: format!("fluxrq-{p}"),
                inst: SchedInstance::new(graph, prune),
            });
        }
        Management { rqs, next: 0 }
    }

    /// Route a binding request to the next FluxRQ (gRPC dispatch in the
    /// real system). Falls over to other partitions when one is full.
    pub fn bind_pod(&mut self, pod_index: usize, pod: &PodSpec) -> Result<Binding, String> {
        let n = self.rqs.len();
        for attempt in 0..n {
            let rq = (self.next + attempt) % n;
            match self.rqs[rq].bind_ma(pod_index, pod) {
                Ok(b) => {
                    self.next = (rq + 1) % n;
                    return Ok(b);
                }
                Err(_) => continue,
            }
        }
        Err("no FluxRQ can bind the pod".to_string())
    }

    /// Deploy a ReplicaSet: first pod via MatchAllocate (creating the
    /// allocation), remaining pods via MatchGrow into the same allocation —
    /// the §5.4 measurement pattern. Returns (MA binding, MG bindings).
    pub fn deploy_replicaset(
        &mut self,
        rs: &ReplicaSet,
    ) -> Result<(Binding, Vec<Binding>), String> {
        assert!(rs.replicas >= 1);
        let first = self.bind_pod(0, &rs.pod)?;
        // grow within the partition that took the first pod
        let rq = self
            .rqs
            .iter_mut()
            .find(|r| r.inst.allocs.get(first.job).is_some())
            .expect("binding came from some RQ");
        let mut grows = Vec::with_capacity(rs.replicas - 1);
        for i in 1..rs.replicas {
            grows.push(rq.bind_mg(i, &rs.pod, first.job)?);
        }
        Ok((first, grows))
    }

    /// Combined graph size (vertices + edges) across all partitions.
    pub fn total_graph_size(&self) -> usize {
        self.rqs.iter().map(|r| r.inst.graph.size()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_pod() -> PodSpec {
        PodSpec {
            cpu_milli: 500,
            mem_mib: 256,
            gpus: 0,
        }
    }

    #[test]
    fn podspec_translation() {
        let spec = PodSpec {
            cpu_milli: 2500,
            mem_mib: 1024,
            gpus: 1,
        }
        .to_jobspec();
        assert_eq!(spec.total_of("core"), 3); // ceil(2500m)
        assert_eq!(spec.total_of("gpu"), 1);
        assert_eq!(spec.total_of("node"), 1);
    }

    #[test]
    fn openshift_partitioning_covers_cluster() {
        let m = Management::openshift(2);
        assert_eq!(m.rqs.len(), 2);
        // both partitions non-trivial, cores split 50/50 over 26 nodes
        let sizes: Vec<usize> = m.rqs.iter().map(|r| r.inst.graph.num_vertices()).collect();
        assert!(sizes.iter().all(|&s| s > 1000), "{sizes:?}");
    }

    #[test]
    fn single_partition_matches_paper_graph() {
        let m = Management::openshift(1);
        // 4343 vertices + synthesized-root-free (cluster kept) = 4343
        assert_eq!(m.rqs[0].inst.graph.num_vertices(), 4343);
    }

    #[test]
    fn bind_and_unbind() {
        let mut m = Management::openshift(2);
        let b = m.bind_pod(0, &small_pod()).unwrap();
        assert!(b.node_path.contains("/node"));
        let rq = m
            .rqs
            .iter_mut()
            .find(|r| r.inst.allocs.get(b.job).is_some())
            .unwrap();
        rq.unbind(b.job).unwrap();
        rq.inst.check().unwrap();
    }

    #[test]
    fn replicaset_deploys_100_pods() {
        let mut m = Management::openshift(1);
        let rs = ReplicaSet {
            replicas: 100,
            pod: small_pod(),
        };
        let (first, grows) = m.deploy_replicaset(&rs).unwrap();
        assert_eq!(grows.len(), 99);
        // all pods share one allocation (the KubeFlux elasticity extension)
        assert!(grows.iter().all(|g| g.job == first.job));
        m.rqs[0].inst.check().unwrap();
    }

    #[test]
    fn round_robin_spreads_pods() {
        let mut m = Management::openshift(2);
        let b1 = m.bind_pod(0, &small_pod()).unwrap();
        let b2 = m.bind_pod(1, &small_pod()).unwrap();
        assert_ne!(b1.node_path, b2.node_path);
    }

    #[test]
    fn oversize_pod_rejected() {
        let mut m = Management::openshift(2);
        let huge = PodSpec {
            cpu_milli: 1_000_000,
            mem_mib: 0,
            gpus: 0,
        };
        assert!(m.bind_pod(0, &huge).is_err());
    }
}
