//! Open-loop serving harness: replay deterministic multi-tenant op traces
//! ([`crate::workload::optrace`]) against a live [`SchedService`] or a full
//! [`Hierarchy`], measuring per-op latency from **scheduled arrival** to
//! completion.
//!
//! The harness is the load side of the serving-telemetry story: the target
//! carries its own [`crate::telemetry::Telemetry`] (service-side view), and
//! the harness keeps a second, client-side [`Telemetry`] keyed by the five
//! workload kinds ([`OP_KIND_NAMES`]). Latency is measured open-loop:
//! arrivals are fixed up front by the trace, and an op that starts late
//! (because the target is saturated) charges its queueing delay to the
//! measured latency instead of silently stretching the schedule — the
//! coordinated-omission-safe convention.
//!
//! ## Determinism contract
//!
//! [`run_scenario`] replays [`generate_ops`] output, which is a pure
//! function of the [`OpTraceSpec`]: two runs of the same scenario issue the
//! **identical op stream**, so [`ScenarioResult::issued_by_kind`] and the
//! harness per-kind `ops` totals are byte-equal across reruns. Latencies,
//! and (for multi-client or chaos runs) success/error splits, legitimately
//! vary with thread interleaving and wall-clock — the contract is over
//! *issued* counts, not outcomes.
//!
//! ## Targets
//!
//! - [`Target::Service`]: one concurrent [`SchedService`] on a Table 2
//!   graph, hit by `clients` threads (the plan is partitioned round-robin
//!   by op index). `SchedService` is `Send + Sync` (clone-per-thread), so
//!   this is the multi-threaded saturation path.
//! - [`Target::Hierarchy`]: a full hierarchy (optionally with seeded
//!   [`ChaosConfig`] fault injection on every link) replayed from a
//!   **single** dispatcher thread — a `Hierarchy` owns in-proc server
//!   handles whose channel senders predate `Sender: Sync`, so it is never
//!   shared across threads. Per-level service telemetry is still collected
//!   ([`ScenarioResult::services`]).
//!
//! ## Op mapping
//!
//! | [`OpKind`]  | Service target                      | Hierarchy target |
//! |-------------|-------------------------------------|------------------|
//! | `Probe`     | [`SchedService::probe`]             | [`Hierarchy::probe_up`] |
//! | `Allocate`  | `MatchAllocate` (job recorded)      | [`Hierarchy::grow_from_leaf`] (roots recorded) |
//! | `Grow`      | `MatchGrowLocal` on newest live job | [`Hierarchy::grow_from_leaf`] |
//! | `Shrink`    | `FreeJob` oldest live job           | [`Hierarchy::shrink_from_leaf`] oldest grant |
//! | `Free`      | `FreeJob` newest live job           | [`Hierarchy::shrink_from_leaf`] newest grant |
//!
//! A `Grow` with no live job uses the sentinel `JobId(u64::MAX)` (a
//! deterministic `GROW_FAILED`), and a `Shrink`/`Free` with nothing live
//! counts as an error without touching the target — issued counts stay
//! plan-determined either way. `Allocate`/`Grow` failures are re-issued up
//! to [`Scenario::allocate_retries`] times back-to-back (the retry-storm
//! knob), each re-issue counted via [`Telemetry::note_retry`]; the op still
//! records exactly **one** harness latency sample covering all attempts.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use crate::hier::{ChaosConfig, Hierarchy, LevelSpec, LinkPolicy};
use crate::jobspec::JobSpec;
use crate::resource::builder::{table2_graph, UidGen};
use crate::resource::graph::JobId;
use crate::rpc::proto::{SchedOp, SchedReply};
use crate::sched::{PruneConfig, SchedInstance, SchedService};
use crate::telemetry::{HistogramSnapshot, Telemetry, TelemetrySnapshot};
use crate::util::bench::BenchReport;
use crate::util::json::Json;
use crate::workload::optrace::{
    count_by_kind, generate_ops, OpKind, OpTraceSpec, PlannedOp, OP_KINDS, OP_KIND_NAMES,
};

/// What a scenario replays its trace against.
#[derive(Debug, Clone)]
pub enum Target {
    /// One concurrent [`SchedService`] over the Table 2 level-`level`
    /// graph, hit by [`Scenario::clients`] threads.
    Service {
        /// Table 2 level of the backing graph (0 = 128 nodes … 4 = 1 node).
        level: usize,
        /// Probe worker-pool size of the service.
        workers: usize,
    },
    /// A full [`Hierarchy`] replayed from a single dispatcher thread
    /// ([`Scenario::clients`] is ignored — see the module docs on why the
    /// hierarchy is never shared across threads).
    Hierarchy {
        /// Table 2 level of the **root** graph.
        root_level: usize,
        /// Levels below the root (boot sizes + links).
        levels: Vec<LevelSpec>,
        /// Optional deterministic fault injection on every parent link;
        /// when set, the replay loop also ticks [`Hierarchy::maintain`]
        /// every 64 ops so quarantined links get their half-open trials.
        chaos: Option<ChaosConfig>,
    },
}

/// One named serving experiment: a trace, a target, and load-shape knobs.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Row-name prefix in reports (e.g. `serve/balanced@L0/r5000`).
    pub name: String,
    /// The deterministic op trace to replay.
    pub trace: OpTraceSpec,
    /// Client threads issuing ops (Service target only; min 1).
    pub clients: usize,
    /// What to replay against.
    pub target: Target,
    /// Immediate re-issues of a failed `Allocate`/`Grow` (0 = no retry);
    /// drives the allocate-retry-storm scenarios.
    pub allocate_retries: u32,
    /// Subtree-sharded write-commit width armed on the target before the
    /// replay (`0`/`1` = serial commits, the default). On a
    /// [`Target::Service`] this is [`SchedService::set_write_shards`]; on
    /// a [`Target::Hierarchy`] it arms every level. Drives the
    /// multi-writer `churn` scenarios.
    pub write_shards: usize,
    /// Background **churn-writer** threads run alongside the clients for
    /// the whole replay (Service target only; 0 = none, the default).
    /// Each loops allocate/free against the same service off-schedule —
    /// open-loop measurement of the *read path under writer churn*: every
    /// write publishes a fresh RCU snapshot version, so probes keep
    /// completing against pinned versions while the write lock stays hot.
    /// Churn ops are unmeasured by the harness (they are load, not
    /// traffic) but show up in the service-side telemetry and snapshot
    /// lifecycle counters. Drives the `churn-rcu` scenarios.
    pub churn_writers: usize,
    /// `(level, every)`: kill and restart `level` from its write-ahead
    /// journal every `every` replayed ops (Hierarchy target only; `None` =
    /// never, the default). Arms journaling on every level at build.
    /// Restarts are load, not traffic — unmeasured by the harness, but the
    /// replay/reconcile counters land in the per-level service telemetry.
    /// Drives the `kill-restart` scenarios.
    pub kill_restart: Option<(usize, usize)>,
}

impl Scenario {
    /// A scenario against a [`Target::Service`].
    pub fn service(
        name: &str,
        trace: OpTraceSpec,
        clients: usize,
        level: usize,
        workers: usize,
    ) -> Scenario {
        Scenario {
            name: name.to_string(),
            trace,
            clients,
            target: Target::Service { level, workers },
            allocate_retries: 0,
            write_shards: 0,
            churn_writers: 0,
            kill_restart: None,
        }
    }

    /// A scenario against a [`Target::Hierarchy`].
    pub fn hierarchy(
        name: &str,
        trace: OpTraceSpec,
        root_level: usize,
        levels: Vec<LevelSpec>,
        chaos: Option<ChaosConfig>,
    ) -> Scenario {
        Scenario {
            name: name.to_string(),
            trace,
            clients: 1,
            target: Target::Hierarchy {
                root_level,
                levels,
                chaos,
            },
            allocate_retries: 0,
            write_shards: 0,
            churn_writers: 0,
            kill_restart: None,
        }
    }

    /// Builder: set [`Scenario::allocate_retries`].
    pub fn with_retries(mut self, retries: u32) -> Scenario {
        self.allocate_retries = retries;
        self
    }

    /// Builder: set [`Scenario::write_shards`].
    pub fn with_write_shards(mut self, k: usize) -> Scenario {
        self.write_shards = k;
        self
    }

    /// Builder: set [`Scenario::churn_writers`].
    pub fn with_churn_writers(mut self, n: usize) -> Scenario {
        self.churn_writers = n;
        self
    }

    /// Builder: set [`Scenario::kill_restart`].
    pub fn with_kill_restart(mut self, level: usize, every: usize) -> Scenario {
        self.kill_restart = Some((level, every));
        self
    }
}

/// Everything a scenario run measured.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    /// The scenario's name.
    pub name: String,
    /// Planned (= issued) ops.
    pub planned: usize,
    /// Issued ops per kind, indexed by [`OpKind::index`] — identical
    /// across reruns of the same spec (the determinism contract).
    pub issued_by_kind: [u64; OP_KINDS],
    /// Wall-clock of the replay, seconds.
    pub wall_s: f64,
    /// Offered load of the trace (ops / last scheduled arrival).
    pub offered_ops_per_sec: f64,
    /// Attained throughput (ops / wall-clock) — below offered when the
    /// target saturates and the open-loop schedule slips.
    pub attained_ops_per_sec: f64,
    /// Client-side telemetry keyed by the five workload kinds
    /// ([`OP_KIND_NAMES`]): arrival-to-completion latency per kind.
    pub harness: TelemetrySnapshot,
    /// Server-side telemetry — one snapshot for a Service target, one per
    /// level (root first) for a Hierarchy target.
    pub services: Vec<TelemetrySnapshot>,
}

impl ScenarioResult {
    /// Ops that finished with an error reply (harness view).
    pub fn errors(&self) -> u64 {
        self.harness.errors_total()
    }

    /// Allocate/grow re-issues beyond each op's first attempt.
    pub fn retries(&self) -> u64 {
        self.harness.retries
    }

    /// Circuit-breaker trips summed over every target level.
    pub fn breaker_trips(&self) -> u64 {
        self.services.iter().map(|s| s.breaker_trips).sum()
    }

    /// All five kinds' latency distributions merged into one histogram
    /// (the scenario's headline percentiles).
    pub fn overall_hist(&self) -> HistogramSnapshot {
        let mut kinds = self.harness.kinds.iter();
        let mut merged = kinds
            .next()
            .map(|k| k.hist.clone())
            .unwrap_or_else(empty_hist);
        for k in kinds {
            merged.merge(&k.hist);
        }
        merged
    }

    /// Append this result to a bench report: one headline row named after
    /// the scenario (with `p50_s`/`p95_s`/`p99_s`/`ops_per_sec`/`errors`
    /// extras), plus one `name/kind` row per kind that recorded ops.
    pub fn report_rows(&self, report: &mut BenchReport) {
        let overall = self.overall_hist();
        report.row_summary(
            &self.name,
            overall.to_summary(),
            &[
                ("p50_s", overall.quantile_s(0.50)),
                ("p95_s", overall.quantile_s(0.95)),
                ("p99_s", overall.quantile_s(0.99)),
                ("ops_per_sec", self.attained_ops_per_sec),
                ("errors", self.errors() as f64),
            ],
        );
        for k in &self.harness.kinds {
            if k.ops == 0 {
                continue;
            }
            report.row_summary(
                &format!("{}/{}", self.name, k.name),
                k.hist.to_summary(),
                &[
                    ("p50_s", k.hist.quantile_s(0.50)),
                    ("p95_s", k.hist.quantile_s(0.95)),
                    ("p99_s", k.hist.quantile_s(0.99)),
                    ("ops", k.ops as f64),
                    ("errors", k.errors as f64),
                ],
            );
        }
    }

    /// The result as a JSON document (scenario metadata + issued counts +
    /// the harness telemetry export).
    pub fn to_json(&self) -> Json {
        let issued = OP_KIND_NAMES
            .iter()
            .zip(self.issued_by_kind.iter())
            .fold(Json::obj(), |j, (name, n)| j.with(name, Json::from(*n)));
        Json::obj()
            .with("name", Json::from(self.name.as_str()))
            .with("planned", Json::from(self.planned as u64))
            .with("wall_s", Json::from(self.wall_s))
            .with("offered_ops_per_sec", Json::from(self.offered_ops_per_sec))
            .with(
                "attained_ops_per_sec",
                Json::from(self.attained_ops_per_sec),
            )
            .with("errors", Json::from(self.errors()))
            .with("retries", Json::from(self.retries()))
            .with("breaker_trips", Json::from(self.breaker_trips()))
            .with("issued_by_kind", issued)
            .with("harness", self.harness.to_json())
    }
}

/// `HistogramSnapshot` of a histogram that never recorded (snapshot
/// buckets are private, so snapshotting a fresh histogram is the way to
/// mint one).
fn empty_hist() -> HistogramSnapshot {
    crate::telemetry::LatencyHistogram::new().snapshot()
}

/// Replay a scenario and collect every measurement. See the module docs
/// for the op mapping and the determinism contract.
pub fn run_scenario(sc: &Scenario) -> ScenarioResult {
    let plan = generate_ops(&sc.trace);
    let issued_by_kind = count_by_kind(&plan);
    let harness = Telemetry::with_kinds(&OP_KIND_NAMES);
    let (wall_s, services) = match &sc.target {
        Target::Service { level, workers } => {
            run_service(sc, &plan, &harness, *level, *workers)
        }
        Target::Hierarchy {
            root_level,
            levels,
            chaos,
        } => run_hierarchy(sc, &plan, &harness, *root_level, levels, *chaos),
    };
    let offered_ops_per_sec = plan
        .last()
        .map(|op| plan.len() as f64 / (op.at_ns as f64 * 1e-9))
        .unwrap_or(0.0);
    ScenarioResult {
        name: sc.name.clone(),
        planned: plan.len(),
        issued_by_kind,
        wall_s,
        offered_ops_per_sec,
        attained_ops_per_sec: plan.len() as f64 / wall_s.max(1e-9),
        harness: harness.snapshot(),
        services,
    }
}

/// Sleep (coarse) then spin (fine) until `at_ns` nanoseconds after
/// `start`. Returns immediately when the schedule has already slipped past
/// the target — the open-loop late-start case the latency then captures.
fn wait_until(start: Instant, at_ns: u64) {
    let target = Duration::from_nanos(at_ns);
    loop {
        let elapsed = start.elapsed();
        if elapsed >= target {
            return;
        }
        let remaining = target - elapsed;
        if remaining > Duration::from_millis(1) {
            std::thread::sleep(remaining - Duration::from_millis(1));
        } else {
            std::hint::spin_loop();
        }
    }
}

/// Record one completed op into the harness telemetry: latency runs from
/// the op's *scheduled* arrival to now.
fn record_op(harness: &Telemetry, start: Instant, op: &PlannedOp, error: bool) {
    let done_ns = start.elapsed().as_nanos() as u64;
    let latency = Duration::from_nanos(done_ns.saturating_sub(op.at_ns));
    harness.record_kind(op.kind.index(), latency, error);
}

fn run_service(
    sc: &Scenario,
    plan: &[PlannedOp],
    harness: &Telemetry,
    level: usize,
    workers: usize,
) -> (f64, Vec<TelemetrySnapshot>) {
    let svc = SchedService::with_workers(
        SchedInstance::new(table2_graph(level, &mut UidGen::new()), PruneConfig::default()),
        workers,
    );
    if sc.write_shards > 1 {
        svc.set_write_shards(sc.write_shards);
    }
    let clients = sc.clients.max(1);
    let retries = sc.allocate_retries;
    let tenants = sc.trace.tenants;
    let stop_churn = AtomicBool::new(false);
    let start = Instant::now();
    let mut wall_s = 0.0;
    std::thread::scope(|scope| {
        // background churn writers: unscheduled allocate/free load that
        // keeps the write lock hot (and the snapshot head publishing) for
        // the whole replay; stopped only after every client drains
        for w in 0..sc.churn_writers {
            let svc = svc.clone();
            let stop_churn = &stop_churn;
            scope.spawn(move || {
                let spec = JobSpec::nodes_sockets_cores(1, 2, 16);
                let mut jobs: Vec<JobId> = Vec::new();
                while !stop_churn.load(Ordering::Relaxed) {
                    if let SchedReply::Allocated { job, .. } =
                        svc.apply(&SchedOp::MatchAllocate { spec: spec.clone() })
                    {
                        jobs.push(job);
                    }
                    // staggered depth per writer so frees interleave with
                    // allocs instead of phase-locking across writers
                    if jobs.len() > 2 + w {
                        let job = jobs.remove(0);
                        svc.apply(&SchedOp::FreeJob { job });
                    }
                }
                for job in jobs {
                    svc.apply(&SchedOp::FreeJob { job });
                }
            });
        }
        std::thread::scope(|clients_scope| {
            for c in 0..clients {
                let svc = svc.clone();
                clients_scope.spawn(move || {
                    // per-thread live-job tracking: each tenant's list only
                    // sees this thread's slice of the plan, which is all
                    // grow/shrink/free need to exercise real lifecycles
                    let mut live: Vec<Vec<JobId>> = vec![Vec::new(); tenants];
                    for op in plan.iter().skip(c).step_by(clients) {
                        wait_until(start, op.at_ns);
                        let error = service_op(&svc, harness, &mut live, op, retries);
                        record_op(harness, start, op, error);
                    }
                });
            }
        });
        // the replay's wall clock excludes the churn writers' drain
        wall_s = start.elapsed().as_secs_f64();
        stop_churn.store(true, Ordering::Relaxed);
    });
    (wall_s, vec![svc.telemetry_snapshot()])
}

/// Issue one planned op against a service; returns whether it errored.
fn service_op(
    svc: &SchedService,
    harness: &Telemetry,
    live: &mut [Vec<JobId>],
    op: &PlannedOp,
    retries: u32,
) -> bool {
    let spec = JobSpec::nodes_sockets_cores(op.nodes, 2, 16);
    match op.kind {
        OpKind::Probe => svc.probe(&spec).as_error().is_some(),
        OpKind::Allocate => {
            let mut failed = true;
            for attempt in 0..=retries {
                if let SchedReply::Allocated { job, .. } =
                    svc.apply(&SchedOp::MatchAllocate { spec: spec.clone() })
                {
                    live[op.tenant].push(job);
                    failed = false;
                    break;
                }
                if attempt < retries {
                    harness.note_retry();
                }
            }
            failed
        }
        OpKind::Grow => {
            // sentinel job on an empty tenant: a deterministic
            // GROW_FAILED, keeping issued counts plan-determined
            let job = live[op.tenant].last().copied().unwrap_or(JobId(u64::MAX));
            let mut failed = true;
            for attempt in 0..=retries {
                if !svc
                    .apply(&SchedOp::MatchGrowLocal {
                        job,
                        spec: spec.clone(),
                    })
                    .as_error()
                    .is_some()
                {
                    failed = false;
                    break;
                }
                if attempt < retries {
                    harness.note_retry();
                }
            }
            failed
        }
        OpKind::Shrink => match pop_oldest(&mut live[op.tenant]) {
            Some(job) => svc.apply(&SchedOp::FreeJob { job }).as_error().is_some(),
            None => true,
        },
        OpKind::Free => match live[op.tenant].pop() {
            Some(job) => svc.apply(&SchedOp::FreeJob { job }).as_error().is_some(),
            None => true,
        },
    }
}

fn pop_oldest<T>(v: &mut Vec<T>) -> Option<T> {
    if v.is_empty() {
        None
    } else {
        Some(v.remove(0))
    }
}

fn run_hierarchy(
    sc: &Scenario,
    plan: &[PlannedOp],
    harness: &Telemetry,
    root_level: usize,
    levels: &[LevelSpec],
    chaos: Option<ChaosConfig>,
) -> (f64, Vec<TelemetrySnapshot>) {
    let root = table2_graph(root_level, &mut UidGen::new());
    let policy = LinkPolicy {
        chaos,
        ..LinkPolicy::default()
    };
    let hier =
        Hierarchy::build_with_policy(root, levels, None, policy).expect("hierarchy builds");
    if sc.write_shards > 1 {
        hier.set_write_shards_all(sc.write_shards);
    }
    if sc.kill_restart.is_some() {
        hier.enable_journals(64);
    }
    // per tenant: a stack of grant root-path sets (one entry per
    // successful leaf grow), released oldest-first on Shrink, newest-first
    // on Free
    let mut live: Vec<Vec<Vec<String>>> = vec![Vec::new(); sc.trace.tenants];
    let start = Instant::now();
    for (i, op) in plan.iter().enumerate() {
        wait_until(start, op.at_ns);
        let spec = JobSpec::nodes_sockets_cores(op.nodes, 2, 16);
        let error = match op.kind {
            OpKind::Probe => hier
                .probe_up(&spec)
                .map(|(_, reply)| reply.as_error().is_some())
                .unwrap_or(true),
            OpKind::Allocate | OpKind::Grow => {
                let mut failed = true;
                for attempt in 0..=sc.allocate_retries {
                    match hier.grow_from_leaf(&spec) {
                        Ok(report) => {
                            live[op.tenant].push(report.roots);
                            failed = false;
                            break;
                        }
                        Err(_) => {
                            if attempt < sc.allocate_retries {
                                harness.note_retry();
                            }
                        }
                    }
                }
                failed
            }
            OpKind::Shrink => release_grant(&hier, pop_oldest(&mut live[op.tenant])),
            OpKind::Free => release_grant(&hier, live[op.tenant].pop()),
        };
        record_op(harness, start, op, error);
        if chaos.is_some() && i % 64 == 63 {
            hier.maintain();
        }
        // Kill/restart cycles are load, not traffic: the level rebuilds
        // from its journal and reconciles grant ledgers with its parent
        // while the replay clock keeps running, so the recovery cost shows
        // up as latency on the surrounding measured ops.
        if let Some((level, every)) = sc.kill_restart {
            if every > 0 && i % every == every - 1 {
                let level = level.min(hier.depth() - 1);
                hier.kill_and_restart_level(level)
                    .expect("kill/restart during replay");
            }
        }
    }
    let wall_s = start.elapsed().as_secs_f64();
    let services = (0..hier.depth())
        .map(|l| hier.telemetry_snapshot_at(l))
        .collect();
    hier.shutdown();
    (wall_s, services)
}

/// Shrink every root path of one recorded grant back out of the leaf;
/// `None` (nothing live) counts as an error.
fn release_grant(hier: &Hierarchy, roots: Option<Vec<String>>) -> bool {
    match roots {
        None => true,
        Some(paths) => {
            let mut error = false;
            for path in paths {
                if hier.shrink_from_leaf(&path).is_err() {
                    error = true;
                }
            }
            error
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hier::LinkKind;
    use crate::workload::optrace::OpMix;

    fn fast_trace(ops: usize, mix: OpMix) -> OpTraceSpec {
        OpTraceSpec {
            ops,
            seed: 0x5E21CE,
            rate_ops_per_sec: 200_000.0, // pacing stays under ~ops/200k s
            mix,
            tenants: 3,
            nodes: (1, 2),
        }
    }

    /// Multi-writer churn with write sharding armed: issued counts stay
    /// plan-determined, and the service telemetry proves commits actually
    /// went through the OCC sharded write path.
    #[test]
    fn churn_scenario_with_write_sharding_commits_through_shards() {
        let sc = Scenario::service(
            "serve/churn-wrshard@L1",
            fast_trace(80, OpMix::churn()),
            4,
            1,
            2,
        )
        .with_write_shards(4);
        assert_eq!(sc.write_shards, 4);
        let r = run_scenario(&sc);
        assert_eq!(r.planned, 80);
        let issued: u64 = r.issued_by_kind.iter().sum();
        assert_eq!(issued, 80);
        let svc = &r.services[0];
        assert!(svc.shard_commits > 0, "no commits took the sharded path");
    }

    /// Probe traffic under background churn writers: issued counts stay
    /// plan-determined, and the snapshot lifecycle counters prove the
    /// read path pinned RCU versions while the writers kept publishing.
    #[test]
    fn churn_rcu_scenario_pins_snapshots_while_writers_publish() {
        let sc = Scenario::service(
            "serve/churn-rcu@L1",
            fast_trace(80, OpMix::probe_heavy()),
            2,
            1,
            2,
        )
        .with_churn_writers(2);
        assert_eq!(sc.churn_writers, 2);
        let r = run_scenario(&sc);
        assert_eq!(r.planned, 80);
        let issued: u64 = r.issued_by_kind.iter().sum();
        assert_eq!(issued, 80);
        let svc = &r.services[0];
        assert!(svc.snapshot_pins > 0, "no probe pinned a snapshot");
        assert!(svc.snapshot_publishes > 0, "churn writers never published");
        // every superseded version was reclaimed once the run drained
        assert_eq!(svc.snapshot_publishes, svc.snapshots_retired);
    }

    #[test]
    fn service_scenario_counts_every_planned_op() {
        let sc = Scenario::service(
            "serve/test@L1",
            fast_trace(400, OpMix::balanced()),
            2,
            1,
            2,
        );
        let r = run_scenario(&sc);
        assert_eq!(r.planned, 400);
        assert_eq!(r.harness.ops_total(), 400);
        for (k, name) in OP_KIND_NAMES.iter().enumerate() {
            assert_eq!(
                r.harness.kind(name).unwrap().ops,
                r.issued_by_kind[k],
                "kind {name}"
            );
        }
        assert_eq!(r.services.len(), 1);
        // the service-side telemetry saw real traffic too
        assert!(r.services[0].ops_total() > 0);
        assert!(r.attained_ops_per_sec > 0.0);
    }

    #[test]
    fn rerun_reissues_identical_per_kind_counts() {
        let mk = || {
            Scenario::service("serve/rerun", fast_trace(300, OpMix::churn()), 1, 2, 2)
        };
        let a = run_scenario(&mk());
        let b = run_scenario(&mk());
        assert_eq!(a.issued_by_kind, b.issued_by_kind);
        for name in OP_KIND_NAMES.iter() {
            assert_eq!(
                a.harness.kind(name).unwrap().ops,
                b.harness.kind(name).unwrap().ops
            );
        }
        // single client, no chaos: outcomes are deterministic too
        assert_eq!(a.errors(), b.errors());
    }

    #[test]
    fn retry_storm_counts_retries_exactly() {
        // level 4 = a single node; 2-node allocs can never match, so every
        // Allocate exhausts its retry budget
        let sc = Scenario::service(
            "serve/storm@L4",
            OpTraceSpec {
                ops: 60,
                nodes: (2, 2),
                mix: OpMix::allocate_only(),
                ..fast_trace(60, OpMix::allocate_only())
            },
            1,
            4,
            1,
        )
        .with_retries(2);
        let r = run_scenario(&sc);
        assert_eq!(r.issued_by_kind[OpKind::Allocate.index()], 60);
        assert_eq!(r.retries(), 120, "2 re-issues per failed allocate");
        assert_eq!(r.errors(), 60);
    }

    #[test]
    fn hierarchy_scenario_collects_per_level_telemetry() {
        let sc = Scenario::hierarchy(
            "serve/hier",
            OpTraceSpec {
                ops: 40,
                rate_ops_per_sec: 50_000.0,
                ..fast_trace(40, OpMix::balanced())
            },
            2, // root: 4 nodes
            vec![
                LevelSpec {
                    boot_nodes: 2,
                    link: LinkKind::InProc,
                },
                LevelSpec {
                    boot_nodes: 1,
                    link: LinkKind::InProc,
                },
            ],
            None,
        );
        let r = run_scenario(&sc);
        assert_eq!(r.harness.ops_total(), 40);
        assert_eq!(r.services.len(), 3, "one snapshot per level");
        assert_eq!(r.planned as u64, {
            let total: u64 = r.issued_by_kind.iter().sum();
            total
        });
    }

    #[test]
    fn hierarchy_scenario_survives_kill_restart_cycles() {
        let sc = Scenario::hierarchy(
            "serve/kill",
            OpTraceSpec {
                ops: 40,
                rate_ops_per_sec: 50_000.0,
                ..fast_trace(40, OpMix::balanced())
            },
            2, // root: 4 nodes
            vec![
                LevelSpec {
                    boot_nodes: 2,
                    link: LinkKind::InProc,
                },
                LevelSpec {
                    boot_nodes: 1,
                    link: LinkKind::InProc,
                },
            ],
            None,
        )
        .with_kill_restart(2, 16);
        let r = run_scenario(&sc);
        // Restarts are load, not traffic: every planned op still issues.
        assert_eq!(r.harness.ops_total(), 40);
        // 40 ops / kill every 16 = kills at i = 15, 31; the restarted leaf
        // reconciles grant ledgers with its parent after each rebuild.
        let leaf = &r.services[2];
        assert!(leaf.reconciles >= 2, "one reconcile per restart");
    }

    #[test]
    fn report_rows_carry_percentile_extras() {
        let sc = Scenario::service(
            "serve/rows@L2",
            fast_trace(200, OpMix::probe_heavy()),
            1,
            2,
            2,
        );
        let r = run_scenario(&sc);
        let mut report = BenchReport::new();
        r.report_rows(&mut report);
        assert!(report.get("serve/rows@L2").is_some());
        let p99 = report.get_extra("serve/rows@L2", "p99_s").unwrap();
        let p50 = report.get_extra("serve/rows@L2", "p50_s").unwrap();
        assert!(p99 >= p50 && p50 > 0.0);
        assert!(report.get_extra("serve/rows@L2/probe", "ops").unwrap() > 0.0);
        // JSON export of the result round-trips
        let doc = Json::parse(&r.to_json().dump()).unwrap();
        assert_eq!(
            doc.get("planned").and_then(|v| v.as_f64()),
            Some(200.0)
        );
    }
}
