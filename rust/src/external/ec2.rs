//! Simulated AWS EC2 provider (paper §4 "EC2API", §5.3 experiments).
//!
//! The paper's EC2API "takes a Fluxion jobspec as an input argument, and
//! depending on the jobspec either maps the request to corresponding EC2
//! instance types or builds an EC2 Fleet request for generic resources",
//! then returns the new resources as a JGF subgraph, optionally interposing
//! an "EC2 zone vertex between the nodes' vertices and the cluster vertex".
//!
//! This module reproduces that pipeline against a deterministic simulator:
//! - the Table 3 instance catalog plus a ~300-type generated Fleet catalog;
//! - a lognormal creation-latency model ("the time needed for EC2 to
//!   satisfy instance creation requests is effectively constant for all
//!   instance types and request sizes up to eight" — Fig 2), realized with
//!   real `sleep`s scaled by [`Ec2SimConfig::time_scale`];
//! - 77 availability zones (the paper's count);
//! - jobspec→instance-type selection through an [`InstanceSelector`] —
//!   either the rust-native reference or the AOT XLA fleet-scoring artifact
//!   (see `runtime::scorer`), keeping Python off the request path.

use std::time::Duration;

use crate::external::provider::{ExternalGrant, ExternalProvider, ProviderError};
use crate::jobspec::{JobSpec, ResourceReq};
use crate::resource::jgf::{Jgf, JgfNode};
use crate::resource::types::ResourceType;
use crate::util::metrics::Timer;
use crate::util::rng::Rng;

/// One EC2 instance type.
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceType {
    /// API name, e.g. `t2.xlarge`.
    pub name: &'static str,
    /// Virtual CPUs.
    pub vcpus: u64,
    /// Memory in GiB.
    pub mem_gib: u64,
    /// Attached GPUs.
    pub gpus: u64,
    /// On-demand price in tenths of a cent per hour (integer for exact
    /// comparisons).
    pub price_tenths_cent: u64,
}

impl InstanceType {
    /// Subgraph size (vertices + edges) of one instance in our JGF model:
    /// node + cores + GiB memory vertices + gpus, each with its in-edge.
    pub fn subgraph_size(&self) -> u64 {
        2 * (1 + self.vcpus + self.mem_gib + self.gpus)
    }

    /// Feature row for the scoring kernel: [vcpus, mem, gpus].
    pub fn features(&self) -> [f64; 3] {
        [self.vcpus as f64, self.mem_gib as f64, self.gpus as f64]
    }
}

/// The paper's Table 3 catalog.
pub const EC2_CATALOG: [InstanceType; 8] = [
    InstanceType { name: "t2.micro",    vcpus: 1,  mem_gib: 1,   gpus: 0, price_tenths_cent: 116 },
    InstanceType { name: "t2.small",    vcpus: 1,  mem_gib: 2,   gpus: 0, price_tenths_cent: 230 },
    InstanceType { name: "t2.medium",   vcpus: 2,  mem_gib: 4,   gpus: 0, price_tenths_cent: 464 },
    InstanceType { name: "t2.large",    vcpus: 2,  mem_gib: 8,   gpus: 0, price_tenths_cent: 928 },
    InstanceType { name: "t2.xlarge",   vcpus: 4,  mem_gib: 16,  gpus: 0, price_tenths_cent: 1856 },
    InstanceType { name: "t2.2xlarge",  vcpus: 8,  mem_gib: 32,  gpus: 0, price_tenths_cent: 3712 },
    InstanceType { name: "g2.2xlarge",  vcpus: 8,  mem_gib: 15,  gpus: 1, price_tenths_cent: 6500 },
    InstanceType { name: "g3.4xlarge",  vcpus: 16, mem_gib: 128, gpus: 4, price_tenths_cent: 11400 },
];

/// Instance-type selection: given batched generic requests and the
/// candidate catalog, pick a type per request (the fleet-scoring hot path;
/// implemented natively here and by the XLA artifact in `runtime::scorer`).
pub trait InstanceSelector: Send {
    /// `requests[b]` = required [vcpus, mem_gib, gpus]. Returns for each
    /// request the chosen catalog index, or None if nothing is feasible.
    fn select(
        &mut self,
        requests: &[[f64; 3]],
        candidates: &[[f64; 3]],
        prices: &[f64],
    ) -> Vec<Option<usize>>;
}

/// Reference selector: feasibility ∧ minimal (price + waste) score. This is
/// the exact math the L1 Pallas kernel implements (see
/// `python/compile/kernels/fleet_score.py`); tests assert they agree.
pub struct NativeSelector;

/// Score of candidate `c` for request `r`: infeasible → +inf, else
/// normalized price plus normalized over-provision ("waste").
pub fn score_one(req: &[f64; 3], cand: &[f64; 3], price: f64, max_price: f64) -> f64 {
    let feasible = cand[0] >= req[0] && cand[1] >= req[1] && cand[2] >= req[2];
    if !feasible {
        return f64::INFINITY;
    }
    let waste = (cand[0] - req[0]) / cand[0].max(1.0)
        + (cand[1] - req[1]) / cand[1].max(1.0)
        + (cand[2] - req[2]) / cand[2].max(1.0);
    price / max_price + waste / 3.0
}

impl InstanceSelector for NativeSelector {
    fn select(
        &mut self,
        requests: &[[f64; 3]],
        candidates: &[[f64; 3]],
        prices: &[f64],
    ) -> Vec<Option<usize>> {
        let max_price = prices.iter().cloned().fold(1.0, f64::max);
        requests
            .iter()
            .map(|req| {
                let mut best: Option<(usize, f64)> = None;
                for (i, cand) in candidates.iter().enumerate() {
                    let s = score_one(req, cand, prices[i], max_price);
                    if s.is_finite() && best.map(|(_, b)| s < b).unwrap_or(true) {
                        best = Some((i, s));
                    }
                }
                best.map(|(i, _)| i)
            })
            .collect()
    }
}

/// Simulator configuration.
pub struct Ec2SimConfig {
    /// Multiplier on simulated provider latencies. 1.0 = realistic seconds
    /// (Fig 2 scale); tests/benches use ~1e-3.
    pub time_scale: f64,
    /// RNG seed for latency draws and zone placement.
    pub seed: u64,
    /// Containment path the cloud subgraph attaches beneath (the
    /// requester's cluster root).
    pub attach_under: String,
    /// Interpose zone vertices between cluster and nodes (§4).
    pub zone_vertices: bool,
    /// Deadline budget for one creation request (in *scaled* time, i.e.
    /// after `time_scale` is applied). A request whose simulated creation
    /// would exceed it fails with [`ProviderError::Api`] **before any
    /// instance is created** — the failure is atomic, so retrying cannot
    /// orphan instances. `None` (the default) waits creation out.
    pub request_deadline: Option<Duration>,
}

impl Default for Ec2SimConfig {
    fn default() -> Ec2SimConfig {
        Ec2SimConfig {
            time_scale: 1e-3,
            seed: 0xEC2,
            attach_under: "/cluster0".to_string(),
            zone_vertices: true,
            request_deadline: None,
        }
    }
}

/// The 77 availability zones (paper's count): 26 regions × 2–4 zones.
pub fn availability_zones() -> Vec<String> {
    let regions = [
        ("us-east-1", 4), ("us-east-2", 3), ("us-west-1", 3), ("us-west-2", 4),
        ("ca-central-1", 3), ("sa-east-1", 3), ("eu-west-1", 3), ("eu-west-2", 3),
        ("eu-west-3", 3), ("eu-central-1", 3), ("eu-north-1", 3), ("eu-south-1", 3),
        ("ap-northeast-1", 4), ("ap-northeast-2", 3), ("ap-northeast-3", 3),
        ("ap-southeast-1", 3), ("ap-southeast-2", 3), ("ap-south-1", 3),
        ("ap-east-1", 3), ("me-south-1", 3), ("af-south-1", 3), ("cn-north-1", 3),
        ("cn-northwest-1", 3), ("us-gov-east-1", 3), ("us-gov-west-1", 2),
    ];
    let mut zones = Vec::new();
    for (r, n) in regions {
        for i in 0..n {
            zones.push(format!("{r}{}", (b'a' + i as u8) as char));
        }
    }
    zones
}

/// A created (simulated) instance.
#[derive(Debug, Clone)]
pub struct Ec2Instance {
    /// Instance id, e.g. `i-0000000003`.
    pub id: String,
    /// The catalog type it was created as.
    pub itype: InstanceType,
    /// Availability zone it was placed in.
    pub zone: String,
}

/// The simulated EC2 provider.
pub struct Ec2Provider {
    /// Simulator configuration.
    pub cfg: Ec2SimConfig,
    /// Instance-type selection strategy (native or XLA-backed).
    pub selector: Box<dyn InstanceSelector>,
    zones: Vec<String>,
    rng: Rng,
    next_instance: u64,
    next_uniq: u64,
    live: Vec<Ec2Instance>,
    /// Timing of the last request's phases, for §5.3-style reporting.
    pub last_phases: Phases,
}

/// Per-request phase timings (paper §5.3: jobspec→request mapping is <1% of
/// creation; JGF encoding ≈1.6%).
#[derive(Debug, Clone, Copy, Default)]
pub struct Phases {
    /// Jobspec to provider-request mapping seconds.
    pub map_s: f64,
    /// Simulated instance-creation seconds.
    pub create_s: f64,
    /// Response to JGF encoding seconds.
    pub encode_s: f64,
}

impl Ec2Provider {
    /// Build a provider with the native reference selector.
    pub fn new(cfg: Ec2SimConfig) -> Ec2Provider {
        let rng = Rng::new(cfg.seed);
        Ec2Provider {
            cfg,
            selector: Box::new(NativeSelector),
            zones: availability_zones(),
            rng,
            next_instance: 0,
            next_uniq: 1 << 32, // disjoint from on-prem uniq_ids
            live: Vec::new(),
            last_phases: Phases::default(),
        }
    }

    /// Swap in a different instance-type selector (e.g. the XLA one).
    pub fn with_selector(mut self, s: Box<dyn InstanceSelector>) -> Ec2Provider {
        self.selector = s;
        self
    }

    /// Instances created and not yet released.
    pub fn live_instances(&self) -> &[Ec2Instance] {
        &self.live
    }

    /// Simulated instance-creation latency: lognormal, per-family mean,
    /// effectively independent of count (AWS parallelizes creation) — the
    /// Fig 2 shape. Returns the *sleep actually performed*, or — when the
    /// draw exceeds [`Ec2SimConfig::request_deadline`] — sleeps out the
    /// deadline budget and fails WITHOUT creating anything (the caller's
    /// atomicity guarantee: a timed-out request never orphans instances).
    fn simulate_creation(&mut self, itype_names: &[&str]) -> Result<f64, ProviderError> {
        // family base means (seconds, unscaled)
        let mu_of = |name: &str| -> f64 {
            if name.starts_with("g3") {
                11.0
            } else if name.starts_with('g') || name.starts_with('p') {
                10.0
            } else {
                9.0
            }
        };
        let worst = itype_names
            .iter()
            .map(|n| mu_of(n))
            .fold(0.0f64, f64::max);
        let secs = self.rng.lognormal(worst.ln(), 0.10) * self.cfg.time_scale;
        if let Some(deadline) = self.cfg.request_deadline {
            if secs > deadline.as_secs_f64() {
                // model the caller waiting out its budget, then giving up
                std::thread::sleep(deadline);
                return Err(ProviderError::Api(format!(
                    "timeout: instance creation would take {secs:.3}s, exceeding the \
                     {:.3}s request deadline (no instances were created)",
                    deadline.as_secs_f64()
                )));
            }
        }
        std::thread::sleep(Duration::from_secs_f64(secs));
        Ok(secs)
    }

    /// Map a jobspec to concrete (type, count) pairs: explicit
    /// `instance_type` attributes are honored; generic node requests go
    /// through the selector (the paper's "maps the request to corresponding
    /// EC2 instance types").
    fn map_request(&mut self, spec: &JobSpec) -> Result<Vec<(InstanceType, u64)>, ProviderError> {
        let mut explicit: Vec<(InstanceType, u64)> = Vec::new();
        let mut generic: Vec<([f64; 3], u64)> = Vec::new();
        for req in &spec.resources {
            if req.rtype != "node" {
                return Err(ProviderError::Unsatisfiable(format!(
                    "EC2 can only provide nodes, not '{}'",
                    req.rtype
                )));
            }
            if let Some(name) = req.attr("instance_type") {
                let itype = EC2_CATALOG
                    .iter()
                    .find(|t| t.name == name)
                    .cloned()
                    .ok_or_else(|| {
                        ProviderError::Api(format!("unknown instance type '{name}'"))
                    })?;
                explicit.push((itype, req.count));
            } else {
                generic.push((request_features(req), req.count));
            }
        }
        if !generic.is_empty() {
            let reqs: Vec<[f64; 3]> = generic.iter().map(|(f, _)| *f).collect();
            let cands: Vec<[f64; 3]> = EC2_CATALOG.iter().map(InstanceType::features).collect();
            let prices: Vec<f64> = EC2_CATALOG
                .iter()
                .map(|t| t.price_tenths_cent as f64)
                .collect();
            let picks = self.selector.select(&reqs, &cands, &prices);
            for (pick, (_, count)) in picks.into_iter().zip(&generic) {
                let idx = pick.ok_or_else(|| {
                    ProviderError::Unsatisfiable("no instance type satisfies request".into())
                })?;
                explicit.push((EC2_CATALOG[idx].clone(), *count));
            }
        }
        Ok(explicit)
    }

    /// Execute an EC2 Fleet request end-to-end: plan winners, create them,
    /// encode the JGF (the §5.3 fleet experiment's measured path).
    pub fn request_fleet(
        &mut self,
        req: &crate::external::fleet::FleetRequest,
    ) -> Result<ExternalGrant, ProviderError> {
        let t = Timer::start();
        let plan = crate::external::fleet::plan_fleet(req, &mut self.rng)?;
        let map_s = t.elapsed_secs();
        // aggregate per-type counts for the creation call
        let mut wanted: Vec<(InstanceType, u64)> = Vec::new();
        for (itype, _zone) in &plan.picks {
            match wanted.iter_mut().find(|(t, _)| t.name == itype.name) {
                Some((_, c)) => *c += 1,
                None => wanted.push((itype.clone(), 1)),
            }
        }
        let (mut created, _, create_s, _) = self.create_instances(&wanted)?;
        // re-stamp the planned zones (create_instances randomizes them)
        for (inst, (_, zone)) in created.iter_mut().zip(&plan.picks) {
            inst.zone = zone.clone();
        }
        let te = Timer::start();
        let jgf = self.encode_jgf(&created);
        let encode_s = te.elapsed_secs();
        // replace the entries create_instances recorded (zones changed)
        for c in &created {
            if let Some(slot) = self.live.iter_mut().find(|l| l.id == c.id) {
                slot.zone = c.zone.clone();
            }
        }
        self.last_phases = Phases {
            map_s,
            create_s,
            encode_s,
        };
        Ok(ExternalGrant {
            subgraph: jgf,
            instance_ids: created.into_iter().map(|i| i.id).collect(),
            creation_s: create_s,
            encode_s,
        })
    }

    /// Create instances and encode them as a JGF subgraph. Returns
    /// (instances, subgraph, creation seconds, encode seconds).
    pub fn create_instances(
        &mut self,
        wanted: &[(InstanceType, u64)],
    ) -> Result<(Vec<Ec2Instance>, Jgf, f64, f64), ProviderError> {
        let names: Vec<&str> = wanted.iter().map(|(t, _)| t.name).collect();
        // `?` BEFORE any instance is recorded: a deadline failure here is
        // atomic by construction
        let create_s = self.simulate_creation(&names)?;
        let mut created = Vec::new();
        for (itype, count) in wanted {
            for _ in 0..*count {
                let zone = self.rng.choice(&self.zones).clone();
                let id = format!("i-{:012x}", self.next_instance);
                self.next_instance += 1;
                created.push(Ec2Instance {
                    id,
                    itype: itype.clone(),
                    zone,
                });
            }
        }
        let t = Timer::start();
        let jgf = self.encode_jgf(&created);
        let encode_s = t.elapsed_secs();
        self.live.extend(created.clone());
        Ok((created, jgf, create_s, encode_s))
    }

    /// Encode instances as a JGF subgraph under `attach_under`, with zone
    /// vertices interposed ("EC2API can interpose an EC2 zone vertex
    /// between the nodes' vertices and the cluster vertex", §4).
    fn encode_jgf(&mut self, instances: &[Ec2Instance]) -> Jgf {
        let mut jgf = Jgf::default();
        let mut zone_ids: Vec<(String, u64)> = Vec::new();
        let base = &self.cfg.attach_under;
        for inst in instances {
            let node_parent = if self.cfg.zone_vertices {
                let zpath = format!("{base}/{}", inst.zone);
                if !zone_ids.iter().any(|(z, _)| *z == inst.zone)
                    && !jgf.nodes.iter().any(|n| n.path == zpath)
                {
                    let zid = self.next_uniq;
                    self.next_uniq += 1;
                    zone_ids.push((inst.zone.clone(), zid));
                    jgf.nodes.push(JgfNode {
                        uniq_id: zid,
                        rtype: ResourceType::Zone,
                        basename: inst.zone.clone(),
                        id: 0,
                        rank: -1,
                        size: 1,
                        unit: String::new(),
                        path: zpath,
                    });
                    // attach edge source: the on-prem cluster root; the
                    // receiver resolves it via the path index
                    jgf.edges.push((u64::MAX, zid));
                }
                format!("{base}/{}", inst.zone)
            } else {
                base.clone()
            };
            let nid = self.next_uniq;
            self.next_uniq += 1;
            let node_path = format!("{node_parent}/{}", inst.id);
            let parent_uid = zone_ids
                .iter()
                .find(|(z, _)| *z == inst.zone)
                .map(|(_, u)| *u)
                .unwrap_or(u64::MAX);
            jgf.nodes.push(JgfNode {
                uniq_id: nid,
                rtype: ResourceType::Node,
                basename: inst.id.clone(),
                id: 0,
                rank: -1,
                size: 1,
                unit: String::new(),
                path: node_path.clone(),
            });
            jgf.edges.push((parent_uid, nid));
            let mut leaf = |rtype: ResourceType, basename: &str, i: u64, unit: &str| {
                let uid = self.next_uniq;
                self.next_uniq += 1;
                jgf.nodes.push(JgfNode {
                    uniq_id: uid,
                    rtype,
                    basename: basename.to_string(),
                    id: i,
                    rank: -1,
                    size: 1,
                    unit: unit.to_string(),
                    path: format!("{node_path}/{basename}{i}"),
                });
                jgf.edges.push((nid, uid));
            };
            for c in 0..inst.itype.vcpus {
                leaf(ResourceType::Core, "core", c, "");
            }
            for m in 0..inst.itype.mem_gib {
                leaf(ResourceType::Memory, "memory", m, "GiB");
            }
            for g in 0..inst.itype.gpus {
                leaf(ResourceType::Gpu, "gpu", g, "");
            }
        }
        jgf
    }
}

/// Extract [vcpus, mem_gib, gpus] demanded per node of a generic request.
fn request_features(req: &ResourceReq) -> [f64; 3] {
    fn count_in(reqs: &[ResourceReq], rtype: &str) -> f64 {
        reqs.iter()
            .map(|r| {
                let own = if r.rtype == rtype { r.count as f64 } else { 0.0 };
                own + r.count as f64 * count_in(&r.with, rtype)
            })
            .sum()
    }
    [
        count_in(&req.with, "core").max(1.0),
        count_in(&req.with, "memory"),
        count_in(&req.with, "gpu"),
    ]
}

impl ExternalProvider for Ec2Provider {
    fn name(&self) -> &str {
        "ec2-sim"
    }

    fn request(&mut self, spec: &JobSpec) -> Result<ExternalGrant, ProviderError> {
        let t = Timer::start();
        let wanted = self.map_request(spec)?;
        let map_s = t.elapsed_secs();
        let (created, jgf, create_s, encode_s) = self.create_instances(&wanted)?;
        self.last_phases = Phases {
            map_s,
            create_s,
            encode_s,
        };
        Ok(ExternalGrant {
            subgraph: jgf,
            instance_ids: created.into_iter().map(|i| i.id).collect(),
            creation_s: create_s,
            encode_s,
        })
    }

    fn release(&mut self, instance_ids: &[String]) -> Result<(), ProviderError> {
        let before = self.live.len();
        self.live.retain(|i| !instance_ids.contains(&i.id));
        if before - self.live.len() != instance_ids.len() {
            return Err(ProviderError::Api("unknown instance id in release".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobspec::ResourceReq;

    fn provider() -> Ec2Provider {
        Ec2Provider::new(Ec2SimConfig {
            time_scale: 1e-4,
            ..Ec2SimConfig::default()
        })
    }

    #[test]
    fn table3_subgraph_sizes() {
        // paper Table 3 "subgraph size" column; our memory-as-GiB-vertices
        // model matches 6 of 8 rows exactly (see EXPERIMENTS.md §E5)
        let expected = [6u64, 8, 14, 22, 42, 82, 50, 298];
        for (t, want) in EC2_CATALOG.iter().zip(expected) {
            assert_eq!(t.subgraph_size(), want, "{}", t.name);
        }
    }

    #[test]
    fn seventy_seven_zones() {
        assert_eq!(availability_zones().len(), 77);
    }

    #[test]
    fn explicit_instance_request() {
        let mut p = provider();
        let spec = JobSpec::new(vec![ResourceReq::new("node", 2)
            .with_attr("instance_type", "t2.medium")]);
        let grant = p.request(&spec).unwrap();
        assert_eq!(grant.instance_ids.len(), 2);
        // 2 × t2.medium (size 14) + zone vertices
        assert!(grant.subgraph.size() >= 28);
        assert!(grant.creation_s > 0.0);
    }

    #[test]
    fn generic_request_picks_cheapest_feasible() {
        let mut p = provider();
        // 2 cpus, 4 GiB -> t2.medium is the cheapest exact fit
        let spec = JobSpec::new(vec![ResourceReq::new("node", 1)
            .with_child(ResourceReq::new("core", 2))
            .with_child(ResourceReq::new("memory", 4))]);
        p.request(&spec).unwrap();
        assert_eq!(p.live_instances()[0].itype.name, "t2.medium");
    }

    #[test]
    fn gpu_request_needs_gpu_type() {
        let mut p = provider();
        let spec = JobSpec::new(vec![ResourceReq::new("node", 1)
            .with_child(ResourceReq::new("core", 4))
            .with_child(ResourceReq::new("gpu", 1))]);
        p.request(&spec).unwrap();
        assert!(p.live_instances()[0].itype.gpus >= 1);
    }

    #[test]
    fn infeasible_request_fails() {
        let mut p = provider();
        let spec = JobSpec::new(vec![ResourceReq::new("node", 1)
            .with_child(ResourceReq::new("core", 512))]);
        assert!(p.request(&spec).is_err());
    }

    #[test]
    fn zone_vertices_interposed() {
        let mut p = provider();
        let spec = JobSpec::new(vec![ResourceReq::new("node", 4)
            .with_attr("instance_type", "t2.micro")]);
        let grant = p.request(&spec).unwrap();
        let zones: Vec<_> = grant
            .subgraph
            .nodes
            .iter()
            .filter(|n| n.rtype == ResourceType::Zone)
            .collect();
        assert!(!zones.is_empty());
        // every node vertex's path passes through a zone component
        for n in &grant.subgraph.nodes {
            if n.rtype == ResourceType::Node {
                assert!(
                    zones.iter().any(|z| n.path.starts_with(&z.path)),
                    "{} not under a zone",
                    n.path
                );
            }
        }
    }

    #[test]
    fn release_removes_instances() {
        let mut p = provider();
        let spec = JobSpec::new(vec![ResourceReq::new("node", 2)
            .with_attr("instance_type", "t2.small")]);
        let grant = p.request(&spec).unwrap();
        p.release(&grant.instance_ids).unwrap();
        assert!(p.live_instances().is_empty());
        assert!(p.release(&grant.instance_ids).is_err());
    }

    #[test]
    fn creation_deadline_fails_atomically() {
        let mut p = Ec2Provider::new(Ec2SimConfig {
            time_scale: 1e-4,
            // any lognormal draw exceeds a zero budget
            request_deadline: Some(Duration::ZERO),
            ..Ec2SimConfig::default()
        });
        let spec = JobSpec::new(vec![ResourceReq::new("node", 2)
            .with_attr("instance_type", "t2.small")]);
        let err = p.request(&spec).unwrap_err();
        assert!(matches!(err, ProviderError::Api(_)), "{err:?}");
        assert!(err.to_string().contains("timeout"), "{err}");
        // atomic failure: nothing was created, nothing to orphan
        assert!(p.live_instances().is_empty());
        // the same provider serves again once the budget allows
        p.cfg.request_deadline = Some(Duration::from_secs(60));
        let grant = p.request(&spec).unwrap();
        assert_eq!(grant.instance_ids.len(), 2);
    }

    #[test]
    fn retrying_provider_recovers_from_transient_api_faults() {
        use crate::fault::{
            Backoff, FaultInjector, FaultRates, FaultyProvider, ProviderFault, RetryPolicy,
            RetryingProvider,
        };
        let inj = FaultInjector::new(3, FaultRates::none());
        inj.push_provider_fault(ProviderFault::Api);
        inj.push_provider_fault(ProviderFault::Api);
        let faulty = FaultyProvider::new(provider(), inj.clone());
        let mut p = RetryingProvider::new(
            faulty,
            RetryPolicy {
                max_attempts: 3,
                backoff: Backoff {
                    base: Duration::from_millis(1),
                    ..Backoff::default()
                },
                ..RetryPolicy::default()
            },
        );
        let spec = JobSpec::new(vec![ResourceReq::new("node", 1)
            .with_attr("instance_type", "t2.micro")]);
        // two injected API failures, third attempt delivers
        let grant = p.request(&spec).unwrap();
        assert_eq!(grant.instance_ids.len(), 1);
        assert_eq!(inj.stats().provider_api, 2);
        // a well-formed "no" is NOT retried: one more scripted fault would
        // have masked it if the retry loop re-rolled on Unsatisfiable
        inj.push_provider_fault(ProviderFault::Unsatisfiable);
        inj.push_provider_fault(ProviderFault::Api);
        let err = p.request(&spec).unwrap_err();
        assert!(matches!(err, ProviderError::Unsatisfiable(_)), "{err:?}");
        assert_eq!(inj.stats().provider_api, 2, "no retry after unsatisfiable");
    }

    #[test]
    fn native_selector_prefers_fit_over_oversize() {
        let mut s = NativeSelector;
        let picks = s.select(
            &[[1.0, 1.0, 0.0]],
            &EC2_CATALOG.map(|t| t.features()),
            &EC2_CATALOG.map(|t| t.price_tenths_cent as f64),
        );
        assert_eq!(EC2_CATALOG[picks[0].unwrap()].name, "t2.micro");
    }
}
