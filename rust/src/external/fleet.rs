//! EC2 Fleet simulation (paper §5.3).
//!
//! "EC2 Fleet enables requests for sets of instance types, including
//! On-Demand and Spot instances. AWS processes the user request
//! specification and returns a set of instances that meet the constraints.
//! In general, the user does not know which instance types will meet the
//! request or their locations, which is readily accommodated by dynamic
//! binding."
//!
//! The simulator generates the full modern catalog (349 types, of which the
//! paper could request 300 at once — the AWS API errors above that; we
//! reproduce the quirk), picks winners by spot-price-like weighting, and
//! spreads them over availability zones.

use crate::external::ec2::{availability_zones, Ec2Instance, InstanceType};
use crate::external::provider::ProviderError;
use crate::util::rng::Rng;

/// Maximum instance types per Fleet request (the AWS quirk the paper hit:
/// "the AWS API returns an error if all 349 are specified").
pub const MAX_FLEET_TYPES: usize = 300;

/// Generate the full instance-type catalog: 349 types across the familiar
/// families/sizes. Names leak into JGF vertex basenames, so they are leaked
/// as `&'static str` once (the catalog is a process-lifetime singleton).
pub fn full_catalog() -> &'static [InstanceType] {
    use std::sync::OnceLock;
    static CATALOG: OnceLock<Vec<InstanceType>> = OnceLock::new();
    CATALOG.get_or_init(build_catalog)
}

fn build_catalog() -> Vec<InstanceType> {
    // (family, base vcpus, GiB per vcpu, gpus per 8 vcpus, base price
    //  tenths-of-cent for the 1-vcpu-equivalent size)
    let families: [(&str, u64, u64, u64, u64); 13] = [
        ("t2", 1, 2, 0, 116),
        ("t3", 1, 2, 0, 104),
        ("m4", 2, 4, 0, 200),
        ("m5", 2, 4, 0, 192),
        ("m6i", 2, 4, 0, 192),
        ("c4", 2, 2, 0, 199),
        ("c5", 2, 2, 0, 170),
        ("c6i", 2, 2, 0, 170),
        ("r4", 2, 8, 0, 266),
        ("r5", 2, 8, 0, 252),
        ("g3", 16, 8, 2, 11400),
        ("g4dn", 4, 4, 1, 5260),
        ("p3", 8, 8, 1, 30600),
    ];
    let sizes: [(&str, u64); 9] = [
        ("nano", 0),     // ×1/4 of base — handled below
        ("micro", 0),    // ×1/2
        ("small", 1),
        ("medium", 2),
        ("large", 4),
        ("xlarge", 8),
        ("2xlarge", 16),
        ("4xlarge", 32),
        ("8xlarge", 64),
    ];
    let mut out = Vec::new();
    for (fam, base_vcpu, gib_per_vcpu, gpus_per8, base_price) in families {
        for (size, mult) in sizes {
            // small families skip the tiny sizes; accelerated families skip
            // sizes below their base
            let vcpus = match size {
                "nano" | "micro" if base_vcpu > 1 => continue,
                "nano" => 1,
                "micro" => 1,
                _ => base_vcpu * mult / 2,
            };
            if vcpus == 0 {
                continue;
            }
            let mem = vcpus * gib_per_vcpu;
            let gpus = if gpus_per8 > 0 {
                (vcpus * gpus_per8).div_ceil(8)
            } else {
                0
            };
            let price = (base_price * vcpus).max(base_price / 2);
            let name: &'static str =
                Box::leak(format!("{fam}.{size}").into_boxed_str());
            out.push(InstanceType {
                name,
                vcpus,
                mem_gib: mem,
                gpus,
                price_tenths_cent: price,
            });
        }
    }
    // pad/trim deterministically to exactly 349 (the paper's figure) with
    // metal variants of the largest families
    let metal_fams = ["m5", "c5", "r5", "m6i", "c6i", "i3", "i3en", "d3", "x1", "x2"];
    let mut i = 0;
    while out.len() < 349 {
        let fam = metal_fams[i % metal_fams.len()];
        let name: &'static str =
            Box::leak(format!("{fam}.metal-{i}").into_boxed_str());
        out.push(InstanceType {
            name,
            vcpus: 96,
            mem_gib: 384,
            gpus: 0,
            price_tenths_cent: 18_000 + 100 * i as u64,
        });
        i += 1;
    }
    out.truncate(349);
    out
}

/// An EC2 Fleet request: N instances drawn from an allowed type set.
#[derive(Debug, Clone)]
pub struct FleetRequest {
    /// How many instances to acquire in total.
    pub total_instances: u64,
    /// Names of allowed instance types; empty = "any" (capped to
    /// [`MAX_FLEET_TYPES`], as the paper did with 300).
    pub allowed_types: Vec<String>,
    /// On-demand (vs. spot) capacity.
    pub on_demand: bool,
    /// Minimum distinct availability zones to spread across (the kind of
    /// global constraint the paper notes LSF likely cannot enforce).
    pub min_zones: usize,
}

impl FleetRequest {
    /// A request for `total` instances with no type/zone constraints.
    pub fn any(total: u64) -> FleetRequest {
        FleetRequest {
            total_instances: total,
            allowed_types: Vec::new(),
            on_demand: true,
            min_zones: 1,
        }
    }
}

/// Outcome of a fleet placement decision (before instance creation).
#[derive(Debug, Clone)]
pub struct FleetPlan {
    /// Chosen `(instance type, availability zone)` pairs, one per instance.
    pub picks: Vec<(InstanceType, String)>,
}

/// Decide which instances a Fleet request yields. Deterministic given the
/// rng state: spot-market preference = cheaper types win more slots, spread
/// round-robin over zones.
pub fn plan_fleet(req: &FleetRequest, rng: &mut Rng) -> Result<FleetPlan, ProviderError> {
    let catalog = full_catalog();
    let allowed: Vec<&InstanceType> = if req.allowed_types.is_empty() {
        catalog.iter().take(MAX_FLEET_TYPES).collect()
    } else {
        if req.allowed_types.len() > MAX_FLEET_TYPES {
            // the AWS quirk the paper reports for all-349 requests
            return Err(ProviderError::Api(format!(
                "InvalidParameterValue: fleet request specifies {} instance types; \
                 maximum is {MAX_FLEET_TYPES}",
                req.allowed_types.len()
            )));
        }
        let picks: Vec<&InstanceType> = catalog
            .iter()
            .filter(|t| req.allowed_types.iter().any(|n| n == t.name))
            .collect();
        if picks.is_empty() {
            return Err(ProviderError::Unsatisfiable(
                "no allowed instance types exist".into(),
            ));
        }
        picks
    };
    if req.total_instances == 0 {
        return Err(ProviderError::Api("fleet of zero instances".into()));
    }
    // cheaper types are likelier winners (spot-market shape): weight
    // inversely proportional to price
    let weights: Vec<f64> = allowed
        .iter()
        .map(|t| 1.0 / (t.price_tenths_cent as f64))
        .collect();
    let total_w: f64 = weights.iter().sum();
    let zones = availability_zones();
    let zone_pool: Vec<String> = {
        let mut zs = zones.clone();
        rng.shuffle(&mut zs);
        zs.truncate(req.min_zones.max(1).min(zs.len()));
        zs
    };
    let mut picks = Vec::new();
    for i in 0..req.total_instances {
        let mut roll = rng.f64() * total_w;
        let mut chosen = allowed.len() - 1;
        for (j, w) in weights.iter().enumerate() {
            if roll < *w {
                chosen = j;
                break;
            }
            roll -= w;
        }
        let zone = zone_pool[i as usize % zone_pool.len()].clone();
        picks.push(((*allowed[chosen]).clone(), zone));
    }
    Ok(FleetPlan { picks })
}

/// Materialize a plan into instances (ids assigned by the caller's
/// provider; this helper is for tests and standalone planning).
pub fn plan_to_instances(plan: &FleetPlan, next_id: &mut u64) -> Vec<Ec2Instance> {
    plan.picks
        .iter()
        .map(|(itype, zone)| {
            let id = format!("i-{:012x}", *next_id);
            *next_id += 1;
            Ec2Instance {
                id,
                itype: itype.clone(),
                zone: zone.clone(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_has_349_types() {
        let c = full_catalog();
        assert_eq!(c.len(), 349);
        // all names unique
        let mut names: Vec<&str> = c.iter().map(|t| t.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 349);
    }

    #[test]
    fn plan_any_returns_requested_count() {
        let mut rng = Rng::new(1);
        let plan = plan_fleet(&FleetRequest::any(10), &mut rng).unwrap();
        assert_eq!(plan.picks.len(), 10);
    }

    #[test]
    fn too_many_types_errors_like_aws() {
        let mut rng = Rng::new(2);
        let req = FleetRequest {
            total_instances: 1,
            allowed_types: full_catalog().iter().map(|t| t.name.to_string()).collect(),
            on_demand: true,
            min_zones: 1,
        };
        let err = plan_fleet(&req, &mut rng).unwrap_err();
        assert!(err.to_string().contains("349"));
    }

    #[test]
    fn cheap_types_dominate() {
        let mut rng = Rng::new(3);
        let plan = plan_fleet(&FleetRequest::any(200), &mut rng).unwrap();
        let mean_price: f64 = plan
            .picks
            .iter()
            .map(|(t, _)| t.price_tenths_cent as f64)
            .sum::<f64>()
            / 200.0;
        let catalog_mean: f64 = full_catalog()
            .iter()
            .take(MAX_FLEET_TYPES)
            .map(|t| t.price_tenths_cent as f64)
            .sum::<f64>()
            / MAX_FLEET_TYPES as f64;
        assert!(
            mean_price < catalog_mean / 2.0,
            "spot weighting should favor cheap types: {mean_price} vs {catalog_mean}"
        );
    }

    #[test]
    fn zone_spread_honored() {
        let mut rng = Rng::new(4);
        let req = FleetRequest {
            total_instances: 12,
            allowed_types: vec!["t2.micro".into()],
            on_demand: false,
            min_zones: 3,
        };
        let plan = plan_fleet(&req, &mut rng).unwrap();
        let mut zones: Vec<&str> = plan.picks.iter().map(|(_, z)| z.as_str()).collect();
        zones.sort();
        zones.dedup();
        assert_eq!(zones.len(), 3);
    }

    #[test]
    fn restricted_types_respected() {
        let mut rng = Rng::new(5);
        let req = FleetRequest {
            total_instances: 8,
            allowed_types: vec!["g3.xlarge".into(), "g3.2xlarge".into()],
            on_demand: true,
            min_zones: 1,
        };
        let plan = plan_fleet(&req, &mut rng).unwrap();
        for (t, _) in &plan.picks {
            assert!(t.name.starts_with("g3."), "{}", t.name);
        }
    }

    #[test]
    fn instances_get_unique_ids() {
        let mut rng = Rng::new(6);
        let plan = plan_fleet(&FleetRequest::any(5), &mut rng).unwrap();
        let mut next = 0;
        let insts = plan_to_instances(&plan, &mut next);
        let mut ids: Vec<&str> = insts.iter().map(|i| i.id.as_str()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 5);
    }
}
