//! The External API abstraction (paper §3): "translate additive or
//! subtractive transformations from the hierarchical scheduler into external
//! resource provider functions ... To a scheduler instance, the external
//! resource provider is functionally just another parent in the hierarchical
//! scheduling."

use crate::jobspec::JobSpec;
use crate::resource::jgf::Jgf;

/// Outcome of an external resource request.
#[derive(Debug, Clone)]
pub struct ExternalGrant {
    /// The provider-selected resources as a JGF subgraph ready to splice
    /// into the requester's graph.
    pub subgraph: Jgf,
    /// Provider-side instance handles (for later release).
    pub instance_ids: Vec<String>,
    /// Seconds the provider took to create the resources (the dominant cost
    /// in §5.3's measurements).
    pub creation_s: f64,
    /// Seconds spent translating the provider response into JGF (the
    /// ~1.6% overhead the paper reports).
    pub encode_s: f64,
}

/// Why an external-provider request failed.
#[derive(Debug)]
pub enum ProviderError {
    /// The provider cannot satisfy the request (a well-formed "no").
    Unsatisfiable(String),
    /// The provider's API itself failed.
    Api(String),
}

impl ProviderError {
    /// Stable machine-readable code from the typed protocol's error
    /// vocabulary ([`crate::rpc::proto::code`]): provider failures
    /// surfacing through a hierarchy level travel as `provider_*` codes so
    /// callers can tell "the cloud said no" from a local `no_match`.
    pub fn code(&self) -> &'static str {
        match self {
            ProviderError::Unsatisfiable(_) => crate::rpc::proto::code::PROVIDER_UNSATISFIABLE,
            ProviderError::Api(_) => crate::rpc::proto::code::PROVIDER_API,
        }
    }
}

impl std::fmt::Display for ProviderError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProviderError::Unsatisfiable(s) => {
                write!(f, "provider cannot satisfy request: {s}")
            }
            ProviderError::Api(s) => write!(f, "provider API error: {s}"),
        }
    }
}

impl std::error::Error for ProviderError {}

/// An external resource provider. Implementations: [`crate::external::ec2`]
/// (simulated AWS EC2 + EC2 Fleet).
pub trait ExternalProvider: Send {
    /// Human-readable provider name (for reports and errors).
    fn name(&self) -> &str;

    /// Translate a jobspec into provider calls, create the resources, and
    /// return them as a subgraph (the `ExternalAPI(jobSpec)` step in
    /// Algorithm 1).
    fn request(&mut self, spec: &JobSpec) -> Result<ExternalGrant, ProviderError>;

    /// Release previously created instances (subtractive transformation).
    fn release(&mut self, instance_ids: &[String]) -> Result<(), ProviderError>;
}
