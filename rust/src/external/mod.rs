//! External resource providers (paper §3 "External API", §4 "EC2API").
//!
//! The provider abstraction lets the top-level scheduler burst to cloud
//! resources; the EC2 simulator reproduces the paper's §5.3 experiments
//! (instance catalog, creation-latency model, Fleet requests, availability
//! zones) without AWS credentials — see DESIGN.md "Substitutions".

pub mod ec2;
pub mod fleet;
pub mod provider;

pub use ec2::{Ec2Provider, Ec2SimConfig, InstanceType, EC2_CATALOG};
pub use provider::{ExternalGrant, ExternalProvider, ProviderError};
