//! PJRT runtime: load and execute the AOT-compiled XLA artifacts from the
//! rust coordinator — the L3↔L2 bridge, with Python never on the request
//! path.
//!
//! Artifacts are HLO **text** (`artifacts/*.hlo.txt`, produced by
//! `python/compile/aot.py`); text is the interchange format because the
//! image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id serialized
//! protos. Each artifact is compiled once per process on a shared PJRT CPU
//! client and then executed with concrete literals.
//!
//! The PJRT path needs the vendored `xla` crate, which is not part of the
//! offline-clean default build. It is gated behind
//! `--cfg xla_runtime` (see Cargo.toml); without it this module compiles a stub whose
//! [`artifacts_available`] is always false, so every XLA consumer
//! (perfmodel, scorer, linreg) takes its rust-native fallback and the
//! corresponding tests skip — identical behavior to running without built
//! artifacts.

pub mod linreg;
pub mod scorer;
pub mod service;

use std::fmt;
use std::path::PathBuf;

pub use service::{OutBuf, TensorF32, XlaHandle};

/// Why loading or executing an AOT artifact failed.
#[derive(Debug)]
pub enum RuntimeError {
    /// No artifact file at the given path (run `make artifacts`).
    MissingArtifact(PathBuf),
    /// PJRT/XLA reported an error (or the build lacks `--cfg xla_runtime`).
    Xla(String),
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RuntimeError::MissingArtifact(p) => {
                write!(f, "artifact not found: {} (run `make artifacts`)", p.display())
            }
            RuntimeError::Xla(s) => write!(f, "xla error: {s}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

/// Default artifacts directory: `$REPRO_ARTIFACTS`, else `artifacts/`
/// relative to the crate root (works from `cargo test`/`cargo bench`), else
/// the current directory.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("REPRO_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if manifest.exists() {
        return manifest;
    }
    PathBuf::from("artifacts")
}

/// Whether the AOT artifacts can be executed (tests skip XLA paths
/// gracefully when not). Requires both the `--cfg xla_runtime` build and the
/// artifact files on disk.
pub fn artifacts_available() -> bool {
    cfg!(xla_runtime)
        && artifacts_dir().join("fleet_select.hlo.txt").exists()
        && artifacts_dir().join("linreg_fit.hlo.txt").exists()
        && artifacts_dir().join("linreg_predict.hlo.txt").exists()
}

#[cfg(xla_runtime)]
mod backend {
    //! The real PJRT backend (vendored `xla` crate).

    use std::cell::RefCell;
    use std::path::Path;

    use super::{artifacts_dir, RuntimeError};
    use crate::runtime::service::{OutBuf, TensorF32};

    impl From<xla::Error> for RuntimeError {
        fn from(e: xla::Error) -> RuntimeError {
            RuntimeError::Xla(e.to_string())
        }
    }

    thread_local! {
        /// Per-thread PJRT CPU client: the xla crate's client holds `Rc`s
        /// and cannot cross threads. In practice only the `service` thread
        /// creates one; tests that use [`Artifact`] directly get their own.
        static CLIENT: RefCell<Option<xla::PjRtClient>> = const { RefCell::new(None) };
    }

    /// Run `f` with this thread's PJRT client (created on first use).
    fn with_client<T>(
        f: impl FnOnce(&xla::PjRtClient) -> Result<T, RuntimeError>,
    ) -> Result<T, RuntimeError> {
        CLIENT.with(|slot| {
            let mut slot = slot.borrow_mut();
            if slot.is_none() {
                *slot = Some(xla::PjRtClient::cpu()?);
            }
            f(slot.as_ref().expect("just initialized"))
        })
    }

    /// A compiled artifact: HLO text loaded, compiled once, executed many
    /// times.
    pub struct Artifact {
        exe: xla::PjRtLoadedExecutable,
        /// Artifact basename, e.g. `fleet_select`.
        pub name: String,
    }

    impl Artifact {
        /// Load `<name>.hlo.txt` from the artifacts directory.
        pub fn load(name: &str) -> Result<Artifact, RuntimeError> {
            Self::load_from(&artifacts_dir().join(format!("{name}.hlo.txt")), name)
        }

        /// Load and compile HLO text from an explicit path.
        pub fn load_from(path: &Path, name: &str) -> Result<Artifact, RuntimeError> {
            if !path.exists() {
                return Err(RuntimeError::MissingArtifact(path.to_path_buf()));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().expect("utf-8 artifact path"),
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = with_client(|c| Ok(c.compile(&comp)?))?;
            Ok(Artifact {
                exe,
                name: name.to_string(),
            })
        }

        /// Execute with input literals; returns the flattened outputs of the
        /// single result tuple (aot.py lowers with `return_tuple=True`).
        pub fn execute(
            &self,
            inputs: &[xla::Literal],
        ) -> Result<Vec<xla::Literal>, RuntimeError> {
            let result = self.exe.execute::<xla::Literal>(inputs)?;
            let lit = result[0][0].to_literal_sync()?;
            Ok(lit.to_tuple()?)
        }

        /// Execute with tensor inputs, decoding outputs into plain buffers
        /// (the service-boundary form).
        pub fn execute_decoded(
            &self,
            inputs: &[TensorF32],
        ) -> Result<Vec<OutBuf>, RuntimeError> {
            let mut literals = Vec::with_capacity(inputs.len());
            for t in inputs {
                literals.push(literal_f32(&t.data, &t.dims)?);
            }
            let outs = self.execute(&literals)?;
            let mut decoded = Vec::with_capacity(outs.len());
            for lit in outs {
                let ty = lit.ty()?;
                let buf = match ty {
                    xla::ElementType::S32 => OutBuf::I32(lit.to_vec::<i32>()?),
                    xla::ElementType::Pred => OutBuf::I32(
                        lit.convert(xla::PrimitiveType::S32)?.to_vec::<i32>()?,
                    ),
                    _ => OutBuf::F32(lit.to_vec::<f32>()?),
                };
                decoded.push(buf);
            }
            Ok(decoded)
        }
    }

    /// Build an f32 literal of the given shape from row-major data.
    pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal, RuntimeError> {
        let n: i64 = dims.iter().product();
        assert_eq!(n as usize, data.len(), "literal shape mismatch");
        if dims.len() == 1 {
            Ok(xla::Literal::vec1(data))
        } else {
            Ok(xla::Literal::vec1(data).reshape(dims)?)
        }
    }
}

#[cfg(not(xla_runtime))]
mod backend {
    //! Offline stub: reports missing artifacts (or a disabled runtime when
    //! the files exist but the build lacks `--cfg xla_runtime`). Never
    //! executes anything.

    use std::path::Path;

    use super::{artifacts_dir, RuntimeError};
    use crate::runtime::service::{OutBuf, TensorF32};

    /// Stub artifact handle (the `--cfg xla_runtime` build has the real
    /// one); loading always fails, so no instance can exist.
    pub struct Artifact {
        /// Artifact basename, e.g. `fleet_select`.
        pub name: String,
    }

    impl Artifact {
        /// Load `<name>.hlo.txt` from the artifacts directory (always an
        /// error in the stub build).
        pub fn load(name: &str) -> Result<Artifact, RuntimeError> {
            Self::load_from(&artifacts_dir().join(format!("{name}.hlo.txt")), name)
        }

        /// Load from an explicit path (always an error in the stub build).
        pub fn load_from(path: &Path, _name: &str) -> Result<Artifact, RuntimeError> {
            if !path.exists() {
                return Err(RuntimeError::MissingArtifact(path.to_path_buf()));
            }
            Err(RuntimeError::Xla(
                "built without `--cfg xla_runtime`".to_string(),
            ))
        }

        /// Unreachable in practice (no stub `Artifact` can be built).
        pub fn execute_decoded(
            &self,
            _inputs: &[TensorF32],
        ) -> Result<Vec<OutBuf>, RuntimeError> {
            Err(RuntimeError::Xla(
                "built without `--cfg xla_runtime`".to_string(),
            ))
        }
    }
}

pub use backend::Artifact;
#[cfg(xla_runtime)]
pub use backend::literal_f32;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_resolves() {
        let d = artifacts_dir();
        assert!(d.ends_with("artifacts"));
    }

    #[test]
    fn missing_artifact_is_reported() {
        match Artifact::load("definitely_not_there") {
            Err(RuntimeError::MissingArtifact(_)) => {}
            Err(e) => panic!("wrong error: {e}"),
            Ok(_) => panic!("load of missing artifact succeeded"),
        }
    }

    #[cfg(xla_runtime)]
    #[test]
    fn literal_shape_checked() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(lit.element_count(), 4);
    }

    #[cfg(xla_runtime)]
    #[test]
    fn execute_fleet_select_roundtrip() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let art = Artifact::load("fleet_select").unwrap();
        // B=8 requests, N=512 candidates: request 0 wants 2 cpus; candidate
        // 3 offers exactly [2,0,0] at the lowest price
        let mut req = vec![0f32; 8 * 3];
        req[0] = 2.0;
        let mut cand = vec![0f32; 512 * 3];
        let mut price = vec![1000f32; 512];
        cand[3 * 3] = 2.0;
        price[3] = 1.0;
        // all other candidates are infeasible for request 0 (0 cpus < 2)
        let out = art
            .execute(&[
                literal_f32(&req, &[8, 3]).unwrap(),
                literal_f32(&cand, &[512, 3]).unwrap(),
                literal_f32(&price, &[512]).unwrap(),
            ])
            .unwrap();
        assert_eq!(out.len(), 3);
        let best = out[1].to_vec::<i32>().unwrap();
        let feas = out[2].to_vec::<i32>().unwrap();
        assert_eq!(best[0], 3);
        assert_eq!(feas[0], 1);
    }
}
