//! XLA-backed linear regression: the AOT `linreg_fit` / `linreg_predict`
//! artifacts (normal-equations Pallas kernel) used to fit the paper's §6
//! component models from rust. The rust-native `stats::ols` is the oracle
//! these are tested against.

use crate::runtime::{RuntimeError, TensorF32, XlaHandle};
use crate::util::stats::LinFit;

/// AOT sample capacity (python/compile/kernels/linreg.py NSAMP).
pub const NSAMP: usize = 1024;

/// OLS fit/predict backed by the AOT `linreg_*` artifacts.
pub struct XlaLinReg {
    handle: &'static XlaHandle,
}

impl XlaLinReg {
    /// Connect to the XLA service and verify both artifacts execute.
    pub fn load() -> Result<XlaLinReg, RuntimeError> {
        let handle = XlaHandle::global();
        // probe both artifacts so missing files fail here, not mid-fit
        handle.execute(
            "linreg_predict",
            vec![
                TensorF32::new(vec![0.0; NSAMP], vec![NSAMP as i64]),
                TensorF32::new(vec![0.0, 0.0], vec![2]),
            ],
        )?;
        handle.execute(
            "linreg_fit",
            vec![
                TensorF32::new(vec![0.0; NSAMP], vec![NSAMP as i64]),
                TensorF32::new(vec![0.0; NSAMP], vec![NSAMP as i64]),
                TensorF32::new(vec![0.0; NSAMP], vec![NSAMP as i64]),
            ],
        )?;
        Ok(XlaLinReg { handle })
    }

    /// Weighted-OLS fit of `y = beta x + beta0`. Samples beyond NSAMP are
    /// rejected; fewer are zero-weight padded (padding rows are inert — a
    /// property the python tests pin).
    pub fn fit(&self, xs: &[f64], ys: &[f64]) -> Result<LinFit, RuntimeError> {
        assert_eq!(xs.len(), ys.len());
        assert!(
            xs.len() <= NSAMP,
            "sample count {} exceeds AOT capacity {NSAMP}",
            xs.len()
        );
        let mut x = vec![0f32; NSAMP];
        let mut y = vec![0f32; NSAMP];
        let mut w = vec![0f32; NSAMP];
        for (i, (&xi, &yi)) in xs.iter().zip(ys).enumerate() {
            x[i] = xi as f32;
            y[i] = yi as f32;
            w[i] = 1.0;
        }
        let out = self.handle.execute(
            "linreg_fit",
            vec![
                TensorF32::new(x, vec![NSAMP as i64]),
                TensorF32::new(y, vec![NSAMP as i64]),
                TensorF32::new(w, vec![NSAMP as i64]),
            ],
        )?;
        let beta = out[0]
            .as_f32()
            .ok_or_else(|| RuntimeError::Xla("beta not f32".into()))?;
        Ok(LinFit {
            beta0: beta[0] as f64,
            beta: beta[1] as f64,
        })
    }

    /// Evaluate a fitted model over up to NSAMP points.
    pub fn predict(&self, xs: &[f64], fit: &LinFit) -> Result<Vec<f64>, RuntimeError> {
        assert!(xs.len() <= NSAMP);
        let mut x = vec![0f32; NSAMP];
        for (i, &xi) in xs.iter().enumerate() {
            x[i] = xi as f32;
        }
        let beta = vec![fit.beta0 as f32, fit.beta as f32];
        let out = self.handle.execute(
            "linreg_predict",
            vec![
                TensorF32::new(x, vec![NSAMP as i64]),
                TensorF32::new(beta, vec![2]),
            ],
        )?;
        let ys = out[0]
            .as_f32()
            .ok_or_else(|| RuntimeError::Xla("prediction not f32".into()))?;
        Ok(ys.iter().take(xs.len()).map(|&v| v as f64).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::ols;

    #[test]
    fn xla_fit_matches_native_ols() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let reg = XlaLinReg::load().unwrap();
        let mut rng = Rng::new(7);
        // the §6 scale: x = subgraph sizes (tens..thousands), y = seconds
        let xs: Vec<f64> = (0..200).map(|_| rng.uniform(30.0, 4500.0)).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|&x| 9.08e-6 * x + 6.3e-4 + rng.normal(0.0, 1e-5))
            .collect();
        let got = reg.fit(&xs, &ys).unwrap();
        let want = ols(&xs, &ys);
        assert!(
            (got.beta - want.beta).abs() / want.beta < 1e-2,
            "beta {} vs {}",
            got.beta,
            want.beta
        );
        assert!(
            (got.beta0 - want.beta0).abs() < 1e-4,
            "beta0 {} vs {}",
            got.beta0,
            want.beta0
        );
    }

    #[test]
    fn xla_predict_is_linear() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let reg = XlaLinReg::load().unwrap();
        let fit = LinFit {
            beta: 2.0,
            beta0: 1.0,
        };
        let ys = reg.predict(&[0.0, 1.0, 10.0], &fit).unwrap();
        assert_eq!(ys.len(), 3);
        assert!((ys[0] - 1.0).abs() < 1e-6);
        assert!((ys[1] - 3.0).abs() < 1e-6);
        assert!((ys[2] - 21.0).abs() < 1e-6);
    }

    #[test]
    fn exact_line_recovered() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let reg = XlaLinReg::load().unwrap();
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 3.5 * x - 2.0).collect();
        let fit = reg.fit(&xs, &ys).unwrap();
        assert!((fit.beta - 3.5).abs() < 1e-3);
        assert!((fit.beta0 + 2.0).abs() < 1e-2);
    }
}
