//! XLA execution service: a dedicated thread owning the (non-`Send`) PJRT
//! client and compiled artifacts, driven through channels by `Send` handles.
//!
//! The xla crate's client/executable types hold `Rc`s, so they cannot cross
//! threads; the coordinator instead runs one XLA service thread per process
//! ("one compiled executable per model variant", compiled once at first
//! use) and the scheduler threads submit execution jobs.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Mutex, OnceLock};

use crate::runtime::{Artifact, RuntimeError};

/// One tensor crossing the service boundary.
#[derive(Debug, Clone)]
pub struct TensorF32 {
    /// Row-major element data (`dims.iter().product()` values).
    pub data: Vec<f32>,
    /// Tensor shape.
    pub dims: Vec<i64>,
}

impl TensorF32 {
    /// Build a tensor from row-major data and a shape.
    pub fn new(data: Vec<f32>, dims: Vec<i64>) -> TensorF32 {
        TensorF32 { data, dims }
    }
}

/// A decoded output buffer.
#[derive(Debug, Clone)]
pub enum OutBuf {
    /// 32-bit float output.
    F32(Vec<f32>),
    /// 32-bit integer output (also carries decoded predicates).
    I32(Vec<i32>),
}

impl OutBuf {
    /// The f32 payload, if this is an [`OutBuf::F32`].
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            OutBuf::F32(v) => Some(v),
            _ => None,
        }
    }

    /// The i32 payload, if this is an [`OutBuf::I32`].
    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            OutBuf::I32(v) => Some(v),
            _ => None,
        }
    }
}

struct Job {
    artifact: String,
    inputs: Vec<TensorF32>,
    reply: Sender<Result<Vec<OutBuf>, RuntimeError>>,
}

/// `Send` handle to the service thread.
pub struct XlaHandle {
    tx: Mutex<Sender<Job>>,
}

static SERVICE: OnceLock<XlaHandle> = OnceLock::new();

impl XlaHandle {
    /// The process-wide service (spawned on first use).
    pub fn global() -> &'static XlaHandle {
        SERVICE.get_or_init(|| {
            let (tx, rx) = channel::<Job>();
            std::thread::Builder::new()
                .name("xla-service".into())
                .spawn(move || {
                    let mut artifacts: HashMap<String, Artifact> = HashMap::new();
                    while let Ok(job) = rx.recv() {
                        let result = run_job(&mut artifacts, &job);
                        let _ = job.reply.send(result);
                    }
                })
                .expect("spawn xla service");
            XlaHandle { tx: Mutex::new(tx) }
        })
    }

    /// Execute `artifact` (loaded + compiled on first use) with the given
    /// inputs; blocks until the service replies.
    pub fn execute(
        &self,
        artifact: &str,
        inputs: Vec<TensorF32>,
    ) -> Result<Vec<OutBuf>, RuntimeError> {
        let (reply_tx, reply_rx) = channel();
        {
            let tx = self.tx.lock().expect("service sender poisoned");
            tx.send(Job {
                artifact: artifact.to_string(),
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| RuntimeError::Xla("xla service thread died".into()))?;
        }
        reply_rx
            .recv()
            .map_err(|_| RuntimeError::Xla("xla service dropped reply".into()))?
    }
}

fn run_job(
    artifacts: &mut HashMap<String, Artifact>,
    job: &Job,
) -> Result<Vec<OutBuf>, RuntimeError> {
    if !artifacts.contains_key(&job.artifact) {
        let art = Artifact::load(&job.artifact)?;
        artifacts.insert(job.artifact.clone(), art);
    }
    let art = artifacts.get(&job.artifact).expect("just inserted");
    // literal construction + output decoding live with the backend so the
    // service stays xla-type-free (and compiles in the stub build)
    art.execute_decoded(&job.inputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_reports_missing_artifact() {
        let err = XlaHandle::global()
            .execute("no_such_artifact", vec![])
            .unwrap_err();
        assert!(err.to_string().contains("artifact not found"));
    }

    #[test]
    fn service_survives_errors_and_recovers() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let h = XlaHandle::global();
        // first a failing job...
        assert!(h.execute("nope", vec![]).is_err());
        // ...then a good one on the same thread
        let out = h
            .execute(
                "linreg_predict",
                vec![
                    TensorF32::new(vec![0.0; 1024], vec![1024]),
                    TensorF32::new(vec![5.0, 2.0], vec![2]),
                ],
            )
            .unwrap();
        let ys = out[0].as_f32().unwrap();
        assert!((ys[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn handle_usable_from_many_threads() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let threads: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let out = XlaHandle::global()
                        .execute(
                            "linreg_predict",
                            vec![
                                TensorF32::new(vec![i as f32; 1024], vec![1024]),
                                TensorF32::new(vec![1.0, 2.0], vec![2]),
                            ],
                        )
                        .unwrap();
                    out[0].as_f32().unwrap()[0]
                })
            })
            .collect();
        for (i, t) in threads.into_iter().enumerate() {
            let v = t.join().unwrap();
            assert!((v - (1.0 + 2.0 * i as f32)).abs() < 1e-6);
        }
    }
}
