//! XLA-backed fleet instance selector: the AOT `fleet_select` artifact
//! (L2 jax + L1 Pallas scoring kernel) driven from the coordinator's EC2
//! decision path. Drop-in [`InstanceSelector`] replacement for the
//! rust-native reference — tests assert the two agree.

use crate::external::ec2::InstanceSelector;
use crate::runtime::{RuntimeError, TensorF32, XlaHandle};

/// AOT shapes, fixed at lowering time (python/compile/kernels constants).
pub const BATCH: usize = 8;
/// Candidate-catalog capacity of the AOT kernel.
pub const NCAND: usize = 512;
/// Features per request/candidate: `[vcpus, mem_gib, gpus]`.
pub const FEATS: usize = 3;

/// [`InstanceSelector`] backed by the AOT `fleet_select` artifact.
pub struct XlaSelector {
    handle: &'static XlaHandle,
}

impl XlaSelector {
    /// Connect to the XLA service and verify the artifact executes.
    pub fn load() -> Result<XlaSelector, RuntimeError> {
        let handle = XlaHandle::global();
        // fail fast if the artifact is absent: probe with a zero batch
        handle.execute(
            "fleet_select",
            vec![
                TensorF32::new(vec![0.0; BATCH * FEATS], vec![BATCH as i64, FEATS as i64]),
                TensorF32::new(vec![0.0; NCAND * FEATS], vec![NCAND as i64, FEATS as i64]),
                TensorF32::new(vec![1.0; NCAND], vec![NCAND as i64]),
            ],
        )?;
        Ok(XlaSelector { handle })
    }

    /// Score one padded batch; returns (best index, feasible) per row.
    fn run_batch(
        &self,
        req: &[f32],   // BATCH*FEATS
        cand: &[f32],  // NCAND*FEATS
        price: &[f32], // NCAND
    ) -> Result<Vec<(i32, bool)>, RuntimeError> {
        let out = self.handle.execute(
            "fleet_select",
            vec![
                TensorF32::new(req.to_vec(), vec![BATCH as i64, FEATS as i64]),
                TensorF32::new(cand.to_vec(), vec![NCAND as i64, FEATS as i64]),
                TensorF32::new(price.to_vec(), vec![NCAND as i64]),
            ],
        )?;
        let best = out[1]
            .as_i32()
            .ok_or_else(|| RuntimeError::Xla("best idx not i32".into()))?;
        let feas = out[2]
            .as_i32()
            .ok_or_else(|| RuntimeError::Xla("feasible not i32".into()))?;
        Ok(best
            .iter()
            .zip(feas)
            .map(|(&b, &f)| (b, f != 0))
            .collect())
    }
}

impl InstanceSelector for XlaSelector {
    fn select(
        &mut self,
        requests: &[[f64; 3]],
        candidates: &[[f64; 3]],
        prices: &[f64],
    ) -> Vec<Option<usize>> {
        assert!(
            candidates.len() <= NCAND,
            "catalog exceeds AOT candidate capacity"
        );
        // pad candidates with all-zero rows at max price: a zero row is
        // infeasible for any request demanding >0 of some feature, and its
        // high price keeps it from winning for zero-demand requests
        let mut cand = vec![0f32; NCAND * FEATS];
        let mut price = vec![f32::MAX / 2.0; NCAND];
        let max_price = prices.iter().cloned().fold(1.0, f64::max) as f32;
        for (i, c) in candidates.iter().enumerate() {
            for f in 0..FEATS {
                cand[i * FEATS + f] = c[f] as f32;
            }
            price[i] = prices[i] as f32;
        }
        for p in price.iter_mut().skip(candidates.len()) {
            *p = max_price * 1.0e6; // never selected over a real candidate
        }
        let mut out = Vec::with_capacity(requests.len());
        for chunk in requests.chunks(BATCH) {
            let mut req = vec![0f32; BATCH * FEATS];
            for (i, r) in chunk.iter().enumerate() {
                for f in 0..FEATS {
                    req[i * FEATS + f] = r[f] as f32;
                }
            }
            match self.run_batch(&req, &cand, &price) {
                Ok(rows) => {
                    for (i, (best, feas)) in rows.into_iter().enumerate().take(chunk.len()) {
                        let idx = best as usize;
                        // guard: a padding candidate can only win when the
                        // request was itself padding — treat as infeasible
                        if feas && idx < candidates.len() {
                            out.push(Some(idx));
                        } else {
                            out.push(None);
                        }
                        let _ = i;
                    }
                }
                Err(e) => {
                    // fail closed: no selection rather than a wrong one
                    eprintln!("XlaSelector: execution failed: {e}");
                    out.extend(std::iter::repeat(None).take(chunk.len()));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::external::ec2::{InstanceSelector, NativeSelector, EC2_CATALOG};
    use crate::util::rng::Rng;

    fn catalog_inputs() -> (Vec<[f64; 3]>, Vec<f64>) {
        (
            EC2_CATALOG.iter().map(|t| t.features()).collect(),
            EC2_CATALOG
                .iter()
                .map(|t| t.price_tenths_cent as f64)
                .collect(),
        )
    }

    #[test]
    fn xla_agrees_with_native_on_catalog() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let (cands, prices) = catalog_inputs();
        let mut rng = Rng::new(42);
        let requests: Vec<[f64; 3]> = (0..24)
            .map(|_| {
                [
                    rng.range(1, 16) as f64,
                    rng.range(1, 64) as f64,
                    if rng.bool_with(0.3) {
                        rng.range(1, 4) as f64
                    } else {
                        0.0
                    },
                ]
            })
            .collect();
        let mut xla = XlaSelector::load().unwrap();
        let mut native = NativeSelector;
        let got = xla.select(&requests, &cands, &prices);
        let want = native.select(&requests, &cands, &prices);
        assert_eq!(got, want);
    }

    #[test]
    fn infeasible_requests_yield_none() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let (cands, prices) = catalog_inputs();
        let mut xla = XlaSelector::load().unwrap();
        let got = xla.select(&[[4096.0, 0.0, 0.0]], &cands, &prices);
        assert_eq!(got, vec![None]);
    }

    #[test]
    fn full_fleet_catalog_fits() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let catalog = crate::external::fleet::full_catalog();
        let cands: Vec<[f64; 3]> = catalog.iter().map(|t| t.features()).collect();
        let prices: Vec<f64> = catalog
            .iter()
            .map(|t| t.price_tenths_cent as f64)
            .collect();
        let mut xla = XlaSelector::load().unwrap();
        let mut native = NativeSelector;
        let requests = vec![[2.0, 4.0, 0.0], [16.0, 64.0, 2.0]];
        assert_eq!(
            xla.select(&requests, &cands, &prices),
            native.select(&requests, &cands, &prices)
        );
    }
}
