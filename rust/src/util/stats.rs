//! Statistics substrate: summaries, quantiles, OLS regression, MAPE/R²,
//! k-fold cross-validation.
//!
//! This backs the paper's §6 analysis (Table 4, Table 5, Figs 3/4): linear
//! component models for comms / add-update / match times, validated with
//! five-fold CV and reported as MAPE and R². The normal-equations fit also
//! has an XLA-artifact path (see `runtime::linreg`); this module is the
//! rust-native oracle the artifact is tested against.

/// Five-number-style summary of a sample (used for the boxplot figures).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n−1 denominator).
    pub std: f64,
    /// Smallest observation.
    pub min: f64,
    /// First quartile (type-7 interpolation).
    pub q1: f64,
    /// Median.
    pub median: f64,
    /// Third quartile.
    pub q3: f64,
    /// Largest observation.
    pub max: f64,
}

impl Summary {
    /// Interquartile range (`q3 − q1`).
    pub fn iqr(&self) -> f64 {
        self.q3 - self.q1
    }

    /// Boxplot whisker positions (Tukey 1.5·IQR, clamped to data range).
    pub fn whiskers(&self) -> (f64, f64) {
        let lo = (self.q1 - 1.5 * self.iqr()).max(self.min);
        let hi = (self.q3 + 1.5 * self.iqr()).min(self.max);
        (lo, hi)
    }
}

/// Arithmetic mean (`NaN` for an empty sample).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n−1 denominator; 0 below two points).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated quantile (type-7, what numpy/scikit default to —
/// keeps our Table 4 numbers comparable to the paper's toolchain).
///
/// Defined on degenerate inputs: an empty sample yields `0.0` (never NaN,
/// never a panic — telemetry snapshots quantile whatever they have) and a
/// single element is every quantile of itself.
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q));
    if sorted.is_empty() {
        return 0.0;
    }
    let h = (sorted.len() - 1) as f64 * q;
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (h - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Full summary of a sample (sorts a copy). An empty sample yields the
/// all-zero `n = 0` summary — NaN-free, so report rows built from
/// zero-length series (an idle op kind, a scenario that issued nothing)
/// stay printable and JSON-clean.
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary {
            n: 0,
            mean: 0.0,
            std: 0.0,
            min: 0.0,
            q1: 0.0,
            median: 0.0,
            q3: 0.0,
            max: 0.0,
        };
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n: s.len(),
        mean: mean(&s),
        std: std_dev(&s),
        min: s[0],
        q1: quantile(&s, 0.25),
        median: quantile(&s, 0.5),
        q3: quantile(&s, 0.75),
        max: s[s.len() - 1],
    }
}

/// Fitted simple linear model `y = beta * x + beta0`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinFit {
    /// Slope.
    pub beta: f64,
    /// Intercept.
    pub beta0: f64,
}

impl LinFit {
    /// Evaluate the model at `x`.
    pub fn predict(&self, x: f64) -> f64 {
        self.beta * x + self.beta0
    }

    /// The paper zeroes the (slightly negative, unphysical) add-update
    /// intercept; same convention here.
    pub fn clamp_intercept(mut self) -> LinFit {
        if self.beta0 < 0.0 {
            self.beta0 = 0.0;
        }
        self
    }
}

/// Ordinary least squares for a single feature. Closed form.
pub fn ols(xs: &[f64], ys: &[f64]) -> LinFit {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "ols needs >= 2 points");
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let sxx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    if sxx == 0.0 {
        // Degenerate (all x equal): flat model through the mean.
        return LinFit {
            beta: 0.0,
            beta0: my,
        };
    }
    let beta = sxy / sxx;
    LinFit {
        beta,
        beta0: my - beta * mx,
    }
    .tap_check(n)
}

trait TapCheck {
    fn tap_check(self, _n: f64) -> Self
    where
        Self: Sized,
    {
        self
    }
}
impl TapCheck for LinFit {}

/// Multiple linear regression with intercept via normal equations
/// (X'X) b = X'y solved by Gaussian elimination with partial pivoting.
/// This is the rust-native oracle for the `linreg_fit` XLA artifact.
pub fn ols_multi(rows: &[Vec<f64>], ys: &[f64]) -> Vec<f64> {
    assert_eq!(rows.len(), ys.len());
    assert!(!rows.is_empty());
    let k = rows[0].len() + 1; // + intercept column
    let mut xtx = vec![vec![0.0f64; k]; k];
    let mut xty = vec![0.0f64; k];
    for (row, &y) in rows.iter().zip(ys) {
        let mut xi = Vec::with_capacity(k);
        xi.push(1.0);
        xi.extend_from_slice(row);
        for a in 0..k {
            xty[a] += xi[a] * y;
            for b in 0..k {
                xtx[a][b] += xi[a] * xi[b];
            }
        }
    }
    solve(&mut xtx, &mut xty)
}

/// Solve A x = b in place (Gaussian elimination, partial pivoting).
pub fn solve(a: &mut [Vec<f64>], b: &mut [f64]) -> Vec<f64> {
    let n = b.len();
    for col in 0..n {
        // pivot
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        assert!(d.abs() > 1e-12, "singular system in stats::solve");
        for row in (col + 1)..n {
            let f = a[row][col] / d;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                a[row][c] -= f * a[col][c];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0f64; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for c in (row + 1)..n {
            acc -= a[row][c] * x[c];
        }
        x[row] = acc / a[row][row];
    }
    x
}

/// Mean Absolute Percentage Error — the paper's §6 accuracy metric.
pub fn mape(actual: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(actual.len(), predicted.len());
    let mut acc = 0.0;
    let mut n = 0usize;
    for (&a, &p) in actual.iter().zip(predicted) {
        if a != 0.0 {
            acc += ((a - p) / a).abs();
            n += 1;
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        acc / n as f64
    }
}

/// Coefficient of determination.
pub fn r2(actual: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(actual.len(), predicted.len());
    let m = mean(actual);
    let ss_tot: f64 = actual.iter().map(|a| (a - m).powi(2)).sum();
    let ss_res: f64 = actual
        .iter()
        .zip(predicted)
        .map(|(a, p)| (a - p).powi(2))
        .sum();
    if ss_tot == 0.0 {
        return if ss_res == 0.0 { 1.0 } else { f64::NEG_INFINITY };
    }
    1.0 - ss_res / ss_tot
}

/// Result of one cross-validation: per-fold metrics, averaged.
#[derive(Debug, Clone)]
pub struct CvResult {
    /// Mean absolute percentage error, averaged over folds.
    pub avg_mape: f64,
    /// Coefficient of determination, averaged over folds.
    pub avg_r2: f64,
    /// Number of folds actually evaluated.
    pub folds: usize,
}

/// K-fold cross-validation of the simple linear model, reproducing the
/// paper's "typical five-fold cross-validation" (§6.1). Deterministic fold
/// assignment given the seed.
pub fn cross_validate(
    xs: &[f64],
    ys: &[f64],
    k: usize,
    seed: u64,
    zero_intercept: bool,
) -> CvResult {
    assert_eq!(xs.len(), ys.len());
    assert!(k >= 2 && xs.len() >= k);
    let mut order: Vec<usize> = (0..xs.len()).collect();
    crate::util::rng::Rng::new(seed).shuffle(&mut order);
    // pooled CV: gather every fold's held-out (actual, predicted) pairs and
    // compute the metrics once — well-defined even when a fold holds a
    // single point (per-fold R² would be degenerate there)
    let mut held_actual = Vec::with_capacity(xs.len());
    let mut held_pred = Vec::with_capacity(xs.len());
    for fold in 0..k {
        let (mut trx, mut tr_y, mut tex, mut te_y) = (vec![], vec![], vec![], vec![]);
        for (pos, &i) in order.iter().enumerate() {
            if pos % k == fold {
                tex.push(xs[i]);
                te_y.push(ys[i]);
            } else {
                trx.push(xs[i]);
                tr_y.push(ys[i]);
            }
        }
        let mut fit = ols(&trx, &tr_y);
        if zero_intercept {
            fit = fit.clamp_intercept();
        }
        held_pred.extend(tex.iter().map(|&x| fit.predict(x)));
        held_actual.extend(te_y);
    }
    CvResult {
        avg_mape: mape(&held_actual, &held_pred),
        avg_r2: r2(&held_actual, &held_pred),
        folds: k,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.q1, 2.0);
        assert_eq!(s.q3, 4.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_interpolates() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert!((quantile(&s, 0.5) - 2.5).abs() < 1e-12);
        assert_eq!(quantile(&s, 0.0), 1.0);
        assert_eq!(quantile(&s, 1.0), 4.0);
    }

    #[test]
    fn quantile_degenerate_inputs_are_nan_free() {
        assert_eq!(quantile(&[], 0.5), 0.0);
        assert_eq!(quantile(&[], 0.99), 0.0);
        // a single element is every quantile of itself
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(quantile(&[7.5], q), 7.5);
        }
    }

    #[test]
    fn quantile_p99_tail_small_samples() {
        // type-7 interpolation at the tail: h = (n-1)·q sits between the
        // last two order statistics for small n
        let five = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!((quantile(&five, 0.99) - 4.96).abs() < 1e-12);
        let two = [10.0, 20.0];
        assert!((quantile(&two, 0.99) - 19.9).abs() < 1e-12);
        let three = [0.0, 1.0, 100.0];
        // h = 2·0.99 = 1.98 → 1.0 + 0.98·(100−1)
        assert!((quantile(&three, 0.99) - 98.02).abs() < 1e-12);
        // p99 below the max, p100 exactly the max
        assert!(quantile(&five, 0.99) < 5.0);
        assert_eq!(quantile(&five, 1.0), 5.0);
    }

    #[test]
    fn summarize_degenerate_inputs_are_nan_free() {
        let empty = summarize(&[]);
        assert_eq!(empty.n, 0);
        for v in [
            empty.mean, empty.std, empty.min, empty.q1, empty.median, empty.q3, empty.max,
        ] {
            assert_eq!(v, 0.0);
        }
        assert_eq!(empty.iqr(), 0.0);
        let (lo, hi) = empty.whiskers();
        assert!(!lo.is_nan() && !hi.is_nan());
        let one = summarize(&[3.25]);
        assert_eq!(one.n, 1);
        assert_eq!(one.std, 0.0);
        assert_eq!((one.min, one.median, one.max), (3.25, 3.25, 3.25));
        assert_eq!((one.q1, one.q3), (3.25, 3.25));
    }

    #[test]
    fn ols_exact_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 7.0).collect();
        let fit = ols(&xs, &ys);
        assert!((fit.beta - 3.0).abs() < 1e-10);
        assert!((fit.beta0 - 7.0).abs() < 1e-10);
        assert!((r2(&ys, &xs.iter().map(|&x| fit.predict(x)).collect::<Vec<_>>()) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ols_noisy_recovers() {
        let mut rng = crate::util::rng::Rng::new(5);
        let xs: Vec<f64> = (0..500).map(|_| rng.uniform(0.0, 100.0)).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|&x| 1.5e-5 * x + 2.0e-3 + rng.normal(0.0, 1e-5))
            .collect();
        let fit = ols(&xs, &ys);
        assert!((fit.beta - 1.5e-5).abs() < 2e-6, "beta={}", fit.beta);
        assert!((fit.beta0 - 2.0e-3).abs() < 2e-5, "beta0={}", fit.beta0);
    }

    #[test]
    fn ols_multi_matches_simple() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x - 1.0).collect();
        let rows: Vec<Vec<f64>> = xs.iter().map(|&x| vec![x]).collect();
        let b = ols_multi(&rows, &ys);
        assert!((b[0] - (-1.0)).abs() < 1e-9);
        assert!((b[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ols_multi_two_features() {
        // y = 1 + 2a + 3b exactly
        let mut rows = vec![];
        let mut ys = vec![];
        for a in 0..10 {
            for b in 0..10 {
                rows.push(vec![a as f64, b as f64]);
                ys.push(1.0 + 2.0 * a as f64 + 3.0 * b as f64);
            }
        }
        let b = ols_multi(&rows, &ys);
        for (got, want) in b.iter().zip([1.0, 2.0, 3.0]) {
            assert!((got - want).abs() < 1e-9);
        }
    }

    #[test]
    fn mape_r2_perfect() {
        let a = [1.0, 2.0, 4.0];
        assert_eq!(mape(&a, &a), 0.0);
        assert_eq!(r2(&a, &a), 1.0);
    }

    #[test]
    fn cv_on_clean_line_is_accurate() {
        let xs: Vec<f64> = (0..100).map(|i| (i * 40) as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 9.08e-6 * x + 6.3e-4).collect();
        let cv = cross_validate(&xs, &ys, 5, 42, false);
        assert!(cv.avg_mape < 1e-9, "mape={}", cv.avg_mape);
        assert!(cv.avg_r2 > 0.999999, "r2={}", cv.avg_r2);
    }

    #[test]
    fn clamp_intercept() {
        let f = LinFit {
            beta: 1.0,
            beta0: -0.5,
        }
        .clamp_intercept();
        assert_eq!(f.beta0, 0.0);
    }

    #[test]
    fn solve_3x3() {
        let mut a = vec![
            vec![2.0, 1.0, -1.0],
            vec![-3.0, -1.0, 2.0],
            vec![-2.0, 1.0, 2.0],
        ];
        let mut b = vec![8.0, -11.0, -3.0];
        let x = solve(&mut a, &mut b);
        for (got, want) in x.iter().zip([2.0, 3.0, -1.0]) {
            assert!((got - want).abs() < 1e-9);
        }
    }
}
