//! Minimal JSON value model, parser, and serializer.
//!
//! serde is not available in this offline environment, so the repo carries its
//! own JSON substrate. It is used for JGF (JSON Graph Format) subgraph
//! interchange between scheduler levels, jobspecs, RPC framing, and bench
//! report emission. Object key order is preserved (JGF consumers care about
//! stable output for diffing).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are stored as `f64` with an integer fast-path for
/// display (JGF ids and counts are integers; they must not print as `3.0`).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (see the type doc for integer handling).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// Insertion-ordered object. Key lookup is linear; JGF objects are small
    /// (vertex metadata ~10 keys), so this beats hashing in practice.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// An empty JSON object (builder entry point; chain with [`Json::with`]).
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or replace) a key in an object. Panics on non-objects —
    /// builder misuse is a programming error, not a runtime condition.
    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        match self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = val;
                } else {
                    pairs.push((key.to_string(), val));
                }
                self
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Chainable set for builders.
    pub fn with(mut self, key: &str, val: Json) -> Json {
        self.set(key, val);
        self
    }

    /// Field of an object (`None` for absent keys and non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Mutable field of an object.
    pub fn get_mut(&mut self, key: &str) -> Option<&mut Json> {
        match self {
            Json::Obj(pairs) => pairs.iter_mut().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric payload as a non-negative integer (rejects fractions).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// The numeric payload as a signed integer (rejects fractions).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    /// The boolean payload, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The items, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key/value pairs in insertion order, if this is an object.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Whether this value is `null`.
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Convenience: `get` + `as_str`, for the common "required string field"
    /// pattern in JGF/jobspec decoding.
    pub fn str_field(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| JsonError::Schema(format!("missing string field '{key}'")))
    }

    /// Convenience: `get` + `as_u64`, for required integer fields.
    pub fn u64_field(&self, key: &str) -> Result<u64, JsonError> {
        self.get(key)
            .and_then(Json::as_u64)
            .ok_or_else(|| JsonError::Schema(format!("missing integer field '{key}'")))
    }

    /// Serialize compactly (no whitespace). Used on the RPC path.
    pub fn dump(&self) -> String {
        let mut out = String::with_capacity(256);
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation. Used for files a human reads.
    pub fn dump_pretty(&self) -> String {
        let mut out = String::with_capacity(256);
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    /// Serialize compactly into an existing buffer.
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    item.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(pairs) if !pairs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
            _ => self.write(out),
        }
    }

    /// Parse one JSON document (rejects trailing data).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data after JSON value"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.dump())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}
impl From<BTreeMap<String, Json>> for Json {
    fn from(m: BTreeMap<String, Json>) -> Json {
        Json::Obj(m.into_iter().collect())
    }
}

/// Why parsing or schema-directed decoding failed.
#[derive(Debug)]
pub enum JsonError {
    /// The text is not valid JSON (byte position + reason).
    Parse {
        /// Byte offset of the failure.
        pos: usize,
        /// What the parser expected.
        msg: String,
    },
    /// The JSON is valid but does not match the expected schema.
    Schema(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse { pos, msg } => {
                write!(f, "json parse error at byte {pos}: {msg}")
            }
            JsonError::Schema(s) => write!(f, "json schema error: {s}"),
        }
    }
}

impl std::error::Error for JsonError {}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 9.0e15 {
        // Integer fast path: JGF ids/counts must not render as "3.0".
        // Manual itoa — fmt::Write's machinery dominated the JGF dump
        // profile (§Perf): ~45% of a 600 kB grant is integer fields.
        let mut v = n as i64;
        if v < 0 {
            out.push('-');
            v = -v;
        }
        let mut buf = [0u8; 20];
        let mut i = buf.len();
        loop {
            i -= 1;
            buf[i] = b'0' + (v % 10) as u8;
            v /= 10;
            if v == 0 {
                break;
            }
        }
        // digits are ASCII
        out.push_str(unsafe { std::str::from_utf8_unchecked(&buf[i..]) });
    } else if n.is_finite() {
        let _ = fmt::Write::write_fmt(out, format_args!("{n}"));
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    // bulk fast path: copy maximal clean runs, escape only where needed
    let bytes = s.as_bytes();
    let mut start = 0;
    let mut i = 0;
    while i < bytes.len() {
        let b = bytes[i];
        if b == b'"' || b == b'\\' || b < 0x20 {
            out.push_str(&s[start..i]);
            match b {
                b'"' => out.push_str("\\\""),
                b'\\' => out.push_str("\\\\"),
                b'\n' => out.push_str("\\n"),
                b'\r' => out.push_str("\\r"),
                b'\t' => out.push_str("\\t"),
                c => {
                    let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c));
                }
            }
            start = i + 1;
        }
        i += 1;
    }
    out.push_str(&s[start..]);
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        // bulk fast path: most strings (paths, names) contain no escapes —
        // scan to the closing quote and copy the slice in one shot
        let start = self.pos;
        let mut j = self.pos;
        while j < self.bytes.len() {
            let b = self.bytes[j];
            if b == b'"' {
                let s = std::str::from_utf8(&self.bytes[start..j])
                    .map_err(|_| self.err("invalid UTF-8"))?
                    .to_string();
                self.pos = j + 1;
                return Ok(s);
            }
            if b == b'\\' || b < 0x20 {
                break; // escape or control char: fall through to slow path
            }
            j += 1;
        }
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling for completeness.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad surrogate pair"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad \\u escape"))?
                        };
                        s.push(ch);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode a multibyte UTF-8 sequence.
                    let len = utf8_len(c);
                    let start = self.pos - 1;
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(self.err("truncated UTF-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(pairs)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Tiny builder macro for literals in tests and experiment reports.
#[macro_export]
macro_rules! json_obj {
    ( $( $k:expr => $v:expr ),* $(,)? ) => {{
        #[allow(unused_mut)]
        let mut o = $crate::util::json::Json::obj();
        $( o.set($k, $crate::util::json::Json::from($v)); )*
        o
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(v.dump(), text);
        }
    }

    #[test]
    fn integer_rendering() {
        assert_eq!(Json::Num(3.0).dump(), "3");
        assert_eq!(Json::Num(3.25).dump(), "3.25");
        assert_eq!(Json::Num(-8.0).dump(), "-8");
    }

    #[test]
    fn nested_roundtrip() {
        let text = r#"{"graph":{"nodes":[{"id":"0","metadata":{"type":"node","rank":-1}}],"edges":[]}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\"Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\"Aé");
        let round = Json::parse(&v.dump()).unwrap();
        assert_eq!(round, v);
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn object_access() {
        let mut o = Json::obj();
        o.set("a", Json::from(1u64)).set("b", Json::from("x"));
        assert_eq!(o.get("a").unwrap().as_u64(), Some(1));
        assert_eq!(o.str_field("b").unwrap(), "x");
        assert!(o.str_field("missing").is_err());
        // replacement keeps position
        o.set("a", Json::from(2u64));
        assert_eq!(o.as_obj().unwrap()[0].0, "a");
        assert_eq!(o.get("a").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn rejects_garbage() {
        for text in ["{", "[1,", "\"abc", "{\"a\" 1}", "12..5", "nul", "[1] x"] {
            assert!(Json::parse(text).is_err(), "should reject {text:?}");
        }
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" { \"a\" : [ 1 , 2 ] , \"b\" : null } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("b").unwrap().is_null());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::parse(r#"{"a":[1,{"b":true}],"c":"d"}"#).unwrap();
        assert_eq!(Json::parse(&v.dump_pretty()).unwrap(), v);
    }

    #[test]
    fn macro_builder() {
        let o = json_obj! {"n" => 4u64, "name" => "core"};
        assert_eq!(o.dump(), r#"{"n":4,"name":"core"}"#);
    }
}
