//! Property-based testing helper (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` generated inputs from a seeded
//! generator; on failure it performs a bounded "shrink-lite" pass by retrying
//! with fresh, smaller inputs from the generator's `shrunk` hook, then panics
//! with the seed so the case is reproducible.

use crate::util::rng::Rng;

/// A generator of test inputs. `gen` produces an arbitrary value at a size
/// hint; implementors should make smaller sizes produce structurally smaller
/// values so the shrink pass is meaningful.
pub trait Gen {
    /// The type of generated values.
    type Value;
    /// Produce one arbitrary value at the given size hint.
    fn generate(&self, rng: &mut Rng, size: usize) -> Self::Value;
}

impl<T, F: Fn(&mut Rng, usize) -> T> Gen for F {
    type Value = T;
    fn generate(&self, rng: &mut Rng, size: usize) -> T {
        self(rng, size)
    }
}

/// Run `prop` over `cases` generated inputs. On failure, retry with
/// decreasing size to report the smallest failing size found.
pub fn check<G: Gen>(
    seed: u64,
    cases: usize,
    max_size: usize,
    gen: G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) where
    G::Value: std::fmt::Debug,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        // Ramp size up over the run, like proptest/quickcheck do.
        let size = 1 + (max_size.saturating_sub(1)) * case / cases.max(1);
        let value = gen.generate(&mut rng, size);
        if let Err(msg) = prop(&value) {
            // shrink-lite: look for a smaller failing input
            let mut best: (usize, String, String) = (size, format!("{value:?}"), msg);
            for s in (1..size).rev() {
                let mut srng = Rng::new(seed ^ (s as u64).wrapping_mul(0x9E37));
                for _ in 0..16 {
                    let v = gen.generate(&mut srng, s);
                    if let Err(m) = prop(&v) {
                        best = (s, format!("{v:?}"), m);
                        break;
                    }
                }
                if best.0 != s {
                    break; // no failure at this size; stop shrinking
                }
            }
            panic!(
                "property failed (seed={seed}, case={case}, size={}):\n  input: {}\n  error: {}",
                best.0, best.1, best.2
            );
        }
    }
}

/// Assertion adapter: turn a bool into the Result the checker wants.
pub fn ensure(cond: bool, msg: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(
            1,
            50,
            20,
            |rng: &mut Rng, size: usize| rng.below(size as u64 + 1),
            |&v| ensure(v <= 20, "bounded"),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            2,
            50,
            100,
            |rng: &mut Rng, size: usize| rng.below(size as u64 + 1),
            |&v| ensure(v < 5, "v too big"),
        );
    }
}
