//! Substrate utilities built from scratch for the offline environment:
//! JSON codec, PRNG, statistics, metrics, bench harness, property testing.

pub mod bench;
pub mod json;
pub mod metrics;
pub mod prop;
pub mod rng;
pub mod stats;
