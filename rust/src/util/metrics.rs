//! Timing + memory measurement used by every experiment.
//!
//! The paper reports elapsed times for match / comms / add-update phases and
//! max RSS (resident set size) from `resource-query`. We mirror that: a
//! monotonic `Timer`, a named-phase `Stopwatch`, and `max_rss_kb()` via
//! `getrusage(2)`.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::stats::{summarize, Summary};

/// Monotonic elapsed-time helper.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }

    /// Time since `start`.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Time since `start`, in seconds.
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

/// Max resident set size of this process in kB, as the paper's
/// resource-query reports. Read from /proc/self/status (VmHWM — the same
/// number getrusage's ru_maxrss reports on Linux) so no libc binding is
/// needed in the offline build.
pub fn max_rss_kb() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(0)
}

/// Current RSS in kB from /proc/self/statm (max RSS is sticky; experiments
/// that compare configurations inside one process need the live value).
pub fn current_rss_kb() -> u64 {
    let page_kb = 4; // x86-64 Linux
    std::fs::read_to_string("/proc/self/statm")
        .ok()
        .and_then(|s| {
            s.split_whitespace()
                .nth(1)
                .and_then(|f| f.parse::<u64>().ok())
        })
        .map(|pages| pages * page_kb)
        .unwrap_or(0)
}

/// Accumulates timing samples under named series — one series per measured
/// phase per level, e.g. `comms/L1`, `add_upd/L3`.
#[derive(Debug, Default, Clone)]
pub struct Recorder {
    series: BTreeMap<String, Vec<f64>>,
}

impl Recorder {
    /// An empty recorder.
    pub fn new() -> Recorder {
        Recorder::default()
    }

    /// Append one observation to a named series.
    pub fn record(&mut self, series: &str, seconds: f64) {
        self.series.entry(series.to_string()).or_default().push(seconds);
    }

    /// Append many observations to a named series.
    pub fn record_all(&mut self, series: &str, xs: &[f64]) {
        self.series
            .entry(series.to_string())
            .or_default()
            .extend_from_slice(xs);
    }

    /// The observations of a series, if any were recorded.
    pub fn get(&self, series: &str) -> Option<&[f64]> {
        self.series.get(series).map(|v| v.as_slice())
    }

    /// All recorded series names, sorted.
    pub fn series_names(&self) -> impl Iterator<Item = &str> {
        self.series.keys().map(String::as_str)
    }

    /// Summary statistics of a series, if non-empty.
    pub fn summary(&self, series: &str) -> Option<Summary> {
        self.series.get(series).map(|v| summarize(v))
    }

    /// Fold another recorder's series into this one.
    pub fn merge(&mut self, other: &Recorder) {
        for (k, v) in &other.series {
            self.series.entry(k.clone()).or_default().extend_from_slice(v);
        }
    }

    /// Render all series as an aligned text table (what benches print).
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<28} {:>6} {:>12} {:>12} {:>12} {:>12} {:>12}\n",
            "series", "n", "mean(s)", "median(s)", "q1(s)", "q3(s)", "std(s)"
        ));
        for (name, xs) in &self.series {
            let s = summarize(xs);
            out.push_str(&format!(
                "{:<28} {:>6} {:>12.6} {:>12.6} {:>12.6} {:>12.6} {:>12.6}\n",
                name, s.n, s.mean, s.median, s.q1, s.q3, s.std
            ));
        }
        out
    }

    /// CSV export: series,value rows (raw samples, for offline plotting).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("series,seconds\n");
        for (name, xs) in &self.series {
            for x in xs {
                out.push_str(&format!("{name},{x}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_secs() >= 0.002);
    }

    #[test]
    fn rss_nonzero() {
        assert!(max_rss_kb() > 0);
        assert!(current_rss_kb() > 0);
    }

    #[test]
    fn recorder_summary() {
        let mut r = Recorder::new();
        for v in [1.0, 2.0, 3.0] {
            r.record("x", v);
        }
        let s = r.summary("x").unwrap();
        assert_eq!(s.n, 3);
        assert_eq!(s.median, 2.0);
        assert!(r.summary("missing").is_none());
    }

    #[test]
    fn recorder_merge_and_csv() {
        let mut a = Recorder::new();
        a.record("x", 1.0);
        let mut b = Recorder::new();
        b.record("x", 2.0);
        b.record("y", 3.0);
        a.merge(&b);
        assert_eq!(a.get("x").unwrap().len(), 2);
        let csv = a.to_csv();
        assert!(csv.contains("x,1"));
        assert!(csv.contains("y,3"));
    }
}
