//! Micro-bench harness (criterion is unavailable offline).
//!
//! Cargo benches in this repo use `harness = false` and drive this module:
//! warmup, fixed iteration counts (the paper repeats each test 100 times and
//! reports distribution statistics, which we mirror), and quantile reports.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::{summarize, Summary};

/// Run `iters` timed repetitions of `f` after `warmup` untimed ones.
/// `setup` runs before every repetition and is excluded from timing.
pub fn run_timed<S, T>(
    warmup: usize,
    iters: usize,
    mut setup: impl FnMut() -> S,
    mut f: impl FnMut(S) -> T,
) -> Vec<f64> {
    for _ in 0..warmup {
        let s = setup();
        std::hint::black_box(f(s));
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let s = setup();
        let t = Instant::now();
        std::hint::black_box(f(s));
        samples.push(t.elapsed().as_secs_f64());
    }
    samples
}

/// Simple variant with no per-iteration setup.
pub fn run_simple<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    run_timed(warmup, iters, || (), |()| f())
}

/// One printed row of a bench report.
pub fn report_row(name: &str, samples: &[f64]) -> String {
    let s = summarize(samples);
    format!(
        "{:<36} n={:<4} mean={:>11.6}s median={:>11.6}s q1={:>11.6}s q3={:>11.6}s",
        name, s.n, s.mean, s.median, s.q1, s.q3
    )
}

/// Print a section header for a bench run.
pub fn print_header(title: &str) {
    println!("\n=== {title} ===");
}

/// Print one bench row and return its summary.
pub fn print_row(name: &str, samples: &[f64]) -> Summary {
    println!("{}", report_row(name, samples));
    summarize(samples)
}

/// Throughput helper: items/sec given total seconds.
pub fn throughput(items: usize, secs: f64) -> f64 {
    items as f64 / secs
}

/// One recorded report row: the summary plus optional named extra fields
/// (percentiles, ops/sec) carried into the JSON object.
#[derive(Debug)]
struct Row {
    name: String,
    summary: Summary,
    extras: Vec<(String, f64)>,
}

/// Machine-readable bench report: named rows accumulated as a run prints,
/// then emitted as JSON so successive PRs can diff medians mechanically
/// (the perf trajectory files, e.g. `BENCH_hotpath.json` and
/// `BENCH_serving.json`).
#[derive(Debug, Default)]
pub struct BenchReport {
    rows: Vec<Row>,
}

impl BenchReport {
    /// An empty report.
    pub fn new() -> BenchReport {
        BenchReport::default()
    }

    /// Print the human row AND record it for the JSON report.
    pub fn row(&mut self, name: &str, samples: &[f64]) -> Summary {
        let s = print_row(name, samples);
        self.rows.push(Row {
            name: name.to_string(),
            summary: s.clone(),
            extras: Vec::new(),
        });
        s
    }

    /// Record a row from an already-computed [`Summary`] (e.g. synthesized
    /// from a telemetry latency histogram, where raw per-op samples are
    /// never stored) plus named extra JSON fields — the serving report uses
    /// `p50_s`/`p95_s`/`p99_s`/`ops_per_sec`/`errors`. Prints a human row
    /// with the extras appended.
    pub fn row_summary(&mut self, name: &str, s: Summary, extras: &[(&str, f64)]) -> Summary {
        let mut line = format!(
            "{:<36} n={:<7} mean={:>10.3e}s median={:>10.3e}s",
            name, s.n, s.mean, s.median
        );
        for (k, v) in extras {
            line.push_str(&format!(" {k}={v:.3e}"));
        }
        println!("{line}");
        self.rows.push(Row {
            name: name.to_string(),
            summary: s.clone(),
            extras: extras.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
        s
    }

    /// Summary of a named row, if recorded.
    pub fn get(&self, name: &str) -> Option<&Summary> {
        self.rows
            .iter()
            .find(|r| r.name == name)
            .map(|r| &r.summary)
    }

    /// An extra field of a named row, if recorded with one.
    pub fn get_extra(&self, name: &str, key: &str) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.name == name)
            .and_then(|r| r.extras.iter().find(|(k, _)| k == key))
            .map(|(_, v)| *v)
    }

    /// Number of recorded rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether no rows were recorded.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// The report as the `BENCH_hotpath.json` document shape
    /// (`{"benchmarks": [{name, n, mean_s, median_s, ...}]}`); rows
    /// recorded with extras carry those keys too (the `BENCH_serving.json`
    /// percentile fields).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|row| {
                let s = &row.summary;
                let base = Json::obj()
                    .with("name", Json::from(row.name.as_str()))
                    .with("n", Json::from(s.n as u64))
                    .with("mean_s", Json::from(s.mean))
                    .with("median_s", Json::from(s.median))
                    .with("q1_s", Json::from(s.q1))
                    .with("q3_s", Json::from(s.q3))
                    .with("std_s", Json::from(s.std))
                    .with("min_s", Json::from(s.min))
                    .with("max_s", Json::from(s.max));
                row.extras
                    .iter()
                    .fold(base, |j, (k, v)| j.with(k.as_str(), Json::from(*v)))
            })
            .collect();
        Json::obj().with("benchmarks", Json::Arr(rows))
    }

    /// Write the JSON report to `path` (pretty-printed for diffs).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().dump_pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_simple_counts() {
        let mut calls = 0;
        let samples = run_simple(2, 5, || {
            calls += 1;
            calls
        });
        assert_eq!(samples.len(), 5);
        assert_eq!(calls, 7); // 2 warmup + 5 timed
        assert!(samples.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn setup_excluded_from_timing() {
        // setup sleeps; measured body is ~instant -> samples must be far
        // below the sleep duration.
        let samples = run_timed(
            0,
            3,
            || std::thread::sleep(std::time::Duration::from_millis(5)),
            |()| 1 + 1,
        );
        assert!(samples.iter().all(|&s| s < 0.004), "{samples:?}");
    }

    #[test]
    fn report_contains_name() {
        let row = report_row("my_bench", &[0.1, 0.2]);
        assert!(row.contains("my_bench"));
        assert!(row.contains("n=2"));
    }

    #[test]
    fn row_summary_extras_reach_json() {
        let mut r = BenchReport::new();
        let s = summarize(&[0.001, 0.002, 0.003]);
        r.row_summary(
            "serve/mix@L0/r1000",
            s,
            &[("p99_s", 0.0029), ("ops_per_sec", 950.0)],
        );
        assert_eq!(r.get_extra("serve/mix@L0/r1000", "p99_s"), Some(0.0029));
        assert_eq!(r.get_extra("serve/mix@L0/r1000", "nope"), None);
        let doc = crate::util::json::Json::parse(&r.to_json().dump()).unwrap();
        let rows = doc.get("benchmarks").and_then(|b| b.as_arr()).unwrap();
        assert_eq!(
            rows[0].get("ops_per_sec").and_then(|v| v.as_f64()),
            Some(950.0)
        );
        // base schema fields still present alongside extras
        assert!(rows[0].get("median_s").is_some());
    }

    #[test]
    fn bench_report_json_roundtrips() {
        let mut r = BenchReport::new();
        r.row("match/T1@L0", &[0.1, 0.2, 0.3]);
        r.row("jgf/encode", &[0.5]);
        assert_eq!(r.len(), 2);
        assert_eq!(r.get("jgf/encode").unwrap().n, 1);
        let doc = crate::util::json::Json::parse(&r.to_json().dump()).unwrap();
        let rows = doc.get("benchmarks").and_then(|b| b.as_arr()).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(
            rows[0].get("name").and_then(|n| n.as_str()),
            Some("match/T1@L0")
        );
        let median = rows[0].get("median_s").and_then(|m| m.as_f64()).unwrap();
        assert!((median - 0.2).abs() < 1e-12);
    }
}
