//! Deterministic PRNG + distributions.
//!
//! The `rand` crate is unavailable offline; experiments need reproducible
//! workload generation and latency models, so we carry a small xoshiro256**
//! generator (Blackman/Vigna) seeded through SplitMix64, plus the handful of
//! distributions the simulators use (uniform, normal, lognormal, exponential).

/// xoshiro256** seeded via SplitMix64. Deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed a generator (same seed ⇒ same sequence).
    pub fn new(seed: u64) -> Rng {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias
    /// (matters for reproducible workload traces more than for statistics).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (single draw; the pair is discarded —
    /// simplicity over throughput, this is not on the hot path).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Lognormal parameterized by the *underlying* normal's mu/sigma.
    /// Used by the EC2 latency model (creation times are right-skewed).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda). Job interarrivals.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform choice from a non-empty slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n). O(n) reservoir-free variant.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(5.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        let s = r.sample_indices(100, 10);
        assert_eq!(s.len(), 10);
        let mut t = s.clone();
        t.sort();
        t.dedup();
        assert_eq!(t.len(), 10);
    }
}
