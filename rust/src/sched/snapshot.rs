//! Epoch-versioned RCU snapshots of the resource graph (PR 9).
//!
//! The lock-free read path: writers keep mutating the authoritative
//! [`crate::sched::SchedInstance`] under the service `RwLock` exactly as
//! before, but every write now ends with a **publish** — a cheap
//! [`ResourceGraph::clone`] (copy-on-write: refcount bumps, see
//! `resource::graph` §Snapshots) swapped into a [`SnapshotHead`]. Readers
//! **pin** the head (`Arc::clone` under a pointer-sized critical section)
//! and traverse their pinned [`GraphSnapshot`] with no instance lock held:
//! a probe issued while a writer holds the write lock completes against
//! the prior version without blocking.
//!
//! §Version lifecycle: `publish(E)` → any number of `pin()`s at `E` →
//! superseded by `publish(E')` → **retired** when the last pin drops (the
//! `Arc` refcount reaching zero runs [`GraphSnapshot`]'s `Drop`, which is
//! counted — the leak test in `tests/rcu.rs` holds the accounting to
//! exactly `live = 1 + published − retired`). There is no grace-period
//! machinery to get wrong: retirement *is* `Arc` reclamation.
//!
//! §Why a `Mutex` head is still "lock-free enough": the head mutex guards
//! two pointer copies (readers: `Arc::clone`; writers: pointer swap) and
//! is never held across traversal, I/O, or allocation of the new version —
//! writers build the next graph entirely off to the side. Readers can
//! therefore stall each other for the duration of a refcount bump, but
//! never behind a writer's graph mutation, which is the hazard that
//! matters (and the one the stress test pins down with a deliberately
//! stalled writer). `std` has no `AtomicArc`; this is the std-only RCU.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::jobspec::JobSpec;
use crate::resource::graph::ResourceGraph;
use crate::rpc::proto::SchedReply;
use crate::sched::instance::probe_graph;
use crate::sched::matcher::MatchScratch;
use crate::sched::pruning::PruneConfig;

/// One immutable published version of the resource graph, pinned by
/// readers via `Arc<GraphSnapshot>`. Holds everything a probe needs —
/// graph and pruning config — so the read path never touches the live
/// instance.
#[derive(Debug)]
pub struct GraphSnapshot {
    /// The graph as of `version` (COW clone — shares chunks with the
    /// authoritative graph until a writer touches them).
    pub graph: ResourceGraph,
    /// Pruning configuration the graph's aggregates were built under.
    pub prune: PruneConfig,
    /// The graph epoch this version was published at. Monotonic across
    /// publishes; equal versions imply bit-identical observable state,
    /// so this is also the probe-cache key for results computed here.
    pub version: u64,
    /// Retirement counter shared with the head (bumped on drop).
    retired: Arc<AtomicU64>,
}

impl GraphSnapshot {
    /// Feasibility probe against this pinned version. Same reply
    /// vocabulary as [`crate::sched::SchedInstance::probe_with`]; takes no
    /// lock of any kind.
    pub fn probe_with(&self, spec: &JobSpec, scratch: &mut MatchScratch) -> SchedReply {
        probe_graph(&self.graph, &self.prune, spec, scratch)
    }
}

impl Drop for GraphSnapshot {
    fn drop(&mut self) {
        // last unpin retires the version; counted so leak tests (and
        // telemetry) can assert reclamation actually happens
        self.retired.fetch_add(1, Ordering::Relaxed);
    }
}

/// Pin/publish/retire statistics (surfaced through service telemetry).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SnapshotStats {
    /// Reader pins taken (`SnapshotHead::pin` calls).
    pub pins: u64,
    /// Versions published after the initial one.
    pub publishes: u64,
    /// Versions fully retired (dropped by their last pinner).
    pub retired: u64,
    /// Versions currently reachable: the head plus any still pinned.
    pub live: u64,
}

/// The RCU head: the latest published [`GraphSnapshot`] plus lifecycle
/// counters. One per [`crate::sched::SchedService`].
#[derive(Debug)]
pub struct SnapshotHead {
    /// Latest version. The mutex critical section is two pointer copies —
    /// see the module docs for why this never blocks readers behind
    /// writers.
    head: Mutex<Arc<GraphSnapshot>>,
    published: AtomicU64,
    pins: AtomicU64,
    retired: Arc<AtomicU64>,
}

impl SnapshotHead {
    /// Start the version chain with an initial published snapshot.
    pub fn new(graph: &ResourceGraph, prune: &PruneConfig) -> SnapshotHead {
        let retired = Arc::new(AtomicU64::new(0));
        let first = Arc::new(GraphSnapshot {
            graph: graph.clone(),
            prune: prune.clone(),
            version: graph.epoch(),
            retired: Arc::clone(&retired),
        });
        SnapshotHead {
            head: Mutex::new(first),
            published: AtomicU64::new(0),
            pins: AtomicU64::new(0),
            retired,
        }
    }

    /// Pin the latest published version. Wait-free in practice: the lock
    /// covers one `Arc::clone`.
    pub fn pin(&self) -> Arc<GraphSnapshot> {
        self.pins.fetch_add(1, Ordering::Relaxed);
        let head = self
            .head
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        Arc::clone(&head)
    }

    /// Version of the latest published snapshot, without taking a pin
    /// (used by pre-checks that only need the stamp, not the graph).
    pub fn version(&self) -> u64 {
        self.head
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .version
    }

    /// Publish a new version cloned from the authoritative graph. Called
    /// by the service write guard on drop, while the write lock is still
    /// held — so publishes are totally ordered and `version` is monotonic
    /// along the chain.
    pub fn publish(&self, graph: &ResourceGraph, prune: &PruneConfig) {
        let next = Arc::new(GraphSnapshot {
            graph: graph.clone(),
            prune: prune.clone(),
            version: graph.epoch(),
            retired: Arc::clone(&self.retired),
        });
        let prev = {
            let mut head = self
                .head
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            std::mem::replace(&mut *head, next)
        };
        self.published.fetch_add(1, Ordering::Relaxed);
        // superseded version (if unpinned) retires here, outside the lock
        drop(prev);
    }

    /// Lifecycle counters. `live` counts versions not yet retired — with
    /// no outstanding reader pins it must be exactly 1 (the head), which
    /// is the no-leak invariant.
    pub fn stats(&self) -> SnapshotStats {
        let publishes = self.published.load(Ordering::Relaxed);
        let retired = self.retired.load(Ordering::Relaxed);
        SnapshotStats {
            pins: self.pins.load(Ordering::Relaxed),
            publishes,
            retired,
            live: 1 + publishes - retired,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobspec::table1_jobspec;
    use crate::resource::builder::{table2_graph, UidGen};

    // build through SchedInstance::new so pruning aggregates are
    // initialized before the first version is published (the service does
    // the same)
    fn head() -> (ResourceGraph, PruneConfig, SnapshotHead) {
        let inst = crate::sched::SchedInstance::new(
            table2_graph(0, &mut UidGen::new()),
            PruneConfig::default(),
        );
        let h = SnapshotHead::new(&inst.graph, &inst.prune);
        (inst.graph, inst.prune, h)
    }

    #[test]
    fn pin_returns_latest_published_version() {
        let (mut g, prune, h) = head();
        let v0 = h.pin().version;
        assert_eq!(v0, g.epoch());
        g.bump_epochs(3);
        h.publish(&g, &prune);
        let pinned = h.pin();
        assert_eq!(pinned.version, g.epoch());
        assert!(pinned.version > v0);
        assert_eq!(h.stats().pins, 2);
        assert_eq!(h.stats().publishes, 1);
    }

    #[test]
    fn old_version_survives_while_pinned_and_retires_on_unpin() {
        let (mut g, prune, h) = head();
        let old = h.pin();
        let old_version = old.version;
        g.bump_epochs(1);
        h.publish(&g, &prune);
        // superseded but pinned: still readable, not retired
        assert_eq!(old.version, old_version);
        assert_eq!(h.stats().live, 2);
        assert_eq!(h.stats().retired, 0);
        drop(old);
        let s = h.stats();
        assert_eq!(s.retired, 1);
        assert_eq!(s.live, 1, "only the head survives once unpinned");
    }

    #[test]
    fn snapshot_probe_matches_instance_probe() {
        let inst = crate::sched::SchedInstance::new(
            table2_graph(0, &mut UidGen::new()),
            PruneConfig::default(),
        );
        let h = SnapshotHead::new(&inst.graph, &inst.prune);
        let spec = table1_jobspec("T1");
        let mut s1 = MatchScratch::default();
        let mut s2 = MatchScratch::default();
        let a = inst.probe_with(&spec, &mut s1);
        let b = h.pin().probe_with(&spec, &mut s2);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn churn_without_pins_keeps_exactly_one_live_version() {
        let (mut g, prune, h) = head();
        for _ in 0..100 {
            g.bump_epochs(1);
            h.publish(&g, &prune);
        }
        let s = h.stats();
        assert_eq!(s.publishes, 100);
        assert_eq!(s.retired, 100, "every superseded version retired");
        assert_eq!(s.live, 1);
    }
}
