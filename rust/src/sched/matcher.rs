//! Resource matching: depth-first traversal with pruning filters.
//!
//! `match_resources` implements the selection half of MatchAllocate: walk
//! the containment tree looking for free vertices satisfying the jobspec's
//! hierarchical request, pruning subtrees whose free-resource aggregates
//! (see [`crate::sched::pruning`]) cannot satisfy one candidate's needs.
//!
//! Complexity: O(n+m) worst case for a graph of n vertices and m edges, but
//! with the `ALL:core` filter a null match only visits vertices *above* the
//! tracked type (§5.2.3: "complexity dependent on the number of high-level
//! resources"), because insufficient subtrees are skipped without descent.

use crate::jobspec::{JobSpec, ResourceReq};
use crate::resource::graph::{ResourceGraph, VertexId};
use crate::resource::types::ResourceType;
use crate::sched::pruning::PruneConfig;

/// A successful match: selected vertices in parents-before-children order
/// (ready for JGF emission), plus traversal statistics.
#[derive(Debug, Clone)]
pub struct MatchResult {
    pub selection: Vec<VertexId>,
    pub visited: usize,
}

/// Why a match failed (carried up the hierarchy by MatchGrow).
#[derive(Debug, Clone, thiserror::Error)]
pub enum MatchFail {
    #[error("no satisfying resources (visited {visited} vertices)")]
    NoMatch { visited: usize },
}

struct Ctx<'a> {
    g: &'a ResourceGraph,
    cfg: &'a PruneConfig,
    visited: usize,
    /// Vertices tentatively selected in this match (they are not yet marked
    /// in the graph, so the traversal itself must avoid double-picking).
    selected: Vec<bool>,
    /// Per-request-node tracked-type demands, memoized by request identity —
    /// `demand_of` is recursive and the traversal consults it per candidate
    /// (§Perf: recomputing it was ~30% of a large match).
    demands: std::collections::HashMap<*const ResourceReq, Vec<i64>>,
}

impl<'a> Ctx<'a> {
    fn is_free(&self, vid: VertexId) -> bool {
        !self.g.vertex(vid).alloc.is_allocated() && !self.selected[vid.0 as usize]
    }

    /// Pruning check: can the subtree under `vid` possibly supply the
    /// tracked-type demands of one candidate of `req`?
    fn prune_ok(&mut self, vid: VertexId, req: &ResourceReq) -> bool {
        let key = req as *const ResourceReq;
        if !self.demands.contains_key(&key) {
            let v: Vec<i64> = self
                .cfg
                .tracked
                .iter()
                .map(|t| demand_of(req, t))
                .collect();
            self.demands.insert(key, v);
        }
        let needs = &self.demands[&key];
        for (t, &need) in self.cfg.tracked.iter().zip(needs) {
            if need > 0 && self.g.vertex(vid).agg_get(t) < need {
                return false;
            }
        }
        true
    }
}

/// Tracked-type demand of ONE candidate of `req` (itself + nested).
fn demand_of(req: &ResourceReq, t: &ResourceType) -> i64 {
    let own = if req.rtype == t.name() { 1 } else { 0 };
    let nested: i64 = req
        .with
        .iter()
        .map(|c| c.count as i64 * demand_of(c, t))
        .sum();
    own + nested
}

/// Try to satisfy `req.count` candidates within the children of `scope`
/// (descending through intermediate container types). On success appends
/// the selected vertices (parents-first) to `out`.
fn satisfy(ctx: &mut Ctx, scope: VertexId, req: &ResourceReq, out: &mut Vec<VertexId>) -> bool {
    let mut found = 0u64;
    let start = out.len();
    if collect(ctx, scope, req, &mut found, out) {
        true
    } else {
        // roll back tentative selections from this request level
        for &v in &out[start..] {
            ctx.selected[v.0 as usize] = false;
        }
        out.truncate(start);
        false
    }
}

/// DFS over `scope`'s children; candidates are vertices of the requested
/// type, other types are descended through. Returns true once
/// `found == req.count`.
fn collect(
    ctx: &mut Ctx,
    scope: VertexId,
    req: &ResourceReq,
    found: &mut u64,
    out: &mut Vec<VertexId>,
) -> bool {
    let nchild = ctx.g.children_of(scope).len();
    for i in 0..nchild {
        let child = ctx.g.children_of(scope)[i];
        ctx.visited += 1;
        let ctype = &ctx.g.vertex(child).rtype;
        if ctype.name() == req.rtype {
            // exclusive candidates must be free; non-exclusive ("shared")
            // requests use the vertex as scope only and never claim it
            if (req.exclusive && !ctx.is_free(child)) || !ctx.prune_ok(child, req) {
                continue;
            }
            let mark = out.len();
            if req.exclusive {
                // tentatively select the candidate, then its nested needs
                ctx.selected[child.0 as usize] = true;
                out.push(child);
            }
            let mut ok = true;
            for sub in &req.with {
                if !satisfy(ctx, child, sub, out) {
                    ok = false;
                    break;
                }
            }
            if ok {
                *found += 1;
                if *found == req.count {
                    return true;
                }
            } else {
                for &v in &out[mark..] {
                    ctx.selected[v.0 as usize] = false;
                }
                out.truncate(mark);
            }
        } else {
            // descend through an intermediate container (e.g. rack, zone) —
            // but prune if its subtree cannot host even one candidate
            if !ctx.prune_ok(child, req) {
                continue;
            }
            if collect(ctx, child, req, found, out) {
                return true;
            }
        }
    }
    false
}

/// Match a jobspec against the graph. Does NOT mark allocations — callers
/// pass the selection to [`crate::sched::alloc::AllocTable`].
pub fn match_resources(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
) -> Result<MatchResult, MatchFail> {
    let Some(root) = g.root() else {
        return Err(MatchFail::NoMatch { visited: 0 });
    };
    let mut ctx = Ctx {
        g,
        cfg,
        visited: 1,
        selected: vec![false; g.arena_len()],
        demands: std::collections::HashMap::new(),
    };
    let mut out = Vec::new();
    for req in &spec.resources {
        if !satisfy(&mut ctx, root, req, &mut out) {
            return Err(MatchFail::NoMatch {
                visited: ctx.visited,
            });
        }
    }
    // order parents-before-children for JGF emission
    let mut selection = out;
    sort_topological(g, &mut selection);
    Ok(MatchResult {
        selection,
        visited: ctx.visited,
    })
}

/// Order a selection parents-before-children (depth then discovery order).
/// Depth comes from the containment path ('/' count) — O(path length)
/// instead of an ancestor walk per sort-key evaluation.
fn sort_topological(g: &ResourceGraph, selection: &mut [VertexId]) {
    let mut keyed: Vec<(u32, VertexId)> = selection
        .iter()
        .map(|&v| {
            let depth = g.vertex(v).path.bytes().filter(|&b| b == b'/').count() as u32;
            (depth, v)
        })
        .collect();
    keyed.sort_unstable_by_key(|&(d, v)| (d, v.0));
    for (slot, (_, v)) in selection.iter_mut().zip(keyed) {
        *slot = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobspec::{table1_jobspec, JobSpec};
    use crate::resource::builder::{table2_graph, ClusterSpec, UidGen};
    use crate::sched::alloc::AllocTable;
    use crate::sched::pruning::init_aggregates;

    fn ready(g: &mut ResourceGraph) -> PruneConfig {
        let cfg = PruneConfig::default();
        init_aggregates(g, &cfg);
        cfg
    }

    #[test]
    fn t7_matches_on_l3_graph() {
        let mut g = table2_graph(3, &mut UidGen::new()); // 2 nodes
        let cfg = ready(&mut g);
        let spec = table1_jobspec("T7"); // 1 node, 2 sockets, 32 cores
        let m = match_resources(&g, &cfg, &spec).unwrap();
        // 1 node + 2 sockets + 32 cores = 35 vertices
        assert_eq!(m.selection.len(), 35);
        // parents-first: node before sockets before cores
        assert_eq!(g.vertex(m.selection[0]).rtype.name(), "node");
    }

    #[test]
    fn match_does_not_overcommit() {
        let mut g = table2_graph(4, &mut UidGen::new()); // 1 node, 2 sockets, 32 cores
        let cfg = ready(&mut g);
        let mut t = AllocTable::new();
        let spec = JobSpec::nodes_sockets_cores(0, 1, 16); // T8
        let m1 = match_resources(&g, &cfg, &spec).unwrap();
        t.allocate(&mut g, &cfg, m1.selection).unwrap();
        let m2 = match_resources(&g, &cfg, &spec).unwrap();
        t.allocate(&mut g, &cfg, m2.selection).unwrap();
        // both sockets now allocated -> third request must fail
        assert!(match_resources(&g, &cfg, &spec).is_err());
        t.check_consistency(&g).unwrap();
    }

    #[test]
    fn null_match_visits_few_vertices_with_pruning() {
        // fully allocate the graph, then a new request must fail *fast*:
        // pruning skips each node subtree at the node vertex.
        let mut g = table2_graph(1, &mut UidGen::new()); // 8 nodes, 563 sz
        let cfg = ready(&mut g);
        let mut t = AllocTable::new();
        let all = match_resources(&g, &cfg, &JobSpec::nodes_sockets_cores(8, 2, 16)).unwrap();
        t.allocate(&mut g, &cfg, all.selection).unwrap();
        let fail = match_resources(&g, &cfg, &table1_jobspec("T7")).unwrap_err();
        let MatchFail::NoMatch { visited } = fail;
        // 8 node vertices visited (+root), not all 281
        assert!(visited <= 10, "visited {visited}");
    }

    #[test]
    fn partial_allocation_finds_free_sibling() {
        let mut g = table2_graph(3, &mut UidGen::new()); // 2 nodes
        let cfg = ready(&mut g);
        let mut t = AllocTable::new();
        let spec = table1_jobspec("T7");
        let m1 = match_resources(&g, &cfg, &spec).unwrap();
        let first_node = g.vertex(m1.selection[0]).path.clone();
        t.allocate(&mut g, &cfg, m1.selection).unwrap();
        let m2 = match_resources(&g, &cfg, &spec).unwrap();
        let second_node = g.vertex(m2.selection[0]).path.clone();
        assert_ne!(first_node, second_node);
    }

    #[test]
    fn insufficient_nested_resources_fail() {
        let mut g = ClusterSpec::new("c", 2, 2, 8).build(&mut UidGen::new());
        let cfg = ready(&mut g);
        // ask for 16 cores per socket; sockets only have 8
        let spec = JobSpec::nodes_sockets_cores(1, 2, 16);
        assert!(match_resources(&g, &cfg, &spec).is_err());
    }

    #[test]
    fn gpu_request_matches_mixed_graph() {
        let mut g = ClusterSpec::new("c", 2, 2, 4)
            .with_gpus(1)
            .build(&mut UidGen::new());
        let cfg = PruneConfig::all_of(&[ResourceType::Core, ResourceType::Gpu]);
        init_aggregates(&mut g, &cfg);
        let spec = JobSpec::new(vec![crate::jobspec::ResourceReq::new("node", 1)
            .with_child(
                crate::jobspec::ResourceReq::new("socket", 2)
                    .with_child(crate::jobspec::ResourceReq::new("core", 2))
                    .with_child(crate::jobspec::ResourceReq::new("gpu", 1)),
            )]);
        let m = match_resources(&g, &cfg, &spec).unwrap();
        // 1 node + 2 sockets + 4 cores + 2 gpus = 9
        assert_eq!(m.selection.len(), 9);
    }

    #[test]
    fn backtracks_over_fragmented_sockets() {
        // node0 socket0 has 2/4 cores taken; request for 1 socket with 4
        // cores must pick socket1 (requires skipping the fragmented one).
        let mut g = ClusterSpec::new("c", 1, 2, 4).build(&mut UidGen::new());
        let cfg = ready(&mut g);
        let mut t = AllocTable::new();
        let frag: Vec<_> = (0..2)
            .map(|i| g.lookup_path(&format!("/c0/node0/socket0/core{i}")).unwrap())
            .collect();
        t.allocate(&mut g, &cfg, frag).unwrap();
        let spec = JobSpec::nodes_sockets_cores(0, 1, 4);
        let m = match_resources(&g, &cfg, &spec).unwrap();
        assert!(g.vertex(m.selection[0]).path.ends_with("socket1"));
    }

    #[test]
    fn empty_graph_fails() {
        let g = ResourceGraph::new();
        let cfg = PruneConfig::default();
        assert!(match_resources(&g, &cfg, &table1_jobspec("T8")).is_err());
    }
}
