//! Resource matching: depth-first traversal with pruning filters.
//!
//! `match_resources` implements the selection half of MatchAllocate: walk
//! the containment tree looking for free vertices satisfying the jobspec's
//! hierarchical request, pruning subtrees whose free-resource aggregates
//! (see [`crate::sched::pruning`]) cannot satisfy one candidate's needs.
//!
//! Complexity: O(n+m) worst case for a graph of n vertices and m edges, but
//! with the `ALL:core` filter a null match only visits vertices *above* the
//! tracked type (§5.2.3: "complexity dependent on the number of high-level
//! resources"), because insufficient subtrees are skipped without descent.
//!
//! §Perf: the traversal is allocation-free in steady state. All per-match
//! state lives in a reusable [`MatchScratch`]: the tentative-selection set
//! is a word-packed [`BitSet`] sized to the vertex arena, request types are
//! resolved to interned [`TypeId`]s once per call (candidate checks become
//! `u16` compares), and per-request tracked-type demands are precompiled
//! into a dense index-addressed table — replacing a pointer-keyed
//! `HashMap<*const ResourceReq, _>` memo whose address-identity keying was
//! unsound the moment scratch state outlived one jobspec borrow.
//!
//! The per-spec compile ([`compile_spec_into`]) and the traversal
//! ([`match_compiled`]) are separate halves so batched submission
//! ([`crate::sched::SchedInstance::apply_batch`]) can compile once per
//! distinct spec and traverse once per op.

use std::fmt;

use crate::bitmap::BitSet;
use crate::jobspec::{JobSpec, ResourceReq};
use crate::resource::graph::{ResourceGraph, VertexId};
use crate::resource::types::{TypeId, TypeTable};
use crate::sched::pruning::{PruneConfig, TrackedSlots};

/// Sentinel request-type id: the graph has never interned this type, so no
/// vertex can match it (real ids are always below `u16::MAX`).
const NO_TYPE: u16 = u16::MAX;

/// A successful match: selected vertices in parents-before-children order
/// (ready for JGF emission), plus traversal statistics.
#[derive(Debug, Clone)]
pub struct MatchResult {
    /// Selected vertices, parents before children.
    pub selection: Vec<VertexId>,
    /// Vertices visited by the traversal (the paper's match-cost metric).
    pub visited: usize,
}

/// Why a match failed (carried up the hierarchy by MatchGrow).
#[derive(Debug, Clone)]
pub enum MatchFail {
    /// No satisfying free resources.
    NoMatch {
        /// Vertices visited before giving up.
        visited: usize,
    },
}

impl fmt::Display for MatchFail {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MatchFail::NoMatch { visited } => {
                write!(f, "no satisfying resources (visited {visited} vertices)")
            }
        }
    }
}

impl std::error::Error for MatchFail {}

/// Reusable per-match state. One instance per scheduler thread (each
/// `SchedInstance` owns one); after warm-up no match performs heap
/// allocation in the traversal loop — buffers only ever grow.
#[derive(Debug, Default)]
pub struct MatchScratch {
    /// Vertices tentatively selected in this match (they are not yet marked
    /// in the graph, so the traversal itself must avoid double-picking).
    selected: BitSet,
    /// Per request node: interned type id (`NO_TYPE` when unknown).
    req_tid: Vec<u16>,
    /// Per request node × pruning slot: tracked-type demand of ONE
    /// candidate (itself + nested), row-major `[node * nslots + slot]`.
    demand: Vec<i64>,
    /// Per request node: size of its request subtree, so a node's children
    /// sit at consecutive `ix + 1`, `ix + 1 + subtree[ix+1]`, ... indices.
    subtree: Vec<usize>,
    /// Selection buffer filled during traversal.
    out: Vec<VertexId>,
}

/// Capacity snapshot of a [`MatchScratch`] — used by tests to prove steady
/// state performs no per-call allocation (capacities stop changing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScratchFootprint {
    /// Words backing the tentative-selection bitset.
    pub selected_words: usize,
    /// Capacity of the per-request interned-type table.
    pub req_capacity: usize,
    /// Capacity of the dense demand table.
    pub demand_capacity: usize,
    /// Capacity of the request-subtree-size table.
    pub subtree_capacity: usize,
    /// Capacity of the selection output buffer.
    pub out_capacity: usize,
}

// One warm scratch per scheduler *thread*: `SchedService` pool workers each
// own one and probe a shared graph concurrently, so the scratch must be
// safe to move to (and keep on) another thread.
#[allow(dead_code)]
fn _assert_scratch_is_send() {
    fn is_send<T: Send>() {}
    is_send::<MatchScratch>();
}

impl MatchScratch {
    /// An empty scratch; buffers warm up on first use and are then reused.
    pub fn new() -> MatchScratch {
        MatchScratch::default()
    }

    /// Capacity snapshot (see [`ScratchFootprint`]).
    pub fn footprint(&self) -> ScratchFootprint {
        ScratchFootprint {
            selected_words: self.selected.words_len(),
            req_capacity: self.req_tid.capacity(),
            demand_capacity: self.demand.capacity(),
            subtree_capacity: self.subtree.capacity(),
            out_capacity: self.out.capacity(),
        }
    }
}

/// Compile one request node (and recursively its children) into the scratch
/// tables. Returns the node's index. Demand of one candidate of `req` is
/// its own contribution plus `count`-weighted child demands — the same
/// recurrence the old per-(request, type) memo computed, now resolved once
/// per call into dense rows.
fn compile_req(
    req: &ResourceReq,
    types: &TypeTable,
    tracked: &TrackedSlots,
    nslots: usize,
    req_tid: &mut Vec<u16>,
    demand: &mut Vec<i64>,
    subtree: &mut Vec<usize>,
) -> usize {
    let ix = req_tid.len();
    let tid = types
        .lookup_name(&req.rtype)
        .map(|t| t.0)
        .unwrap_or(NO_TYPE);
    req_tid.push(tid);
    demand.resize(demand.len() + nslots, 0);
    subtree.push(1);
    for sub in &req.with {
        let cix = compile_req(sub, types, tracked, nslots, req_tid, demand, subtree);
        subtree[ix] += subtree[cix];
        for slot in 0..nslots {
            let d = demand[cix * nslots + slot];
            demand[ix * nslots + slot] += sub.count as i64 * d;
        }
    }
    if tid != NO_TYPE {
        if let Some(slot) = tracked.slot_of_tid(TypeId(tid)) {
            demand[ix * nslots + slot] += 1;
        }
    }
    ix
}

struct Ctx<'a> {
    g: &'a ResourceGraph,
    nslots: usize,
    visited: usize,
    selected: &'a mut BitSet,
    req_tid: &'a [u16],
    demand: &'a [i64],
    subtree: &'a [usize],
}

impl Ctx<'_> {
    #[inline]
    fn is_free(&self, vid: VertexId) -> bool {
        !self.g.vertex(vid).alloc.is_allocated() && !self.selected.get(vid.0 as usize)
    }

    /// Pruning check: can the subtree under `vid` possibly supply the
    /// tracked-type demands of one candidate of request node `ix`?
    /// Array indexing on both sides — no type resolution per vertex.
    #[inline]
    fn prune_ok(&self, vid: VertexId, ix: usize) -> bool {
        let v = self.g.vertex(vid);
        let base = ix * self.nslots;
        for slot in 0..self.nslots {
            let need = self.demand[base + slot];
            if need > 0 && v.agg_slot(slot) < need {
                return false;
            }
        }
        true
    }
}

/// Try to satisfy `req.count` candidates within the children of `scope`
/// (descending through intermediate container types). On success appends
/// the selected vertices (parents-first) to `out`.
fn satisfy(
    ctx: &mut Ctx,
    out: &mut Vec<VertexId>,
    scope: VertexId,
    req: &ResourceReq,
    ix: usize,
) -> bool {
    let mut found = 0u64;
    let start = out.len();
    if collect(ctx, out, scope, req, ix, &mut found) {
        true
    } else {
        // roll back tentative selections from this request level
        for &v in &out[start..] {
            ctx.selected.clear(v.0 as usize);
        }
        out.truncate(start);
        false
    }
}

/// DFS over `scope`'s children; candidates are vertices of the requested
/// type, other types are descended through. Returns true once
/// `found == req.count`.
fn collect(
    ctx: &mut Ctx,
    out: &mut Vec<VertexId>,
    scope: VertexId,
    req: &ResourceReq,
    ix: usize,
    found: &mut u64,
) -> bool {
    let want = ctx.req_tid[ix];
    let nchild = ctx.g.children_of(scope).len();
    for i in 0..nchild {
        let child = ctx.g.children_of(scope)[i];
        ctx.visited += 1;
        if ctx.g.vertex(child).tid.0 == want {
            // exclusive candidates must be free; non-exclusive ("shared")
            // requests use the vertex as scope only and never claim it
            if (req.exclusive && !ctx.is_free(child)) || !ctx.prune_ok(child, ix) {
                continue;
            }
            let mark = out.len();
            if req.exclusive {
                // tentatively select the candidate, then its nested needs
                ctx.selected.set(child.0 as usize);
                out.push(child);
            }
            let mut ok = true;
            let mut cix = ix + 1;
            for sub in &req.with {
                if !satisfy(ctx, out, child, sub, cix) {
                    ok = false;
                    break;
                }
                cix += ctx.subtree[cix];
            }
            if ok {
                *found += 1;
                if *found == req.count {
                    return true;
                }
            } else {
                for &v in &out[mark..] {
                    ctx.selected.clear(v.0 as usize);
                }
                out.truncate(mark);
            }
        } else {
            // descend through an intermediate container (e.g. rack, zone) —
            // but prune if its subtree cannot host even one candidate
            if !ctx.prune_ok(child, ix) {
                continue;
            }
            if collect(ctx, out, child, req, ix, found) {
                return true;
            }
        }
    }
    false
}

/// Compile `spec`'s request tree into the scratch's per-spec tables
/// (interned type ids, dense demand rows, subtree sizes) — the per-spec
/// half of a match. [`match_compiled`] then runs any number of traversals
/// against the compiled tables; the batch path
/// ([`crate::sched::SchedInstance::apply_batch`]) calls this once per
/// *distinct* spec and skips it when consecutive ops repeat the same spec.
///
/// The tables depend only on the spec, the graph's type intern table, and
/// the pruning config — allocation-state changes between traversals do not
/// invalidate them; structural edits that intern new types
/// (`AddSubgraph`) do.
pub fn compile_spec_into(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
    scratch: &mut MatchScratch,
) {
    let tracked = cfg.resolve(g.types());
    let nslots = cfg.nslots();
    scratch.req_tid.clear();
    scratch.demand.clear();
    scratch.subtree.clear();
    for req in &spec.resources {
        compile_req(
            req,
            g.types(),
            &tracked,
            nslots,
            &mut scratch.req_tid,
            &mut scratch.demand,
            &mut scratch.subtree,
        );
    }
}

/// Traversal core shared by [`match_compiled`] and [`probe_compiled`]:
/// run the compiled request against the graph, leaving the tentative
/// selection in `scratch.out`. Returns visited count.
fn traverse_compiled(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
    scratch: &mut MatchScratch,
) -> Result<usize, MatchFail> {
    let Some(root) = g.root() else {
        return Err(MatchFail::NoMatch { visited: 0 });
    };
    let nslots = cfg.nslots();
    scratch.selected.ensure(g.arena_len());
    scratch.selected.clear_all();
    scratch.out.clear();

    let MatchScratch {
        selected,
        req_tid,
        demand,
        subtree,
        out,
    } = scratch;
    let mut ctx = Ctx {
        g,
        nslots,
        visited: 1,
        selected,
        req_tid: req_tid.as_slice(),
        demand: demand.as_slice(),
        subtree: subtree.as_slice(),
    };
    let mut ix = 0usize;
    for req in &spec.resources {
        if !satisfy(&mut ctx, out, root, req, ix) {
            return Err(MatchFail::NoMatch {
                visited: ctx.visited,
            });
        }
        ix += ctx.subtree[ix];
    }
    Ok(ctx.visited)
}

/// Traverse the graph against tables previously compiled from `spec` by
/// [`compile_spec_into`] (callers must pass the *same* spec to both halves;
/// `SchedInstance` enforces that pairing). Does NOT mark allocations —
/// callers pass the selection to [`crate::sched::alloc::AllocTable`].
pub fn match_compiled(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
    scratch: &mut MatchScratch,
) -> Result<MatchResult, MatchFail> {
    let visited = traverse_compiled(g, cfg, spec, scratch)?;
    // order parents-before-children for JGF emission (one exact-size copy
    // out of the reusable buffer; the traversal itself never allocates)
    let mut selection = scratch.out.as_slice().to_vec();
    sort_topological(g, &mut selection);
    Ok(MatchResult { selection, visited })
}

/// Feasibility-only variant of [`match_compiled`]: returns
/// `(selected vertex count, visited)` without the selection copy or the
/// topological sort — probes discard the selection, so the probe path
/// skips the only remaining per-op allocation entirely.
pub fn probe_compiled(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
    scratch: &mut MatchScratch,
) -> Result<(usize, usize), MatchFail> {
    let visited = traverse_compiled(g, cfg, spec, scratch)?;
    Ok((scratch.out.len(), visited))
}

/// Match a jobspec against the graph, reusing `scratch` across calls:
/// compile, then traverse. One-spec-at-a-time entry point.
pub fn match_resources_in(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
    scratch: &mut MatchScratch,
) -> Result<MatchResult, MatchFail> {
    compile_spec_into(g, cfg, spec, scratch);
    match_compiled(g, cfg, spec, scratch)
}

/// One-shot variant constructing a throwaway scratch. Long-lived callers
/// ([`crate::sched::SchedInstance`]) hold a scratch and use
/// [`match_resources_in`].
pub fn match_resources(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
) -> Result<MatchResult, MatchFail> {
    let mut scratch = MatchScratch::new();
    match_resources_in(g, cfg, spec, &mut scratch)
}

/// Order a selection parents-before-children (depth then discovery order).
/// Depth is cached on the vertex (maintained by `add_child`), so the key is
/// two integer loads — no path scanning, no side table.
fn sort_topological(g: &ResourceGraph, selection: &mut [VertexId]) {
    selection.sort_unstable_by_key(|&v| (g.vertex(v).depth, v.0));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobspec::{table1_jobspec, JobSpec};
    use crate::resource::builder::{table2_graph, ClusterSpec, UidGen};
    use crate::resource::types::ResourceType;
    use crate::sched::alloc::AllocTable;
    use crate::sched::pruning::init_aggregates;

    fn ready(g: &mut ResourceGraph) -> PruneConfig {
        let cfg = PruneConfig::default();
        init_aggregates(g, &cfg);
        cfg
    }

    #[test]
    fn t7_matches_on_l3_graph() {
        let mut g = table2_graph(3, &mut UidGen::new()); // 2 nodes
        let cfg = ready(&mut g);
        let spec = table1_jobspec("T7"); // 1 node, 2 sockets, 32 cores
        let m = match_resources(&g, &cfg, &spec).unwrap();
        // 1 node + 2 sockets + 32 cores = 35 vertices
        assert_eq!(m.selection.len(), 35);
        // parents-first: node before sockets before cores
        assert_eq!(g.type_name(m.selection[0]), "node");
    }

    #[test]
    fn match_does_not_overcommit() {
        let mut g = table2_graph(4, &mut UidGen::new()); // 1 node, 2 sockets, 32 cores
        let cfg = ready(&mut g);
        let mut t = AllocTable::new();
        let spec = JobSpec::nodes_sockets_cores(0, 1, 16); // T8
        let m1 = match_resources(&g, &cfg, &spec).unwrap();
        t.allocate(&mut g, &cfg, m1.selection).unwrap();
        let m2 = match_resources(&g, &cfg, &spec).unwrap();
        t.allocate(&mut g, &cfg, m2.selection).unwrap();
        // both sockets now allocated -> third request must fail
        assert!(match_resources(&g, &cfg, &spec).is_err());
        t.check_consistency(&g).unwrap();
    }

    #[test]
    fn null_match_visits_few_vertices_with_pruning() {
        // fully allocate the graph, then a new request must fail *fast*:
        // pruning skips each node subtree at the node vertex.
        let mut g = table2_graph(1, &mut UidGen::new()); // 8 nodes, 563 sz
        let cfg = ready(&mut g);
        let mut t = AllocTable::new();
        let all = match_resources(&g, &cfg, &JobSpec::nodes_sockets_cores(8, 2, 16)).unwrap();
        t.allocate(&mut g, &cfg, all.selection).unwrap();
        let fail = match_resources(&g, &cfg, &table1_jobspec("T7")).unwrap_err();
        let MatchFail::NoMatch { visited } = fail;
        // 8 node vertices visited (+root), not all 281
        assert!(visited <= 10, "visited {visited}");
    }

    #[test]
    fn partial_allocation_finds_free_sibling() {
        let mut g = table2_graph(3, &mut UidGen::new()); // 2 nodes
        let cfg = ready(&mut g);
        let mut t = AllocTable::new();
        let spec = table1_jobspec("T7");
        let m1 = match_resources(&g, &cfg, &spec).unwrap();
        let first_node = g.vertex(m1.selection[0]).path.clone();
        t.allocate(&mut g, &cfg, m1.selection).unwrap();
        let m2 = match_resources(&g, &cfg, &spec).unwrap();
        let second_node = g.vertex(m2.selection[0]).path.clone();
        assert_ne!(first_node, second_node);
    }

    #[test]
    fn insufficient_nested_resources_fail() {
        let mut g = ClusterSpec::new("c", 2, 2, 8).build(&mut UidGen::new());
        let cfg = ready(&mut g);
        // ask for 16 cores per socket; sockets only have 8
        let spec = JobSpec::nodes_sockets_cores(1, 2, 16);
        assert!(match_resources(&g, &cfg, &spec).is_err());
    }

    #[test]
    fn gpu_request_matches_mixed_graph() {
        let mut g = ClusterSpec::new("c", 2, 2, 4)
            .with_gpus(1)
            .build(&mut UidGen::new());
        let cfg = PruneConfig::all_of(&[ResourceType::Core, ResourceType::Gpu]);
        init_aggregates(&mut g, &cfg);
        let spec = JobSpec::new(vec![crate::jobspec::ResourceReq::new("node", 1)
            .with_child(
                crate::jobspec::ResourceReq::new("socket", 2)
                    .with_child(crate::jobspec::ResourceReq::new("core", 2))
                    .with_child(crate::jobspec::ResourceReq::new("gpu", 1)),
            )]);
        let m = match_resources(&g, &cfg, &spec).unwrap();
        // 1 node + 2 sockets + 4 cores + 2 gpus = 9
        assert_eq!(m.selection.len(), 9);
    }

    #[test]
    fn backtracks_over_fragmented_sockets() {
        // node0 socket0 has 2/4 cores taken; request for 1 socket with 4
        // cores must pick socket1 (requires skipping the fragmented one).
        let mut g = ClusterSpec::new("c", 1, 2, 4).build(&mut UidGen::new());
        let cfg = ready(&mut g);
        let mut t = AllocTable::new();
        let frag: Vec<_> = (0..2)
            .map(|i| g.lookup_path(&format!("/c0/node0/socket0/core{i}")).unwrap())
            .collect();
        t.allocate(&mut g, &cfg, frag).unwrap();
        let spec = JobSpec::nodes_sockets_cores(0, 1, 4);
        let m = match_resources(&g, &cfg, &spec).unwrap();
        assert!(g.vertex(m.selection[0]).path.ends_with("socket1"));
    }

    #[test]
    fn empty_graph_fails() {
        let g = ResourceGraph::new();
        let cfg = PruneConfig::default();
        assert!(match_resources(&g, &cfg, &table1_jobspec("T8")).is_err());
    }

    #[test]
    fn unknown_request_type_fails_without_panic() {
        let mut g = table2_graph(4, &mut UidGen::new());
        let cfg = ready(&mut g);
        let spec = JobSpec::new(vec![crate::jobspec::ResourceReq::new("quantum", 1)]);
        assert!(match_resources(&g, &cfg, &spec).is_err());
    }

    /// Regression for the pointer-keyed demand memo: one scratch reused
    /// across specs living at different (and possibly recycled) heap
    /// addresses must never alias their demand rows.
    #[test]
    fn reused_scratch_is_correct_across_spec_allocations() {
        let mut g = table2_graph(3, &mut UidGen::new());
        let cfg = ready(&mut g);
        let mut scratch = MatchScratch::new();
        let spec_a = Box::new(table1_jobspec("T7"));
        let a = match_resources_in(&g, &cfg, &spec_a, &mut scratch).unwrap();
        drop(spec_a); // free the request nodes; the next Box may reuse them
        let spec_b = Box::new(JobSpec::nodes_sockets_cores(1, 1, 4));
        let b = match_resources_in(&g, &cfg, &spec_b, &mut scratch).unwrap();
        assert_eq!(a.selection.len(), 35);
        assert_eq!(b.selection.len(), 6);
        // the same spec rebuilt at a fresh address reproduces the result
        let spec_c = Box::new(table1_jobspec("T7"));
        let c = match_resources_in(&g, &cfg, &spec_c, &mut scratch).unwrap();
        assert_eq!(c.selection, a.selection);
    }

    /// The split compile/traverse halves agree with the one-shot path, and
    /// re-traversing without recompiling (the batch dedup path) is stable.
    #[test]
    fn compiled_reuse_matches_fresh_compile() {
        let mut g = table2_graph(3, &mut UidGen::new());
        let cfg = ready(&mut g);
        let mut scratch = MatchScratch::new();
        let spec = table1_jobspec("T7");
        compile_spec_into(&g, &cfg, &spec, &mut scratch);
        let a = match_compiled(&g, &cfg, &spec, &mut scratch).unwrap();
        let b = match_compiled(&g, &cfg, &spec, &mut scratch).unwrap();
        assert_eq!(a.selection, b.selection);
        let c = match_resources_in(&g, &cfg, &spec, &mut scratch).unwrap();
        assert_eq!(a.selection, c.selection);
    }

    /// Scratch capacities stabilize: after the first match, repeated
    /// matching allocates nothing new in the traversal state.
    #[test]
    fn scratch_capacities_stabilize() {
        let mut g = table2_graph(1, &mut UidGen::new());
        let cfg = ready(&mut g);
        let mut scratch = MatchScratch::new();
        let spec = table1_jobspec("T4"); // 8 nodes
        match_resources_in(&g, &cfg, &spec, &mut scratch).unwrap();
        let warm = scratch.footprint();
        for _ in 0..100 {
            match_resources_in(&g, &cfg, &spec, &mut scratch).unwrap();
        }
        assert_eq!(scratch.footprint(), warm);
    }
}
