//! Resource matching: depth-first traversal with pruning filters.
//!
//! `match_resources` implements the selection half of MatchAllocate: walk
//! the containment tree looking for free vertices satisfying the jobspec's
//! hierarchical request, pruning subtrees whose free-resource aggregates
//! (see [`crate::sched::pruning`]) cannot satisfy one candidate's needs.
//!
//! Complexity: O(n+m) worst case for a graph of n vertices and m edges, but
//! with the `ALL:core` filter a null match only visits vertices *above* the
//! tracked type (§5.2.3: "complexity dependent on the number of high-level
//! resources"), because insufficient subtrees are skipped without descent.
//!
//! §Perf: the traversal is allocation-free in steady state. All per-match
//! state lives in a reusable [`MatchScratch`]: the tentative-selection set
//! is a word-packed [`BitSet`] sized to the vertex arena, request types are
//! resolved to interned [`TypeId`]s once per call (candidate checks become
//! `u16` compares), and per-request tracked-type demands are precompiled
//! into a dense index-addressed table — replacing a pointer-keyed
//! `HashMap<*const ResourceReq, _>` memo whose address-identity keying was
//! unsound the moment scratch state outlived one jobspec borrow.
//!
//! The per-spec compile ([`compile_spec_into`]) and the traversal
//! ([`match_compiled`]) are separate halves so batched submission
//! ([`crate::sched::SchedInstance::apply_batch`]) can compile once per
//! distinct spec and traverse once per op. The compiled tables live in a
//! standalone [`CompiledSpec`] inside the scratch so the sharded path can
//! share one compile across every shard scan.
//!
//! §Sharding: one match's candidate scan can also be **split across the
//! root's child subtrees** (the ROADMAP's "parallel per-node match").
//! Pruning aggregates are a function of each subtree alone, candidates of
//! one request level form an antichain (disjoint subtrees), and a shard
//! never reads state outside its contiguous child range — so K shard scans
//! ([`run_shard`]) against shard-local scratches plus a deterministic
//! shard-order merge ([`traverse_sharded`]) select a set **bit-identical**
//! to the sequential scan: shard k+1's surplus candidates are consumed
//! only after shard k's are exhausted, preserving first-fit order. The
//! executor that fans shards out is injected (`SchedService` supplies its
//! shard worker pool; tests use [`match_resources_sharded`]'s inline
//! loop), keeping this module thread-free. `visited` counts are the one
//! non-identical output: surplus shards scan past the point where the
//! sequential scan would have stopped, so the sharded cost metric is an
//! upper bound on the sequential one.

use std::fmt;

use crate::bitmap::BitSet;
use crate::jobspec::{JobSpec, ResourceReq};
use crate::resource::graph::{ResourceGraph, VertexId};
use crate::resource::types::{TypeId, TypeTable};
use crate::sched::pruning::{PruneConfig, TrackedSlots};

/// Sentinel request-type id: the graph has never interned this type, so no
/// vertex can match it (real ids are always below `u16::MAX`).
const NO_TYPE: u16 = u16::MAX;

/// A successful match: selected vertices in parents-before-children order
/// (ready for JGF emission), plus traversal statistics.
#[derive(Debug, Clone)]
pub struct MatchResult {
    /// Selected vertices, parents before children.
    pub selection: Vec<VertexId>,
    /// Vertices visited by the traversal (the paper's match-cost metric).
    pub visited: usize,
}

/// Why a match failed (carried up the hierarchy by MatchGrow).
#[derive(Debug, Clone)]
pub enum MatchFail {
    /// No satisfying free resources.
    NoMatch {
        /// Vertices visited before giving up.
        visited: usize,
    },
}

impl fmt::Display for MatchFail {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MatchFail::NoMatch { visited } => {
                write!(f, "no satisfying resources (visited {visited} vertices)")
            }
        }
    }
}

impl std::error::Error for MatchFail {}

/// The per-spec compiled tables — everything a traversal needs that depends
/// only on (spec, graph type table, prune config), none of it on allocation
/// state. Split out of the traversal scratch so the sharded path can share
/// **one** compile across every shard's scan: each shard borrows the
/// dispatcher's `CompiledSpec` read-only while running against its own
/// shard-local traversal state.
///
/// `Clone` because the snapshot-era shard dispatcher hands each fan-out an
/// **owned** copy (alongside its pinned `Arc<GraphSnapshot>`) instead of a
/// raw borrow — the tables are three flat vectors, so the copy is cheap
/// next to a shard scan.
#[derive(Debug, Clone, Default)]
pub struct CompiledSpec {
    /// Per request node: interned type id (`NO_TYPE` when unknown).
    req_tid: Vec<u16>,
    /// Per request node × pruning slot: tracked-type demand of ONE
    /// candidate (itself + nested), row-major `[node * nslots + slot]`.
    demand: Vec<i64>,
    /// Per request node: size of its request subtree, so a node's children
    /// sit at consecutive `ix + 1`, `ix + 1 + subtree[ix+1]`, ... indices.
    subtree: Vec<usize>,
}

/// Reusable buffers for shard planning (see [`traverse_sharded`]): the
/// computed contiguous child ranges plus the DFS stack used to weigh each
/// top-level subtree. Plan state is recomputed per sharded call — it is
/// deliberately NOT cached across calls, because one thread-local scratch
/// serves many graphs (the same aliasing trap the PR 1 pointer-keyed memo
/// fell into). Balance only affects speed, never the selection: the merge
/// is order-preserving for ANY contiguous partition.
#[derive(Debug, Default)]
struct PlanBuf {
    /// Contiguous `[lo, hi)` ranges over the root's child list, in order.
    ranges: Vec<(u32, u32)>,
    /// Reused DFS stack for subtree weighing.
    stack: Vec<VertexId>,
    /// Per top-level child: subtree vertex count.
    weights: Vec<usize>,
}

/// Reusable per-match state. One instance per scheduler thread (each
/// `SchedInstance` owns one); after warm-up no match performs heap
/// allocation in the traversal loop — buffers only ever grow.
#[derive(Debug, Default)]
pub struct MatchScratch {
    /// Vertices tentatively selected in this match (they are not yet marked
    /// in the graph, so the traversal itself must avoid double-picking).
    /// In a shard scan this starts as a copy of the dispatcher's merged
    /// selection (earlier top-level requests), shard-local from there.
    selected: BitSet,
    /// Per-spec compiled tables (see [`CompiledSpec`]).
    compiled: CompiledSpec,
    /// Selection buffer filled during traversal.
    out: Vec<VertexId>,
    /// Shard scans only: `out` offset after each accepted top-level
    /// candidate, so the merge can truncate surplus at candidate
    /// granularity. Untouched on the sequential path.
    ends: Vec<usize>,
    /// Shard-planning buffers (sharded dispatcher only).
    plan: PlanBuf,
}

/// Capacity snapshot of a [`MatchScratch`] — used by tests to prove steady
/// state performs no per-call allocation (capacities stop changing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScratchFootprint {
    /// Words backing the tentative-selection bitset.
    pub selected_words: usize,
    /// Capacity of the per-request interned-type table.
    pub req_capacity: usize,
    /// Capacity of the dense demand table.
    pub demand_capacity: usize,
    /// Capacity of the request-subtree-size table.
    pub subtree_capacity: usize,
    /// Capacity of the selection output buffer.
    pub out_capacity: usize,
}

// One warm scratch per scheduler *thread*: `SchedService` pool workers each
// own one and probe a shared graph concurrently, so the scratch must be
// safe to move to (and keep on) another thread.
#[allow(dead_code)]
fn _assert_scratch_is_send() {
    fn is_send<T: Send>() {}
    is_send::<MatchScratch>();
}

impl MatchScratch {
    /// An empty scratch; buffers warm up on first use and are then reused.
    pub fn new() -> MatchScratch {
        MatchScratch::default()
    }

    /// Capacity snapshot (see [`ScratchFootprint`]).
    pub fn footprint(&self) -> ScratchFootprint {
        ScratchFootprint {
            selected_words: self.selected.words_len(),
            req_capacity: self.compiled.req_tid.capacity(),
            demand_capacity: self.compiled.demand.capacity(),
            subtree_capacity: self.compiled.subtree.capacity(),
            out_capacity: self.out.capacity(),
        }
    }
}

/// Compile one request node (and recursively its children) into the scratch
/// tables. Returns the node's index. Demand of one candidate of `req` is
/// its own contribution plus `count`-weighted child demands — the same
/// recurrence the old per-(request, type) memo computed, now resolved once
/// per call into dense rows.
fn compile_req(
    req: &ResourceReq,
    types: &TypeTable,
    tracked: &TrackedSlots,
    nslots: usize,
    req_tid: &mut Vec<u16>,
    demand: &mut Vec<i64>,
    subtree: &mut Vec<usize>,
) -> usize {
    let ix = req_tid.len();
    let tid = types
        .lookup_name(&req.rtype)
        .map(|t| t.0)
        .unwrap_or(NO_TYPE);
    req_tid.push(tid);
    demand.resize(demand.len() + nslots, 0);
    subtree.push(1);
    for sub in &req.with {
        let cix = compile_req(sub, types, tracked, nslots, req_tid, demand, subtree);
        subtree[ix] += subtree[cix];
        for slot in 0..nslots {
            let d = demand[cix * nslots + slot];
            demand[ix * nslots + slot] += sub.count as i64 * d;
        }
    }
    if tid != NO_TYPE {
        if let Some(slot) = tracked.slot_of_tid(TypeId(tid)) {
            demand[ix * nslots + slot] += 1;
        }
    }
    ix
}

struct Ctx<'a> {
    g: &'a ResourceGraph,
    nslots: usize,
    visited: usize,
    selected: &'a mut BitSet,
    req_tid: &'a [u16],
    demand: &'a [i64],
    subtree: &'a [usize],
    /// `out` offset after each accepted candidate of the request node at
    /// `top_ix` — the shard merge's truncation boundaries. The sequential
    /// path sets `top_ix = usize::MAX` so nothing is ever recorded.
    ends: &'a mut Vec<usize>,
    top_ix: usize,
}

impl Ctx<'_> {
    #[inline]
    fn is_free(&self, vid: VertexId) -> bool {
        !self.g.vertex(vid).alloc.is_allocated() && !self.selected.get(vid.0 as usize)
    }

    /// Pruning check: can the subtree under `vid` possibly supply the
    /// tracked-type demands of one candidate of request node `ix`?
    /// Array indexing on both sides — no type resolution per vertex.
    #[inline]
    fn prune_ok(&self, vid: VertexId, ix: usize) -> bool {
        let v = self.g.vertex(vid);
        let base = ix * self.nslots;
        for slot in 0..self.nslots {
            let need = self.demand[base + slot];
            if need > 0 && v.agg_slot(slot) < need {
                return false;
            }
        }
        true
    }
}

/// Try to satisfy `req.count` candidates within the children of `scope`
/// (descending through intermediate container types). On success appends
/// the selected vertices (parents-first) to `out`.
fn satisfy(
    ctx: &mut Ctx,
    out: &mut Vec<VertexId>,
    scope: VertexId,
    req: &ResourceReq,
    ix: usize,
) -> bool {
    let mut found = 0u64;
    let start = out.len();
    if collect(ctx, out, scope, req, ix, &mut found, 0, usize::MAX) {
        true
    } else {
        // roll back tentative selections from this request level
        for &v in &out[start..] {
            ctx.selected.clear(v.0 as usize);
        }
        out.truncate(start);
        false
    }
}

/// DFS over `scope`'s children restricted to the index range `[lo, hi)`
/// (`usize::MAX` = all; recursion always descends the full child list —
/// only a shard's *top-level* loop is range-limited); candidates are
/// vertices of the requested type, other types are descended through.
/// Returns true once `found == req.count`.
#[allow(clippy::too_many_arguments)]
fn collect(
    ctx: &mut Ctx,
    out: &mut Vec<VertexId>,
    scope: VertexId,
    req: &ResourceReq,
    ix: usize,
    found: &mut u64,
    lo: usize,
    hi: usize,
) -> bool {
    let want = ctx.req_tid[ix];
    let nchild = ctx.g.children_of(scope).len();
    let hi = hi.min(nchild);
    for i in lo..hi {
        let child = ctx.g.children_of(scope)[i];
        ctx.visited += 1;
        if ctx.g.vertex(child).tid.0 == want {
            // exclusive candidates must be free; non-exclusive ("shared")
            // requests use the vertex as scope only and never claim it
            if (req.exclusive && !ctx.is_free(child)) || !ctx.prune_ok(child, ix) {
                continue;
            }
            let mark = out.len();
            if req.exclusive {
                // tentatively select the candidate, then its nested needs
                ctx.selected.set(child.0 as usize);
                out.push(child);
            }
            let mut ok = true;
            let mut cix = ix + 1;
            for sub in &req.with {
                if !satisfy(ctx, out, child, sub, cix) {
                    ok = false;
                    break;
                }
                cix += ctx.subtree[cix];
            }
            if ok {
                *found += 1;
                if ix == ctx.top_ix {
                    // shard scan: remember where this candidate's segment
                    // ends so the merge can truncate surplus exactly here
                    ctx.ends.push(out.len());
                }
                if *found == req.count {
                    return true;
                }
            } else {
                for &v in &out[mark..] {
                    ctx.selected.clear(v.0 as usize);
                }
                out.truncate(mark);
            }
        } else {
            // descend through an intermediate container (e.g. rack, zone) —
            // but prune if its subtree cannot host even one candidate
            if !ctx.prune_ok(child, ix) {
                continue;
            }
            if collect(ctx, out, child, req, ix, found, 0, usize::MAX) {
                return true;
            }
        }
    }
    false
}

/// Compile `spec`'s request tree into the scratch's per-spec tables
/// (interned type ids, dense demand rows, subtree sizes) — the per-spec
/// half of a match. [`match_compiled`] then runs any number of traversals
/// against the compiled tables; the batch path
/// ([`crate::sched::SchedInstance::apply_batch`]) calls this once per
/// *distinct* spec and skips it when consecutive ops repeat the same spec.
///
/// The tables depend only on the spec, the graph's type intern table, and
/// the pruning config — allocation-state changes between traversals do not
/// invalidate them; structural edits that intern new types
/// (`AddSubgraph`) do.
pub fn compile_spec_into(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
    scratch: &mut MatchScratch,
) {
    let tracked = cfg.resolve(g.types());
    let nslots = cfg.nslots();
    scratch.compiled.req_tid.clear();
    scratch.compiled.demand.clear();
    scratch.compiled.subtree.clear();
    for req in &spec.resources {
        compile_req(
            req,
            g.types(),
            &tracked,
            nslots,
            &mut scratch.compiled.req_tid,
            &mut scratch.compiled.demand,
            &mut scratch.compiled.subtree,
        );
    }
}

/// Traversal core shared by [`match_compiled`] and [`probe_compiled`]:
/// run the compiled request against the graph, leaving the tentative
/// selection in `scratch.out`. Returns visited count.
fn traverse_compiled(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
    scratch: &mut MatchScratch,
) -> Result<usize, MatchFail> {
    let Some(root) = g.root() else {
        return Err(MatchFail::NoMatch { visited: 0 });
    };
    let nslots = cfg.nslots();
    scratch.selected.ensure(g.arena_len());
    scratch.selected.clear_all();
    scratch.out.clear();
    // the sequential path never reads `ends`, but the scratch is shared
    // with the sharded path — don't leave another call's boundaries behind
    scratch.ends.clear();

    let MatchScratch {
        selected,
        compiled,
        out,
        ends,
        ..
    } = scratch;
    let mut ctx = Ctx {
        g,
        nslots,
        visited: 1,
        selected,
        req_tid: compiled.req_tid.as_slice(),
        demand: compiled.demand.as_slice(),
        subtree: compiled.subtree.as_slice(),
        ends,
        top_ix: usize::MAX,
    };
    let mut ix = 0usize;
    for req in &spec.resources {
        if !satisfy(&mut ctx, out, root, req, ix) {
            return Err(MatchFail::NoMatch {
                visited: ctx.visited,
            });
        }
        ix += ctx.subtree[ix];
    }
    Ok(ctx.visited)
}

/// Traverse the graph against tables previously compiled from `spec` by
/// [`compile_spec_into`] (callers must pass the *same* spec to both halves;
/// `SchedInstance` enforces that pairing). Does NOT mark allocations —
/// callers pass the selection to [`crate::sched::alloc::AllocTable`].
pub fn match_compiled(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
    scratch: &mut MatchScratch,
) -> Result<MatchResult, MatchFail> {
    let visited = traverse_compiled(g, cfg, spec, scratch)?;
    // order parents-before-children for JGF emission (one exact-size copy
    // out of the reusable buffer; the traversal itself never allocates)
    let mut selection = scratch.out.as_slice().to_vec();
    sort_topological(g, &mut selection);
    Ok(MatchResult { selection, visited })
}

/// Feasibility-only variant of [`match_compiled`]: returns
/// `(selected vertex count, visited)` without the selection copy or the
/// topological sort — probes discard the selection, so the probe path
/// skips the only remaining per-op allocation entirely.
pub fn probe_compiled(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
    scratch: &mut MatchScratch,
) -> Result<(usize, usize), MatchFail> {
    let visited = traverse_compiled(g, cfg, spec, scratch)?;
    Ok((scratch.out.len(), visited))
}

/// Match a jobspec against the graph, reusing `scratch` across calls:
/// compile, then traverse. One-spec-at-a-time entry point.
pub fn match_resources_in(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
    scratch: &mut MatchScratch,
) -> Result<MatchResult, MatchFail> {
    compile_spec_into(g, cfg, spec, scratch);
    match_compiled(g, cfg, spec, scratch)
}

/// One-shot variant constructing a throwaway scratch. Long-lived callers
/// ([`crate::sched::SchedInstance`]) hold a scratch and use
/// [`match_resources_in`].
pub fn match_resources(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
) -> Result<MatchResult, MatchFail> {
    let mut scratch = MatchScratch::new();
    match_resources_in(g, cfg, spec, &mut scratch)
}

// ---- intra-match sharding ---------------------------------------------------

/// One top-level request's shard fan-out, handed to the executor: the graph,
/// the dispatcher's compiled tables and already-merged selection (both
/// borrowed read-only by every shard), the request node being scanned, and
/// the contiguous child ranges. The executor must return exactly one
/// [`ShardScan`] per range, **in range order** — the merge's first-fit
/// guarantee depends on it.
pub struct ShardJob<'a> {
    /// The graph under match (read-only for the whole fan-out).
    pub g: &'a ResourceGraph,
    /// Pruning slot count of the active config.
    pub nslots: usize,
    /// Compiled per-spec tables, shared by every shard.
    pub compiled: &'a CompiledSpec,
    /// Merged selection of earlier top-level requests; each shard seeds its
    /// local selection from this.
    pub base_selected: &'a BitSet,
    /// The top-level request node being scanned.
    pub req: &'a ResourceReq,
    /// Compiled index of `req` (its row base in the demand table).
    pub ix: usize,
    /// Contiguous `[lo, hi)` ranges over the root's children, in order.
    pub ranges: &'a [(u32, u32)],
}

/// What one shard scan produced: up to `req.count` accepted candidates from
/// its child range, in DFS order.
#[derive(Debug, Clone, Default)]
pub struct ShardScan {
    /// Accepted top-level candidates (== `ends.len()`).
    pub found: u64,
    /// Shard-local tentative selection, DFS order (candidate segments
    /// back-to-back, each candidate followed by its nested picks).
    pub out: Vec<VertexId>,
    /// `out` offset after each accepted candidate — the merge truncates
    /// surplus at these boundaries.
    pub ends: Vec<usize>,
    /// Vertices this shard visited (cost metric; sums across shards to an
    /// upper bound on the sequential scan's count).
    pub visited: usize,
}

/// Partition the root's children into at most `shards` contiguous ranges
/// balanced by subtree vertex count (one iterative DFS per child, stack
/// reused). Never emits an empty range; emits fewer ranges than requested
/// when the root has fewer children.
///
/// The weighing walk is O(total vertices) per plan — deliberate: the
/// sharded path is opt-in for the wide-scan regime where the scan itself
/// is O(n) and dwarfs the walk (PERF.md's cost model). In prune-strong
/// regimes where the sequential scan is already O(root children), planning
/// would cost more than the scan — callers belong on the K=1 sequential
/// path there, not on a cheaper plan.
fn plan_shards(g: &ResourceGraph, root: VertexId, shards: usize, plan: &mut PlanBuf) {
    plan.ranges.clear();
    let n = g.children_of(root).len();
    if n == 0 {
        return;
    }
    let k = shards.clamp(1, n);
    if k == 1 {
        plan.ranges.push((0, n as u32));
        return;
    }
    plan.weights.clear();
    let mut total = 0usize;
    for i in 0..n {
        let child = g.children_of(root)[i];
        let mut w = 0usize;
        plan.stack.clear();
        plan.stack.push(child);
        while let Some(v) = plan.stack.pop() {
            w += 1;
            for &cc in g.children_of(v) {
                plan.stack.push(cc);
            }
        }
        plan.weights.push(w);
        total += w;
    }
    let target = total.div_ceil(k);
    let mut lo = 0usize;
    let mut acc = 0usize;
    for i in 0..n {
        acc += plan.weights[i];
        // shards still owed after the one being built
        let remaining_shards = k - plan.ranges.len() - 1;
        let children_left = n - i - 1;
        // close the current range once it carries its share — or when every
        // remaining child is needed to keep the remaining shards non-empty
        if remaining_shards > 0 && (acc >= target || children_left == remaining_shards) {
            plan.ranges.push((lo as u32, (i + 1) as u32));
            lo = i + 1;
            acc = 0;
        }
    }
    plan.ranges.push((lo as u32, n as u32));
    debug_assert!(plan.ranges.len() <= k);
    debug_assert_eq!(plan.ranges.last().map(|r| r.1), Some(n as u32));
}

/// Public entry to the PR 5 shard planner for the **write-sharding** path
/// ([`crate::sched::alloc`]): partition the root's children into at most
/// `shards` contiguous `[lo, hi)` index ranges balanced by subtree vertex
/// count — the same partition `traverse_sharded` scans with, so read-side
/// shard scans and write-side commit shards agree on which subtree belongs
/// to which shard. Returns an empty vec when the graph has no root or the
/// root has no children (callers fall back to serial commits).
pub fn plan_write_shards(g: &ResourceGraph, shards: usize) -> Vec<(u32, u32)> {
    let Some(root) = g.root() else {
        return Vec::new();
    };
    let mut plan = PlanBuf::default();
    plan_shards(g, root, shards, &mut plan);
    plan.ranges
}

/// Run one shard of a [`ShardJob`]: scan the child range `job.ranges[shard]`
/// for up to `job.req.count` candidates against `scratch`'s shard-local
/// traversal state (selection seeded from `job.base_selected`, compiled
/// tables borrowed from the job). Identical decisions to the sequential scan
/// restricted to that range: candidates are disjoint subtrees, so nothing a
/// shard reads is influenced by any other shard.
pub fn run_shard(job: &ShardJob<'_>, shard: usize, scratch: &mut MatchScratch) -> ShardScan {
    let (lo, hi) = job.ranges[shard];
    let root = job.g.root().expect("sharded scan requires a rooted graph");
    let MatchScratch {
        selected,
        out,
        ends,
        ..
    } = scratch;
    selected.ensure(job.g.arena_len());
    selected.clear_all();
    selected.union_with(job.base_selected);
    out.clear();
    ends.clear();
    // reborrow (not move) the scratch fields into the context so they are
    // usable again for the copy-out below
    let mut ctx = Ctx {
        g: job.g,
        nslots: job.nslots,
        visited: 0,
        selected: &mut *selected,
        req_tid: &job.compiled.req_tid,
        demand: &job.compiled.demand,
        subtree: &job.compiled.subtree,
        ends: &mut *ends,
        top_ix: job.ix,
    };
    let mut found = 0u64;
    // no rollback on shortfall: partial candidates are exactly what the
    // sequential scan would have kept when reaching this range mid-request
    collect(
        &mut ctx,
        out,
        root,
        job.req,
        job.ix,
        &mut found,
        lo as usize,
        hi as usize,
    );
    let visited = ctx.visited;
    ShardScan {
        found,
        out: out.clone(),
        ends: ends.clone(),
        visited,
    }
}

/// Sharded counterpart of the sequential traversal core behind
/// [`match_compiled`]/[`probe_compiled`]: plan contiguous child
/// ranges, fan each top-level request's scan out through `exec`, and merge
/// in shard order — shard k+1's candidates are consumed only after shard
/// k's are exhausted, so the merged selection is **bit-identical** to the
/// sequential scan's (first-fit order preserved). Bails to the sequential
/// path when `shards <= 1` or the plan collapses to one range (a root with
/// one child, or none): split/merge overhead buys nothing there.
///
/// Caller must have compiled `spec` into `scratch` first
/// ([`compile_spec_into`]), exactly as with [`match_compiled`].
pub fn traverse_sharded(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
    scratch: &mut MatchScratch,
    shards: usize,
    exec: &mut dyn FnMut(&ShardJob<'_>) -> Vec<ShardScan>,
) -> Result<usize, MatchFail> {
    let Some(root) = g.root() else {
        return Err(MatchFail::NoMatch { visited: 0 });
    };
    plan_shards(g, root, shards, &mut scratch.plan);
    if shards <= 1 || scratch.plan.ranges.len() <= 1 {
        return traverse_compiled(g, cfg, spec, scratch);
    }
    let nslots = cfg.nslots();
    scratch.selected.ensure(g.arena_len());
    scratch.selected.clear_all();
    scratch.out.clear();
    let mut visited = 1usize;
    let mut ix = 0usize;
    for req in &spec.resources {
        if req.count == 0 {
            // mirror the sequential scan, which never reports success for a
            // zero-count request
            return Err(MatchFail::NoMatch { visited });
        }
        let scans = {
            let MatchScratch {
                selected,
                compiled,
                plan,
                ..
            } = &*scratch;
            let job = ShardJob {
                g,
                nslots,
                compiled,
                base_selected: selected,
                req,
                ix,
                ranges: &plan.ranges,
            };
            exec(&job)
        };
        debug_assert_eq!(scans.len(), scratch.plan.ranges.len());
        for s in &scans {
            visited += s.visited;
        }
        // deterministic shard-order reduction: take whole candidates from
        // each shard in range order until the request is satisfied
        let mut remaining = req.count;
        for s in &scans {
            if remaining == 0 {
                break;
            }
            let take = s.found.min(remaining);
            if take > 0 {
                let end = s.ends[take as usize - 1];
                for &v in &s.out[..end] {
                    scratch.selected.set(v.0 as usize);
                    scratch.out.push(v);
                }
                remaining -= take;
            }
        }
        if remaining > 0 {
            return Err(MatchFail::NoMatch { visited });
        }
        ix += scratch.compiled.subtree[ix];
    }
    Ok(visited)
}

/// Sharded counterpart of [`probe_compiled`]: `(selected count, visited)`
/// without the selection copy. Selection count is bit-identical to the
/// sequential probe; `visited` is the sharded cost (an upper bound).
pub fn probe_sharded_compiled(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
    scratch: &mut MatchScratch,
    shards: usize,
    exec: &mut dyn FnMut(&ShardJob<'_>) -> Vec<ShardScan>,
) -> Result<(usize, usize), MatchFail> {
    let visited = traverse_sharded(g, cfg, spec, scratch, shards, exec)?;
    Ok((scratch.out.len(), visited))
}

/// Sharded counterpart of [`match_compiled`]: the returned selection is
/// bit-identical to the sequential one (same set, same topological order).
pub fn match_sharded_compiled(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
    scratch: &mut MatchScratch,
    shards: usize,
    exec: &mut dyn FnMut(&ShardJob<'_>) -> Vec<ShardScan>,
) -> Result<MatchResult, MatchFail> {
    let visited = traverse_sharded(g, cfg, spec, scratch, shards, exec)?;
    let mut selection = scratch.out.as_slice().to_vec();
    sort_topological(g, &mut selection);
    Ok(MatchResult { selection, visited })
}

/// One-shot sharded match running every shard inline on the calling thread
/// (one shard-local scratch reused serially) — the deterministic reference
/// the oracle tests compare against, and the single-threaded fallback.
/// Concurrent fan-out lives in `crate::sched::SchedService`, which supplies
/// a pooled executor instead.
pub fn match_resources_sharded(
    g: &ResourceGraph,
    cfg: &PruneConfig,
    spec: &JobSpec,
    shards: usize,
) -> Result<MatchResult, MatchFail> {
    let mut scratch = MatchScratch::new();
    let mut shard_scratch = MatchScratch::new();
    compile_spec_into(g, cfg, spec, &mut scratch);
    let mut exec = |job: &ShardJob<'_>| -> Vec<ShardScan> {
        (0..job.ranges.len())
            .map(|s| run_shard(job, s, &mut shard_scratch))
            .collect()
    };
    match_sharded_compiled(g, cfg, spec, &mut scratch, shards, &mut exec)
}

/// Order a selection parents-before-children (depth then discovery order).
/// Depth is cached on the vertex (maintained by `add_child`), so the key is
/// two integer loads — no path scanning, no side table.
fn sort_topological(g: &ResourceGraph, selection: &mut [VertexId]) {
    selection.sort_unstable_by_key(|&v| (g.vertex(v).depth, v.0));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobspec::{table1_jobspec, JobSpec};
    use crate::resource::builder::{table2_graph, ClusterSpec, UidGen};
    use crate::resource::types::ResourceType;
    use crate::sched::alloc::AllocTable;
    use crate::sched::pruning::init_aggregates;

    fn ready(g: &mut ResourceGraph) -> PruneConfig {
        let cfg = PruneConfig::default();
        init_aggregates(g, &cfg);
        cfg
    }

    #[test]
    fn t7_matches_on_l3_graph() {
        let mut g = table2_graph(3, &mut UidGen::new()); // 2 nodes
        let cfg = ready(&mut g);
        let spec = table1_jobspec("T7"); // 1 node, 2 sockets, 32 cores
        let m = match_resources(&g, &cfg, &spec).unwrap();
        // 1 node + 2 sockets + 32 cores = 35 vertices
        assert_eq!(m.selection.len(), 35);
        // parents-first: node before sockets before cores
        assert_eq!(g.type_name(m.selection[0]), "node");
    }

    #[test]
    fn match_does_not_overcommit() {
        let mut g = table2_graph(4, &mut UidGen::new()); // 1 node, 2 sockets, 32 cores
        let cfg = ready(&mut g);
        let mut t = AllocTable::new();
        let spec = JobSpec::nodes_sockets_cores(0, 1, 16); // T8
        let m1 = match_resources(&g, &cfg, &spec).unwrap();
        t.allocate(&mut g, &cfg, m1.selection).unwrap();
        let m2 = match_resources(&g, &cfg, &spec).unwrap();
        t.allocate(&mut g, &cfg, m2.selection).unwrap();
        // both sockets now allocated -> third request must fail
        assert!(match_resources(&g, &cfg, &spec).is_err());
        t.check_consistency(&g).unwrap();
    }

    #[test]
    fn null_match_visits_few_vertices_with_pruning() {
        // fully allocate the graph, then a new request must fail *fast*:
        // pruning skips each node subtree at the node vertex.
        let mut g = table2_graph(1, &mut UidGen::new()); // 8 nodes, 563 sz
        let cfg = ready(&mut g);
        let mut t = AllocTable::new();
        let all = match_resources(&g, &cfg, &JobSpec::nodes_sockets_cores(8, 2, 16)).unwrap();
        t.allocate(&mut g, &cfg, all.selection).unwrap();
        let fail = match_resources(&g, &cfg, &table1_jobspec("T7")).unwrap_err();
        let MatchFail::NoMatch { visited } = fail;
        // 8 node vertices visited (+root), not all 281
        assert!(visited <= 10, "visited {visited}");
    }

    #[test]
    fn partial_allocation_finds_free_sibling() {
        let mut g = table2_graph(3, &mut UidGen::new()); // 2 nodes
        let cfg = ready(&mut g);
        let mut t = AllocTable::new();
        let spec = table1_jobspec("T7");
        let m1 = match_resources(&g, &cfg, &spec).unwrap();
        let first_node = g.vertex(m1.selection[0]).path.clone();
        t.allocate(&mut g, &cfg, m1.selection).unwrap();
        let m2 = match_resources(&g, &cfg, &spec).unwrap();
        let second_node = g.vertex(m2.selection[0]).path.clone();
        assert_ne!(first_node, second_node);
    }

    #[test]
    fn insufficient_nested_resources_fail() {
        let mut g = ClusterSpec::new("c", 2, 2, 8).build(&mut UidGen::new());
        let cfg = ready(&mut g);
        // ask for 16 cores per socket; sockets only have 8
        let spec = JobSpec::nodes_sockets_cores(1, 2, 16);
        assert!(match_resources(&g, &cfg, &spec).is_err());
    }

    #[test]
    fn gpu_request_matches_mixed_graph() {
        let mut g = ClusterSpec::new("c", 2, 2, 4)
            .with_gpus(1)
            .build(&mut UidGen::new());
        let cfg = PruneConfig::all_of(&[ResourceType::Core, ResourceType::Gpu]);
        init_aggregates(&mut g, &cfg);
        let spec = JobSpec::new(vec![crate::jobspec::ResourceReq::new("node", 1)
            .with_child(
                crate::jobspec::ResourceReq::new("socket", 2)
                    .with_child(crate::jobspec::ResourceReq::new("core", 2))
                    .with_child(crate::jobspec::ResourceReq::new("gpu", 1)),
            )]);
        let m = match_resources(&g, &cfg, &spec).unwrap();
        // 1 node + 2 sockets + 4 cores + 2 gpus = 9
        assert_eq!(m.selection.len(), 9);
    }

    #[test]
    fn backtracks_over_fragmented_sockets() {
        // node0 socket0 has 2/4 cores taken; request for 1 socket with 4
        // cores must pick socket1 (requires skipping the fragmented one).
        let mut g = ClusterSpec::new("c", 1, 2, 4).build(&mut UidGen::new());
        let cfg = ready(&mut g);
        let mut t = AllocTable::new();
        let frag: Vec<_> = (0..2)
            .map(|i| g.lookup_path(&format!("/c0/node0/socket0/core{i}")).unwrap())
            .collect();
        t.allocate(&mut g, &cfg, frag).unwrap();
        let spec = JobSpec::nodes_sockets_cores(0, 1, 4);
        let m = match_resources(&g, &cfg, &spec).unwrap();
        assert!(g.vertex(m.selection[0]).path.ends_with("socket1"));
    }

    #[test]
    fn empty_graph_fails() {
        let g = ResourceGraph::new();
        let cfg = PruneConfig::default();
        assert!(match_resources(&g, &cfg, &table1_jobspec("T8")).is_err());
    }

    #[test]
    fn unknown_request_type_fails_without_panic() {
        let mut g = table2_graph(4, &mut UidGen::new());
        let cfg = ready(&mut g);
        let spec = JobSpec::new(vec![crate::jobspec::ResourceReq::new("quantum", 1)]);
        assert!(match_resources(&g, &cfg, &spec).is_err());
    }

    /// Regression for the pointer-keyed demand memo: one scratch reused
    /// across specs living at different (and possibly recycled) heap
    /// addresses must never alias their demand rows.
    #[test]
    fn reused_scratch_is_correct_across_spec_allocations() {
        let mut g = table2_graph(3, &mut UidGen::new());
        let cfg = ready(&mut g);
        let mut scratch = MatchScratch::new();
        let spec_a = Box::new(table1_jobspec("T7"));
        let a = match_resources_in(&g, &cfg, &spec_a, &mut scratch).unwrap();
        drop(spec_a); // free the request nodes; the next Box may reuse them
        let spec_b = Box::new(JobSpec::nodes_sockets_cores(1, 1, 4));
        let b = match_resources_in(&g, &cfg, &spec_b, &mut scratch).unwrap();
        assert_eq!(a.selection.len(), 35);
        assert_eq!(b.selection.len(), 6);
        // the same spec rebuilt at a fresh address reproduces the result
        let spec_c = Box::new(table1_jobspec("T7"));
        let c = match_resources_in(&g, &cfg, &spec_c, &mut scratch).unwrap();
        assert_eq!(c.selection, a.selection);
    }

    /// The split compile/traverse halves agree with the one-shot path, and
    /// re-traversing without recompiling (the batch dedup path) is stable.
    #[test]
    fn compiled_reuse_matches_fresh_compile() {
        let mut g = table2_graph(3, &mut UidGen::new());
        let cfg = ready(&mut g);
        let mut scratch = MatchScratch::new();
        let spec = table1_jobspec("T7");
        compile_spec_into(&g, &cfg, &spec, &mut scratch);
        let a = match_compiled(&g, &cfg, &spec, &mut scratch).unwrap();
        let b = match_compiled(&g, &cfg, &spec, &mut scratch).unwrap();
        assert_eq!(a.selection, b.selection);
        let c = match_resources_in(&g, &cfg, &spec, &mut scratch).unwrap();
        assert_eq!(a.selection, c.selection);
    }

    /// Sharded selection is bit-identical to the sequential scan, across
    /// shard widths, on free and fragmented graphs.
    #[test]
    fn sharded_selection_bit_identical_to_sequential() {
        let mut g = table2_graph(1, &mut UidGen::new()); // 8 nodes
        let cfg = ready(&mut g);
        let mut t = AllocTable::new();
        // fragment: take 2 cores of node1's socket0 and all of node3
        let frag: Vec<_> = (0..2)
            .map(|i| {
                g.lookup_path(&format!("/cluster0/node1/socket0/core{i}"))
                    .unwrap()
            })
            .collect();
        t.allocate(&mut g, &cfg, frag).unwrap();
        let node3 = g.lookup_path("/cluster0/node3").unwrap();
        let node3_all = g.dfs(node3);
        t.allocate(&mut g, &cfg, node3_all).unwrap();
        for spec in [
            table1_jobspec("T7"),
            table1_jobspec("T6"),
            table1_jobspec("T4"), // all 8 nodes: infeasible after node3 went
            JobSpec::nodes_sockets_cores(0, 3, 16),
            JobSpec::nodes_sockets_cores(5, 2, 16),
        ] {
            let seq = match_resources(&g, &cfg, &spec);
            for k in [2usize, 3, 4, 8, 17] {
                let sharded = match_resources_sharded(&g, &cfg, &spec, k);
                match (&seq, &sharded) {
                    (Ok(a), Ok(b)) => {
                        assert_eq!(a.selection, b.selection, "spec {} k {k}", spec.dump())
                    }
                    (Err(_), Err(_)) => {}
                    _ => panic!("feasibility diverged for {} at k {k}", spec.dump()),
                }
            }
        }
    }

    /// `shards <= 1` (and single-child roots) bail to the sequential path —
    /// including the `visited` cost metric, which the sharded path only
    /// upper-bounds.
    #[test]
    fn sharded_k1_bails_to_sequential_exactly() {
        let mut g = table2_graph(3, &mut UidGen::new());
        let cfg = ready(&mut g);
        let spec = table1_jobspec("T7");
        let seq = match_resources(&g, &cfg, &spec).unwrap();
        let k1 = match_resources_sharded(&g, &cfg, &spec, 1).unwrap();
        assert_eq!(seq.selection, k1.selection);
        assert_eq!(seq.visited, k1.visited, "k=1 must be the sequential scan");
        // single root child: any k collapses to one range -> sequential
        let mut g1 = table2_graph(4, &mut UidGen::new()); // 1 node
        let cfg1 = ready(&mut g1);
        let s = JobSpec::nodes_sockets_cores(1, 2, 16);
        let seq1 = match_resources(&g1, &cfg1, &s).unwrap();
        let k4 = match_resources_sharded(&g1, &cfg1, &s, 4).unwrap();
        assert_eq!(seq1.selection, k4.selection);
        assert_eq!(seq1.visited, k4.visited);
    }

    /// Multiple top-level requests: shard scans of request r must see the
    /// merged selection of requests 1..r-1 (the base-selected seeding).
    #[test]
    fn sharded_multi_request_spec_propagates_selection() {
        let mut g = table2_graph(3, &mut UidGen::new()); // 2 nodes
        let cfg = ready(&mut g);
        let sock = crate::jobspec::ResourceReq::new("socket", 1)
            .with_child(crate::jobspec::ResourceReq::new("core", 16));
        let spec = JobSpec::new(vec![
            crate::jobspec::ResourceReq::new("node", 1).with_child(sock.clone()),
            crate::jobspec::ResourceReq::new("node", 1).with_child(sock),
        ]);
        let seq = match_resources(&g, &cfg, &spec).unwrap();
        for k in [2usize, 4] {
            let sharded = match_resources_sharded(&g, &cfg, &spec, k).unwrap();
            assert_eq!(seq.selection, sharded.selection, "k {k}");
        }
        // the two requests picked two DIFFERENT nodes
        let nodes: Vec<_> = seq
            .selection
            .iter()
            .filter(|&&v| g.type_name(v) == "node")
            .collect();
        assert_eq!(nodes.len(), 2);
    }

    /// Zero-count and degenerate inputs fail exactly like the sequential
    /// scan (which never reports success for a zero-count request).
    #[test]
    fn sharded_degenerate_inputs_match_sequential() {
        let mut g = table2_graph(3, &mut UidGen::new());
        let cfg = ready(&mut g);
        let zero = JobSpec::new(vec![crate::jobspec::ResourceReq::new("node", 0)]);
        assert!(match_resources(&g, &cfg, &zero).is_err());
        assert!(match_resources_sharded(&g, &cfg, &zero, 4).is_err());
        let empty = ResourceGraph::new();
        assert!(match_resources_sharded(&empty, &cfg, &table1_jobspec("T8"), 4).is_err());
        let unknown = JobSpec::new(vec![crate::jobspec::ResourceReq::new("quantum", 1)]);
        assert!(match_resources_sharded(&g, &cfg, &unknown, 2).is_err());
    }

    /// Scratch capacities stabilize: after the first match, repeated
    /// matching allocates nothing new in the traversal state.
    #[test]
    fn scratch_capacities_stabilize() {
        let mut g = table2_graph(1, &mut UidGen::new());
        let cfg = ready(&mut g);
        let mut scratch = MatchScratch::new();
        let spec = table1_jobspec("T4"); // 8 nodes
        match_resources_in(&g, &cfg, &spec, &mut scratch).unwrap();
        let warm = scratch.footprint();
        for _ in 0..100 {
            match_resources_in(&g, &cfg, &spec, &mut scratch).unwrap();
        }
        assert_eq!(scratch.footprint(), warm);
    }
}
