//! The graph scheduler core: matching with pruning filters, allocation
//! bookkeeping, and the dynamic grow/shrink transformations of paper §3.
//!
//! The entry surface is the typed protocol ([`crate::rpc::proto`]):
//! [`SchedInstance::apply`] interprets one [`SchedOp`],
//! [`SchedInstance::apply_batch`] a whole queue with spec-level dedup, and
//! [`SchedService`] serves either concurrently — read-only probes run
//! lock-free against pinned RCU snapshots ([`snapshot`]) and fan out
//! across a worker pool (with epoch-keyed result caching) while mutating
//! ops serialize on the write side and publish a fresh snapshot version
//! on commit. When journaling is enabled ([`journal`]), every mutating op
//! is written ahead to a checksummed frame log so a crashed level recovers
//! by snapshot + bounded replay, bit-identical to its committed state.

pub mod alloc;
pub mod grow;
pub mod instance;
pub mod journal;
pub mod matcher;
pub mod pruning;
pub mod service;
pub mod snapshot;

pub use alloc::{AllocTable, WriteShards};
pub use instance::SchedInstance;
pub use journal::{recover, states_bit_identical, JournalSnapshot, OpJournal, Recovery};
pub use snapshot::{GraphSnapshot, SnapshotHead, SnapshotStats};
pub use matcher::{
    compile_spec_into, match_compiled, match_resources, match_resources_in,
    match_resources_sharded, plan_write_shards, MatchFail, MatchResult, MatchScratch,
};
pub use pruning::PruneConfig;
pub use service::{CacheStats, SchedService, ServiceWriteGuard};

// Re-exported so scheduler callers get the op/reply vocabulary without
// reaching into the rpc module (the protocol is the scheduler's API).
pub use crate::rpc::proto::{SchedOp, SchedReply};
