//! The graph scheduler core: matching with pruning filters, allocation
//! bookkeeping, and the dynamic grow/shrink transformations of paper §3.

pub mod alloc;
pub mod grow;
pub mod instance;
pub mod matcher;
pub mod pruning;

pub use alloc::AllocTable;
pub use instance::SchedInstance;
pub use matcher::{match_resources, match_resources_in, MatchFail, MatchResult, MatchScratch};
pub use pruning::PruneConfig;
