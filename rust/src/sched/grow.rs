//! Dynamic graph transformations: `AddSubgraph`, `UpdateMetadata`, and
//! subgraph removal (paper §3 and Algorithm 1).
//!
//! `add_subgraph` walks a JGF document and splices missing vertices/edges
//! into the local graph. Vertex identity is the containment path; the attach
//! point of each subgraph root is found through the graph's path index in
//! O(1) ("localization"), so the whole operation is **O(n + m)** in the
//! subgraph size — independent of the resource graph size, which is what
//! makes hierarchical elasticity scalable (§5.2.2 / Fig 1b).
//!
//! `update_metadata` then refreshes scheduling metadata: interior aggregates
//! in one pass plus the subgraph roots' totals bubbled to their `p`
//! pre-existing ancestors — **O(n + m + p)**.

use crate::resource::graph::{GraphError, JobId, ResourceGraph, VertexId};
use crate::resource::jgf::Jgf;
use crate::sched::alloc::{AllocError, AllocTable};
use crate::sched::pruning::{update_for_attach, update_for_detach, PruneConfig};

/// Why a dynamic graph transformation failed.
#[derive(Debug)]
pub enum GrowError {
    /// A subgraph root's parent path is absent from this graph.
    NoAttachPoint(String),
    /// The underlying graph edit was rejected.
    Graph(GraphError),
    /// The allocation bookkeeping step was rejected.
    Alloc(AllocError),
}

impl std::fmt::Display for GrowError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GrowError::NoAttachPoint(p) => {
                write!(f, "subgraph root '{p}' has no attach point in this graph")
            }
            GrowError::Graph(e) => e.fmt(f),
            GrowError::Alloc(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for GrowError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            GrowError::NoAttachPoint(_) => None,
            GrowError::Graph(e) => Some(e),
            GrowError::Alloc(e) => Some(e),
        }
    }
}

impl From<GraphError> for GrowError {
    fn from(e: GraphError) -> GrowError {
        GrowError::Graph(e)
    }
}

impl From<AllocError> for GrowError {
    fn from(e: AllocError) -> GrowError {
        GrowError::Alloc(e)
    }
}

/// Result of adding a subgraph: which vertices were newly created (in
/// parents-before-children order) and how many already existed (the paper:
/// "the addition is the identity if the vertices already exist").
#[derive(Debug, Clone)]
pub struct AddReport {
    /// Newly created vertices, parents before children.
    pub added: Vec<VertexId>,
    /// Vertices that already existed (identity).
    pub preexisting: usize,
}

/// Algorithm 1, `AddSubgraph`: splice `jgf` into `g`. Nodes must be ordered
/// parents-before-children (JGF emitted by this crate always is).
pub fn add_subgraph(g: &mut ResourceGraph, jgf: &Jgf) -> Result<AddReport, GrowError> {
    let mut added = Vec::with_capacity(jgf.nodes.len());
    let mut preexisting = 0usize;
    for n in &jgf.nodes {
        if g.lookup_path(&n.path).is_some() {
            preexisting += 1; // identity: vertex already present
            continue;
        }
        let vid = match n.parent_path() {
            None => g.add_root(n.to_vertex())?,
            Some(pp) => {
                // O(1) attach-point lookup via the path index
                let parent = g
                    .lookup_path(pp)
                    .ok_or_else(|| GrowError::NoAttachPoint(n.path.clone()))?;
                g.add_child(parent, n.to_vertex())?
            }
        };
        added.push(vid);
    }
    Ok(AddReport { added, preexisting })
}

/// Algorithm 1, `UpdateMetadata`: refresh pruning aggregates for the newly
/// attached vertices and their ancestors.
pub fn update_metadata(g: &mut ResourceGraph, report: &AddReport, cfg: &PruneConfig) {
    update_for_attach(g, &report.added, cfg);
}

/// `RunGrow` with `add = true` (Algorithm 1): splice the subgraph, refresh
/// metadata, and (if `job` is given) hand the new vertices to that running
/// job's allocation — arriving resources belong to the job that grew.
pub fn run_grow(
    g: &mut ResourceGraph,
    allocs: &mut AllocTable,
    cfg: &PruneConfig,
    jgf: &Jgf,
    job: Option<JobId>,
) -> Result<AddReport, GrowError> {
    let report = add_subgraph(g, jgf)?;
    update_metadata(g, &report, cfg);
    if let Some(job) = job {
        allocs.grow(g, cfg, job, report.added.clone())?;
    }
    Ok(report)
}

/// Subtractive transformation: detach the subtree rooted at `path`,
/// updating ancestor aggregates first (bottom-up direction, §3).
/// Returns the number of removed vertices.
pub fn remove_subgraph(
    g: &mut ResourceGraph,
    cfg: &PruneConfig,
    path: &str,
) -> Result<usize, GrowError> {
    let root = g
        .lookup_path(path)
        .ok_or_else(|| GrowError::NoAttachPoint(path.to_string()))?;
    update_for_detach(g, root, cfg);
    Ok(g.remove_subtree(root)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobspec::JobSpec;
    use crate::resource::builder::{ClusterSpec, UidGen};
    use crate::resource::types::ResourceType;
    use crate::sched::matcher::match_resources;
    use crate::sched::pruning::{check_aggregates, init_aggregates};

    /// Build a parent graph, match a request on it, and emit the grant JGF —
    /// the top-down payload of a MatchGrow.
    fn grant(uids: &mut UidGen, nodes: usize) -> (Jgf, PruneConfig) {
        let mut parent = ClusterSpec::new("cluster", 8, 2, 8).build(uids);
        let cfg = PruneConfig::default();
        init_aggregates(&mut parent, &cfg);
        let spec = JobSpec::nodes_sockets_cores(nodes as u64, 2, 8);
        let m = match_resources(&parent, &cfg, &spec).unwrap();
        (Jgf::from_selection(&parent, &m.selection), cfg)
    }

    fn child_graph(uids: &mut UidGen) -> ResourceGraph {
        // child owns nodes 6..8 of the same cluster namespace
        let mut g = ClusterSpec::new("cluster", 2, 2, 8)
            .with_node_base(6)
            .build(uids);
        init_aggregates(&mut g, &PruneConfig::default());
        g
    }

    #[test]
    fn add_subgraph_attaches_and_updates() {
        let mut uids = UidGen::new();
        let (jgf, cfg) = grant(&mut uids, 2); // grants node0, node1
        let mut child = child_graph(&mut uids);
        let before = child.size();
        let report = add_subgraph(&mut child, &jgf).unwrap();
        update_metadata(&mut child, &report, &cfg);
        assert_eq!(report.added.len(), jgf.nodes.len());
        assert_eq!(report.preexisting, 0);
        assert_eq!(child.size(), before + jgf.size());
        child.check_invariants().unwrap();
        check_aggregates(&child, &cfg).unwrap();
        // free cores grew by the subgraph's cores
        let root = child.root().unwrap();
        assert_eq!(cfg.free_at(&child, root, &ResourceType::Core), 32 + 32);
    }

    #[test]
    fn add_is_identity_on_existing_vertices() {
        let mut uids = UidGen::new();
        let (jgf, cfg) = grant(&mut uids, 1);
        let mut child = child_graph(&mut uids);
        let r1 = add_subgraph(&mut child, &jgf).unwrap();
        update_metadata(&mut child, &r1, &cfg);
        let size = child.size();
        // adding the same subgraph again is the identity
        let r2 = add_subgraph(&mut child, &jgf).unwrap();
        assert!(r2.added.is_empty());
        assert_eq!(r2.preexisting, jgf.nodes.len());
        assert_eq!(child.size(), size);
        check_aggregates(&child, &cfg).unwrap();
    }

    #[test]
    fn missing_attach_point_fails() {
        let mut uids = UidGen::new();
        let (jgf, _) = grant(&mut uids, 1);
        // a graph with a different cluster namespace has no attach point
        let mut other = ClusterSpec::new("elsewhere", 1, 1, 2).build(&mut uids);
        assert!(matches!(
            add_subgraph(&mut other, &jgf),
            Err(GrowError::NoAttachPoint(_))
        ));
    }

    #[test]
    fn run_grow_assigns_to_job() {
        let mut uids = UidGen::new();
        let (jgf, cfg) = grant(&mut uids, 1);
        let mut child = child_graph(&mut uids);
        let mut allocs = AllocTable::new();
        // the child has a running job occupying one of its own nodes
        let spec = JobSpec::nodes_sockets_cores(1, 2, 8);
        let m = match_resources(&child, &cfg, &spec).unwrap();
        let job = allocs.allocate(&mut child, &cfg, m.selection).unwrap();

        let report = run_grow(&mut child, &mut allocs, &cfg, &jgf, Some(job)).unwrap();
        assert_eq!(
            allocs.get(job).unwrap().vertices.len(),
            19 + report.added.len()
        );
        // grown vertices are allocated -> they contribute 0 free cores
        check_aggregates(&child, &cfg).unwrap();
        allocs.check_consistency(&child).unwrap();
    }

    #[test]
    fn remove_subgraph_roundtrip() {
        let mut uids = UidGen::new();
        let (jgf, cfg) = grant(&mut uids, 1);
        let mut child = child_graph(&mut uids);
        let before_size = child.size();
        let root = child.root().unwrap();
        let before_free = cfg.free_at(&child, root, &ResourceType::Core);

        let report = add_subgraph(&mut child, &jgf).unwrap();
        update_metadata(&mut child, &report, &cfg);
        let added_root_path = child.vertex(report.added[0]).path.clone();
        let removed = remove_subgraph(&mut child, &cfg, &added_root_path).unwrap();

        assert_eq!(removed, report.added.len());
        assert_eq!(child.size(), before_size);
        assert_eq!(cfg.free_at(&child, root, &ResourceType::Core), before_free);
        child.check_invariants().unwrap();
        check_aggregates(&child, &cfg).unwrap();
    }

    #[test]
    fn grown_resources_can_be_matched_later() {
        // after growing, a new MatchAllocate can use the added resources
        let mut uids = UidGen::new();
        let (jgf, cfg) = grant(&mut uids, 2);
        let mut child = child_graph(&mut uids);
        let mut allocs = AllocTable::new();
        run_grow(&mut child, &mut allocs, &cfg, &jgf, None).unwrap();
        // child originally has 2 nodes; now 4 -> a 4-node request matches
        let spec = JobSpec::nodes_sockets_cores(4, 2, 8);
        let m = match_resources(&child, &cfg, &spec).unwrap();
        assert_eq!(m.selection.len(), 4 * 19);
    }
}
