//! Allocation bookkeeping: which vertices belong to which jobs.
//!
//! The paper's MatchGrow differs from MatchAllocate only in that "the new
//! resources are given the allocation metadata of a running job allocation"
//! (§5.1) — so grow extends an existing [`JobId`]'s vertex set instead of
//! minting a new one.
//!
//! ## Sharded write commits (PR 8)
//!
//! [`WriteShards`] partitions the allocation bookkeeping by **root-child
//! subtree**, reusing the PR 5 shard planner
//! ([`crate::sched::matcher::plan_write_shards`]) so write shards and the
//! sharded read scan agree on subtree ownership. Each shard owns a
//! per-subtree allocation map (the partition of [`AllocTable`]'s vertex
//! sets) plus its own [`SpineBuf`] aggregate-delta buffer; a commit marks
//! shard-owned vertices and bubbles aggregates strictly inside the shard's
//! subtree, then merges every shard's buffered spine deltas at the depth-1
//! root in one short coalesced pass. The protocol preserves the PR 5
//! determinism contract: for a fixed op stream the final graph, allocation
//! table, pruning aggregates, **and epoch** are bit-identical to serial
//! [`AllocTable::allocate`]/[`AllocTable::free`] application — deltas are
//! additive (order-independent within one op) and the spine merge
//! compensates the epoch for every coalesced write
//! ([`ResourceGraph::bump_epochs`]). [`AllocTable`] itself stays
//! authoritative (JGF encoding, structural grow/shrink, and the
//! consistency oracle all keep reading it); the shard maps are the
//! commit-path index, and [`WriteShards::check_partition`] proves the two
//! views stay equal.

use std::collections::HashMap;

use crate::resource::graph::{JobId, ResourceGraph, VertexId};
use crate::sched::matcher::plan_write_shards;
use crate::sched::pruning::{bubble_delta, bubble_delta_split, PruneConfig, SpineBuf};

/// Lifecycle state of a job allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// The job holds resources.
    Running,
    /// The job has been freed; its record remains for id stability.
    Completed,
}

/// One job's allocation record.
#[derive(Debug, Clone)]
pub struct Allocation {
    /// The job this record belongs to.
    pub job: JobId,
    /// Vertices currently held (empty once completed).
    pub vertices: Vec<VertexId>,
    /// Lifecycle state.
    pub state: JobState,
}

/// Allocation table for one scheduler instance.
#[derive(Debug, Default, Clone)]
pub struct AllocTable {
    jobs: HashMap<JobId, Allocation>,
    next_job: u64,
}

/// Why an allocation-table operation failed.
#[derive(Debug)]
pub enum AllocError {
    /// The job id is not in the table.
    NoSuchJob(JobId),
    /// A selected vertex is already held by another job.
    AlreadyAllocated(VertexId),
    /// The job exists but has completed.
    NotRunning(JobId),
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::NoSuchJob(j) => write!(f, "job {j:?} not found"),
            AllocError::AlreadyAllocated(v) => write!(f, "vertex {v:?} already allocated"),
            AllocError::NotRunning(j) => write!(f, "job {j:?} is not running"),
        }
    }
}

impl std::error::Error for AllocError {}

impl AllocTable {
    /// An empty table (job ids start at 0).
    pub fn new() -> AllocTable {
        AllocTable::default()
    }

    /// Mint the next job id.
    pub fn fresh_job_id(&mut self) -> JobId {
        let id = JobId(self.next_job);
        self.next_job += 1;
        id
    }

    /// A job's allocation record, if known.
    pub fn get(&self, job: JobId) -> Option<&Allocation> {
        self.jobs.get(&job)
    }

    /// Iterate records of jobs currently holding resources.
    pub fn running_jobs(&self) -> impl Iterator<Item = &Allocation> {
        self.jobs.values().filter(|a| a.state == JobState::Running)
    }

    /// Number of job records (running and completed).
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    /// Whether the table has no records.
    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Mark `selection` allocated to a *new* job. Updates vertex alloc
    /// metadata and pruning aggregates (ancestor-local, O(k·depth)).
    pub fn allocate(
        &mut self,
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        selection: Vec<VertexId>,
    ) -> Result<JobId, AllocError> {
        let job = self.fresh_job_id();
        self.mark(g, cfg, job, selection.clone())?;
        self.jobs.insert(
            job,
            Allocation {
                job,
                vertices: selection,
                state: JobState::Running,
            },
        );
        Ok(job)
    }

    /// Grow an existing running job by `selection` (MatchGrow semantics).
    pub fn grow(
        &mut self,
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        job: JobId,
        selection: Vec<VertexId>,
    ) -> Result<(), AllocError> {
        match self.jobs.get(&job) {
            None => return Err(AllocError::NoSuchJob(job)),
            Some(a) if a.state != JobState::Running => {
                return Err(AllocError::NotRunning(job))
            }
            Some(_) => {}
        }
        self.mark(g, cfg, job, selection.clone())?;
        self.jobs
            .get_mut(&job)
            .expect("checked above")
            .vertices
            .extend(selection);
        Ok(())
    }

    fn mark(
        &mut self,
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        job: JobId,
        selection: Vec<VertexId>,
    ) -> Result<(), AllocError> {
        // validate first so failure leaves no partial marks
        for &vid in &selection {
            if g.vertex(vid).alloc.is_allocated() {
                return Err(AllocError::AlreadyAllocated(vid));
            }
        }
        for vid in selection {
            g.vertex_mut(vid).alloc.jobs.push(job);
            bubble_delta(g, vid, cfg, -1);
        }
        Ok(())
    }

    /// Release a job's resources (shrink-to-zero / completion).
    pub fn free(
        &mut self,
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        job: JobId,
    ) -> Result<usize, AllocError> {
        let alloc = self.jobs.get_mut(&job).ok_or(AllocError::NoSuchJob(job))?;
        if alloc.state != JobState::Running {
            return Err(AllocError::NotRunning(job));
        }
        alloc.state = JobState::Completed;
        let vertices = std::mem::take(&mut alloc.vertices);
        let n = vertices.len();
        for vid in vertices {
            if g.vertex(vid).dead {
                continue; // vertex left with a removed subgraph
            }
            g.vertex_mut(vid).alloc.jobs.retain(|&j| j != job);
            if !g.vertex(vid).alloc.is_allocated() {
                bubble_delta(g, vid, cfg, 1);
            }
        }
        Ok(n)
    }

    /// Release a subset of a running job's vertices (partial shrink).
    pub fn shrink(
        &mut self,
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        job: JobId,
        victims: &[VertexId],
    ) -> Result<(), AllocError> {
        let alloc = self.jobs.get_mut(&job).ok_or(AllocError::NoSuchJob(job))?;
        if alloc.state != JobState::Running {
            return Err(AllocError::NotRunning(job));
        }
        alloc.vertices.retain(|v| !victims.contains(v));
        for &vid in victims {
            if g.vertex(vid).dead {
                continue;
            }
            g.vertex_mut(vid).alloc.jobs.retain(|&j| j != job);
            if !g.vertex(vid).alloc.is_allocated() {
                bubble_delta(g, vid, cfg, 1);
            }
        }
        Ok(())
    }

    /// Conservation check for tests: every vertex's job list agrees with the
    /// table and vice versa.
    pub fn check_consistency(&self, g: &ResourceGraph) -> Result<(), String> {
        for a in self.jobs.values() {
            if a.state != JobState::Running {
                continue;
            }
            for &vid in &a.vertices {
                if g.vertex(vid).dead {
                    return Err(format!("job {:?} holds dead vertex", a.job));
                }
                if !g.vertex(vid).alloc.jobs.contains(&a.job) {
                    return Err(format!(
                        "vertex {} missing job {:?}",
                        g.vertex(vid).path,
                        a.job
                    ));
                }
            }
        }
        for vid in g.iter_live() {
            for j in &g.vertex(vid).alloc.jobs {
                let Some(a) = self.jobs.get(j) else {
                    return Err(format!("vertex {} has unknown job", g.vertex(vid).path));
                };
                if !a.vertices.contains(&vid) {
                    return Err(format!(
                        "table for {:?} missing vertex {}",
                        j,
                        g.vertex(vid).path
                    ));
                }
            }
        }
        Ok(())
    }
}

// ---- sharded write commits (PR 8) ------------------------------------------

/// One write shard's slice of the allocation bookkeeping: this shard's
/// partition of the allocation table (job → vertices held *inside the
/// shard's root-child subtree*) plus the shard's deferred aggregate-delta
/// buffer for the commit's spine merge.
#[derive(Debug, Clone, Default)]
pub struct AllocShard {
    /// Job → vertices this shard holds for it. Never contains an empty
    /// vector or a completed job — entries are removed as jobs drain.
    jobs: HashMap<JobId, Vec<VertexId>>,
    /// Spine-delta buffer for the in-flight commit; drained (empty)
    /// between commits.
    spine: SpineBuf,
}

impl AllocShard {
    /// Vertices this shard holds for `job` (empty if none).
    pub fn vertices_of(&self, job: JobId) -> &[VertexId] {
        self.jobs.get(&job).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Number of jobs with at least one vertex in this shard.
    pub fn job_count(&self) -> usize {
        self.jobs.len()
    }
}

/// Subtree-sharded write-commit state: the PR 5 shard plan over the root's
/// children, the child→shard ownership map derived from it, and one
/// [`AllocShard`] per plan range plus a trailing **spine bucket** for
/// vertices no shard owns (the root itself, or root children grown in
/// after the plan was built). See the module docs for the commit protocol
/// and the determinism argument.
#[derive(Debug, Clone, Default)]
pub struct WriteShards {
    /// Contiguous `[lo, hi)` ranges over the root's children, in order
    /// (the PR 5 partition — read scans and write commits agree on it).
    ranges: Vec<(u32, u32)>,
    /// Root-child vertex → owning shard index.
    child_shard: HashMap<VertexId, usize>,
    /// Per-shard state; `ranges.len() + 1` entries, the last being the
    /// spine/unowned bucket.
    shards: Vec<AllocShard>,
}

impl WriteShards {
    /// Plan `shards` write shards over the graph's current root children
    /// (empty shard maps — call [`WriteShards::rebuild`] to index an
    /// already-populated table). A rootless or childless graph yields zero
    /// planned shards; every vertex then lands in the spine bucket.
    pub fn plan(g: &ResourceGraph, shards: usize) -> WriteShards {
        let ranges = plan_write_shards(g, shards);
        let mut child_shard = HashMap::new();
        if let Some(root) = g.root() {
            let children = g.children_of(root);
            for (s, &(lo, hi)) in ranges.iter().enumerate() {
                for i in lo as usize..hi as usize {
                    child_shard.insert(children[i], s);
                }
            }
        }
        let buckets = ranges.len() + 1;
        WriteShards {
            ranges,
            child_shard,
            shards: vec![AllocShard::default(); buckets],
        }
    }

    /// Number of planned subtree shards (the spine bucket not counted).
    pub fn num_shards(&self) -> usize {
        self.ranges.len()
    }

    /// The planned `[lo, hi)` root-child ranges.
    pub fn ranges(&self) -> &[(u32, u32)] {
        &self.ranges
    }

    /// Index of the spine/unowned bucket.
    pub fn spine_bucket(&self) -> usize {
        self.ranges.len()
    }

    /// A shard bucket by index (`0..=num_shards()`, the last being the
    /// spine bucket).
    pub fn shard(&self, s: usize) -> Option<&AllocShard> {
        self.shards.get(s)
    }

    /// Owning bucket of a vertex: the shard of its depth-2 (root-child)
    /// ancestor, or the spine bucket for the root itself and for subtrees
    /// the plan has never seen. O(depth) parent walk, read-only.
    pub fn shard_of(&self, g: &ResourceGraph, vid: VertexId) -> usize {
        let mut cur = vid;
        loop {
            let d = g.vertex(cur).depth;
            if d < 2 {
                return self.spine_bucket();
            }
            if d == 2 {
                return self
                    .child_shard
                    .get(&cur)
                    .copied()
                    .unwrap_or_else(|| self.spine_bucket());
            }
            match g.parent_of(cur) {
                Some(p) => cur = p,
                None => return self.spine_bucket(),
            }
        }
    }

    /// Re-index the shard maps from the authoritative table (used after
    /// serial-fallback ops — structural grow/shrink, snapshot restores —
    /// that mutate the table without going through a sharded commit).
    pub fn rebuild(&mut self, g: &ResourceGraph, table: &AllocTable) {
        for shard in &mut self.shards {
            shard.jobs.clear();
        }
        for a in table.jobs.values() {
            if a.state != JobState::Running {
                continue;
            }
            for &vid in &a.vertices {
                if g.vertex(vid).dead {
                    continue;
                }
                let s = self.shard_of(g, vid);
                self.shards[s].jobs.entry(a.job).or_default().push(vid);
            }
        }
    }

    /// Oracle: the shard maps are exactly a partition of the table's
    /// running allocations — every sharded vertex is in the table under
    /// its owning shard, every running table vertex is in its owning
    /// shard's map, and no spine buffer holds undrained deltas.
    pub fn check_partition(
        &self,
        g: &ResourceGraph,
        table: &AllocTable,
    ) -> Result<(), String> {
        for (s, shard) in self.shards.iter().enumerate() {
            if !shard.spine.is_empty() {
                return Err(format!("shard {s} has undrained spine deltas"));
            }
            for (job, held) in &shard.jobs {
                let Some(a) = table.jobs.get(job) else {
                    return Err(format!("shard {s} holds unknown job {job:?}"));
                };
                if a.state != JobState::Running {
                    return Err(format!("shard {s} holds completed job {job:?}"));
                }
                if held.is_empty() {
                    return Err(format!("shard {s} has empty entry for {job:?}"));
                }
                for &vid in held {
                    if self.shard_of(g, vid) != s {
                        return Err(format!(
                            "vertex {vid:?} of {job:?} filed under wrong shard {s}"
                        ));
                    }
                    if !a.vertices.contains(&vid) {
                        return Err(format!(
                            "shard {s} holds {vid:?} not in table for {job:?}"
                        ));
                    }
                }
            }
        }
        for a in table.jobs.values() {
            if a.state != JobState::Running {
                continue;
            }
            for &vid in &a.vertices {
                if g.vertex(vid).dead {
                    continue;
                }
                let s = self.shard_of(g, vid);
                let present = self.shards[s]
                    .jobs
                    .get(&a.job)
                    .map(|held| held.contains(&vid))
                    .unwrap_or(false);
                if !present {
                    return Err(format!(
                        "table vertex {vid:?} of {:?} missing from shard {s}",
                        a.job
                    ));
                }
            }
        }
        Ok(())
    }
}

impl AllocTable {
    /// Sharded twin of [`AllocTable::allocate`]: mark `selection` for a new
    /// job via the subtree-sharded commit protocol. `on_shard` fires once
    /// per shard bucket that participates, *before* that bucket's writes —
    /// the service's telemetry/fault-injection hook. Bit-identical final
    /// state (table, aggregates, epoch) to the serial twin.
    pub fn allocate_sharded(
        &mut self,
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        ws: &mut WriteShards,
        selection: Vec<VertexId>,
        on_shard: impl FnMut(usize),
    ) -> Result<JobId, AllocError> {
        let job = self.fresh_job_id();
        self.mark_sharded(g, cfg, ws, job, &selection, on_shard)?;
        self.jobs.insert(
            job,
            Allocation {
                job,
                vertices: selection,
                state: JobState::Running,
            },
        );
        Ok(job)
    }

    /// Sharded twin of [`AllocTable::grow`] (same `on_shard` hook as
    /// [`AllocTable::allocate_sharded`]).
    pub fn grow_sharded(
        &mut self,
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        ws: &mut WriteShards,
        job: JobId,
        selection: Vec<VertexId>,
        on_shard: impl FnMut(usize),
    ) -> Result<(), AllocError> {
        match self.jobs.get(&job) {
            None => return Err(AllocError::NoSuchJob(job)),
            Some(a) if a.state != JobState::Running => {
                return Err(AllocError::NotRunning(job))
            }
            Some(_) => {}
        }
        self.mark_sharded(g, cfg, ws, job, &selection, on_shard)?;
        self.jobs
            .get_mut(&job)
            .expect("checked above")
            .vertices
            .extend(selection);
        Ok(())
    }

    /// The sharded mark/bubble phase: validate, bucket the selection by
    /// owning shard, write each bucket strictly inside its subtree (spine
    /// deltas buffered per shard), then merge every buffer at the root in
    /// one coalesced pass (the short spine critical section).
    fn mark_sharded(
        &mut self,
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        ws: &mut WriteShards,
        job: JobId,
        selection: &[VertexId],
        mut on_shard: impl FnMut(usize),
    ) -> Result<(), AllocError> {
        // validate first so failure leaves no partial marks (serial parity)
        for &vid in selection {
            if g.vertex(vid).alloc.is_allocated() {
                return Err(AllocError::AlreadyAllocated(vid));
            }
        }
        let mut buckets: Vec<Vec<VertexId>> = vec![Vec::new(); ws.shards.len()];
        for &vid in selection {
            buckets[ws.shard_of(g, vid)].push(vid);
        }
        for (s, bucket) in buckets.iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            on_shard(s);
            let shard = &mut ws.shards[s];
            for &vid in bucket {
                g.vertex_mut(vid).alloc.jobs.push(job);
                bubble_delta_split(g, vid, cfg, -1, &mut shard.spine);
                shard.jobs.entry(job).or_default().push(vid);
            }
        }
        for shard in &mut ws.shards {
            shard.spine.merge_into(g, cfg);
        }
        Ok(())
    }

    /// Sharded twin of [`AllocTable::free`] (same `on_shard` hook as
    /// [`AllocTable::allocate_sharded`]).
    pub fn free_sharded(
        &mut self,
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        ws: &mut WriteShards,
        job: JobId,
        on_shard: impl FnMut(usize),
    ) -> Result<usize, AllocError> {
        let alloc = self.jobs.get_mut(&job).ok_or(AllocError::NoSuchJob(job))?;
        if alloc.state != JobState::Running {
            return Err(AllocError::NotRunning(job));
        }
        alloc.state = JobState::Completed;
        let vertices = std::mem::take(&mut alloc.vertices);
        let n = vertices.len();
        Self::release_sharded(g, cfg, ws, job, &vertices, on_shard);
        Ok(n)
    }

    /// Sharded twin of [`AllocTable::shrink`] (same `on_shard` hook as
    /// [`AllocTable::allocate_sharded`]).
    pub fn shrink_sharded(
        &mut self,
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        ws: &mut WriteShards,
        job: JobId,
        victims: &[VertexId],
        on_shard: impl FnMut(usize),
    ) -> Result<(), AllocError> {
        let alloc = self.jobs.get_mut(&job).ok_or(AllocError::NoSuchJob(job))?;
        if alloc.state != JobState::Running {
            return Err(AllocError::NotRunning(job));
        }
        alloc.vertices.retain(|v| !victims.contains(v));
        Self::release_sharded(g, cfg, ws, job, victims, on_shard);
        Ok(())
    }

    /// Shared unmark path of the sharded free/shrink: bucket by shard,
    /// drop shard-map entries, unmark live vertices, bubble +1 deltas with
    /// spine amounts buffered, merge at the root.
    fn release_sharded(
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        ws: &mut WriteShards,
        job: JobId,
        vertices: &[VertexId],
        mut on_shard: impl FnMut(usize),
    ) {
        let mut buckets: Vec<Vec<VertexId>> = vec![Vec::new(); ws.shards.len()];
        for &vid in vertices {
            buckets[ws.shard_of(g, vid)].push(vid);
        }
        for (s, bucket) in buckets.iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            on_shard(s);
            let shard = &mut ws.shards[s];
            for &vid in bucket {
                // the shard map drops the vertex even when the graph vertex
                // is dead — the table record is gone either way
                if let Some(held) = shard.jobs.get_mut(&job) {
                    held.retain(|&v| v != vid);
                    if held.is_empty() {
                        shard.jobs.remove(&job);
                    }
                }
                if g.vertex(vid).dead {
                    continue;
                }
                g.vertex_mut(vid).alloc.jobs.retain(|&j| j != job);
                if !g.vertex(vid).alloc.is_allocated() {
                    bubble_delta_split(g, vid, cfg, 1, &mut shard.spine);
                }
            }
        }
        for shard in &mut ws.shards {
            shard.spine.merge_into(g, cfg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resource::builder::{ClusterSpec, UidGen};
    use crate::resource::types::ResourceType;
    use crate::sched::pruning::{check_aggregates, init_aggregates};

    fn setup() -> (ResourceGraph, AllocTable, PruneConfig) {
        let mut g = ClusterSpec::new("c", 1, 1, 4).build(&mut UidGen::new());
        let cfg = PruneConfig::default();
        init_aggregates(&mut g, &cfg);
        (g, AllocTable::new(), cfg)
    }

    #[test]
    fn allocate_then_free_restores() {
        let (mut g, mut t, cfg) = setup();
        let cores: Vec<_> = (0..2)
            .map(|i| g.lookup_path(&format!("/c0/node0/socket0/core{i}")).unwrap())
            .collect();
        let job = t.allocate(&mut g, &cfg, cores.clone()).unwrap();
        assert!(g.vertex(cores[0]).alloc.is_allocated());
        let root = g.root().unwrap();
        assert_eq!(cfg.free_at(&g, root, &ResourceType::Core), 2);
        t.check_consistency(&g).unwrap();
        check_aggregates(&g, &cfg).unwrap();

        let n = t.free(&mut g, &cfg, job).unwrap();
        assert_eq!(n, 2);
        assert_eq!(cfg.free_at(&g, root, &ResourceType::Core), 4);
        assert!(!g.vertex(cores[0]).alloc.is_allocated());
        check_aggregates(&g, &cfg).unwrap();
    }

    #[test]
    fn double_allocation_rejected() {
        let (mut g, mut t, cfg) = setup();
        let core = g.lookup_path("/c0/node0/socket0/core0").unwrap();
        t.allocate(&mut g, &cfg, vec![core]).unwrap();
        assert!(t.allocate(&mut g, &cfg, vec![core]).is_err());
        // failed alloc left no marks on other vertices
        t.check_consistency(&g).unwrap();
    }

    #[test]
    fn grow_extends_same_job() {
        let (mut g, mut t, cfg) = setup();
        let c0 = g.lookup_path("/c0/node0/socket0/core0").unwrap();
        let c1 = g.lookup_path("/c0/node0/socket0/core1").unwrap();
        let job = t.allocate(&mut g, &cfg, vec![c0]).unwrap();
        t.grow(&mut g, &cfg, job, vec![c1]).unwrap();
        assert_eq!(t.get(job).unwrap().vertices.len(), 2);
        assert!(g.vertex(c1).alloc.jobs.contains(&job));
        t.check_consistency(&g).unwrap();
        check_aggregates(&g, &cfg).unwrap();
    }

    #[test]
    fn grow_unknown_job_fails() {
        let (mut g, mut t, cfg) = setup();
        let c0 = g.lookup_path("/c0/node0/socket0/core0").unwrap();
        assert!(t.grow(&mut g, &cfg, JobId(99), vec![c0]).is_err());
    }

    #[test]
    fn shrink_releases_subset() {
        let (mut g, mut t, cfg) = setup();
        let cores: Vec<_> = (0..4)
            .map(|i| g.lookup_path(&format!("/c0/node0/socket0/core{i}")).unwrap())
            .collect();
        let job = t.allocate(&mut g, &cfg, cores.clone()).unwrap();
        t.shrink(&mut g, &cfg, job, &cores[2..]).unwrap();
        assert_eq!(t.get(job).unwrap().vertices.len(), 2);
        let root = g.root().unwrap();
        assert_eq!(cfg.free_at(&g, root, &ResourceType::Core), 2);
        t.check_consistency(&g).unwrap();
        check_aggregates(&g, &cfg).unwrap();
    }

    #[test]
    fn free_twice_rejected() {
        let (mut g, mut t, cfg) = setup();
        let c0 = g.lookup_path("/c0/node0/socket0/core0").unwrap();
        let job = t.allocate(&mut g, &cfg, vec![c0]).unwrap();
        t.free(&mut g, &cfg, job).unwrap();
        assert!(t.free(&mut g, &cfg, job).is_err());
    }

    fn setup4() -> (ResourceGraph, AllocTable, PruneConfig) {
        let mut g = ClusterSpec::new("c", 4, 1, 4).build(&mut UidGen::new());
        let cfg = PruneConfig::default();
        init_aggregates(&mut g, &cfg);
        (g, AllocTable::new(), cfg)
    }

    fn pick(g: &ResourceGraph, n: usize, c: usize) -> VertexId {
        g.lookup_path(&format!("/c0/node{n}/socket0/core{c}")).unwrap()
    }

    #[test]
    fn sharded_commits_match_serial_bit_for_bit() {
        let (mut ga, mut ta, cfg) = setup4();
        let (mut gb, mut tb, _) = setup4();
        assert_eq!(ga.epoch(), gb.epoch());
        let mut ws = WriteShards::plan(&gb, 2);
        assert_eq!(ws.num_shards(), 2);
        // serial reference stream
        let j0a = ta
            .allocate(&mut ga, &cfg, vec![pick(&ga, 0, 0), pick(&ga, 3, 1)])
            .unwrap();
        let j1a = ta.allocate(&mut ga, &cfg, vec![pick(&ga, 1, 2)]).unwrap();
        ta.free(&mut ga, &cfg, j0a).unwrap();
        // identical stream through the sharded commit path
        let mut touched = Vec::new();
        let j0b = tb
            .allocate_sharded(
                &mut gb,
                &cfg,
                &mut ws,
                vec![pick(&gb, 0, 0), pick(&gb, 3, 1)],
                |s| touched.push(s),
            )
            .unwrap();
        let j1b = tb
            .allocate_sharded(&mut gb, &cfg, &mut ws, vec![pick(&gb, 1, 2)], |_| {})
            .unwrap();
        tb.free_sharded(&mut gb, &cfg, &mut ws, j0b, |_| {}).unwrap();
        assert_eq!(j0a, j0b);
        assert_eq!(j1a, j1b);
        assert_eq!(touched, vec![0, 1], "disjoint subtrees hit two shards");
        assert_eq!(ga.epoch(), gb.epoch(), "epochs must stay bit-identical");
        let root = ga.root().unwrap();
        assert_eq!(
            cfg.free_at(&ga, root, &ResourceType::Core),
            cfg.free_at(&gb, root, &ResourceType::Core)
        );
        check_aggregates(&gb, &cfg).unwrap();
        tb.check_consistency(&gb).unwrap();
        ws.check_partition(&gb, &tb).unwrap();
    }

    #[test]
    fn shard_partition_tracks_grow_shrink_and_rebuild() {
        let (mut g, mut t, cfg) = setup4();
        let mut ws = WriteShards::plan(&g, 4);
        assert_eq!(ws.num_shards(), 4);
        let sel = vec![pick(&g, 0, 0), pick(&g, 0, 1)];
        let job = t
            .allocate_sharded(&mut g, &cfg, &mut ws, sel, |_| {})
            .unwrap();
        t.grow_sharded(&mut g, &cfg, &mut ws, job, vec![pick(&g, 2, 0)], |_| {})
            .unwrap();
        ws.check_partition(&g, &t).unwrap();
        let s0 = ws.shard_of(&g, pick(&g, 0, 0));
        let s2 = ws.shard_of(&g, pick(&g, 2, 0));
        assert_ne!(s0, s2);
        assert_eq!(ws.shard(s0).unwrap().vertices_of(job).len(), 2);
        assert_eq!(ws.shard(s2).unwrap().vertices_of(job).len(), 1);
        // partial shrink drains one shard's slice, then rebuild re-derives
        // the same partition from the authoritative table
        let victims = [pick(&g, 2, 0)];
        t.shrink_sharded(&mut g, &cfg, &mut ws, job, &victims, |_| {})
            .unwrap();
        assert_eq!(ws.shard(s2).unwrap().vertices_of(job).len(), 0);
        ws.check_partition(&g, &t).unwrap();
        let mut rebuilt = WriteShards::plan(&g, 4);
        rebuilt.rebuild(&g, &t);
        rebuilt.check_partition(&g, &t).unwrap();
        check_aggregates(&g, &cfg).unwrap();
        t.check_consistency(&g).unwrap();
    }

    #[test]
    fn failed_sharded_mark_leaves_no_partial_state() {
        let (mut g, mut t, cfg) = setup4();
        let mut ws = WriteShards::plan(&g, 2);
        let held = pick(&g, 1, 0);
        t.allocate_sharded(&mut g, &cfg, &mut ws, vec![held], |_| {})
            .unwrap();
        let epoch = g.epoch();
        // second op selects a free vertex AND the held one: must fail whole
        let err = t.allocate_sharded(
            &mut g,
            &cfg,
            &mut ws,
            vec![pick(&g, 0, 0), held],
            |_| {},
        );
        assert!(matches!(err, Err(AllocError::AlreadyAllocated(_))));
        assert_eq!(g.epoch(), epoch, "failed validation writes nothing");
        assert!(!g.vertex(pick(&g, 0, 0)).alloc.is_allocated());
        ws.check_partition(&g, &t).unwrap();
        check_aggregates(&g, &cfg).unwrap();
    }
}
