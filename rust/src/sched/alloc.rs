//! Allocation bookkeeping: which vertices belong to which jobs.
//!
//! The paper's MatchGrow differs from MatchAllocate only in that "the new
//! resources are given the allocation metadata of a running job allocation"
//! (§5.1) — so grow extends an existing [`JobId`]'s vertex set instead of
//! minting a new one.

use std::collections::HashMap;

use crate::resource::graph::{JobId, ResourceGraph, VertexId};
use crate::sched::pruning::{bubble_delta, PruneConfig};

/// Lifecycle state of a job allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// The job holds resources.
    Running,
    /// The job has been freed; its record remains for id stability.
    Completed,
}

/// One job's allocation record.
#[derive(Debug, Clone)]
pub struct Allocation {
    /// The job this record belongs to.
    pub job: JobId,
    /// Vertices currently held (empty once completed).
    pub vertices: Vec<VertexId>,
    /// Lifecycle state.
    pub state: JobState,
}

/// Allocation table for one scheduler instance.
#[derive(Debug, Default, Clone)]
pub struct AllocTable {
    jobs: HashMap<JobId, Allocation>,
    next_job: u64,
}

/// Why an allocation-table operation failed.
#[derive(Debug)]
pub enum AllocError {
    /// The job id is not in the table.
    NoSuchJob(JobId),
    /// A selected vertex is already held by another job.
    AlreadyAllocated(VertexId),
    /// The job exists but has completed.
    NotRunning(JobId),
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::NoSuchJob(j) => write!(f, "job {j:?} not found"),
            AllocError::AlreadyAllocated(v) => write!(f, "vertex {v:?} already allocated"),
            AllocError::NotRunning(j) => write!(f, "job {j:?} is not running"),
        }
    }
}

impl std::error::Error for AllocError {}

impl AllocTable {
    /// An empty table (job ids start at 0).
    pub fn new() -> AllocTable {
        AllocTable::default()
    }

    /// Mint the next job id.
    pub fn fresh_job_id(&mut self) -> JobId {
        let id = JobId(self.next_job);
        self.next_job += 1;
        id
    }

    /// A job's allocation record, if known.
    pub fn get(&self, job: JobId) -> Option<&Allocation> {
        self.jobs.get(&job)
    }

    /// Iterate records of jobs currently holding resources.
    pub fn running_jobs(&self) -> impl Iterator<Item = &Allocation> {
        self.jobs.values().filter(|a| a.state == JobState::Running)
    }

    /// Number of job records (running and completed).
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    /// Whether the table has no records.
    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Mark `selection` allocated to a *new* job. Updates vertex alloc
    /// metadata and pruning aggregates (ancestor-local, O(k·depth)).
    pub fn allocate(
        &mut self,
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        selection: Vec<VertexId>,
    ) -> Result<JobId, AllocError> {
        let job = self.fresh_job_id();
        self.mark(g, cfg, job, selection.clone())?;
        self.jobs.insert(
            job,
            Allocation {
                job,
                vertices: selection,
                state: JobState::Running,
            },
        );
        Ok(job)
    }

    /// Grow an existing running job by `selection` (MatchGrow semantics).
    pub fn grow(
        &mut self,
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        job: JobId,
        selection: Vec<VertexId>,
    ) -> Result<(), AllocError> {
        match self.jobs.get(&job) {
            None => return Err(AllocError::NoSuchJob(job)),
            Some(a) if a.state != JobState::Running => {
                return Err(AllocError::NotRunning(job))
            }
            Some(_) => {}
        }
        self.mark(g, cfg, job, selection.clone())?;
        self.jobs
            .get_mut(&job)
            .expect("checked above")
            .vertices
            .extend(selection);
        Ok(())
    }

    fn mark(
        &mut self,
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        job: JobId,
        selection: Vec<VertexId>,
    ) -> Result<(), AllocError> {
        // validate first so failure leaves no partial marks
        for &vid in &selection {
            if g.vertex(vid).alloc.is_allocated() {
                return Err(AllocError::AlreadyAllocated(vid));
            }
        }
        for vid in selection {
            g.vertex_mut(vid).alloc.jobs.push(job);
            bubble_delta(g, vid, cfg, -1);
        }
        Ok(())
    }

    /// Release a job's resources (shrink-to-zero / completion).
    pub fn free(
        &mut self,
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        job: JobId,
    ) -> Result<usize, AllocError> {
        let alloc = self.jobs.get_mut(&job).ok_or(AllocError::NoSuchJob(job))?;
        if alloc.state != JobState::Running {
            return Err(AllocError::NotRunning(job));
        }
        alloc.state = JobState::Completed;
        let vertices = std::mem::take(&mut alloc.vertices);
        let n = vertices.len();
        for vid in vertices {
            if g.vertex(vid).dead {
                continue; // vertex left with a removed subgraph
            }
            g.vertex_mut(vid).alloc.jobs.retain(|&j| j != job);
            if !g.vertex(vid).alloc.is_allocated() {
                bubble_delta(g, vid, cfg, 1);
            }
        }
        Ok(n)
    }

    /// Release a subset of a running job's vertices (partial shrink).
    pub fn shrink(
        &mut self,
        g: &mut ResourceGraph,
        cfg: &PruneConfig,
        job: JobId,
        victims: &[VertexId],
    ) -> Result<(), AllocError> {
        let alloc = self.jobs.get_mut(&job).ok_or(AllocError::NoSuchJob(job))?;
        if alloc.state != JobState::Running {
            return Err(AllocError::NotRunning(job));
        }
        alloc.vertices.retain(|v| !victims.contains(v));
        for &vid in victims {
            if g.vertex(vid).dead {
                continue;
            }
            g.vertex_mut(vid).alloc.jobs.retain(|&j| j != job);
            if !g.vertex(vid).alloc.is_allocated() {
                bubble_delta(g, vid, cfg, 1);
            }
        }
        Ok(())
    }

    /// Conservation check for tests: every vertex's job list agrees with the
    /// table and vice versa.
    pub fn check_consistency(&self, g: &ResourceGraph) -> Result<(), String> {
        for a in self.jobs.values() {
            if a.state != JobState::Running {
                continue;
            }
            for &vid in &a.vertices {
                if g.vertex(vid).dead {
                    return Err(format!("job {:?} holds dead vertex", a.job));
                }
                if !g.vertex(vid).alloc.jobs.contains(&a.job) {
                    return Err(format!(
                        "vertex {} missing job {:?}",
                        g.vertex(vid).path,
                        a.job
                    ));
                }
            }
        }
        for vid in g.iter_live() {
            for j in &g.vertex(vid).alloc.jobs {
                let Some(a) = self.jobs.get(j) else {
                    return Err(format!("vertex {} has unknown job", g.vertex(vid).path));
                };
                if !a.vertices.contains(&vid) {
                    return Err(format!(
                        "table for {:?} missing vertex {}",
                        j,
                        g.vertex(vid).path
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resource::builder::{ClusterSpec, UidGen};
    use crate::resource::types::ResourceType;
    use crate::sched::pruning::{check_aggregates, init_aggregates};

    fn setup() -> (ResourceGraph, AllocTable, PruneConfig) {
        let mut g = ClusterSpec::new("c", 1, 1, 4).build(&mut UidGen::new());
        let cfg = PruneConfig::default();
        init_aggregates(&mut g, &cfg);
        (g, AllocTable::new(), cfg)
    }

    #[test]
    fn allocate_then_free_restores() {
        let (mut g, mut t, cfg) = setup();
        let cores: Vec<_> = (0..2)
            .map(|i| g.lookup_path(&format!("/c0/node0/socket0/core{i}")).unwrap())
            .collect();
        let job = t.allocate(&mut g, &cfg, cores.clone()).unwrap();
        assert!(g.vertex(cores[0]).alloc.is_allocated());
        let root = g.root().unwrap();
        assert_eq!(cfg.free_at(&g, root, &ResourceType::Core), 2);
        t.check_consistency(&g).unwrap();
        check_aggregates(&g, &cfg).unwrap();

        let n = t.free(&mut g, &cfg, job).unwrap();
        assert_eq!(n, 2);
        assert_eq!(cfg.free_at(&g, root, &ResourceType::Core), 4);
        assert!(!g.vertex(cores[0]).alloc.is_allocated());
        check_aggregates(&g, &cfg).unwrap();
    }

    #[test]
    fn double_allocation_rejected() {
        let (mut g, mut t, cfg) = setup();
        let core = g.lookup_path("/c0/node0/socket0/core0").unwrap();
        t.allocate(&mut g, &cfg, vec![core]).unwrap();
        assert!(t.allocate(&mut g, &cfg, vec![core]).is_err());
        // failed alloc left no marks on other vertices
        t.check_consistency(&g).unwrap();
    }

    #[test]
    fn grow_extends_same_job() {
        let (mut g, mut t, cfg) = setup();
        let c0 = g.lookup_path("/c0/node0/socket0/core0").unwrap();
        let c1 = g.lookup_path("/c0/node0/socket0/core1").unwrap();
        let job = t.allocate(&mut g, &cfg, vec![c0]).unwrap();
        t.grow(&mut g, &cfg, job, vec![c1]).unwrap();
        assert_eq!(t.get(job).unwrap().vertices.len(), 2);
        assert!(g.vertex(c1).alloc.jobs.contains(&job));
        t.check_consistency(&g).unwrap();
        check_aggregates(&g, &cfg).unwrap();
    }

    #[test]
    fn grow_unknown_job_fails() {
        let (mut g, mut t, cfg) = setup();
        let c0 = g.lookup_path("/c0/node0/socket0/core0").unwrap();
        assert!(t.grow(&mut g, &cfg, JobId(99), vec![c0]).is_err());
    }

    #[test]
    fn shrink_releases_subset() {
        let (mut g, mut t, cfg) = setup();
        let cores: Vec<_> = (0..4)
            .map(|i| g.lookup_path(&format!("/c0/node0/socket0/core{i}")).unwrap())
            .collect();
        let job = t.allocate(&mut g, &cfg, cores.clone()).unwrap();
        t.shrink(&mut g, &cfg, job, &cores[2..]).unwrap();
        assert_eq!(t.get(job).unwrap().vertices.len(), 2);
        let root = g.root().unwrap();
        assert_eq!(cfg.free_at(&g, root, &ResourceType::Core), 2);
        t.check_consistency(&g).unwrap();
        check_aggregates(&g, &cfg).unwrap();
    }

    #[test]
    fn free_twice_rejected() {
        let (mut g, mut t, cfg) = setup();
        let c0 = g.lookup_path("/c0/node0/socket0/core0").unwrap();
        let job = t.allocate(&mut g, &cfg, vec![c0]).unwrap();
        t.free(&mut g, &cfg, job).unwrap();
        assert!(t.free(&mut g, &cfg, job).is_err());
    }
}
