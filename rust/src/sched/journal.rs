//! Write-ahead op journal + snapshot/replay recovery (PR 10).
//!
//! Crash consistency for one scheduler level: every state-mutating
//! [`SchedOp`] a [`crate::sched::SchedService`] accepts is **appended to
//! the journal before it commits**, as a sequence-numbered, checksummed,
//! canonical-JSON frame; once the mutation completes (success *or* typed
//! failure — failed ops may still have advanced the graph epoch and replay
//! must reproduce that) a matching commit frame lands behind it. Every
//! `snapshot_every` commits the journal takes a checkpoint — a cheap
//! copy-on-write clone of the graph + allocation table (the PR 9 chunked
//! arena makes this O(chunks) refcount bumps) — and drops the op/commit
//! frames it covers, so recovery is **snapshot + bounded replay**.
//!
//! ## Frame format
//!
//! One canonical-JSON object per frame (a line in a real on-disk log; this
//! simulation keeps the encoded strings in memory so tests can tear and
//! corrupt them byte-for-byte):
//!
//! | `"kind"`  | fields                                   | durable at    |
//! |-----------|------------------------------------------|---------------|
//! | `op`      | `seq`, `op` (a [`SchedOp`] doc), `sum`   | commit frame  |
//! | `commit`  | `seq`, `epoch` (post-op), `fin`, `sum`   | append        |
//! | `note`    | `seq`, `tag`, `data`, `sum`              | append        |
//!
//! `sum` is an FNV-1a 64 checksum (hex string — the crate's JSON numbers
//! are exact only to 2^53) over the frame's payload. `note` frames carry
//! hierarchy bookkeeping (grant ledgers, see [`crate::hier`]) that is not
//! a `SchedOp`; they are durable as soon as they are appended and survive
//! checkpoints (ledger recovery folds the *last* committed note, so notes
//! are never dropped with the op frames they interleave).
//!
//! ## Recovery contract
//!
//! [`recover`] parses frames in order and **discards the torn tail**: the
//! first frame that fails to parse or checksum truncates everything after
//! it, and op frames with no commit frame (the op was appended but the
//! crash hit before its mutation completed) are dropped. The committed
//! prefix is replayed — in sequence order, through the same serial
//! [`SchedInstance::apply`] the service linearizes to — onto a clone of
//! the checkpoint, and the result is **bit-identical** to the pre-crash
//! committed state: same graph epoch, same allocation table, same pruning
//! aggregates (the PR 8 equivalence contract; [`states_bit_identical`] is
//! the checker). Replay never goes through [`SchedInstance::new`] or
//! `restore_from`, both of which perturb graph state (`init_aggregates`
//! mutates, `restore_from` advances the epoch); it uses
//! [`SchedInstance::from_parts`] on the checkpoint's clones.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::resource::graph::ResourceGraph;
use crate::rpc::proto::SchedOp;
use crate::sched::alloc::AllocTable;
use crate::sched::instance::SchedInstance;
use crate::sched::pruning::PruneConfig;
use crate::util::json::Json;

/// FNV-1a 64-bit checksum — the journal's frame integrity check (zero-dep,
/// deterministic, good enough to catch torn writes and bit rot; this is an
/// integrity code, not a cryptographic one).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A journal checkpoint: the full level state at sequence `seq`, held as
/// cheap copy-on-write clones. Recovery replays only frames after `seq`.
#[derive(Clone)]
pub struct JournalSnapshot {
    /// Last sequence number the checkpoint covers (0 = journal creation).
    pub seq: u64,
    /// The graph at checkpoint time (epoch preserved exactly by `clone`).
    pub graph: ResourceGraph,
    /// The allocation table at checkpoint time.
    pub allocs: AllocTable,
}

/// The write-ahead journal of one scheduler level.
pub struct OpJournal {
    base: JournalSnapshot,
    frames: Vec<String>,
    next_seq: u64,
    snapshot_every: u64,
    commits_since_snapshot: u64,
    appends: u64,
}

impl OpJournal {
    /// Open a journal over the instance's current state: the creation
    /// checkpoint is `seq` 0 and covers everything that happened before.
    /// `snapshot_every` bounds replay length: a checkpoint is taken after
    /// that many commit frames (minimum 1).
    pub fn new(inst: &SchedInstance, snapshot_every: u64) -> OpJournal {
        OpJournal {
            base: JournalSnapshot {
                seq: 0,
                graph: inst.graph.clone(),
                allocs: inst.allocs.clone(),
            },
            frames: Vec::new(),
            next_seq: 1,
            snapshot_every: snapshot_every.max(1),
            commits_since_snapshot: 0,
            appends: 0,
        }
    }

    /// Append one op frame **before** its mutation runs; returns the
    /// sequence number the caller must pass back to
    /// [`OpJournal::commit_op`] once the mutation completes. An op frame
    /// with no commit frame behind it is exactly what a crash between
    /// append and commit leaves — recovery drops it.
    pub fn append_op(&mut self, op: &SchedOp) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        let op_doc = op.to_json();
        let sum = fnv1a(op_doc.dump().as_bytes());
        let frame = Json::obj()
            .with("kind", Json::from("op"))
            .with("seq", Json::from(seq))
            .with("op", op_doc)
            .with("sum", Json::from(format!("{sum:016x}").as_str()));
        self.frames.push(frame.dump());
        self.appends += 1;
        seq
    }

    /// Append the commit frame for `seq`, recording the post-op graph
    /// epoch (replay asserts it re-derives the same one). Takes the
    /// periodic checkpoint when due.
    pub fn commit_op(&mut self, seq: u64, inst: &SchedInstance) {
        self.commit_frame(seq, inst, true);
    }

    /// Commit frame for a **mid-phase** op: one applied inside a batched
    /// write phase, where the recorded epoch is the post-*phase* value —
    /// per-op replay can't re-derive it, so the frame is flagged non-final
    /// (`fin: false`) and [`recover`] skips its epoch cross-check. The
    /// phase's last op commits through [`OpJournal::commit_op`] and its
    /// epoch IS checked, which pins the whole phase.
    pub fn commit_op_mid(&mut self, seq: u64, inst: &SchedInstance) {
        self.commit_frame(seq, inst, false);
    }

    fn commit_frame(&mut self, seq: u64, inst: &SchedInstance, fin: bool) {
        let epoch = inst.graph.epoch();
        let sum = fnv1a(format!("commit:{seq}:{epoch}:{fin}").as_bytes());
        let frame = Json::obj()
            .with("kind", Json::from("commit"))
            .with("seq", Json::from(seq))
            .with("epoch", Json::from(epoch))
            .with("fin", Json::from(fin))
            .with("sum", Json::from(format!("{sum:016x}").as_str()));
        self.frames.push(frame.dump());
        self.commits_since_snapshot += 1;
        if self.commits_since_snapshot >= self.snapshot_every {
            self.checkpoint(inst);
        }
    }

    /// Append one note frame: hierarchy bookkeeping (grant ledgers) that
    /// is durable at append and survives checkpoints. Returns its seq.
    pub fn note(&mut self, tag: &str, data: Json) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        let sum = fnv1a(format!("note:{seq}:{tag}:{}", data.dump()).as_bytes());
        let frame = Json::obj()
            .with("kind", Json::from("note"))
            .with("seq", Json::from(seq))
            .with("tag", Json::from(tag))
            .with("data", data)
            .with("sum", Json::from(format!("{sum:016x}").as_str()));
        self.frames.push(frame.dump());
        self.appends += 1;
        seq
    }

    /// Take a checkpoint of the instance's state now and drop the op and
    /// commit frames it covers (note frames are retained — ledger recovery
    /// folds over them regardless of checkpoint cadence). The hierarchy
    /// calls this after mutations that bypass the op path (grant splices,
    /// shrinks driven through the write guard).
    pub fn checkpoint(&mut self, inst: &SchedInstance) {
        self.base = JournalSnapshot {
            seq: self.next_seq - 1,
            graph: inst.graph.clone(),
            allocs: inst.allocs.clone(),
        };
        self.frames.retain(|f| {
            Json::parse(f)
                .ok()
                .and_then(|doc| doc.str_field("kind").ok().map(|k| k == "note"))
                .unwrap_or(false)
        });
        self.commits_since_snapshot = 0;
    }

    /// Op frames appended so far (note frames included; commit frames are
    /// not appends).
    pub fn appends(&self) -> u64 {
        self.appends
    }

    /// Frames currently in the log (after checkpoint trimming).
    pub fn frames(&self) -> &[String] {
        &self.frames
    }

    /// Clone out the recovery inputs: the latest checkpoint and every
    /// frame after it. Tests tear and corrupt the returned frames to
    /// exercise the torn-tail contract.
    pub fn export(&self) -> (JournalSnapshot, Vec<String>) {
        (self.base.clone(), self.frames.clone())
    }
}

/// The outcome of a snapshot-plus-replay recovery.
pub struct Recovery {
    /// The recovered instance: checkpoint clone + committed-op replay.
    pub inst: SchedInstance,
    /// Committed ops replayed on top of the checkpoint.
    pub replayed: u64,
    /// Frames discarded as the torn tail (first unparseable or
    /// checksum-failed frame and everything after it).
    pub torn: u64,
    /// Parsed op frames dropped for having no commit frame (the crash hit
    /// between append and commit — the mutation never completed).
    pub uncommitted: u64,
    /// Replayed ops whose re-derived graph epoch disagreed with the epoch
    /// recorded in their commit frame. Zero on every healthy recovery; a
    /// nonzero count means replay diverged from the original execution.
    pub epoch_mismatches: u64,
    /// Committed notes in append order (`(tag, data)`); the hierarchy
    /// folds these to rebuild its grant ledgers.
    pub notes: Vec<(String, Json)>,
}

/// Parse one frame; `None` means the frame is torn/corrupt (bad JSON, bad
/// checksum, unknown kind, missing fields) and truncates the log there.
enum Frame {
    Op { seq: u64, op: SchedOp },
    Commit { seq: u64, epoch: u64, fin: bool },
    Note { tag: String, data: Json },
}

fn parse_frame(line: &str) -> Option<Frame> {
    let doc = Json::parse(line).ok()?;
    let sum = u64::from_str_radix(doc.str_field("sum").ok()?, 16).ok()?;
    match doc.str_field("kind").ok()? {
        "op" => {
            let seq = doc.u64_field("seq").ok()?;
            let op_doc = doc.get("op")?;
            if fnv1a(op_doc.dump().as_bytes()) != sum {
                return None;
            }
            Some(Frame::Op {
                seq,
                op: SchedOp::from_json(op_doc).ok()?,
            })
        }
        "commit" => {
            let seq = doc.u64_field("seq").ok()?;
            let epoch = doc.u64_field("epoch").ok()?;
            let fin = doc.get("fin")?.as_bool()?;
            if fnv1a(format!("commit:{seq}:{epoch}:{fin}").as_bytes()) != sum {
                return None;
            }
            Some(Frame::Commit { seq, epoch, fin })
        }
        "note" => {
            let seq = doc.u64_field("seq").ok()?;
            let tag = doc.str_field("tag").ok()?.to_string();
            let data = doc.get("data")?.clone();
            if fnv1a(format!("note:{seq}:{tag}:{}", data.dump()).as_bytes()) != sum {
                return None;
            }
            Some(Frame::Note { tag, data })
        }
        _ => None,
    }
}

/// Replay one committed op with the same containment the service write
/// path uses: a panicking op rolls the instance back to its pre-op clones
/// (epoch advanced by `restore_from`), exactly like
/// `SchedService`'s contained apply — so a journaled stream that included
/// a contained panic replays to the same state it left behind.
fn replay_op(inst: &mut SchedInstance, op: &SchedOp) {
    let graph_before = inst.graph.clone();
    let allocs_before = inst.allocs.clone();
    let result = catch_unwind(AssertUnwindSafe(|| {
        inst.apply(op);
    }));
    if result.is_err() {
        inst.graph.restore_from(&graph_before);
        inst.allocs = allocs_before;
        inst.refresh_write_shards();
    }
}

/// Rebuild a level's state from its journal: clone the checkpoint, replay
/// the committed op suffix in sequence order, surface the committed notes.
/// See the module docs for the torn-tail and bit-identity contracts.
pub fn recover(base: &JournalSnapshot, frames: &[String], prune: PruneConfig) -> Recovery {
    let mut ops: Vec<(u64, SchedOp)> = Vec::new();
    let mut commits: HashMap<u64, (u64, bool)> = HashMap::new();
    let mut notes: Vec<(String, Json)> = Vec::new();
    let mut torn = 0u64;
    for (i, line) in frames.iter().enumerate() {
        match parse_frame(line) {
            Some(Frame::Op { seq, op }) => ops.push((seq, op)),
            Some(Frame::Commit { seq, epoch, fin }) => {
                commits.insert(seq, (epoch, fin));
            }
            Some(Frame::Note { tag, data }) => notes.push((tag, data)),
            None => {
                torn = (frames.len() - i) as u64;
                break;
            }
        }
    }
    ops.sort_by_key(|(seq, _)| *seq);
    let mut inst = SchedInstance::from_parts(base.graph.clone(), base.allocs.clone(), prune);
    let mut replayed = 0u64;
    let mut uncommitted = 0u64;
    let mut epoch_mismatches = 0u64;
    for (seq, op) in &ops {
        let Some(&(epoch, fin)) = commits.get(seq) else {
            uncommitted += 1;
            continue;
        };
        replay_op(&mut inst, op);
        replayed += 1;
        if fin && inst.graph.epoch() != epoch {
            epoch_mismatches += 1;
        }
    }
    Recovery {
        inst,
        replayed,
        torn,
        uncommitted,
        epoch_mismatches,
        notes,
    }
}

/// The PR 8 bit-identity contract as a checker: same graph epoch, same
/// live vertex set, same per-vertex allocation info, same running half of
/// the allocation table. `Ok(())` or a description of the first
/// divergence. (Pruning aggregates are covered transitively:
/// [`SchedInstance::check`] recomputes them, and both recovery tests and
/// the hierarchy restart path run it alongside this.)
pub fn states_bit_identical(a: &SchedInstance, b: &SchedInstance) -> Result<(), String> {
    if a.graph.epoch() != b.graph.epoch() {
        return Err(format!(
            "epoch {} != {}",
            a.graph.epoch(),
            b.graph.epoch()
        ));
    }
    let live_a: Vec<_> = a.graph.iter_live().collect();
    let live_b: Vec<_> = b.graph.iter_live().collect();
    if live_a != live_b {
        return Err(format!(
            "live vertex sets differ ({} vs {} vertices)",
            live_a.len(),
            live_b.len()
        ));
    }
    for &v in &live_a {
        if a.graph.vertex(v).alloc != b.graph.vertex(v).alloc {
            return Err(format!("alloc info diverges at vertex {v:?}"));
        }
    }
    let running = |inst: &SchedInstance| -> Vec<(u64, Vec<u32>)> {
        let mut js: Vec<(u64, Vec<u32>)> = inst
            .allocs
            .running_jobs()
            .map(|al| (al.job.0, al.vertices.iter().map(|v| v.0).collect()))
            .collect();
        js.sort();
        js
    };
    let (ra, rb) = (running(a), running(b));
    if ra != rb {
        return Err(format!(
            "running allocation tables differ ({} vs {} jobs)",
            ra.len(),
            rb.len()
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobspec::JobSpec;
    use crate::resource::builder::{ClusterSpec, UidGen};

    fn inst() -> SchedInstance {
        SchedInstance::new(
            ClusterSpec::new("c", 3, 2, 8).build(&mut UidGen::new()),
            PruneConfig::default(),
        )
    }

    fn spec() -> JobSpec {
        JobSpec::nodes_sockets_cores(1, 1, 4)
    }

    #[test]
    fn fnv1a_is_stable_and_input_sensitive() {
        // reference vectors for the 64-bit FNV-1a parameters
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a(b"commit:1:2"), fnv1a(b"commit:2:1"));
    }

    #[test]
    fn append_commit_replay_is_bit_identical() {
        let mut live = inst();
        let mut journal = OpJournal::new(&live, 1000); // never checkpoints
        for _ in 0..4 {
            let op = SchedOp::MatchAllocate { spec: spec() };
            let seq = journal.append_op(&op);
            live.apply(&op);
            journal.commit_op(seq, &live);
        }
        let op = SchedOp::FreeJob {
            job: crate::resource::graph::JobId(1),
        };
        let seq = journal.append_op(&op);
        live.apply(&op);
        journal.commit_op(seq, &live);

        let (base, frames) = journal.export();
        let rec = recover(&base, &frames, PruneConfig::default());
        assert_eq!(rec.replayed, 5);
        assert_eq!(rec.torn, 0);
        assert_eq!(rec.uncommitted, 0);
        assert_eq!(rec.epoch_mismatches, 0);
        states_bit_identical(&rec.inst, &live).unwrap();
        rec.inst.check().unwrap();
    }

    #[test]
    fn failed_ops_replay_too() {
        // a committed op that answered with an error still replays: failed
        // grants can mutate the graph, so the journal never filters them
        let mut live = inst();
        let mut journal = OpJournal::new(&live, 1000);
        let ops = [
            SchedOp::MatchAllocate {
                spec: JobSpec::nodes_sockets_cores(100, 1, 1), // no_match
            },
            SchedOp::MatchAllocate { spec: spec() },
            SchedOp::FreeJob {
                job: crate::resource::graph::JobId(77), // unknown job
            },
        ];
        for op in &ops {
            let seq = journal.append_op(op);
            live.apply(op);
            journal.commit_op(seq, &live);
        }
        let (base, frames) = journal.export();
        let rec = recover(&base, &frames, PruneConfig::default());
        assert_eq!(rec.replayed, 3);
        assert_eq!(rec.epoch_mismatches, 0);
        states_bit_identical(&rec.inst, &live).unwrap();
    }

    #[test]
    fn checkpoint_bounds_replay_and_keeps_notes() {
        let mut live = inst();
        let mut journal = OpJournal::new(&live, 2); // checkpoint every 2 commits
        journal.note("ledger", Json::obj().with("v", Json::from(1u64)));
        for i in 0..5u64 {
            let op = SchedOp::MatchAllocate { spec: spec() };
            let seq = journal.append_op(&op);
            live.apply(&op);
            journal.commit_op(seq, &live);
            journal.note("ledger", Json::obj().with("v", Json::from(i + 2)));
        }
        let (base, frames) = journal.export();
        // 4 of the 5 commits are behind checkpoints; at most 1 op replays
        let rec = recover(&base, &frames, PruneConfig::default());
        assert!(rec.replayed <= 1, "replayed {}", rec.replayed);
        assert_eq!(base.seq > 0, true);
        states_bit_identical(&rec.inst, &live).unwrap();
        // every note survived every checkpoint, in order
        assert_eq!(rec.notes.len(), 6);
        let last = rec.notes.last().unwrap();
        assert_eq!(last.0, "ledger");
        assert_eq!(last.1.get("v").and_then(Json::as_u64), Some(6));
    }

    #[test]
    fn uncommitted_op_frame_is_dropped() {
        let mut live = inst();
        let mut journal = OpJournal::new(&live, 1000);
        let op = SchedOp::MatchAllocate { spec: spec() };
        let seq = journal.append_op(&op);
        live.apply(&op);
        journal.commit_op(seq, &live);
        // appended, never committed — the crash window
        journal.append_op(&SchedOp::MatchAllocate { spec: spec() });
        let (base, frames) = journal.export();
        let rec = recover(&base, &frames, PruneConfig::default());
        assert_eq!(rec.replayed, 1);
        assert_eq!(rec.uncommitted, 1);
        states_bit_identical(&rec.inst, &live).unwrap();
    }

    #[test]
    fn torn_tail_truncates_from_first_bad_frame() {
        let mut live = inst();
        let mut journal = OpJournal::new(&live, 1000);
        let mut reference = None;
        for i in 0..3 {
            let op = SchedOp::MatchAllocate { spec: spec() };
            let seq = journal.append_op(&op);
            live.apply(&op);
            journal.commit_op(seq, &live);
            if i == 1 {
                // state after the second committed op — where a tear
                // right after frame 4 must land recovery
                reference = Some((live.graph.clone(), live.allocs.clone()));
            }
        }
        let (base, mut frames) = journal.export();
        assert_eq!(frames.len(), 6);
        // corrupt the 5th frame (3rd op's op frame): everything from it on
        // is discarded even though the 6th frame is well-formed
        frames[4] = frames[4].replace("match_allocate", "match_allocatX");
        let rec = recover(&base, &frames, PruneConfig::default());
        assert_eq!(rec.torn, 2);
        assert_eq!(rec.replayed, 2);
        let (g, a) = reference.unwrap();
        let want = SchedInstance::from_parts(g, a, PruneConfig::default());
        states_bit_identical(&rec.inst, &want).unwrap();
    }

    #[test]
    fn checksum_catches_payload_tampering() {
        let mut live = inst();
        let mut journal = OpJournal::new(&live, 1000);
        let op = SchedOp::FreeJob {
            job: crate::resource::graph::JobId(3),
        };
        let seq = journal.append_op(&op);
        live.apply(&op);
        journal.commit_op(seq, &live);
        let (base, mut frames) = journal.export();
        // flip the job id inside the op payload; frame still parses as
        // JSON but the checksum no longer matches
        frames[0] = frames[0].replace("\"job\":3", "\"job\":4");
        assert!(parse_frame(&frames[0]).is_none());
        let rec = recover(&base, &frames, PruneConfig::default());
        assert_eq!(rec.replayed, 0);
        assert_eq!(rec.torn, 2);
    }
}
