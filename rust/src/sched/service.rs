//! Concurrent scheduler serving: the read/write-partitioned instance.
//!
//! The paper's scalability argument (§5.2.3) is that fully hierarchical
//! scheduling lets many instances match concurrently against bounded-size
//! graphs — and converged-computing traffic is dominated by *feasibility
//! probes* (capacity queries that mutate nothing). [`SchedService`] is the
//! serving layer that exploits both facts:
//!
//! - **Read/write partitioning.** The single-threaded [`SchedInstance`]
//!   sits behind an `RwLock`. Read-only ops ([`SchedOp::Probe`] — see
//!   [`SchedOp::is_read_only`]) take the read side and run in parallel;
//!   mutating ops take the write side, and every graph mutation advances
//!   the graph's monotonic **epoch**
//!   ([`crate::resource::graph::ResourceGraph::epoch`]).
//! - **Per-worker scratch pool.** A pool of `std::thread` workers
//!   (spawned lazily on the first batched fan-out) each owns one warm
//!   [`MatchScratch`], and single probes use a thread-local caller
//!   scratch — replacing the instance's single serializing scratch
//!   (`SchedInstance`'s own scratch is now just the 1-thread special
//!   case). [`SchedService::apply_batch`] partitions a
//!   queue into read/write phases, fans each read phase across the pool,
//!   and preserves reply order index-for-index with sequential
//!   [`SchedInstance::apply_batch`].
//! - **Epoch-keyed probe cache.** Identical probe specs within an
//!   unchanged-graph window are answered from a result cache without
//!   re-traversal (the ROADMAP's "cross-op result reuse"). An entry is
//!   valid iff its recorded epoch equals the graph's current epoch, so any
//!   mutation — *including one that fails halfway* — invalidates exactly
//!   by bumping the epoch. See the invalidation rules below.
//!
//! ## Cache invalidation rules
//!
//! 1. Entries are keyed by the probe spec's canonical JSON and stamped
//!    with the epoch they were computed at; a lookup only hits when the
//!    stamp equals the current epoch (stale entries are evicted lazily).
//! 2. Every lookup and insert happens while holding the instance lock
//!    (read side), so the epoch cannot move between the stamp being read
//!    and the entry being used.
//! 3. A failed mutating op needs no special-casing: if it touched the
//!    graph at all before failing (e.g. `AcceptGrant` splices the subgraph
//!    and then the allocation step rejects an unknown job), the mutation
//!    itself advanced the epoch. Ops that fail without touching the graph
//!    leave the epoch — and therefore the still-accurate cache — alone.
//! 4. Epochs must never rewind. Snapshot restores MUST go through
//!    [`ResourceGraph::restore_from`](crate::resource::graph::ResourceGraph::restore_from),
//!    which moves the epoch forward past both timelines — that is the
//!    contract. As defense in depth, the write guard records the epoch at
//!    entry and clears the whole cache if the counter at drop has moved
//!    backwards (a plain `guard.graph = snapshot` swap). The one thing
//!    this last-resort check cannot see is a contract-violating swap that
//!    *also* manually re-advances the counter onto a previously observed
//!    value within a single guard; `restore_from` exists precisely so no
//!    caller ever needs to touch the field directly.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::thread::JoinHandle;

use crate::jobspec::JobSpec;
use crate::rpc::proto::{SchedOp, SchedReply};
use crate::sched::instance::SchedInstance;
use crate::sched::matcher::MatchScratch;

/// Upper bound on cached probe entries; exceeding it clears the map (the
/// cache is an epoch-window optimization, not a store — correctness never
/// depends on retention).
const CACHE_CAP: usize = 4096;

/// One cached probe answer, valid only at the epoch it was computed.
struct CacheEntry {
    epoch: u64,
    reply: SchedReply,
}

/// Probe-result cache guts (behind the service's cache mutex).
struct CacheInner {
    map: HashMap<String, CacheEntry>,
    /// Last epoch observed by any lookup or write-guard drop; used to
    /// detect a rewound counter (see module invalidation rule 4).
    last_epoch: u64,
    hits: u64,
    misses: u64,
    invalidations: u64,
}

impl CacheInner {
    fn new() -> CacheInner {
        CacheInner {
            map: HashMap::new(),
            last_epoch: 0,
            hits: 0,
            misses: 0,
            invalidations: 0,
        }
    }

    /// Record the current graph epoch. A value below the last observation
    /// means the epoch rewound (a snapshot was swapped in behind the
    /// service's back) — every entry could alias a future epoch value, so
    /// the whole map is dropped.
    fn observe_epoch(&mut self, epoch: u64) {
        if epoch < self.last_epoch {
            self.map.clear();
            self.invalidations += 1;
        }
        self.last_epoch = epoch;
    }

    /// Look up a probe result valid at `epoch`; evicts a stale entry.
    fn get(&mut self, key: &str, epoch: u64) -> Option<SchedReply> {
        match self.map.get(key) {
            Some(e) if e.epoch == epoch => {
                self.hits += 1;
                Some(e.reply.clone())
            }
            Some(_) => {
                self.map.remove(key);
                self.misses += 1;
                None
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    fn insert(&mut self, key: String, epoch: u64, reply: SchedReply) {
        if self.map.len() >= CACHE_CAP && !self.map.contains_key(&key) {
            self.map.clear();
            self.invalidations += 1;
        }
        self.map.insert(key, CacheEntry { epoch, reply });
    }
}

/// Counters describing the probe cache's behavior (for tests, benches, and
/// capacity planning).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache at the current epoch.
    pub hits: u64,
    /// Lookups that missed (absent or stale entry).
    pub misses: u64,
    /// Whole-map clears (explicit, capacity, or epoch-rewind defense).
    pub invalidations: u64,
    /// Entries currently resident (any epoch; stale ones evict lazily).
    pub entries: usize,
}

/// Canonical cache key of a probe spec: its wire-form JSON. Structurally
/// identical specs collide (that is the point); the encoding is the same
/// canonical one the typed protocol uses, so key identity matches protocol
/// identity.
fn probe_key(spec: &JobSpec) -> String {
    spec.dump()
}

/// One queued probe of a parallel read phase. A task is unique per spec —
/// identical specs within one phase share a task (batch-level dedup:
/// one traversal answers all of them).
struct ReadTask {
    /// Indices into the batch's reply vector this task answers.
    slots: Vec<usize>,
    key: String,
    spec: JobSpec,
}

/// A read phase in flight: workers pull tasks via the atomic cursor and
/// push `(task index, reply)` pairs; the dispatcher sleeps on `done` until
/// every task is answered — or every worker has checked out, whichever
/// comes first (a lost worker's tasks are then computed inline).
struct ReadRun {
    tasks: Vec<ReadTask>,
    cursor: AtomicUsize,
    results: Mutex<Vec<(usize, SchedReply)>>,
    progress: Mutex<Progress>,
    done: Condvar,
}

/// Wait state of one read phase (guarded by `ReadRun::progress`).
struct Progress {
    /// Tasks answered so far.
    completed: usize,
    /// Workers that have not yet checked out of this run.
    workers: usize,
}

/// Check-out of one worker from one run, performed on drop so a panicking
/// probe still wakes the dispatcher (which recomputes any task the worker
/// lost) instead of hanging `apply_batch` forever.
struct Checkout<'a>(&'a ReadRun);

impl Drop for Checkout<'_> {
    fn drop(&mut self) {
        let mut p = lock(&self.0.progress);
        p.workers -= 1;
        if p.workers == 0 {
            self.0.done.notify_all();
        }
    }
}

enum WorkerMsg {
    Run(Arc<ReadRun>),
    Shutdown,
}

/// State shared between the service handles and the pool workers.
struct Shared {
    inst: RwLock<SchedInstance>,
    cache: Mutex<CacheInner>,
}

thread_local! {
    /// Warm scratch for probes executed on the *calling* thread (single
    /// probes and degenerate one-task phases skip the pool entirely).
    /// Thread-local so concurrent callers traverse in parallel instead of
    /// serializing on one shared scratch; `probe_with` recompiles per call,
    /// so sharing one scratch across services on the same thread is fine.
    static CALLER_SCRATCH: std::cell::RefCell<MatchScratch> =
        std::cell::RefCell::new(MatchScratch::new());
}

/// The worker pool. Threads are spawned **lazily** on the first batched
/// read-phase fan-out — a service that only ever serves single probes
/// (how `hier` uses it) carries zero idle threads. Dropped (and joined)
/// when the last service handle goes away.
struct Pool {
    /// Configured pool size; threads exist only after first use.
    target: usize,
    txs: Mutex<Vec<Sender<WorkerMsg>>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Pool {
    /// Spawn up to `target` workers if not yet running; returns the sender
    /// list to dispatch on (length 0 only when `target` is 0).
    fn ensure_spawned(&self, shared: &Arc<Shared>) -> Vec<Sender<WorkerMsg>> {
        let mut txs = lock(&self.txs);
        if txs.len() < self.target {
            let mut handles = lock(&self.handles);
            for i in txs.len()..self.target {
                let (tx, rx) = channel();
                let worker_shared = shared.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("sched-probe-{i}"))
                    .spawn(move || worker_loop(worker_shared, rx))
                    .expect("spawn sched probe worker");
                txs.push(tx);
                handles.push(handle);
            }
        }
        txs.clone()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        if let Ok(txs) = self.txs.lock() {
            for tx in txs.iter() {
                let _ = tx.send(WorkerMsg::Shutdown);
            }
        }
        if let Ok(mut handles) = self.handles.lock() {
            for h in handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// Traverse `spec` against `inst` — which the caller holds a read lock on,
/// freezing `epoch` for the whole operation (invalidation rule 2) — and
/// record the reply in the cache stamped with that epoch. The single copy
/// of the cache-coherence-critical sequence; every probe path (single,
/// pool worker, inline fallback) funnels through here.
fn probe_and_cache(
    inst: &SchedInstance,
    cache: &Mutex<CacheInner>,
    key: &str,
    spec: &JobSpec,
    epoch: u64,
    scratch: &mut MatchScratch,
) -> SchedReply {
    let reply = inst.probe_with(spec, scratch);
    let mut c = lock(cache);
    c.observe_epoch(epoch);
    c.insert(key.to_string(), epoch, reply.clone());
    reply
}

/// Worker body: one warm [`MatchScratch`] for the thread's lifetime; each
/// run is drained under a single read lock, so every probe in it is
/// consistent with one epoch. A panicking probe is caught so the thread
/// survives to serve runs already queued in its channel (a dead receiver
/// would drop them without ever checking out, hanging their dispatchers);
/// the caught run's unfinished tasks fall through to the dispatcher's
/// inline fallback, which re-raises the panic on the calling thread.
fn worker_loop(shared: Arc<Shared>, rx: Receiver<WorkerMsg>) {
    let mut scratch = MatchScratch::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Run(run) => {
                let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let _checkout = Checkout(&run);
                    let inst = read_lock(&shared.inst);
                    let epoch = inst.graph.epoch();
                    loop {
                        let i = run.cursor.fetch_add(1, Ordering::Relaxed);
                        let Some(task) = run.tasks.get(i) else { break };
                        let reply = probe_and_cache(
                            &inst,
                            &shared.cache,
                            &task.key,
                            &task.spec,
                            epoch,
                            &mut scratch,
                        );
                        lock(&run.results).push((i, reply));
                        let mut p = lock(&run.progress);
                        p.completed += 1;
                        if p.completed == run.tasks.len() {
                            run.done.notify_all();
                        }
                    }
                }))
                .is_err();
                if panicked {
                    // the scratch may hold a half-built traversal state
                    scratch = MatchScratch::new();
                }
            }
            WorkerMsg::Shutdown => break,
        }
    }
}

/// Mutex lock that shrugs off poisoning: probe state is self-contained per
/// call, so a panicked peer leaves nothing half-updated worth refusing over.
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn read_lock(l: &RwLock<SchedInstance>) -> RwLockReadGuard<'_, SchedInstance> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

fn write_lock(l: &RwLock<SchedInstance>) -> RwLockWriteGuard<'_, SchedInstance> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

/// Write-side access to the shared instance. Dereferences to
/// [`SchedInstance`]; on drop it re-observes the graph epoch so the probe
/// cache can detect (and defend against) a rewound counter.
pub struct ServiceWriteGuard<'a> {
    guard: RwLockWriteGuard<'a, SchedInstance>,
    cache: &'a Mutex<CacheInner>,
    /// Epoch when the guard was taken; compared on drop.
    entered_epoch: u64,
}

impl std::ops::Deref for ServiceWriteGuard<'_> {
    type Target = SchedInstance;
    fn deref(&self) -> &SchedInstance {
        &self.guard
    }
}

impl std::ops::DerefMut for ServiceWriteGuard<'_> {
    fn deref_mut(&mut self) -> &mut SchedInstance {
        &mut self.guard
    }
}

impl Drop for ServiceWriteGuard<'_> {
    fn drop(&mut self) {
        // still holding the write lock here, so the observation is exact.
        // `epoch < entered_epoch` catches a rewind even when the cache had
        // never observed the pre-guard value (observe_epoch's own check
        // compares against the last *cache* observation, which can lag).
        let epoch = self.guard.graph.epoch();
        let mut cache = lock(self.cache);
        // only clear here when observe_epoch below won't see the rewind
        // itself (the cache never observed the pre-guard value), so one
        // rewind counts as exactly one invalidation
        if epoch < self.entered_epoch && epoch >= cache.last_epoch {
            cache.map.clear();
            cache.invalidations += 1;
        }
        cache.observe_epoch(epoch);
    }
}

/// A concurrent scheduler service: a [`SchedInstance`] behind a read/write
/// lock, a pool of probe workers with one warm scratch each, and an
/// epoch-keyed probe-result cache. Cloning yields another handle to the
/// same service (handles are `Send + Sync`; the pool is joined when the
/// last one drops).
///
/// Deadlock rule: never call [`SchedService::probe`],
/// [`SchedService::apply`], or [`SchedService::apply_batch`] while holding
/// a guard returned by [`SchedService::read`] or [`SchedService::write`]
/// on the same thread.
#[derive(Clone)]
pub struct SchedService {
    shared: Arc<Shared>,
    pool: Arc<Pool>,
}

impl SchedService {
    /// Wrap an instance with a default-sized worker pool (the machine's
    /// available parallelism, clamped to `1..=8`).
    pub fn new(inst: SchedInstance) -> SchedService {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2)
            .clamp(1, 8);
        SchedService::with_workers(inst, workers)
    }

    /// Wrap an instance with an explicit pool size. `workers == 0` is
    /// valid: every probe then runs on the calling thread (the sequential
    /// special case, useful as a bench baseline). Worker threads are
    /// spawned lazily on the first batched read-phase fan-out.
    pub fn with_workers(inst: SchedInstance, workers: usize) -> SchedService {
        let shared = Arc::new(Shared {
            inst: RwLock::new(inst),
            cache: Mutex::new(CacheInner::new()),
        });
        SchedService {
            shared,
            pool: Arc::new(Pool {
                target: workers,
                txs: Mutex::new(Vec::new()),
                handles: Mutex::new(Vec::new()),
            }),
        }
    }

    /// Configured pool size (threads exist only once a batched read phase
    /// has fanned out).
    pub fn workers(&self) -> usize {
        self.pool.target
    }

    /// Shared read access to the instance (parallel with probes; excludes
    /// writers). For probe traffic prefer [`SchedService::probe`], which
    /// also consults the result cache.
    pub fn read(&self) -> RwLockReadGuard<'_, SchedInstance> {
        read_lock(&self.shared.inst)
    }

    /// Exclusive write access to the instance. All mutations MUST go
    /// through here (or [`SchedService::apply`] and
    /// [`SchedService::apply_batch`], which do): the guard's drop hook is
    /// part of the
    /// cache's epoch-rewind defense.
    pub fn write(&self) -> ServiceWriteGuard<'_> {
        let guard = write_lock(&self.shared.inst);
        let entered_epoch = guard.graph.epoch();
        ServiceWriteGuard {
            guard,
            cache: &self.shared.cache,
            entered_epoch,
        }
    }

    /// Current graph epoch (see `ResourceGraph::epoch`).
    pub fn epoch(&self) -> u64 {
        self.read().graph.epoch()
    }

    /// Serve one feasibility probe: cache hit within the current epoch, or
    /// one traversal on the calling thread (inserted for the next caller).
    pub fn probe(&self, spec: &JobSpec) -> SchedReply {
        // hold the read lock across lookup, traversal, and insert: the
        // epoch is frozen for the whole operation (invalidation rule 2)
        let inst = read_lock(&self.shared.inst);
        let epoch = inst.graph.epoch();
        let key = probe_key(spec);
        {
            let mut cache = lock(&self.shared.cache);
            cache.observe_epoch(epoch);
            if let Some(reply) = cache.get(&key, epoch) {
                return reply;
            }
        }
        CALLER_SCRATCH.with(|s| {
            probe_and_cache(
                &inst,
                &self.shared.cache,
                &key,
                spec,
                epoch,
                &mut s.borrow_mut(),
            )
        })
    }

    /// Interpret one typed op: read-only ops take the concurrent cached
    /// path, everything else the write side. Reply-compatible with
    /// [`SchedInstance::apply`].
    pub fn apply(&self, op: &SchedOp) -> SchedReply {
        if let SchedOp::Probe { spec } = op {
            return self.probe(spec);
        }
        self.write().apply(op)
    }

    /// Run a queue of ops, partitioned into read/write phases: maximal
    /// runs of read-only ops fan out across the worker pool (consulting
    /// the probe cache first), maximal mutating runs execute under one
    /// write lock via the sequential [`SchedInstance::apply_batch`]
    /// (keeping its spec-level compile dedup). Replies correspond to ops
    /// index-for-index, exactly as the sequential batch orders them.
    pub fn apply_batch(&self, ops: &[SchedOp]) -> Vec<SchedReply> {
        let mut replies: Vec<Option<SchedReply>> = vec![None; ops.len()];
        let mut i = 0;
        while i < ops.len() {
            let read = ops[i].is_read_only();
            let mut j = i + 1;
            while j < ops.len() && ops[j].is_read_only() == read {
                j += 1;
            }
            if read {
                self.read_phase(&ops[i..j], i, &mut replies);
            } else {
                let mut guard = self.write();
                for (k, reply) in guard.apply_batch(&ops[i..j]).into_iter().enumerate() {
                    replies[i + k] = Some(reply);
                }
            }
            i = j;
        }
        replies
            .into_iter()
            .map(|r| r.expect("every op in the batch is answered"))
            .collect()
    }

    /// Execute one contiguous run of read-only ops: resolve cache hits,
    /// dedup identical specs into shared tasks, then fan the misses across
    /// the pool (or inline for degenerate runs). `base` is the run's
    /// offset into `replies`.
    fn read_phase(&self, ops: &[SchedOp], base: usize, replies: &mut [Option<SchedReply>]) {
        // 1. cache pass under the read lock (epoch frozen); misses dedup
        //    into one task per distinct spec
        let mut tasks: Vec<ReadTask> = Vec::new();
        let mut task_of_key: HashMap<String, usize> = HashMap::new();
        {
            let inst = read_lock(&self.shared.inst);
            let epoch = inst.graph.epoch();
            let mut cache = lock(&self.shared.cache);
            cache.observe_epoch(epoch);
            for (k, op) in ops.iter().enumerate() {
                let SchedOp::Probe { spec } = op else {
                    unreachable!("read phases contain only read-only ops");
                };
                let key = probe_key(spec);
                if let Some(ti) = task_of_key.get(&key) {
                    tasks[*ti].slots.push(base + k);
                    continue;
                }
                match cache.get(&key, epoch) {
                    Some(reply) => replies[base + k] = Some(reply),
                    None => {
                        task_of_key.insert(key.clone(), tasks.len());
                        tasks.push(ReadTask {
                            slots: vec![base + k],
                            key,
                            spec: spec.clone(),
                        });
                    }
                }
            }
        }
        if tasks.is_empty() {
            return;
        }
        let workers = self.workers();
        if workers == 0 || tasks.len() == 1 {
            for task in &tasks {
                let reply = self.compute_task(task);
                for &slot in &task.slots {
                    replies[slot] = Some(reply.clone());
                }
            }
            return;
        }
        // 2. fan out across the pool (spawned on first use); the
        //    dispatcher holds NO lock while waiting (workers each take
        //    their own read lock, so a queued writer can never deadlock
        //    the phase)
        let txs = self.pool.ensure_spawned(&self.shared);
        let ntasks = tasks.len();
        // never wake more workers than there are tasks — a surplus worker
        // would only acquire the read lock, find the cursor exhausted, and
        // check out
        let fanout = txs.len().min(ntasks);
        let run = Arc::new(ReadRun {
            tasks,
            cursor: AtomicUsize::new(0),
            results: Mutex::new(Vec::with_capacity(ntasks)),
            progress: Mutex::new(Progress {
                completed: 0,
                workers: fanout,
            }),
            done: Condvar::new(),
        });
        let mut failed_sends = 0usize;
        for tx in txs.iter().take(fanout) {
            if tx.send(WorkerMsg::Run(run.clone())).is_err() {
                failed_sends += 1;
            }
        }
        {
            // wake on either "all tasks answered" (don't wait for a worker
            // that is busy finishing someone else's run) or "all workers
            // checked out" (a dead/panicked worker's tasks fall through to
            // the inline fallback below)
            let mut p = lock(&run.progress);
            p.workers -= failed_sends;
            while p.completed < ntasks && p.workers > 0 {
                p = run.done.wait(p).unwrap_or_else(|e| e.into_inner());
            }
        }
        let mut task_replies: Vec<Option<SchedReply>> = vec![None; ntasks];
        for (ti, reply) in lock(&run.results).drain(..) {
            task_replies[ti] = Some(reply);
        }
        for (ti, task) in run.tasks.iter().enumerate() {
            // defense: compute any task the pool lost on this thread
            let reply = match task_replies[ti].take() {
                Some(r) => r,
                None => self.compute_task(task),
            };
            for &slot in &task.slots {
                replies[slot] = Some(reply.clone());
            }
        }
    }

    /// Probe one task on the calling thread with its thread-local scratch
    /// (and record it in the cache).
    fn compute_task(&self, task: &ReadTask) -> SchedReply {
        let inst = read_lock(&self.shared.inst);
        let epoch = inst.graph.epoch();
        CALLER_SCRATCH.with(|s| {
            probe_and_cache(
                &inst,
                &self.shared.cache,
                &task.key,
                &task.spec,
                epoch,
                &mut s.borrow_mut(),
            )
        })
    }

    /// Drop every cached probe result (counts as one invalidation). Benches
    /// use this to measure the cold path honestly; correctness never needs
    /// it.
    pub fn clear_cache(&self) {
        let mut cache = lock(&self.shared.cache);
        cache.map.clear();
        cache.invalidations += 1;
    }

    /// Snapshot of the probe cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        let cache = lock(&self.shared.cache);
        CacheStats {
            hits: cache.hits,
            misses: cache.misses,
            invalidations: cache.invalidations,
            entries: cache.map.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobspec::{table1_jobspec, JobSpec};
    use crate::resource::builder::{table2_graph, UidGen};
    use crate::resource::graph::JobId;
    use crate::rpc::proto::code;
    use crate::sched::PruneConfig;

    fn service(level: usize, workers: usize) -> SchedService {
        SchedService::with_workers(
            SchedInstance::new(table2_graph(level, &mut UidGen::new()), PruneConfig::default()),
            workers,
        )
    }

    #[test]
    fn probe_hits_cache_within_epoch() {
        let svc = service(3, 2);
        let spec = table1_jobspec("T7");
        let a = svc.probe(&spec);
        assert!(matches!(a, SchedReply::Probed { .. }));
        let b = svc.probe(&spec);
        assert_eq!(a, b);
        let stats = svc.cache_stats();
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 1);
    }

    #[test]
    fn mutation_invalidates_cached_probe() {
        let svc = service(4, 2); // 1 node
        let spec = JobSpec::nodes_sockets_cores(1, 2, 16);
        assert!(matches!(svc.probe(&spec), SchedReply::Probed { .. }));
        // allocate the only node: the cached feasibility answer is now wrong
        let SchedReply::Allocated { job, .. } =
            svc.apply(&SchedOp::MatchAllocate { spec: spec.clone() })
        else {
            panic!("expected Allocated");
        };
        let r = svc.probe(&spec);
        assert_eq!(r.as_error().unwrap().code, code::NO_MATCH);
        // free it: feasible again (and again not served from the old entry)
        svc.apply(&SchedOp::FreeJob { job });
        assert!(matches!(svc.probe(&spec), SchedReply::Probed { .. }));
        svc.read().check().unwrap();
    }

    #[test]
    fn zero_worker_service_still_serves_batches() {
        let svc = service(3, 0);
        let t7 = table1_jobspec("T7");
        let ops: Vec<SchedOp> = (0..6)
            .map(|_| SchedOp::Probe { spec: t7.clone() })
            .collect();
        let replies = svc.apply_batch(&ops);
        assert_eq!(replies.len(), 6);
        assert!(replies.iter().all(|r| matches!(r, SchedReply::Probed { .. })));
        // all six identical probes deduped into ONE task; one entry cached
        assert_eq!(svc.cache_stats().entries, 1);
        // a second identical batch is answered entirely from the cache
        let again = svc.apply_batch(&ops);
        assert_eq!(again, replies);
        assert_eq!(svc.cache_stats().hits, 6);
    }

    #[test]
    fn parallel_batch_matches_sequential_batch() {
        let svc = service(1, 4);
        let mut twin =
            SchedInstance::new(table2_graph(1, &mut UidGen::new()), PruneConfig::default());
        let t7 = table1_jobspec("T7");
        let mut ops: Vec<SchedOp> = Vec::new();
        // distinct probe specs exercise the fan-out path
        for nodes in 1..=6u64 {
            ops.push(SchedOp::Probe {
                spec: JobSpec::nodes_sockets_cores(nodes, 2, 16),
            });
        }
        ops.push(SchedOp::MatchAllocate { spec: t7.clone() });
        ops.push(SchedOp::Probe { spec: t7.clone() });
        ops.push(SchedOp::FreeJob { job: JobId(0) });
        ops.push(SchedOp::Probe { spec: t7 });
        let par = svc.apply_batch(&ops);
        let seq = twin.apply_batch(&ops);
        assert_eq!(par.len(), seq.len());
        for (p, s) in par.iter().zip(&seq) {
            match (p, s) {
                (
                    SchedReply::Allocated {
                        job: j1,
                        subgraph: g1,
                        ..
                    },
                    SchedReply::Allocated {
                        job: j2,
                        subgraph: g2,
                        ..
                    },
                ) => {
                    assert_eq!(j1, j2);
                    assert_eq!(g1, g2);
                }
                _ => assert_eq!(p, s),
            }
        }
        svc.read().check().unwrap();
        twin.check().unwrap();
    }

    #[test]
    fn clear_cache_forces_recomputation() {
        let svc = service(3, 1);
        let spec = table1_jobspec("T7");
        svc.probe(&spec);
        svc.clear_cache();
        svc.probe(&spec);
        let stats = svc.cache_stats();
        assert_eq!(stats.hits, 0);
        assert_eq!(stats.misses, 2);
        assert!(stats.invalidations >= 1);
    }

    #[test]
    fn write_guard_rewind_defense_clears_cache() {
        let svc = service(3, 1);
        let spec = table1_jobspec("T7");
        let snapshot = svc.read().graph.clone();
        // advance the epoch well past the snapshot's, ending in the same
        // free state (allocate + free)
        let SchedReply::Allocated { job, .. } =
            svc.apply(&SchedOp::MatchAllocate { spec: spec.clone() })
        else {
            panic!("expected Allocated");
        };
        svc.apply(&SchedOp::FreeJob { job });
        assert!(matches!(svc.probe(&spec), SchedReply::Probed { .. }));
        assert!(svc.cache_stats().entries >= 1);
        {
            // hostile restore: swap the snapshot in WITHOUT restore_from,
            // rewinding the epoch counter
            let mut guard = svc.write();
            guard.graph = snapshot;
        }
        // the guard drop observed the rewound epoch and dropped the map
        assert_eq!(svc.cache_stats().entries, 0);
        // and probes still answer correctly
        assert!(matches!(svc.probe(&spec), SchedReply::Probed { .. }));
        svc.read().check().unwrap();
    }

    /// A clean local-match failure through the write guard (how an
    /// escalating `hier` MatchGrow starts) must NOT wipe the cache: no
    /// epoch movement means every entry is still accurate.
    #[test]
    fn clean_write_guard_use_preserves_cache_entries() {
        let svc = service(4, 1); // 1 node
        let spec = table1_jobspec("T7");
        svc.probe(&spec);
        assert_eq!(svc.cache_stats().entries, 1);
        {
            let mut guard = svc.write();
            // scratch-only mutation, epoch untouched — the no-match path
            // of hier::NodeState::match_grow
            let _ = guard.match_only(&JobSpec::nodes_sockets_cores(64, 2, 16));
        }
        assert_eq!(
            svc.cache_stats().entries,
            1,
            "clean guard use must not invalidate"
        );
        assert_eq!(svc.cache_stats().hits, 0);
        svc.probe(&spec);
        assert_eq!(svc.cache_stats().hits, 1, "entry still serves");
        svc.read().check().unwrap();
    }
}
